"""AOT driver checks: configs parse, manifest contract, fingerprint skip."""

import json
import os

import pytest

from compile import aot
from compile.kernels.gridding import GriddingVariant

HERE = os.path.dirname(os.path.abspath(__file__))
CONFIGS = os.path.join(HERE, "..", "compile", "configs.json")
REPO_ARTIFACTS = os.path.join(HERE, "..", "..", "artifacts")


def test_configs_load_and_are_unique():
    variants = aot.load_configs(CONFIGS)
    assert len(variants) >= 15
    names = [v.name for v, _ in variants]
    assert len(set(names)) == len(names)
    tags = {t for _, ts in variants for t in ts}
    # every experiment family must have at least one artifact
    for required in ("default", "fig13", "fig16", "tiny", "ktype"):
        assert required in tags, f"missing tag {required}"


def test_variant_name_round_trips_fields():
    v = GriddingVariant("", "gauss1d", m=256, bm=64, k=32, c=4, n=4096, gamma=1)
    assert aot.variant_name(v) == "gauss1d_m256_b64_k32_c4_g1_n4096"


def test_fingerprint_stable():
    assert aot.source_fingerprint() == aot.source_fingerprint()


def test_aot_tiny_end_to_end(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--only", "m256_b64_k32_c1"])
    assert rc == 0
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["interchange"] == "hlo-text"
    assert manifest["param_order"] == aot.PARAM_ORDER
    [entry] = manifest["variants"]
    hlo_path = tmp_path / entry["file"]
    assert hlo_path.exists()
    assert hlo_path.read_text().startswith("HloModule")
    assert entry["shapes"]["sval"]["dims"] == [entry["c"], entry["n"]]
    assert entry["outputs"]["acc"]["dims"] == [entry["c"], entry["m"]]
    assert entry["perf_estimate"]["fits_16mib_vmem"] in (True, False)


def test_aot_skips_when_up_to_date(tmp_path, capsys):
    assert aot.main(["--out-dir", str(tmp_path), "--only", "m256_b64_k32_c1"]) == 0
    # --only bypasses the skip, so write a fake full manifest to exercise it
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    manifest["fingerprint"] = aot.source_fingerprint()
    # pretend the full set is just this one variant
    (tmp_path / "manifest.json").write_text(json.dumps(manifest))
    capsys.readouterr()
    assert aot.main(["--out-dir", str(tmp_path)]) == 0
    assert "up to date" in capsys.readouterr().out


def test_aot_unknown_only_fails(tmp_path):
    assert aot.main(["--out-dir", str(tmp_path), "--only", "doesnotexist"]) == 1


@pytest.mark.skipif(not os.path.exists(os.path.join(REPO_ARTIFACTS, "manifest.json")),
                    reason="repo artifacts not built")
def test_repo_manifest_consistent_with_configs():
    manifest = json.load(open(os.path.join(REPO_ARTIFACTS, "manifest.json")))
    configured = {v.name for v, _ in aot.load_configs(CONFIGS)}
    built = {e["name"] for e in manifest["variants"]}
    assert built == configured
    for e in manifest["variants"]:
        assert os.path.exists(os.path.join(REPO_ARTIFACTS, e["file"]))
