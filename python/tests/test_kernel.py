"""L1 correctness: Pallas gridding kernel vs the pure-numpy oracle.

Hypothesis sweeps shapes, reuse factors, kernel types and value regimes; every
case asserts allclose against ``ref.gridding_ref_vec`` (and the scalar-loop
oracle cross-checks the vectorised one on small cases).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.gridding import (
    GAUSS1D,
    GAUSS2D,
    KERNEL_TYPES,
    TAPERED_SINC,
    GriddingVariant,
    angular_dist2,
    eval_weight,
    make_gridding_fn,
    vmem_estimate_bytes,
)
from compile.kernels import ref

RTOL, ATOL = 3e-4, 3e-5


def make_inputs(v: GriddingVariant, seed: int, lon_span=(0.3, 0.7), lat_span=(0.5, 0.9)):
    rng = np.random.default_rng(seed)
    cl = rng.uniform(*lon_span, v.m).astype(np.float32)
    ct = rng.uniform(*lat_span, v.m).astype(np.float32)
    nbr = rng.integers(-1, v.n, (v.groups, v.k)).astype(np.int32)
    sl = rng.uniform(*lon_span, v.n).astype(np.float32)
    st_ = rng.uniform(*lat_span, v.n).astype(np.float32)
    sv = rng.normal(size=(v.c, v.n)).astype(np.float32)
    # σ and support chosen so a meaningful fraction of neighbours fall inside R
    kp = np.array([800.0, 0.004, 0.004, 0.0], dtype=np.float32)
    if v.kernel_type == TAPERED_SINC:
        kp = np.array([40.0, 25.0, 0.004, 0.0], dtype=np.float32)
    return cl, ct, nbr, sl, st_, sv, kp


def run_both(v: GriddingVariant, seed: int):
    args = make_inputs(v, seed)
    got = jax.jit(make_gridding_fn(v))(*args)
    want = ref.gridding_ref_vec(*args, v.kernel_type, v.gamma)
    return np.asarray(got[0]), np.asarray(got[1]), want[0], want[1]


@pytest.mark.parametrize("ktype", KERNEL_TYPES)
def test_kernel_types_match_ref(ktype):
    v = GriddingVariant("t", ktype, m=128, bm=32, k=16, c=3, n=256, gamma=1)
    acc, wsum, racc, rwsum = run_both(v, seed=7)
    np.testing.assert_allclose(acc, racc, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(wsum, rwsum, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("gamma,bm", [(1, 48), (2, 48), (3, 48), (4, 48)])
def test_gamma_reuse_matches_ref(gamma, bm):
    v = GriddingVariant("t", GAUSS1D, m=96, bm=bm, k=8, c=2, n=128, gamma=gamma)
    acc, wsum, racc, rwsum = run_both(v, seed=gamma)
    np.testing.assert_allclose(acc, racc, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(wsum, rwsum, rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    bm_blocks=st.integers(1, 4),
    bm=st.sampled_from([16, 32, 64]),
    k=st.sampled_from([1, 4, 16, 33]),
    c=st.integers(1, 6),
    n=st.sampled_from([1, 64, 300]),
    ktype=st.sampled_from(KERNEL_TYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shape_sweep(bm_blocks, bm, k, c, n, ktype, seed):
    v = GriddingVariant("t", ktype, m=bm * bm_blocks, bm=bm, k=k, c=c, n=n, gamma=1)
    acc, wsum, racc, rwsum = run_both(v, seed)
    np.testing.assert_allclose(acc, racc, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(wsum, rwsum, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    gamma=st.sampled_from([2, 3, 4, 6]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_gamma_sweep(gamma, seed):
    v = GriddingVariant("t", GAUSS1D, m=48 * 2, bm=48, k=8, c=3, n=96, gamma=gamma)
    acc, wsum, racc, rwsum = run_both(v, seed)
    np.testing.assert_allclose(acc, racc, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(wsum, rwsum, rtol=RTOL, atol=ATOL)


def test_all_padding_neighbours_gives_zero():
    v = GriddingVariant("t", GAUSS1D, m=64, bm=32, k=8, c=2, n=32, gamma=1)
    cl, ct, _, sl, st_, sv, kp = make_inputs(v, 3)
    nbr = np.full((v.groups, v.k), -1, dtype=np.int32)
    acc, wsum = jax.jit(make_gridding_fn(v))(cl, ct, nbr, sl, st_, sv, kp)
    assert np.all(np.asarray(acc) == 0.0)
    assert np.all(np.asarray(wsum) == 0.0)


def test_support_radius_excludes_far_samples():
    """Samples beyond R² contribute exactly zero weight."""
    v = GriddingVariant("t", GAUSS1D, m=32, bm=32, k=4, c=1, n=8, gamma=1)
    cl = np.full(v.m, 0.5, np.float32)
    ct = np.full(v.m, 0.5, np.float32)
    sl = np.full(v.n, 0.9, np.float32)  # ~0.35 rad away
    st_ = np.full(v.n, 0.9, np.float32)
    sv = np.ones((1, v.n), np.float32)
    nbr = np.zeros((v.m, v.k), np.int32)
    kp = np.array([800.0, 0.004, 0.0, 0.0], np.float32)  # R² = 0.004 rad²
    acc, wsum = jax.jit(make_gridding_fn(v))(cl, ct, nbr, sl, st_, sv, kp)
    assert np.all(np.asarray(wsum) == 0.0)
    assert np.all(np.asarray(acc) == 0.0)


def test_scalar_oracle_cross_checks_vectorised():
    v = GriddingVariant("t", GAUSS2D, m=24, bm=12, k=5, c=2, n=40, gamma=2)
    args = make_inputs(v, 11)
    a1, w1 = ref.gridding_ref(*args, v.kernel_type, v.gamma)
    a2, w2 = ref.gridding_ref_vec(*args, v.kernel_type, v.gamma)
    np.testing.assert_allclose(a1, a2, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(w1, w2, rtol=1e-6, atol=1e-7)


def test_weights_channel_invariant():
    """The same wsum must come back regardless of channel count C."""
    base = dict(kernel_type=GAUSS1D, m=64, bm=32, k=8, n=128, gamma=1)
    v1 = GriddingVariant("t", c=1, **base)
    v4 = GriddingVariant("t", c=4, **base)
    cl, ct, nbr, sl, st_, sv4, kp = make_inputs(v4, 5)
    _, w4 = jax.jit(make_gridding_fn(v4))(cl, ct, nbr, sl, st_, sv4, kp)
    _, w1 = jax.jit(make_gridding_fn(v1))(cl, ct, nbr, sl, st_, sv4[:1], kp)
    np.testing.assert_allclose(np.asarray(w4), np.asarray(w1), rtol=1e-6, atol=0)


def test_duplicate_neighbour_indices_accumulate():
    """The kernel is a plain sum: listing a sample twice doubles its weight."""
    v = GriddingVariant("t", GAUSS1D, m=32, bm=32, k=4, c=1, n=4, gamma=1)
    cl = np.full(v.m, 0.5, np.float32)
    ct = np.full(v.m, 0.5, np.float32)
    sl = np.full(v.n, 0.5, np.float32)
    st_ = np.full(v.n, 0.5, np.float32)
    sv = np.ones((1, v.n), np.float32)
    kp = np.array([800.0, 0.01, 0.0, 0.0], np.float32)
    one = np.array([[0, -1, -1, -1]] * v.m, np.int32)
    two = np.array([[0, 0, -1, -1]] * v.m, np.int32)
    f = jax.jit(make_gridding_fn(v))
    _, w_one = f(cl, ct, one, sl, st_, sv, kp)
    _, w_two = f(cl, ct, two, sl, st_, sv, kp)
    np.testing.assert_allclose(2 * np.asarray(w_one), np.asarray(w_two), rtol=1e-6)


@given(
    lat=st.floats(-1.4, 1.4),
    lon=st.floats(0.0, 6.28),
    dlat=st.floats(-1e-3, 1e-3),
    dlon=st.floats(-1e-3, 1e-3),
)
@settings(max_examples=50, deadline=None)
def test_angular_dist2_small_angle_matches_planar(lat, lon, dlat, dlon):
    """At arcminute separations haversine ≈ cos-corrected planar distance."""
    d2 = float(
        angular_dist2(
            jnp.float32(lon), jnp.float32(lat), jnp.float32(lon + dlon), jnp.float32(lat + dlat)
        )
    )
    planar = (dlon * np.cos(lat + dlat / 2)) ** 2 + dlat**2
    assert d2 == pytest.approx(planar, rel=2e-2, abs=1e-9)


def test_angular_dist2_symmetry_and_zero():
    a = (jnp.float32(1.0), jnp.float32(0.3))
    b = (jnp.float32(1.2), jnp.float32(0.5))
    dab = float(angular_dist2(a[0], a[1], b[0], b[1]))
    dba = float(angular_dist2(b[0], b[1], a[0], a[1]))
    assert dab == pytest.approx(dba, rel=1e-6)
    assert float(angular_dist2(a[0], a[1], a[0], a[1])) == pytest.approx(0.0, abs=1e-12)


def test_eval_weight_peak_is_one_at_zero_distance():
    kp = jnp.array([800.0, 0.004, 0.004, 0.0], jnp.float32)
    for ktype in (GAUSS1D, GAUSS2D):
        w = float(eval_weight(ktype, jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0), kp))
        assert w == pytest.approx(1.0, rel=1e-6)
    kp_s = jnp.array([40.0, 25.0, 0.004, 0.0], jnp.float32)
    w = float(eval_weight(TAPERED_SINC, jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0), kp_s))
    assert w == pytest.approx(1.0, rel=1e-6)


def test_variant_validation():
    with pytest.raises(ValueError):
        GriddingVariant("t", "nope", m=32, bm=32, k=4, c=1, n=4, gamma=1)
    with pytest.raises(ValueError):
        GriddingVariant("t", GAUSS1D, m=33, bm=32, k=4, c=1, n=4, gamma=1)
    with pytest.raises(ValueError):
        GriddingVariant("t", GAUSS1D, m=64, bm=32, k=4, c=1, n=4, gamma=3)
    with pytest.raises(ValueError):
        GriddingVariant("t", GAUSS1D, m=64, bm=32, k=0, c=1, n=4, gamma=1)


def test_vmem_estimate_monotone_in_n():
    base = dict(kernel_type=GAUSS1D, m=256, bm=64, k=32, c=4, gamma=1)
    small = vmem_estimate_bytes(GriddingVariant("a", n=4096, **base))
    big = vmem_estimate_bytes(GriddingVariant("b", n=262144, **base))
    assert big["resident_bytes"] > small["resident_bytes"]
    assert small["fits_16mib_vmem"]
