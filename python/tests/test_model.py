"""L2 checks: lowering, HLO structure, and the redundancy-elimination claim."""

import jax
import numpy as np
import pytest

from compile.kernels.gridding import GAUSS1D, GriddingVariant, make_gridding_fn
from compile.model import hlo_op_counts, lower_variant, make_dispatch_fn

TINY = GriddingVariant("tiny", GAUSS1D, m=64, bm=32, k=8, c=4, n=128, gamma=1)


def test_lower_variant_produces_hlo_text():
    hlo = lower_variant(TINY)
    assert hlo.startswith("HloModule"), hlo[:80]
    assert "ENTRY" in hlo


def test_hlo_entry_signature_matches_contract():
    """7 parameters in manifest order; tuple of (acc, wsum) out."""
    hlo = lower_variant(TINY)
    entry = [l for l in hlo.splitlines() if l.startswith("ENTRY")][0]
    for i in range(7):
        assert f"parameter.{i}" in hlo or f"Arg_{i}" in hlo or "parameter(" in hlo
    assert f"f32[{TINY.c},{TINY.m}]" in hlo  # acc
    assert f"f32[{TINY.m}]" in hlo  # wsum
    assert f"s32[{TINY.groups},{TINY.k}]" in hlo  # nbr
    assert entry  # non-empty entry computation


def test_weight_pipeline_channel_invariant_in_hlo():
    """Redundancy elimination at L2: the number of `exponential` ops in the
    lowered module must not grow with C (weights computed once, contracted
    against all channels)."""
    base = dict(kernel_type=GAUSS1D, m=64, bm=32, k=8, n=128, gamma=1)
    ops1 = hlo_op_counts(lower_variant(GriddingVariant("a", c=1, **base)))
    ops8 = hlo_op_counts(lower_variant(GriddingVariant("b", c=8, **base)))
    assert ops8.get("exponential", 0) == ops1.get("exponential", 0)
    assert ops8.get("exponential", 0) >= 1


def test_dispatch_fn_matches_kernel_fn():
    rng = np.random.default_rng(0)
    v = TINY
    args = (
        rng.uniform(0.4, 0.6, v.m).astype(np.float32),
        rng.uniform(0.4, 0.6, v.m).astype(np.float32),
        rng.integers(-1, v.n, (v.groups, v.k)).astype(np.int32),
        rng.uniform(0.4, 0.6, v.n).astype(np.float32),
        rng.uniform(0.4, 0.6, v.n).astype(np.float32),
        rng.normal(size=(v.c, v.n)).astype(np.float32),
        np.array([800.0, 0.004, 0.0, 0.0], np.float32),
    )
    a = jax.jit(make_dispatch_fn(v))(*args)
    b = jax.jit(make_gridding_fn(v))(*args)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_lowering_is_deterministic():
    assert lower_variant(TINY) == lower_variant(TINY)


@pytest.mark.parametrize("bm", [16, 32, 64])
def test_bm_variants_agree_numerically(bm):
    """Block size is a pure scheduling knob: results must be bit-stable
    across bm (same reduction order within a cell)."""
    rng = np.random.default_rng(42)
    vs = [GriddingVariant("t", GAUSS1D, m=64, bm=b, k=8, c=2, n=64, gamma=1) for b in (bm, 64)]
    args = (
        rng.uniform(0.4, 0.6, 64).astype(np.float32),
        rng.uniform(0.4, 0.6, 64).astype(np.float32),
        rng.integers(-1, 64, (64, 8)).astype(np.int32),
        rng.uniform(0.4, 0.6, 64).astype(np.float32),
        rng.uniform(0.4, 0.6, 64).astype(np.float32),
        rng.normal(size=(2, 64)).astype(np.float32),
        np.array([800.0, 0.004, 0.0, 0.0], np.float32),
    )
    outs = [jax.jit(make_dispatch_fn(v))(*args) for v in vs]
    np.testing.assert_allclose(np.asarray(outs[0][0]), np.asarray(outs[1][0]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(outs[0][1]), np.asarray(outs[1][1]), rtol=1e-6)
