import os
import sys

# Make `compile.*` importable regardless of where pytest is invoked from.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
