"""AOT driver: lower every configured gridding variant to HLO text.

Build-time only (``make artifacts``); Python never runs on the request path.
Emits, into ``--out-dir``:

  {variant}.hlo.txt      HLO text, loadable by xla::HloModuleProto::from_text_file
  manifest.json          machine-readable index the Rust runtime consumes:
                         variant shapes, parameter order, file names, and the
                         static L1 VMEM/roofline estimates (DESIGN.md §Perf)

HLO *text* is the interchange format (NOT ``lowered.compile().serialize()``):
jax >= 0.5 writes HloModuleProto with 64-bit instruction ids which the
xla_extension 0.5.1 bundled with the Rust ``xla`` crate rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

from .kernels.gridding import GriddingVariant, vmem_estimate_bytes
from .model import hlo_op_counts, lower_variant

# Parameter order of every artifact; the Rust runtime asserts against this.
PARAM_ORDER = ["cell_lon", "cell_lat", "nbr", "slon", "slat", "sval", "kparam"]
MANIFEST_VERSION = 2


def variant_name(v: GriddingVariant) -> str:
    return f"{v.kernel_type}_m{v.m}_b{v.bm}_k{v.k}_c{v.c}_g{v.gamma}_n{v.n}"


def load_configs(path: str):
    with open(path) as f:
        raw = json.load(f)
    variants = []
    for entry in raw["variants"]:
        tags = entry.get("tags", [])
        v = GriddingVariant(
            name="",  # filled below
            kernel_type=entry["kernel_type"],
            m=entry["m"],
            bm=entry["bm"],
            k=entry["k"],
            c=entry["c"],
            n=entry["n"],
            gamma=entry["gamma"],
        )
        v = GriddingVariant(
            name=variant_name(v),
            kernel_type=v.kernel_type,
            m=v.m,
            bm=v.bm,
            k=v.k,
            c=v.c,
            n=v.n,
            gamma=v.gamma,
        )
        variants.append((v, tags))
    names = [v.name for v, _ in variants]
    if len(set(names)) != len(names):
        raise ValueError("duplicate variant names in configs.json")
    return variants


def source_fingerprint() -> str:
    """Hash of the compile-path sources; lets `make artifacts` skip rebuilds."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for rel in sorted(
        os.path.join(dp, f)
        for dp, _, fs in os.walk(here)
        for f in fs
        if f.endswith((".py", ".json")) and "__pycache__" not in dp
    ):
        with open(rel, "rb") as f:
            h.update(rel.encode())
            h.update(f.read())
    return h.hexdigest()


def variant_manifest_entry(v: GriddingVariant, tags, hlo_path: str, hlo_text: str) -> dict:
    shapes = {
        "cell_lon": {"dims": [v.m], "dtype": "f32"},
        "cell_lat": {"dims": [v.m], "dtype": "f32"},
        "nbr": {"dims": [v.groups, v.k], "dtype": "s32"},
        "slon": {"dims": [v.n], "dtype": "f32"},
        "slat": {"dims": [v.n], "dtype": "f32"},
        "sval": {"dims": [v.c, v.n], "dtype": "f32"},
        "kparam": {"dims": [4], "dtype": "f32"},
    }
    ops = hlo_op_counts(hlo_text)
    return {
        "name": v.name,
        "file": os.path.basename(hlo_path),
        "kernel_type": v.kernel_type,
        "m": v.m,
        "bm": v.bm,
        "k": v.k,
        "c": v.c,
        "n": v.n,
        "gamma": v.gamma,
        "groups": v.groups,
        "tags": tags,
        "param_order": PARAM_ORDER,
        "shapes": shapes,
        "outputs": {
            "acc": {"dims": [v.c, v.m], "dtype": "f32"},
            "wsum": {"dims": [v.m], "dtype": "f32"},
        },
        "perf_estimate": vmem_estimate_bytes(v),
        "hlo_ops": {k: ops.get(k, 0) for k in ("exponential", "dot", "while", "gather")},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    here = os.path.dirname(os.path.abspath(__file__))
    ap.add_argument("--out-dir", default=os.path.join(here, "..", "..", "artifacts"))
    ap.add_argument("--configs", default=os.path.join(here, "configs.json"))
    ap.add_argument("--only", nargs="*", help="lower only variants whose name contains any of these substrings")
    ap.add_argument("--force", action="store_true", help="re-lower even if fingerprint matches")
    args = ap.parse_args(argv)

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    fingerprint = source_fingerprint()

    if not args.force and not args.only and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("fingerprint") == fingerprint and all(
                os.path.exists(os.path.join(out_dir, e["file"])) for e in old["variants"]
            ):
                print(f"artifacts up to date ({len(old['variants'])} variants); skipping")
                return 0
        except (json.JSONDecodeError, KeyError):
            pass  # rebuild

    variants = load_configs(args.configs)
    if args.only:
        variants = [(v, t) for v, t in variants if any(s in v.name for s in args.only)]
        if not variants:
            print("no variants match --only", file=sys.stderr)
            return 1

    entries = []
    t_all = time.time()
    for i, (v, tags) in enumerate(variants):
        t0 = time.time()
        hlo = lower_variant(v)
        path = os.path.join(out_dir, f"{v.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        entries.append(variant_manifest_entry(v, tags, path, hlo))
        print(
            f"[{i + 1}/{len(variants)}] {v.name}: {len(hlo) / 1024:.0f} KiB HLO "
            f"in {time.time() - t0:.1f}s"
        )

    manifest = {
        "version": MANIFEST_VERSION,
        "fingerprint": fingerprint,
        "interchange": "hlo-text",
        "param_order": PARAM_ORDER,
        "variants": entries,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(
        f"wrote {len(entries)} variants + manifest to {out_dir} "
        f"in {time.time() - t_all:.1f}s"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
