"""Pure-jnp/numpy oracle for the gridding cell-update kernel.

This is the CORE correctness signal for L1: ``python/tests/test_kernel.py``
asserts the Pallas kernel matches this reference over hypothesis-driven
shape/value sweeps, and the Rust CPU gridder is validated against the same
semantics through the integration tests (identical weight functions live in
``rust/src/grid/kernels.rs``).
"""

from __future__ import annotations

import numpy as np


def angular_dist2_np(lon_a, lat_a, lon_b, lat_b):
    """Squared haversine separation in rad² (numpy, float64 internally)."""
    lon_a = np.asarray(lon_a, dtype=np.float64)
    lat_a = np.asarray(lat_a, dtype=np.float64)
    lon_b = np.asarray(lon_b, dtype=np.float64)
    lat_b = np.asarray(lat_b, dtype=np.float64)
    sdlat = np.sin((lat_b - lat_a) * 0.5)
    sdlon = np.sin((lon_b - lon_a) * 0.5)
    h = sdlat * sdlat + np.cos(lat_a) * np.cos(lat_b) * sdlon * sdlon
    h = np.clip(h, 0.0, 1.0)
    d = 2.0 * np.arcsin(np.sqrt(h))
    return d * d


def eval_weight_np(kernel_type, d2, dlon_cos, dlat, kparam):
    """Reference weight evaluation; layout documented in gridding.eval_weight."""
    kparam = np.asarray(kparam, dtype=np.float64)
    if kernel_type == "gauss1d":
        w = np.exp(-d2 * kparam[0])
        r2 = kparam[1]
    elif kernel_type == "gauss2d":
        w = np.exp(-(dlon_cos**2) * kparam[0] - (dlat**2) * kparam[1])
        r2 = kparam[2]
    elif kernel_type == "tapered_sinc":
        d = np.sqrt(d2)
        x = d * kparam[0]
        w = np.sinc(x / np.pi) * np.exp(-((d * kparam[1]) ** 2))
        r2 = kparam[2]
    else:
        raise ValueError(kernel_type)
    return np.where(d2 <= r2, w, 0.0)


def gridding_ref(cell_lon, cell_lat, nbr, slon, slat, sval, kparam, kernel_type, gamma=1):
    """Reference cell update (scalar loops, float64 accumulation).

    Mirrors the artifact contract: returns ``(acc[c, m], wsum[m])``,
    unnormalised. ``nbr`` has shape ``[m // gamma, k]``; group ``g`` serves
    cells ``gγ .. gγ+γ-1``.
    """
    cell_lon = np.asarray(cell_lon, dtype=np.float64)
    cell_lat = np.asarray(cell_lat, dtype=np.float64)
    nbr = np.asarray(nbr)
    slon = np.asarray(slon, dtype=np.float64)
    slat = np.asarray(slat, dtype=np.float64)
    sval = np.asarray(sval, dtype=np.float64)
    m = cell_lon.shape[0]
    c = sval.shape[0]
    acc = np.zeros((c, m), dtype=np.float64)
    wsum = np.zeros(m, dtype=np.float64)
    for i in range(m):
        g = i // gamma
        for j in nbr[g]:
            if j < 0:
                continue
            d2 = angular_dist2_np(cell_lon[i], cell_lat[i], slon[j], slat[j])
            dlon_cos = (slon[j] - cell_lon[i]) * np.cos(cell_lat[i])
            dlat = slat[j] - cell_lat[i]
            w = float(eval_weight_np(kernel_type, d2, dlon_cos, dlat, kparam))
            wsum[i] += w
            acc[:, i] += w * sval[:, j]
    return acc.astype(np.float32), wsum.astype(np.float32)


def gridding_ref_vec(cell_lon, cell_lat, nbr, slon, slat, sval, kparam, kernel_type, gamma=1):
    """Vectorised variant of :func:`gridding_ref` for larger sweeps."""
    cell_lon = np.asarray(cell_lon, dtype=np.float64)
    cell_lat = np.asarray(cell_lat, dtype=np.float64)
    nbr = np.asarray(nbr)
    slon = np.asarray(slon, dtype=np.float64)
    slat = np.asarray(slat, dtype=np.float64)
    sval = np.asarray(sval, dtype=np.float64)
    valid = nbr >= 0  # [groups, k]
    safe = np.where(valid, nbr, 0)
    glon = np.repeat(slon[safe], gamma, axis=0)  # [m, k]
    glat = np.repeat(slat[safe], gamma, axis=0)
    valid_c = np.repeat(valid, gamma, axis=0)
    d2 = angular_dist2_np(cell_lon[:, None], cell_lat[:, None], glon, glat)
    dlon_cos = (glon - cell_lon[:, None]) * np.cos(cell_lat[:, None])
    dlat = glat - cell_lat[:, None]
    w = eval_weight_np(kernel_type, d2, dlon_cos, dlat, kparam)
    w = np.where(valid_c, w, 0.0)
    gval = np.repeat(sval[:, safe], gamma, axis=1)  # [c, m, k]
    acc = np.einsum("mk,cmk->cm", w, gval)
    return acc.astype(np.float32), w.sum(axis=1).astype(np.float32)
