"""L1 — Pallas cell-update kernel for convolution-based gridding.

This is the device hot-spot of HEGrid (Algorithm 1 in the paper), re-expressed
for a TPU-style memory hierarchy:

* The CUDA thread block becomes a Pallas ``BlockSpec`` tile of ``bm`` target
  cells; the kernel grid walks ``m // bm`` tiles (the Fig-13 "thread block
  size" sweep is a ``bm`` sweep here).
* The paper's per-cell dynamic ``while`` loop over LUT rings becomes a masked
  fixed-``K`` gather: L3 pre-processing materializes at most ``K`` candidate
  neighbour indices per cell (padded with ``-1``), so the device computation
  is fully static-shaped and SIMD-clean — the paper's own motivation for
  moving cell update onto SIMT hardware.
* The sorted sample arrays (the LUT payload) are mapped whole into every tile
  (``pl.BlockSpec`` with a constant index map), standing in for the L1/L2
  cache residency the paper engineers via warp placement.
* Convolution weights depend only on coordinates, never on the channel, so a
  single ``[bm, K]`` weight matrix is contracted against all ``C`` channels
  of a dispatch (``einsum('mk,cmk->cm')``): the kernel-level twin of the
  paper's component share-based redundancy elimination.
* Thread-level data reuse (reuse factor γ, Fig 16) shares one neighbour list
  among γ adjacent cells: ``nbr`` has shape ``[m // γ, K]`` and is expanded
  on device, so host-side neighbour search and the H2D transfer shrink by γ×.

The kernel MUST run with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Numerical correctness is
pinned against the pure-jnp oracle in ``ref.py`` by ``python/tests``.

Inputs (one dispatch = one tile of ``m`` cells × ``c`` channels):
  cell_lon f32[m], cell_lat f32[m]   flattened target-cell world coordinates (rad)
  nbr      i32[m//γ, K]              candidate sample indices, -1 padded
  slon     f32[n], slat f32[n]       sorted sample coordinates (rad)
  sval     f32[c, n]                 sorted per-channel sample values
  kparam   f32[4]                    kernel parameters (see KernelType)
Outputs:
  acc  f32[c, m]                     Σ w·v  (unnormalised)
  wsum f32[m]                        Σ w    (normalisation accumulates at L3)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Kernel (weighting-function) types. Must stay in sync with
# rust/src/grid/kernels.rs::ConvKernelType.
GAUSS1D = "gauss1d"
GAUSS2D = "gauss2d"
TAPERED_SINC = "tapered_sinc"
KERNEL_TYPES = (GAUSS1D, GAUSS2D, TAPERED_SINC)


@dataclass(frozen=True)
class GriddingVariant:
    """Static shape configuration of one compiled artifact."""

    name: str
    kernel_type: str
    m: int  # cells per dispatch tile
    bm: int  # cells per Pallas block ("thread block size")
    k: int  # max candidate neighbours per cell group
    c: int  # channels per dispatch
    n: int  # sample-shard capacity
    gamma: int  # reuse factor: cells sharing one neighbour list

    def __post_init__(self):
        if self.kernel_type not in KERNEL_TYPES:
            raise ValueError(f"unknown kernel type {self.kernel_type!r}")
        if self.m % self.bm != 0:
            raise ValueError(f"bm={self.bm} must divide m={self.m}")
        if self.bm % self.gamma != 0:
            raise ValueError(f"gamma={self.gamma} must divide bm={self.bm}")
        for field in ("m", "bm", "k", "c", "n", "gamma"):
            if getattr(self, field) <= 0:
                raise ValueError(f"{field} must be positive")

    @property
    def groups(self) -> int:
        """Number of neighbour-list groups per dispatch."""
        return self.m // self.gamma

    def arg_shapes(self):
        """ShapeDtypeStructs in artifact parameter order."""
        f32, i32 = jnp.float32, jnp.int32
        return (
            jax.ShapeDtypeStruct((self.m,), f32),  # cell_lon
            jax.ShapeDtypeStruct((self.m,), f32),  # cell_lat
            jax.ShapeDtypeStruct((self.groups, self.k), i32),  # nbr
            jax.ShapeDtypeStruct((self.n,), f32),  # slon
            jax.ShapeDtypeStruct((self.n,), f32),  # slat
            jax.ShapeDtypeStruct((self.c, self.n), f32),  # sval
            jax.ShapeDtypeStruct((4,), f32),  # kparam
        )


def angular_dist2(lon_a, lat_a, lon_b, lat_b):
    """Squared angular separation (rad²) via the haversine form.

    Haversine is numerically stable at the small separations gridding cares
    about (arcminutes), unlike the spherical law of cosines.
    """
    sdlat = jnp.sin((lat_b - lat_a) * 0.5)
    sdlon = jnp.sin((lon_b - lon_a) * 0.5)
    h = sdlat * sdlat + jnp.cos(lat_a) * jnp.cos(lat_b) * sdlon * sdlon
    h = jnp.clip(h, 0.0, 1.0)
    d = 2.0 * jnp.arcsin(jnp.sqrt(h))
    return d * d


def eval_weight(kernel_type, d2, dlon_cos, dlat, kparam):
    """Evaluate the convolution weight for squared distance ``d2``.

    kparam layout per kernel type (matches rust/src/grid/kernels.rs):
      gauss1d:      [0]=1/(2σ²),      [1]=R²(support), - , -
      gauss2d:      [0]=1/(2σx²),     [1]=1/(2σy²),    [2]=R², -
      tapered_sinc: [0]=1/σ (sinc),   [1]=1/b (taper), [2]=R², -
    """
    if kernel_type == GAUSS1D:
        w = jnp.exp(-d2 * kparam[0])
        r2 = kparam[1]
    elif kernel_type == GAUSS2D:
        w = jnp.exp(-(dlon_cos * dlon_cos) * kparam[0] - (dlat * dlat) * kparam[1])
        r2 = kparam[2]
    elif kernel_type == TAPERED_SINC:
        d = jnp.sqrt(d2)
        x = d * kparam[0]
        # sinc with a gaussian taper; sinc(0)=1 handled by jnp.sinc (normalised
        # sinc: sin(πx)/(πx)), matching cygrid's tapered-sinc family.
        w = jnp.sinc(x / jnp.pi) * jnp.exp(-(d * kparam[1]) ** 2)
        r2 = kparam[2]
    else:  # pragma: no cover - guarded by GriddingVariant
        raise ValueError(kernel_type)
    return jnp.where(d2 <= r2, w, 0.0)


def _cell_update_kernel(
    variant: GriddingVariant,
    cell_lon_ref,
    cell_lat_ref,
    nbr_ref,
    slon_ref,
    slat_ref,
    sval_ref,
    kparam_ref,
    acc_ref,
    wsum_ref,
):
    """One Pallas block: update ``bm`` cells against the resident shard."""
    v = variant
    bg = v.bm // v.gamma  # neighbour groups in this block

    idx = nbr_ref[...]  # [bg, K]
    valid = idx >= 0
    safe = jnp.where(valid, idx, 0)

    slon = slon_ref[...]
    slat = slat_ref[...]
    glon = slon[safe]  # [bg, K] gathered once per γ-cell group
    glat = slat[safe]

    cell_lon = cell_lon_ref[...]  # [bm]
    cell_lat = cell_lat_ref[...]

    # Expand group-level gathers to cell level: cell i uses group i // γ.
    if v.gamma > 1:
        glon = jnp.repeat(glon, v.gamma, axis=0)  # [bm, K]
        glat = jnp.repeat(glat, v.gamma, axis=0)
        valid_c = jnp.repeat(valid, v.gamma, axis=0)
    else:
        valid_c = valid

    kparam = kparam_ref[...]
    clon = cell_lon[:, None]
    clat = cell_lat[:, None]
    d2 = angular_dist2(clon, clat, glon, glat)
    dlon_cos = (glon - clon) * jnp.cos(clat)
    dlat = glat - clat
    w = eval_weight(v.kernel_type, d2, dlon_cos, dlat, kparam)
    w = jnp.where(valid_c, w, 0.0)  # [bm, K]

    # One weight matrix serves all C channels (redundancy elimination).
    sval = sval_ref[...]  # [C, n]
    gval = sval[:, safe]  # [C, bg, K]
    if v.gamma > 1:
        gval = jnp.repeat(gval, v.gamma, axis=1)  # [C, bm, K]
    acc_ref[...] = jnp.einsum(
        "mk,cmk->cm", w, gval, preferred_element_type=jnp.float32
    )
    wsum_ref[...] = jnp.sum(w, axis=1)


def make_gridding_fn(variant: GriddingVariant):
    """Build the jit-able dispatch function for ``variant``.

    Returns ``fn(cell_lon, cell_lat, nbr, slon, slat, sval, kparam) ->
    (acc[c, m], wsum[m])``.
    """
    v = variant
    grid = (v.m // v.bm,)
    bg = v.bm // v.gamma

    kernel = functools.partial(_cell_update_kernel, v)

    def fn(cell_lon, cell_lat, nbr, slon, slat, sval, kparam):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((v.bm,), lambda i: (i,)),  # cell_lon tile
                pl.BlockSpec((v.bm,), lambda i: (i,)),  # cell_lat tile
                pl.BlockSpec((bg, v.k), lambda i: (i, 0)),  # nbr tile
                pl.BlockSpec((v.n,), lambda i: (0,)),  # slon resident
                pl.BlockSpec((v.n,), lambda i: (0,)),  # slat resident
                pl.BlockSpec((v.c, v.n), lambda i: (0, 0)),  # sval resident
                pl.BlockSpec((4,), lambda i: (0,)),  # kparam
            ],
            out_specs=(
                pl.BlockSpec((v.c, v.bm), lambda i: (0, i)),
                pl.BlockSpec((v.bm,), lambda i: (i,)),
            ),
            out_shape=(
                jax.ShapeDtypeStruct((v.c, v.m), jnp.float32),
                jax.ShapeDtypeStruct((v.m,), jnp.float32),
            ),
            interpret=True,  # CPU-PJRT execution path; see module docstring
        )(cell_lon, cell_lat, nbr, slon, slat, sval, kparam)

    return fn


def vmem_estimate_bytes(variant: GriddingVariant) -> dict:
    """Static VMEM footprint estimate for one Pallas block (DESIGN.md §Perf).

    On a real TPU the resident shard (slon/slat/sval) plus one cell tile must
    fit VMEM (~16 MiB/core). interpret=True wallclock is NOT a TPU proxy, so
    this estimate is the L1 'profile'.
    """
    v = variant
    bg = v.bm // v.gamma
    tile = 4 * (2 * v.bm + bg * v.k)  # cell coords + nbr block
    resident = 4 * (2 * v.n + v.c * v.n)  # sample shard
    out = 4 * (v.c * v.bm + v.bm)
    work = 4 * (3 * v.bm * v.k + v.c * v.bm * v.k)  # gathered coords/weights/vals
    total = tile + resident + out + work
    # MXU/VPU arithmetic intensity: ~8 flops per (cell, nbr) for the distance
    # + weight, then 2·C flops for the contraction, over 4·(3 + C) gathered
    # bytes per (group, nbr).
    flops = v.m * v.k * (8 + 2 * v.c)
    bytes_moved = 4 * bg * v.k * (3 + v.c) + 4 * v.m * (2 + v.c + 1)
    return {
        "tile_bytes": tile,
        "resident_bytes": resident,
        "scratch_bytes": work,
        "out_bytes": out,
        "total_bytes": total,
        "flops_per_dispatch": flops,
        "bytes_per_dispatch": bytes_moved,
        "arithmetic_intensity": flops / max(bytes_moved, 1),
        "fits_16mib_vmem": total <= 16 * 1024 * 1024,
    }
