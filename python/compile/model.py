"""L2 — the JAX compute graph around the L1 Pallas kernel.

One "model" = one gridding dispatch: the cell-update kernel over a tile of
``m`` cells × ``c`` channels against a resident sample shard, exactly the unit
of work the Rust coordinator schedules onto a PJRT stream slot.

The L2 graph is deliberately thin — the paper's host-side logic (LUT build,
sorting, pipeline scheduling) lives in Rust — but it is where cross-channel
fusion happens: weights are computed once and contracted against all channels
(see kernels/gridding.py), and XLA fuses mask/weight/normalisation-free
epilogue into a single module per variant.

``lower_variant`` produces HLO TEXT (not a serialized proto): jax ≥ 0.5 emits
64-bit instruction ids that xla_extension 0.5.1 rejects; the HLO text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import jax

from jax._src.lib import xla_client as xc

from .kernels.gridding import GriddingVariant, make_gridding_fn


def make_dispatch_fn(variant: GriddingVariant):
    """The end-to-end dispatch graph for one artifact.

    Signature: ``(cell_lon, cell_lat, nbr, slon, slat, sval, kparam) ->
    (acc[c, m], wsum[m])`` — unnormalised partial sums so L3 can accumulate
    across sample shards before normalising.
    """
    kernel_fn = make_gridding_fn(variant)

    def dispatch(cell_lon, cell_lat, nbr, slon, slat, sval, kparam):
        acc, wsum = kernel_fn(cell_lon, cell_lat, nbr, slon, slat, sval, kparam)
        return (acc, wsum)

    return dispatch


def lower_variant(variant: GriddingVariant) -> str:
    """Lower one variant to HLO text for the Rust PJRT loader."""
    fn = make_dispatch_fn(variant)
    lowered = jax.jit(fn).lower(*variant.arg_shapes())
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def hlo_op_counts(hlo_text: str) -> dict:
    """Tiny HLO "profile" used by L2 perf checks (DESIGN.md §Perf).

    Counts the ops that matter for the redundancy argument: the weight
    pipeline (exp) must appear once per module regardless of C, and the
    channel contraction must be a single dot/fused loop.
    """
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        rhs = line.split("=", 2)[-1].lstrip()
        if rhs.startswith(("f32", "s32", "pred", "u32", "bf16", "(")):
            rhs = rhs.split(" ", 1)[-1].lstrip()
        op = rhs.split("(", 1)[0].strip()
        if op and op.isidentifier():
            counts[op] = counts.get(op, 0) + 1
    return counts
