//! Table-4 style portability demo: the same job under the Server_V and
//! Server_M device profiles.
//!
//! The paper ports HEGrid from NVIDIA V100 (Server_V) to AMD MI50 (Server_M)
//! via ROCm; the MI50 schedules fewer parallel threads for HEGrid's kernel
//! (≤128/CU) and sustains fewer concurrent pipelines, so HEGrid-on-M is
//! slower than HEGrid-on-V but still beats the CPU baseline at low channel
//! counts. Here, profiles cap the engine's stream slots + block size, and
//! the analytical occupancy model prints each profile's device-side budget.
//!
//! ```bash
//! make artifacts && cargo run --release --example portability
//! ```

use hegrid::baselines::CygridBaseline;
use hegrid::grid::occupancy::OccupancyModel;
use hegrid::prelude::*;
use hegrid::sim::SimConfig;

fn main() -> Result<()> {
    // Device-side budgets from the occupancy model (paper §5.3.2 / §5.4).
    for (name, model) in [("Server_V (V100)", OccupancyModel::v100()), ("Server_M (MI50)", OccupancyModel::mi50())] {
        let opt = model.optimal_block(1024, 100_000);
        println!(
            "{name}: warp={} optimal block={} parallel threads/SM={}",
            model.warp,
            opt,
            model.parallel_threads(opt)
        );
    }

    let dataset = SimConfig::observed(10).generate();
    println!(
        "\nworkload: {} samples × {} channels",
        dataset.n_samples(),
        dataset.n_channels()
    );

    let mut results: Vec<(String, f64)> = Vec::new();
    for profile in [DeviceProfile::ServerV, DeviceProfile::ServerM] {
        let mut cfg = HegridConfig::default();
        cfg.profile = profile;
        let job = GriddingJob::for_dataset(&dataset, &cfg)?;
        let engine = HegridEngine::new(cfg)?;
        // Warm compile with the full dispatch width so the measured run
        // reuses the same executable variant.
        let _ = engine.grid(&dataset.take_channels(engine.config.channels_per_dispatch), &job)?;
        let (_, report) = engine.grid(&dataset, &job)?;
        println!(
            "HEGrid on {:<9}: {:.3}s  (streams={} block={} variant={})",
            profile.name(),
            report.wall.as_secs_f64(),
            report.n_streams,
            engine.config.effective_block(),
            report.variant
        );
        results.push((format!("hegrid_{}", profile.name()), report.wall.as_secs_f64()));
    }

    // Cygrid-16 / Cygrid-32 rows of Table 4.
    let cfg = HegridConfig::default();
    let job = GriddingJob::for_dataset(&dataset, &cfg)?;
    for threads in [16, 32] {
        let (_, dur) = CygridBaseline::new(threads).run(&dataset, &job)?;
        println!("Cygrid-{threads:<2}          : {:.3}s", dur.as_secs_f64());
        results.push((format!("cygrid_{threads}"), dur.as_secs_f64()));
    }

    let hv = results.iter().find(|r| r.0 == "hegrid_server_v").unwrap().1;
    let hm = results.iter().find(|r| r.0 == "hegrid_server_m").unwrap().1;
    println!("\nServer_M / Server_V slowdown: {:.2}x (paper: MI50 trails V100 throughout Table 4)", hm / hv);
    assert!(hm >= hv * 0.8, "profile M should not outperform profile V");
    println!("portability OK");
    Ok(())
}
