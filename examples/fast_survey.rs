//! End-to-end driver: the full HEGrid system on a FAST-like survey workload.
//!
//! Reproduces the paper's headline experiment at 1/100 scale: the Table-2
//! "observed" dataset (2.83e4 samples/channel × 50 channels, 180" beam) is
//! gridded by HEGrid (multi-pipeline, shared component, PJRT streams), by
//! the Cygrid baseline (multi-core CPU), and by the HCGrid baseline
//! (heterogeneous, single-channel pipelines, no sharing). Reports running
//! time, the paper's headline metric (speedup vs the baselines), per-stage
//! timeline, accuracy stats, and writes sky images + a JSON record.
//!
//! ```bash
//! make artifacts && cargo run --release --example fast_survey [-- --channels 50]
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::path::Path;
use std::time::Instant;

use hegrid::baselines::{CygridBaseline, HcgridBaseline};
use hegrid::json::Json;
use hegrid::prelude::*;
use hegrid::sim::SimConfig;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = hegrid::cli::parse(&argv, &["channels", "points", "out-dir", "tile-rows"])?;
    let channels = args.get_usize("channels", 50)?;
    let points = args.get_usize("points", 28_300)?;
    let tile_rows = args.get_usize("tile-rows", 0)?;
    let out_dir = std::path::PathBuf::from(
        args.get_or("out-dir", &std::env::temp_dir().join("hegrid_fast_survey").display().to_string()),
    );
    std::fs::create_dir_all(&out_dir).map_err(HegridError::io(out_dir.display().to_string()))?;

    // ---- workload ----------------------------------------------------------
    let mut sim = SimConfig::observed(channels);
    sim.points = points;
    println!("generating {} samples × {channels} channels (observed preset)…", points);
    let t = Instant::now();
    let dataset = sim.generate();
    println!("  generated in {:.2}s ({:.1} MB)", t.elapsed().as_secs_f64(), dataset.nbytes() as f64 / 1e6);

    // `--tile-rows R` routes HEGrid through the tiled output path
    // (bounded-memory row bands, spilled to an anonymous cube; results are
    // bit-identical to untiled) — the survey at bounded peak RSS.
    let config = HegridConfig { output_tile_rows: tile_rows, ..HegridConfig::default() };
    let job = GriddingJob::for_dataset(&dataset, &config)?;
    println!(
        "  target map: {}×{} cells ({}\" cells), kernel {} R={:.4}°",
        job.spec.nlon,
        job.spec.nlat,
        (hegrid::util::rad2deg(job.spec.step) * 3600.0).round(),
        job.kernel.type_name(),
        hegrid::util::rad2deg(job.kernel.support),
    );

    // ---- HEGrid -------------------------------------------------------------
    let engine = HegridEngine::new(config.clone())?;
    // Warm-up run (compiles executables on every stream — not part of the
    // measured serving path, matching how the paper measures steady state).
    // Uses the full channel batch so the same artifact variant is selected.
    let _ = engine.grid(&dataset.take_channels(config.channels_per_dispatch.min(channels)), &job)?;
    let (he_maps, report) = engine.grid(&dataset, &job)?;
    let he_time = report.wall.as_secs_f64();
    println!("\nHEGrid: {:.3}s  (variant {}, {} streams × {} pipelines, {} dispatches)",
        he_time, report.variant, report.n_streams, report.n_pipelines, report.dispatches);
    for (stage, d, n) in report.stages.stages() {
        println!("    {stage:<22} {:>8.3}s ×{n}", d.as_secs_f64());
    }
    if report.tile_rows > 0 {
        println!(
            "    tiled output: {} bands × {} rows, {:.1} MB spilled, merge {:.3}s",
            report.tile_bands,
            report.tile_rows,
            report.tile_spill_bytes as f64 / 1e6,
            report.tile_merge_s
        );
    }

    // ---- Cygrid baseline ----------------------------------------------------
    let (cy_maps, cy_dur) = CygridBaseline::new(hegrid::util::threads::default_parallelism())
        .run(&dataset, &job)?;
    let cy_time = cy_dur.as_secs_f64();
    println!("Cygrid (CPU ×{}): {:.3}s", hegrid::util::threads::default_parallelism(), cy_time);

    // ---- HCGrid baseline ----------------------------------------------------
    let hc = HcgridBaseline::new(&config)?;
    let _ = hc.run(&dataset.take_channels(1), &job)?; // warm
    let (_, hc_report) = hc.run(&dataset, &job)?;
    let hc_time = hc_report.wall.as_secs_f64();
    println!("HCGrid (1 stream, no sharing): {:.3}s ({} LUT rebuilds)", hc_time, hc_report.shared_builds);

    // ---- headline metric ----------------------------------------------------
    let best_baseline = cy_time.min(hc_time);
    println!("\n=== headline (paper Table 3: HEGrid up to 5.5x vs best baseline) ===");
    println!("  speedup vs Cygrid : {:.2}x", cy_time / he_time);
    println!("  speedup vs HCGrid : {:.2}x", hc_time / he_time);
    println!("  speedup vs best   : {:.2}x", best_baseline / he_time);
    println!(
        "  throughput        : {:.2} Msample·ch/s",
        (dataset.n_samples() * channels) as f64 / he_time / 1e6
    );

    // ---- accuracy (Fig 17) --------------------------------------------------
    let mut worst = (0.0f64, 0.0f64);
    for (a, b) in he_maps.iter().zip(&cy_maps) {
        let d = a.diff_stats(b)?;
        worst = (worst.0.max(d.max_abs), worst.1.max(d.rms));
    }
    println!("  accuracy vs Cygrid: worst max|Δ|={:.2e} rms={:.2e}", worst.0, worst.1);

    // ---- artifacts ----------------------------------------------------------
    he_maps[0].write_pgm(&out_dir.join("hegrid_ch000.pgm"))?;
    cy_maps[0].write_pgm(&out_dir.join("cygrid_ch000.pgm"))?;
    let record = Json::obj(vec![
        ("samples", Json::num(dataset.n_samples() as f64)),
        ("channels", Json::num(channels as f64)),
        ("hegrid_s", Json::num(he_time)),
        ("cygrid_s", Json::num(cy_time)),
        ("hcgrid_s", Json::num(hc_time)),
        ("speedup_vs_cygrid", Json::num(cy_time / he_time)),
        ("speedup_vs_hcgrid", Json::num(hc_time / he_time)),
        ("worst_max_abs_diff", Json::num(worst.0)),
        ("worst_rms_diff", Json::num(worst.1)),
        ("variant", Json::str(report.variant.clone())),
        ("dispatches", Json::num(report.dispatches as f64)),
        ("tile_rows", Json::num(report.tile_rows as f64)),
    ]);
    let json_path = out_dir.join("fast_survey.json");
    std::fs::write(&json_path, record.to_pretty())
        .map_err(HegridError::io(json_path.display().to_string()))?;
    println!("\nwrote {} and sky images to {}", json_path.display(), out_dir.display());

    assert!(worst.1 < 1e-2, "accuracy regression vs CPU baseline");
    let _ = Path::new("ok");
    println!("fast_survey OK");
    Ok(())
}
