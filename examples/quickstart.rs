//! Quickstart: simulate a tiny multi-channel drift scan, grid it through the
//! heterogeneous engine, and write a sky image.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use hegrid::prelude::*;
use hegrid::sim::SimConfig;

fn main() -> Result<()> {
    // 1. A small synthetic FAST-like dataset: 4 000 samples × 4 channels.
    let dataset = SimConfig::quick_preset().generate();
    println!(
        "dataset: {} samples × {} channels, beam {}\"",
        dataset.n_samples(),
        dataset.n_channels(),
        dataset.meta.beam_arcsec
    );

    // 2. Engine with default config (map geometry derived from the dataset).
    let config = HegridConfig::default();
    let engine = HegridEngine::new(config)?;

    // 3. Grid all channels.
    let (maps, report) = engine.grid_dataset(&dataset)?;
    println!(
        "gridded onto {} × {} cells in {:.3}s using variant {}",
        maps[0].spec.nlon,
        maps[0].spec.nlat,
        report.wall.as_secs_f64(),
        report.variant
    );
    println!(
        "coverage {:.1}%  mean brightness {:.4}",
        maps[0].coverage() * 100.0,
        maps[0].mean()
    );

    // 4. Write channel 0 as a PGM image.
    let out = std::env::temp_dir().join("hegrid_quickstart_ch0.pgm");
    maps[0].write_pgm(&out)?;
    println!("wrote {}", out.display());

    // 5. Cross-check against the f64 CPU oracle.
    let job = GriddingJob::for_dataset(&dataset, &engine.config)?;
    let cpu = hegrid::grid::cpu::CpuGridder::new(job.spec.clone(), job.kernel.clone())
        .grid_dataset(&dataset);
    let d = maps[0].diff_stats(&cpu[0])?;
    println!(
        "vs CPU oracle: max|Δ| = {:.2e}, rms = {:.2e} (f32 device vs f64 host)",
        d.max_abs, d.rms
    );
    assert!(d.rms < 1e-3, "device/host mismatch");
    println!("quickstart OK");
    Ok(())
}
