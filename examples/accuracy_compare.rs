//! Fig-17 reproduction: sky images from HEGrid vs the Cygrid baseline, plus
//! their difference map.
//!
//! The paper grids two frequency channels of a real FAST survey with both
//! frameworks and shows the difference is "almost negligible" (caused by the
//! different hardware arithmetic). Here: an observed-preset dataset, HEGrid
//! (f32 device path) vs Cygrid stand-in (f64 CPU), three PGM panels per
//! channel — hegrid / cygrid / |difference| — and the quantitative stats.
//!
//! ```bash
//! make artifacts && cargo run --release --example accuracy_compare
//! ```

use hegrid::baselines::CygridBaseline;
use hegrid::prelude::*;
use hegrid::sim::SimConfig;
use hegrid::sky::SkyMap;

fn main() -> Result<()> {
    let out_dir = std::env::temp_dir().join("hegrid_accuracy");
    std::fs::create_dir_all(&out_dir).map_err(HegridError::io(out_dir.display().to_string()))?;

    // Two channels, as in Fig 17.
    let dataset = SimConfig::observed(10).generate().take_channels(2);
    let config = HegridConfig::default();
    let job = GriddingJob::for_dataset(&dataset, &config)?;

    let engine = HegridEngine::new(config)?;
    let (he, report) = engine.grid(&dataset, &job)?;
    let (cy, _) = CygridBaseline::new(hegrid::util::threads::default_parallelism())
        .run(&dataset, &job)?;
    println!(
        "gridded {} cells × {} channels (HEGrid {:.3}s, variant {})",
        job.spec.n_cells(),
        dataset.n_channels(),
        report.wall.as_secs_f64(),
        report.variant
    );

    for c in 0..dataset.n_channels() {
        let d = he[c].diff_stats(&cy[c])?;
        println!(
            "channel {c}: compared={} max|Δ|={:.3e} rms={:.3e} onlyHE={} onlyCy={}",
            d.compared, d.max_abs, d.rms, d.only_a, d.only_b
        );

        // Three panels, as in the paper's figure.
        he[c].write_pgm(&out_dir.join(format!("ch{c}_hegrid.pgm")))?;
        cy[c].write_pgm(&out_dir.join(format!("ch{c}_cygrid.pgm")))?;
        let diff_vals: Vec<f64> = he[c]
            .values()
            .iter()
            .zip(cy[c].values())
            .map(|(&a, &b)| if a.is_nan() || b.is_nan() { 0.0 } else { (a - b).abs() })
            .collect();
        let diff_w: Vec<f64> = he[c]
            .weights()
            .iter()
            .zip(cy[c].weights())
            .map(|(&a, &b)| if a > 0.0 && b > 0.0 { 1.0 } else { 0.0 })
            .collect();
        let diff = SkyMap::from_parts(job.spec.clone(), diff_vals, diff_w)?;
        diff.write_pgm(&out_dir.join(format!("ch{c}_diff.pgm")))?;

        // The paper's conclusion: the difference is negligible relative to
        // the signal. Enforce it.
        let signal = he[c].mean().abs().max(0.1);
        assert!(
            d.rms < 1e-2 * signal,
            "channel {c}: difference not negligible (rms {} vs signal {signal})",
            d.rms
        );
    }
    println!("wrote 3 panels per channel to {}", out_dir.display());
    println!("accuracy_compare OK — HEGrid retains Cygrid-level accuracy (Fig 17)");
    Ok(())
}
