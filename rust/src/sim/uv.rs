//! Synthetic interferometric visibility sets for the uv-plane gridder.
//!
//! Mirrors the single-dish simulator one level up: a seeded, fully
//! deterministic workload generator standing in for real correlator output.
//! The model is the textbook one — a planar array of antennas, all-pairs
//! baselines, a handful of point sources near the phase centre, and the
//! ideal visibility of a point source
//! `V(u, v) = A · exp(−2πi (u·l + v·m))` (u, v in wavelengths; l, m
//! direction cosines), summed over sources, plus per-channel white noise.
//! Frequencies sit on a ladder (`freq_start_hz + c · freq_step_hz`), so
//! the same metre-space baseline lands on different uv cells per channel —
//! exactly the per-channel u = x·ν/c scaling the gridder implements.

use crate::grid::uv::UvDataset;
use crate::util::prng::SplitMix64;

/// Configuration of one synthetic uv observation. The defaults fit the
/// default `uv_grid` config block: with a 256² grid of 50-wavelength cells
/// (±6400 λ half-width), a 600 m array at 1.4–1.5 GHz spans at most
/// ~±5900 λ — every placement and its conjugate stays on the grid.
#[derive(Clone, Debug)]
pub struct UvSimConfig {
    pub name: String,
    /// Antennas in the synthetic array; baselines = n·(n−1)/2.
    pub n_antennas: usize,
    /// Antenna positions draw uniformly from a square of this half-width,
    /// metres.
    pub array_radius_m: f64,
    pub n_channels: usize,
    /// First channel centre frequency, Hz.
    pub freq_start_hz: f64,
    /// Channel spacing, Hz.
    pub freq_step_hz: f64,
    /// Point sources near the phase centre.
    pub n_sources: usize,
    /// White-noise σ added to each visibility component.
    pub noise_level: f64,
    pub seed: u64,
}

impl Default for UvSimConfig {
    fn default() -> Self {
        UvSimConfig {
            name: "uv_default".into(),
            n_antennas: 16,
            array_radius_m: 600.0,
            n_channels: 8,
            freq_start_hz: 1.4e9,
            freq_step_hz: 1.0e7,
            n_sources: 5,
            noise_level: 0.01,
            seed: 42,
        }
    }
}

impl UvSimConfig {
    /// A seconds-scale smoke preset: 6 antennas (15 baselines), 3 channels.
    pub fn quick_preset() -> UvSimConfig {
        UvSimConfig {
            name: "uv_quick".into(),
            n_antennas: 6,
            n_channels: 3,
            n_sources: 3,
            ..UvSimConfig::default()
        }
    }

    pub fn with_seed(mut self, seed: u64) -> UvSimConfig {
        self.seed = seed;
        self
    }

    pub fn with_channels(mut self, n: usize) -> UvSimConfig {
        self.n_channels = n;
        self
    }

    pub fn n_baselines(&self) -> usize {
        self.n_antennas * self.n_antennas.saturating_sub(1) / 2
    }

    /// Generate the visibility set. Deterministic per seed: every random
    /// draw happens in one fixed order from one `SplitMix64` stream, so
    /// equal configs produce bit-equal datasets.
    pub fn generate(&self) -> UvDataset {
        let mut rng = SplitMix64::new(self.seed ^ 0x7576_5f73_696d_7531);
        let mut px = Vec::with_capacity(self.n_antennas);
        let mut py = Vec::with_capacity(self.n_antennas);
        for _ in 0..self.n_antennas {
            px.push(rng.uniform(-self.array_radius_m, self.array_radius_m));
            py.push(rng.uniform(-self.array_radius_m, self.array_radius_m));
        }
        // Sources: direction cosines within ±0.01 of the phase centre keep
        // the fringe rates low enough that nearby cells stay correlated.
        let mut sources = Vec::with_capacity(self.n_sources);
        for _ in 0..self.n_sources {
            let l = rng.uniform(-0.01, 0.01);
            let m = rng.uniform(-0.01, 0.01);
            let amp = rng.uniform(0.3, 1.0);
            sources.push((l, m, amp));
        }
        let mut ds = UvDataset::default();
        for i in 0..self.n_antennas {
            for j in (i + 1)..self.n_antennas {
                ds.u_m.push(px[i] - px[j]);
                ds.v_m.push(py[i] - py[j]);
                ds.weights.push(rng.uniform(0.5, 1.5) as f32);
            }
        }
        let n_samples = ds.u_m.len();
        for c in 0..self.n_channels {
            let freq = self.freq_start_hz + c as f64 * self.freq_step_hz;
            ds.freqs_hz.push(freq);
            let inv_lambda = freq / crate::grid::uv::SPEED_OF_LIGHT_M_S;
            let mut re = Vec::with_capacity(n_samples);
            let mut im = Vec::with_capacity(n_samples);
            for s in 0..n_samples {
                let u_wl = ds.u_m[s] * inv_lambda;
                let v_wl = ds.v_m[s] * inv_lambda;
                let mut vr = 0.0f64;
                let mut vi = 0.0f64;
                for &(l, m, amp) in &sources {
                    let phase = -2.0 * std::f64::consts::PI * (u_wl * l + v_wl * m);
                    vr += amp * phase.cos();
                    vi += amp * phase.sin();
                }
                vr += self.noise_level * rng.normal();
                vi += self.noise_level * rng.normal();
                re.push(vr as f32);
                im.push(vi as f32);
            }
            ds.re.push(re);
            ds.im.push(im);
        }
        ds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_dataset_is_valid_and_sized() {
        let cfg = UvSimConfig::quick_preset();
        let ds = cfg.generate();
        ds.validate().unwrap();
        assert_eq!(ds.n_samples(), cfg.n_baselines());
        assert_eq!(ds.n_samples(), 15);
        assert_eq!(ds.n_channels(), 3);
        assert!(ds.freqs_hz[1] > ds.freqs_hz[0]);
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let a = UvSimConfig::quick_preset().generate();
        let b = UvSimConfig::quick_preset().generate();
        assert_eq!(a.u_m, b.u_m);
        assert_eq!(a.weights, b.weights);
        assert_eq!(a.re, b.re);
        assert_eq!(a.im, b.im);
        let c = UvSimConfig::quick_preset().with_seed(43).generate();
        assert_ne!(a.re, c.re, "different seeds must differ");
    }

    #[test]
    fn default_preset_fits_the_default_uv_grid() {
        // The docs promise the default simulator stays on the default grid
        // — no clipped placements, direct or conjugate.
        let ds = UvSimConfig::default().generate();
        let cfg = crate::config::UvConfig::default();
        let r = cfg.build_gridder().unwrap().grid(&ds).unwrap();
        assert!(r.clipped.iter().all(|&c| c == 0), "{:?}", r.clipped);
        assert!(r.deposited.iter().all(|&d| d > 0.0));
    }
}
