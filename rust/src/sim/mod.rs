//! FAST drift-scan simulator: the workload generator behind every experiment.
//!
//! The paper evaluates on (a) simulated datasets built from FAST observation
//! parameters and (b) actual FAST observations (Table 2). Neither is
//! available here, so this module synthesises datasets with the spatial
//! statistics gridding cares about:
//!
//! * a 19-beam receiver (center + 6-ring + 12-ring hexagonal layout) rotated
//!   by 23.4°, dragged along right ascension ("drift scan"), so the raw
//!   coverage is much denser in RA than in declination — the anisotropy that
//!   motivates gridding in §2.1;
//! * a sky model of compact Gaussian sources (beam-convolved) over a diffuse
//!   background, with a per-channel spectral line profile so channels are
//!   correlated but distinct;
//! * per-sample white noise, independent per channel.
//!
//! Scale: experiments run at 1/100 of the paper's sample counts (Table 2:
//! 1.5–1.9e7 simulated / 2.83e6 observed per channel) so a full Table-3 sweep
//! completes in minutes on CPU-PJRT; the `--scale` knob restores any ratio.

use std::sync::Arc;

use crate::data::{ChannelSource, Dataset, DatasetMeta};
use crate::sky::GaussianBeam;
use crate::util::error::Result;
use crate::util::prng::Xoshiro256pp;
use crate::util::{deg2rad, SplitMix64};

pub mod uv;
pub use uv::UvSimConfig;

/// Rotation of the 19-beam array relative to the scan direction, degrees
/// (FAST's CRAFTS survey value).
pub const BEAM_ROTATION_DEG: f64 = 23.4;

/// One synthetic point source on the sky.
#[derive(Clone, Copy, Debug)]
pub struct Source {
    pub lon: f64,
    pub lat: f64,
    /// Peak amplitude (brightness temperature, arbitrary units).
    pub amp: f64,
    /// Center of the spectral line, in channel units.
    pub line_center: f64,
    /// Width of the spectral line, in channel units.
    pub line_width: f64,
}

/// Simulator configuration. Defaults mirror Table 2's "simulated" row.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub name: String,
    /// Map/field center, degrees.
    pub center_deg: (f64, f64),
    /// Field extent (RA width, Dec height), degrees.
    pub extent_deg: (f64, f64),
    /// Beam FWHM, arcsec.
    pub beam_arcsec: f64,
    /// Target number of samples per channel.
    pub points: usize,
    /// Number of frequency channels.
    pub channels: usize,
    /// Number of compact sources to draw.
    pub n_sources: usize,
    /// Noise σ relative to the brightest source amplitude.
    pub noise_level: f64,
    /// PRNG seed; equal seeds give identical datasets.
    pub seed: u64,
}

impl SimConfig {
    /// Table 2 "simulated" preset at 1/100 scale: `points` per channel in
    /// 1.5e5..1.9e5 (1/100 of 1.5–1.9e7), 50 channels, 180" beam. The field
    /// is scaled 1/10 linearly (6°×2° vs the paper's 60°×20°) so the sample
    /// density per beam — what gridding cost actually depends on — matches
    /// Table 2.
    pub fn simulated(points: usize) -> SimConfig {
        SimConfig {
            name: format!("simulated_{points}"),
            center_deg: (30.0, 41.0),
            extent_deg: (6.0, 2.0),
            beam_arcsec: 180.0,
            points,
            channels: 50,
            n_sources: 120,
            noise_level: 0.05,
            seed: 0x5EED_0001,
        }
    }

    /// Table 2 "observed (by FAST)" preset at 1/100 scale: 2.83e4 points
    /// (1/100 of 2.83e6), `channels` ∈ 10..=50, field scaled 1/10 linearly
    /// (see [`SimConfig::simulated`]).
    pub fn observed(channels: usize) -> SimConfig {
        SimConfig {
            name: format!("observed_{channels}ch"),
            center_deg: (30.0, 41.0),
            extent_deg: (6.0, 2.0),
            beam_arcsec: 180.0,
            points: 28_300,
            channels,
            n_sources: 80,
            noise_level: 0.08,
            seed: 0x5EED_0002,
        }
    }

    /// Fig-15 extended preset: small fields (5°×5° / 10°×10°), beams 180"/300",
    /// sample sizes 1.5e3..1.5e5 (1/100 of the paper's 1.5e5..1.5e7).
    pub fn extended(field_deg: f64, beam_arcsec: f64, points: usize) -> SimConfig {
        SimConfig {
            name: format!("ext_f{field_deg}_b{beam_arcsec}_p{points}"),
            center_deg: (30.0, 41.0),
            extent_deg: (field_deg, field_deg),
            beam_arcsec,
            points,
            channels: 50,
            n_sources: 40,
            noise_level: 0.05,
            seed: 0x5EED_0003,
        }
    }

    /// Tiny preset for unit tests and the quickstart example.
    pub fn quick_preset() -> SimConfig {
        SimConfig {
            name: "quick".into(),
            center_deg: (30.0, 41.0),
            extent_deg: (2.0, 2.0),
            beam_arcsec: 300.0,
            points: 4000,
            channels: 4,
            n_sources: 12,
            noise_level: 0.02,
            seed: 7,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_channels(mut self, channels: usize) -> Self {
        self.channels = channels;
        self
    }

    /// Generate the dataset (drift-scan geometry + sky model + noise).
    pub fn generate(&self) -> Dataset {
        self.workload().materialize()
    }

    /// Build the channel-independent half of a simulated dataset:
    /// coordinates, sky model, sparse spatial responses, and one PRNG seed
    /// per channel. [`SimWorkload::channel_values`] then synthesizes any
    /// channel on demand, bit-identically to [`SimConfig::generate`] —
    /// the basis of [`SimSource`], the deterministic streaming source.
    pub fn workload(&self) -> SimWorkload {
        let mut seeder = SplitMix64::new(self.seed);
        let sources = self.draw_sources(&mut seeder);
        let (lons, lats) = self.scan_coordinates(&mut seeder);
        let n = lons.len();

        let beam = GaussianBeam::from_fwhm_arcsec(self.beam_arcsec);
        // Beam-convolved source width: source intrinsic ~ beam/2 ⇒ effective
        // σ² = σ_b² + σ_s².
        let sigma_b = beam.sigma();
        let sigma_eff = (sigma_b * sigma_b * 1.25).sqrt();
        let inv_2s2 = 1.0 / (2.0 * sigma_eff * sigma_eff);
        let cut2 = (5.0 * sigma_eff) * (5.0 * sigma_eff);

        // Channel-independent spatial responses, stored sparse: sources are
        // compact (≤ 5σ of a beam), so each sample sees 0–2 of them. Gaussian
        // profile in the plane — small fields: the cos(dec)-corrected planar
        // approx is within 1e-6 of haversine at these scales.
        let workers = crate::util::threads::default_parallelism();
        let chunk = n.div_ceil(workers).max(1);
        let sparse: Vec<Vec<(u32, f64)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    let (start, end) = (w * chunk, ((w + 1) * chunk).min(n));
                    let (lons, lats, sources) = (&lons, &lats, &sources);
                    s.spawn(move || {
                        let mut out = Vec::with_capacity(end.saturating_sub(start));
                        for i in start..end.max(start) {
                            let (lon, lat) = (lons[i], lats[i]);
                            let clat = lat.cos();
                            let mut row: Vec<(u32, f64)> = Vec::new();
                            for (j, src) in sources.iter().enumerate() {
                                let dlon = (lon - src.lon) * clat;
                                let dlat = lat - src.lat;
                                let d2 = dlon * dlon + dlat * dlat;
                                if d2 < cut2 {
                                    row.push((j as u32, src.amp * (-d2 * inv_2s2).exp()));
                                }
                            }
                            out.push(row);
                        }
                        out
                    })
                })
                .collect();
            let mut sparse = Vec::with_capacity(n);
            for h in handles {
                sparse.extend(h.join().expect("sim worker panicked"));
            }
            sparse
        });

        let channel_seeds: Vec<u64> = (0..self.channels).map(|_| seeder.next_u64()).collect();

        let meta = DatasetMeta {
            name: self.name.clone(),
            beam_arcsec: self.beam_arcsec,
            center_deg: self.center_deg,
            extent_deg: self.extent_deg,
        };
        SimWorkload {
            meta,
            lons: Arc::new(lons),
            lats: Arc::new(lats),
            sources,
            sparse,
            channel_seeds,
            noise_level: self.noise_level,
        }
    }

    fn draw_sources(&self, rng: &mut SplitMix64) -> Vec<Source> {
        let (w, h) = (deg2rad(self.extent_deg.0), deg2rad(self.extent_deg.1));
        let (lon_c, lat_c) = (deg2rad(self.center_deg.0), deg2rad(self.center_deg.1));
        (0..self.n_sources)
            .map(|_| Source {
                lon: lon_c + rng.uniform(-0.45, 0.45) * w,
                lat: lat_c + rng.uniform(-0.45, 0.45) * h,
                // Power-law-ish amplitude distribution: many faint, few bright.
                amp: rng.next_f64().powi(3) * 4.0 + 0.2,
                line_center: rng.uniform(0.0, self.channels.max(1) as f64),
                line_width: rng.uniform(1.0, self.channels.max(2) as f64 / 4.0),
            })
            .collect()
    }

    /// Drift-scan sample coordinates: scan rows along RA, rows spaced in Dec
    /// by the rotated 19-beam footprint, with RA sampling several times
    /// denser than Dec (super-Nyquist in RA, the paper's §2.1 anisotropy).
    fn scan_coordinates(&self, seeder: &mut SplitMix64) -> (Vec<f64>, Vec<f64>) {
        let (w, h) = (deg2rad(self.extent_deg.0), deg2rad(self.extent_deg.1));
        let (lon_c, lat_c) = (deg2rad(self.center_deg.0), deg2rad(self.center_deg.1));
        let beams = beam_offsets(deg2rad(self.beam_arcsec / 3600.0) * 1.2, BEAM_ROTATION_DEG);
        let nb = beams.len(); // 19

        // Choose scan-line geometry: total lines L = rows·nb, samples per
        // line P, with RA density ≈ 4× the Dec line spacing.
        let target = self.points.max(nb);
        let aspect = w / h;
        let rows =
            (((target as f64 / nb as f64) / (4.0 * aspect)).sqrt().ceil() as usize).max(1);
        let per_line = (target as f64 / (rows * nb) as f64).ceil().max(1.0) as usize;

        let mut rng = Xoshiro256pp::new(seeder.next_u64());
        let mut lons = Vec::with_capacity(rows * nb * per_line);
        let mut lats = Vec::with_capacity(rows * nb * per_line);
        let row_step = h / rows as f64;
        let ra_step = w / per_line as f64;
        for r in 0..rows {
            let strip_lat = lat_c - h / 2.0 + (r as f64 + 0.5) * row_step;
            for (dx, dy) in &beams {
                for p in 0..per_line {
                    if lons.len() >= target {
                        break;
                    }
                    // Pointing jitter ~ 5% of the step keeps cadence realistic.
                    let lon = lon_c - w / 2.0
                        + (p as f64 + 0.5) * ra_step
                        + rng.uniform(-0.05, 0.05) * ra_step
                        + dx;
                    let lat = strip_lat + dy + rng.uniform(-0.05, 0.05) * row_step;
                    lons.push(lon);
                    lats.push(lat);
                }
            }
        }
        // Top up to exactly `target` with uniform scatter (edge effects).
        while lons.len() < target {
            lons.push(lon_c + rng.uniform(-0.5, 0.5) * w);
            lats.push(lat_c + rng.uniform(-0.5, 0.5) * h);
        }
        (lons, lats)
    }
}

/// The channel-independent half of a simulated dataset (see
/// [`SimConfig::workload`]). Per-channel values are synthesized on demand:
/// spectral line profile × sparse spatial response + per-channel white
/// noise, each channel from its own pre-drawn seed.
pub struct SimWorkload {
    meta: DatasetMeta,
    lons: Arc<Vec<f64>>,
    lats: Arc<Vec<f64>>,
    sources: Vec<Source>,
    sparse: Vec<Vec<(u32, f64)>>,
    channel_seeds: Vec<u64>,
    noise_level: f64,
}

impl SimWorkload {
    pub fn meta(&self) -> &DatasetMeta {
        &self.meta
    }

    pub fn n_samples(&self) -> usize {
        self.lons.len()
    }

    pub fn n_channels(&self) -> usize {
        self.channel_seeds.len()
    }

    /// Synthesize channel `c` into `out` (cleared first). Deterministic:
    /// depends only on the workload and `c`, never on generation order.
    pub fn channel_values_into(&self, c: usize, out: &mut Vec<f32>) {
        let mut rng = Xoshiro256pp::new(self.channel_seeds[c]);
        let line: Vec<f64> = self
            .sources
            .iter()
            .map(|src| {
                let x = (c as f64 - src.line_center) / src.line_width;
                (-0.5 * x * x).exp()
            })
            .collect();
        out.clear();
        out.reserve(self.sparse.len());
        for row in &self.sparse {
            let mut v = 0.02; // diffuse background
            for &(j, r) in row {
                v += r * line[j as usize];
            }
            out.push((v + self.noise_level * rng.normal()) as f32);
        }
    }

    pub fn channel_values(&self, c: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.channel_values_into(c, &mut out);
        out
    }

    /// Materialize every channel (in parallel) into a [`Dataset`].
    pub fn materialize(&self) -> Dataset {
        let channels: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..self.n_channels())
                .map(|c| s.spawn(move || self.channel_values(c)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("channel worker panicked")).collect()
        });
        Dataset::new(
            self.meta.clone(),
            (*self.lons).clone(),
            (*self.lats).clone(),
            channels,
        )
        .expect("simulator produced consistent arrays")
    }
}

/// Deterministic streaming source: channels are synthesized on demand from
/// a [`SimWorkload`], so arbitrarily many channels can be streamed without
/// ever materializing the dataset — the test/bench stand-in for a
/// larger-than-RAM observation.
pub struct SimSource {
    workload: SimWorkload,
}

impl SimSource {
    pub fn new(cfg: &SimConfig) -> SimSource {
        SimSource { workload: cfg.workload() }
    }

    pub fn workload(&self) -> &SimWorkload {
        &self.workload
    }
}

impl ChannelSource for SimSource {
    fn meta(&self) -> &DatasetMeta {
        self.workload.meta()
    }

    fn n_samples(&self) -> usize {
        self.workload.n_samples()
    }

    fn n_channels(&self) -> usize {
        self.workload.n_channels()
    }

    fn coords(&self) -> Result<(&[f64], &[f64])> {
        Ok((self.workload.lons.as_slice(), self.workload.lats.as_slice()))
    }

    fn read_channel_into(&self, c: usize, out: &mut Vec<f32>) -> Result<()> {
        self.workload.channel_values_into(c, out);
        Ok(())
    }
}

/// The 19-beam layout: center, inner hexagon (6), outer ring (12), spaced by
/// `sep` radians, rotated by `rot_deg`. Returns (Δlon, Δlat) offsets.
pub fn beam_offsets(sep: f64, rot_deg: f64) -> Vec<(f64, f64)> {
    let rot = deg2rad(rot_deg);
    let (cr, sr) = (rot.cos(), rot.sin());
    let mut out = vec![(0.0, 0.0)];
    // Inner hexagon.
    for k in 0..6 {
        let a = k as f64 * std::f64::consts::FRAC_PI_3;
        out.push((sep * a.cos(), sep * a.sin()));
    }
    // Outer ring of 12: alternating vertices (2·sep) and edge midpoints (√3·sep).
    for k in 0..12 {
        let a = k as f64 * std::f64::consts::PI / 6.0;
        let r = if k % 2 == 0 { 2.0 * sep } else { 3.0f64.sqrt() * sep };
        out.push((r * a.cos(), r * a.sin()));
    }
    // Rotate the whole pattern.
    out.iter().map(|(x, y)| (x * cr - y * sr, x * sr + y * cr)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rad2deg;

    #[test]
    fn beam_layout_has_19_beams() {
        let b = beam_offsets(0.001, BEAM_ROTATION_DEG);
        assert_eq!(b.len(), 19);
        assert_eq!(b[0], (0.0, 0.0));
        // distinct offsets
        for i in 0..b.len() {
            for j in (i + 1)..b.len() {
                let d = ((b[i].0 - b[j].0).powi(2) + (b[i].1 - b[j].1).powi(2)).sqrt();
                assert!(d > 1e-6, "beams {i} {j} overlap");
            }
        }
    }

    #[test]
    fn rotation_preserves_radii() {
        let b0 = beam_offsets(0.01, 0.0);
        let br = beam_offsets(0.01, 23.4);
        for (a, b) in b0.iter().zip(&br) {
            let ra = (a.0 * a.0 + a.1 * a.1).sqrt();
            let rb = (b.0 * b.0 + b.1 * b.1).sqrt();
            assert!((ra - rb).abs() < 1e-12);
        }
    }

    #[test]
    fn generate_matches_config() {
        let cfg = SimConfig::quick_preset();
        let d = cfg.generate();
        assert_eq!(d.n_samples(), cfg.points);
        assert_eq!(d.n_channels(), cfg.channels);
        assert_eq!(d.meta.beam_arcsec, cfg.beam_arcsec);
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let cfg = SimConfig::quick_preset();
        let a = cfg.generate();
        let b = cfg.generate();
        assert_eq!(a.lons, b.lons);
        assert_eq!(a.channels, b.channels);
        let c = cfg.clone().with_seed(8).generate();
        assert_ne!(a.lons, c.lons);
    }

    #[test]
    fn samples_mostly_inside_field() {
        let cfg = SimConfig::quick_preset();
        let d = cfg.generate();
        let (w, h) = cfg.extent_deg;
        let mut inside = 0;
        for (&lon, &lat) in d.lons.iter().zip(&d.lats) {
            let dlon = rad2deg(lon) - cfg.center_deg.0;
            let dlat = rad2deg(lat) - cfg.center_deg.1;
            // beam offsets can push samples slightly beyond the field edge
            if dlon.abs() <= w / 2.0 + 0.5 && dlat.abs() <= h / 2.0 + 0.5 {
                inside += 1;
            }
        }
        assert!(inside as f64 >= 0.99 * d.n_samples() as f64);
    }

    #[test]
    fn ra_denser_than_dec() {
        // The drift-scan anisotropy: unique-ish RA positions should exceed
        // unique Dec strips by a large factor.
        let cfg = SimConfig::extended(5.0, 300.0, 20_000);
        let d = cfg.generate();
        let mut lats_sorted: Vec<f64> = d.lats.clone();
        lats_sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Count distinct Dec "strips" (gaps larger than 10% of median gap).
        let gaps: Vec<f64> =
            lats_sorted.windows(2).map(|w| w[1] - w[0]).filter(|&g| g > 0.0).collect();
        assert!(!gaps.is_empty());
        // A pure uniform scatter would have ~n distinct strips; the scan
        // geometry clusters them, so the largest gaps dwarf the median.
        let mut sorted_gaps = gaps.clone();
        sorted_gaps.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted_gaps[sorted_gaps.len() / 2];
        let max = *sorted_gaps.last().unwrap();
        assert!(max > 20.0 * median.max(1e-15), "max={max} median={median}");
    }

    #[test]
    fn channels_share_sources_but_differ() {
        let d = SimConfig::quick_preset().generate();
        let a = &d.channels[0];
        let b = &d.channels[d.n_channels() - 1];
        assert_ne!(a, b);
        // Values are finite and bounded.
        for v in a {
            assert!(v.is_finite());
            assert!(v.abs() < 100.0);
        }
    }

    #[test]
    fn sim_source_matches_generate_bitwise() {
        let cfg = SimConfig::quick_preset();
        let d = cfg.generate();
        let src = SimSource::new(&cfg);
        assert_eq!(src.n_samples(), d.n_samples());
        assert_eq!(src.n_channels(), d.n_channels());
        let (lons, lats) = src.coords().unwrap();
        assert_eq!(lons, d.lons.as_slice());
        assert_eq!(lats, d.lats.as_slice());
        let mut buf = Vec::new();
        // Read out of order: values must only depend on the channel index.
        for c in (0..d.n_channels()).rev() {
            src.read_channel_into(c, &mut buf).unwrap();
            assert_eq!(buf, d.channels[c], "channel {c}");
        }
    }

    #[test]
    fn presets_match_table2_scales() {
        let sim = SimConfig::simulated(150_000);
        assert_eq!(sim.channels, 50);
        assert_eq!(sim.extent_deg, (6.0, 2.0));
        let obs = SimConfig::observed(30);
        assert_eq!(obs.points, 28_300);
        assert_eq!(obs.channels, 30);
    }
}
