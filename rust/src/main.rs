//! `hegrid` — the leader binary.
//!
//! ```text
//! hegrid simulate   --preset quick|simulated|observed|extended [...] --out data.hgd
//! hegrid grid       --input data.hgd [--out-prefix out/map] [engine knobs]
//! hegrid inspect    --input data.hgd
//! hegrid accuracy   --input data.hgd [--out-prefix out/acc]   (Fig-17 check)
//! hegrid info       [--artifacts artifacts]                   (list variants)
//! hegrid bench-gate --current BENCH_x.json [--baseline prev.json] [--threshold 0.15]
//! hegrid serve      [--listen ADDR] [engine knobs]              (job server)
//! hegrid uv-grid    [--preset quick|default] [--out-prefix out/uv] [uv knobs]
//! ```
//!
//! Engine knobs (grid/accuracy): `--streams N --pipelines N
//! --pipeline-width W|auto --pipeline-width-max W
//! --channels-per-dispatch C --gamma G --block B --cpu-block B
//! --simd auto|scalar|avx2|neon --affinity none|compact|spread
//! --kernel gauss1d|gauss2d|tapered_sinc --profile v|m --oversample F
//! --no-share --artifacts DIR --prefetch-depth D --io-workers N
//! --tile-rows R --checkpoint DIR --resume`.
//!
//! `--tile-rows R` turns on the bounded-memory tiled reducer: the output is
//! accumulated in R-row bands that stream into an on-disk cube (0 = legacy
//! untiled path; results are bit-identical either way). `--checkpoint DIR`
//! makes the tiled run persist the cube + a CRC'd manifest per finished
//! channel group; `--resume` (with the same `--checkpoint DIR`) skips the
//! groups the manifest records and completes the remaining ones.
//!
//! `--pipeline-width auto` turns on the occupancy-driven width controller
//! (see docs/tuning.md): the coordinator starts at width 2 and shrinks/grows
//! the concurrent pipeline count from measured stage occupancy, bounded by
//! `--pipeline-width-max`. Results are bit-identical to any fixed width.
//!
//! `grid --streaming` reads channels lazily from the HGD file through the
//! T0 prefetcher (bounded memory; I/O overlaps compute) instead of loading
//! the dataset up front.
//!
//! Robustness knobs (see docs/robustness.md): `--fail-fast` (default) aborts
//! on the first error; `--degrade` retries transient channel-read errors
//! (`--retry-io N --retry-backoff-ms MS`) and quarantines channel groups
//! that still fail, reporting them and — with `--checkpoint` — recording
//! them as failed so `--resume` re-grids exactly those. `--faults
//! <seed>:<spec>` (or HEGRID_FAULTS) injects deterministic faults when the
//! crate is built with `--features fault-injection`.
//!
//! `--shard-procs N` (with `--checkpoint DIR`) takes the supervised
//! multi-process path (docs/distributed.md): the sky is split into N
//! contiguous row shards, each gridded by a re-exec'd `shard-worker` child
//! with its own checkpoint; the parent watches heartbeats, restarts crashed
//! or hung workers (`--shard-max-restarts --shard-heartbeat-timeout
//! --shard-backoff-ms`), and deterministically merges the shard cubes —
//! byte-identical to a single-process run.
//!
//! `hegrid uv-grid` grids a synthetic interferometric visibility set
//! (docs/uv-gridding.md): `--antennas N --channels C --sources K --seed S`
//! shape the simulated observation, the `uv_grid` config block (CLI
//! `--uv-nu --uv-nv --uv-cell --uv-kernel gaussian|spheroidal --uv-support
//! --uv-oversample --uv-sigma --uv-tile-rows --no-hermitian`) shapes the
//! grid and kernel, `--oracle` cross-checks the optimized path against the
//! direct-sum oracle bit for bit, and `--out-prefix P` writes
//! `P_re/im/wsum.fits` NAXIS3 cubes.
//!
//! `hegrid serve` runs the multi-tenant job server (docs/service.md): the
//! engine knobs above become the server's *base* config, each `POST /jobs`
//! may overlay a partial `config` object on it, and `--listen ADDR
//! --queue-max N --service-workers N --cache-cap N --keep-results N
//! --drain-timeout S --job-timeout S` (or `HEGRID_SERVICE_*` env vars) set
//! the service layer: admission control, job concurrency, cross-job
//! plan-cache size, result retention, the SIGTERM graceful-drain budget,
//! and the per-job runtime watchdog (terminal `timeout` state).

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use hegrid::baselines::CygridBaseline;
use hegrid::cli;
use hegrid::config::{DeviceProfile, HegridConfig, UvConfig};
use hegrid::coordinator::{GriddingJob, HegridEngine, PipelineReport};
use hegrid::data::{ChannelSource, Dataset, HgdReader, HgdStreamSource};
use hegrid::runtime::Manifest;
use hegrid::service::ServiceConfig;
use hegrid::sim::{SimConfig, UvSimConfig};
use hegrid::util::error::{HegridError, Result};

const VALUE_OPTS: &[&str] = &[
    "preset", "points", "channels", "field", "beam", "seed", "out", "input", "out-prefix",
    "streams", "pipelines", "pipeline-width", "pipeline-width-max", "channels-per-dispatch",
    "gamma", "block", "cpu-block", "simd", "affinity", "kernel", "profile", "oversample",
    "artifacts", "threads", "variant", "prefetch-depth", "io-workers", "baseline", "current",
    "threshold", "tile-rows", "checkpoint", "faults", "retry-io", "retry-backoff-ms",
    "listen", "queue-max", "service-workers", "cache-cap", "keep-results", "drain-timeout",
    "job-timeout", "shard-procs", "shard-max-restarts", "shard-heartbeat-timeout",
    "shard-backoff-ms", "config", "shard-index", "shard-rows", "shard-attempt", "antennas",
    "sources", "uv-nu", "uv-nv", "uv-cell", "uv-kernel", "uv-support", "uv-oversample",
    "uv-sigma", "uv-tile-rows",
];

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("hegrid: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = cli::parse(argv, VALUE_OPTS)?;
    if args.flag("verbose") {
        hegrid::logging::set_level(hegrid::logging::Level::Debug);
    }
    let command = args.command.clone();
    match command.as_deref() {
        Some("simulate") => cmd_simulate(&args)?,
        Some("grid") => cmd_grid(&args)?,
        Some("inspect") => cmd_inspect(&args)?,
        Some("accuracy") => cmd_accuracy(&args)?,
        Some("info") => cmd_info(&args)?,
        Some("bench-gate") => cmd_bench_gate(&args)?,
        Some("serve") => cmd_serve(&args)?,
        Some("uv-grid") => cmd_uv_grid(&args)?,
        Some("shard-worker") => cmd_shard_worker(&args)?,
        Some("help") | None => {
            print_help();
            return Ok(());
        }
        Some(other) => {
            return Err(HegridError::Config(format!(
                "unknown subcommand '{other}' (try `hegrid help`)"
            )))
        }
    }
    args.check_unknown()
}

fn print_help() {
    println!(
        "hegrid {} — multi-channel radio astronomical data gridding\n\n\
         subcommands:\n\
         \x20 simulate  generate a synthetic FAST-like dataset (--preset quick|simulated|observed|extended)\n\
         \x20 grid      grid a dataset (--streaming: bounded-memory prefetched ingest)\n\
         \x20 inspect   print an HGD file's header\n\
         \x20 accuracy  compare HEGrid output against the Cygrid baseline (Fig 17)\n\
         \x20 info      list AOT artifact variants\n\
         \x20 bench-gate  diff a fresh BENCH_*.json against a stored baseline (CI perf gate)\n\
         \x20 serve     run the multi-tenant HTTP job server (docs/service.md)\n\
         \x20 uv-grid   grid synthetic interferometric visibilities onto a uv plane (docs/uv-gridding.md)\n\n\
         run `cargo doc --open` or see README.md for the full option list",
        hegrid::VERSION
    );
}

fn engine_config(args: &cli::Args) -> Result<HegridConfig> {
    // `--pipeline-width` takes an integer or the literal `auto` (the
    // occupancy-driven controller, bounded by `--pipeline-width-max`).
    let (pipeline_width, pipeline_width_auto) = match args.get("pipeline-width") {
        None => (0, false),
        Some("auto") => (0, true),
        Some(v) => (
            v.parse().map_err(|_| {
                HegridError::Config(format!(
                    "option --pipeline-width expects an integer or 'auto', got '{v}'"
                ))
            })?,
            false,
        ),
    };
    let d = HegridConfig::default();
    let mut cfg = HegridConfig {
        artifacts_dir: args.get_or("artifacts", "artifacts").to_string(),
        streams: args.get_usize("streams", 0)?,
        pipelines: args.get_usize("pipelines", 0)?,
        pipeline_width,
        pipeline_width_auto,
        pipeline_width_max: args.get_usize("pipeline-width-max", 0)?,
        channels_per_dispatch: args.get_usize("channels-per-dispatch", 10)?,
        share_preprocessing: !args.flag("no-share"),
        gamma: args.get_usize("gamma", 1)?,
        block_size: args.get_usize("block", 0)?,
        cpu_channel_block: args.get_usize("cpu-block", 0)?,
        simd_isa: args.get_or("simd", "auto").to_string(),
        executor_affinity: args.get_or("affinity", "none").to_string(),
        prefetch_depth: args.get_usize("prefetch-depth", 2)?,
        io_workers: args.get_usize("io-workers", 0)?,
        output_tile_rows: args.get_usize("tile-rows", 0)?,
        checkpoint_dir: args.get_or("checkpoint", "").to_string(),
        resume: args.flag("resume"),
        // `--fail-fast` (the default) aborts on the first error; `--degrade`
        // switches to retry + quarantine. Both flags are consumed so
        // `check_unknown` accepts either spelling; --fail-fast wins a tie.
        fail_fast: args.flag("fail-fast") || !args.flag("degrade"),
        retry_io: args.get_usize("retry-io", d.retry_io)?,
        retry_io_backoff_ms: args.get_usize("retry-backoff-ms", d.retry_io_backoff_ms)?,
        faults: args.get_or("faults", "").to_string(),
        shard_procs: args.get_usize("shard-procs", d.shard_procs)?,
        shard_max_restarts: args.get_usize("shard-max-restarts", d.shard_max_restarts)?,
        shard_heartbeat_timeout_s: args
            .get_usize("shard-heartbeat-timeout", d.shard_heartbeat_timeout_s)?,
        shard_restart_backoff_ms: args.get_usize("shard-backoff-ms", d.shard_restart_backoff_ms)?,
        width_saturation: d.width_saturation,
        width_busy_grow: d.width_busy_grow,
        width_idle_shrink: d.width_idle_shrink,
        kernel_type: args.get_or("kernel", "gauss1d").to_string(),
        variant_override: args.get_or("variant", "").to_string(),
        kernel_sigma_beam: 0.5,
        support_sigma: 3.0,
        oversample: args.get_f64("oversample", 2.0)?,
        uv_grid: {
            let ud = UvConfig::default();
            UvConfig {
                n_u: args.get_usize("uv-nu", ud.n_u)?,
                n_v: args.get_usize("uv-nv", ud.n_v)?,
                cell_wavelengths: args.get_f64("uv-cell", ud.cell_wavelengths)?,
                kernel_type: args.get_or("uv-kernel", &ud.kernel_type).to_string(),
                kernel_support: args.get_usize("uv-support", ud.kernel_support)?,
                kernel_oversample: args.get_usize("uv-oversample", ud.kernel_oversample)?,
                kernel_sigma_cells: args.get_f64("uv-sigma", ud.kernel_sigma_cells)?,
                tile_rows: args.get_usize("uv-tile-rows", ud.tile_rows)?,
                hermitian: !args.flag("no-hermitian"),
            }
        },
        profile: DeviceProfile::from_name(args.get_or("profile", "server_v"))?,
    };
    if cfg.artifacts_dir == "artifacts" && !Path::new("artifacts/manifest.json").exists() {
        // Allow running from anywhere inside the repo.
        if let Ok(exe) = std::env::current_exe() {
            for anc in exe.ancestors() {
                let cand = anc.join("artifacts/manifest.json");
                if cand.exists() {
                    cfg.artifacts_dir = anc.join("artifacts").display().to_string();
                    break;
                }
            }
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

/// `hegrid serve`: the multi-tenant job server (docs/service.md). The
/// engine knobs on the command line become the base config every job
/// inherits (jobs may overlay a partial `config` object per POST);
/// service-layer knobs resolve defaults → `HEGRID_SERVICE_*` env vars →
/// CLI flags, strongest last. Runs until SIGTERM/SIGINT, then drains.
fn cmd_serve(args: &cli::Args) -> Result<()> {
    let base = engine_config(args)?;
    let mut scfg = ServiceConfig::default();
    scfg.apply_env()?;
    if let Some(listen) = args.get("listen") {
        scfg.service_listen = listen.to_string();
    }
    scfg.service_queue_max = args.get_usize("queue-max", scfg.service_queue_max)?;
    scfg.service_workers = args.get_usize("service-workers", scfg.service_workers)?;
    scfg.service_cache_cap = args.get_usize("cache-cap", scfg.service_cache_cap)?;
    scfg.service_keep_results = args.get_usize("keep-results", scfg.service_keep_results)?;
    scfg.service_drain_s = args.get_usize("drain-timeout", scfg.service_drain_s)?;
    scfg.service_job_timeout_s = args.get_usize("job-timeout", scfg.service_job_timeout_s)?;
    hegrid::service::serve(base, scfg)
}

/// `hegrid uv-grid`: generate a seeded synthetic visibility set, grid it
/// onto the configured uv plane through the engine, and optionally write
/// the re/im/wsum planes as FITS NAXIS3 cubes. `--oracle` re-grids with the
/// brute-force direct sum and verifies bit-identity on the spot.
fn cmd_uv_grid(args: &cli::Args) -> Result<()> {
    let cfg = engine_config(args)?;
    let mut sim = match args.get_or("preset", "quick") {
        "quick" => UvSimConfig::quick_preset(),
        "default" => UvSimConfig::default(),
        other => {
            return Err(HegridError::Config(format!(
                "unknown uv preset '{other}' (expected quick|default)"
            )))
        }
    };
    sim.n_antennas = args.get_usize("antennas", sim.n_antennas)?;
    sim.n_channels = args.get_usize("channels", sim.n_channels)?;
    sim.n_sources = args.get_usize("sources", sim.n_sources)?;
    sim.seed = args.get_usize("seed", sim.seed as usize)? as u64;
    let ds = sim.generate();
    let engine = HegridEngine::new(cfg)?;
    let (res, dt) = hegrid::logging::timed(|| engine.grid_uv(&ds));
    let res = res?;
    let uv = &engine.config.uv_grid;
    let clipped: usize = res.clipped.iter().sum();
    let deposited: f64 = res.deposited.iter().sum();
    println!(
        "uv-gridded {} baselines × {} channels onto {}x{} cells ({} kernel) in {:.3}s",
        ds.n_samples(),
        ds.n_channels(),
        uv.n_u,
        uv.n_v,
        uv.kernel_type,
        dt.as_secs_f64()
    );
    println!(
        "  deposited_weight={deposited:.3} clipped_placements={clipped} hermitian={} tile_rows={}",
        uv.hermitian, uv.tile_rows
    );
    if args.flag("oracle") {
        let gridder = uv.build_gridder()?.with_simd(engine.config.simd());
        let oracle = gridder.grid_oracle(&ds)?;
        let mut identical = res.planes.len() == oracle.planes.len();
        if identical {
            'planes: for (a, b) in res.planes.iter().zip(&oracle.planes) {
                for (x, y) in a
                    .re
                    .iter()
                    .zip(&b.re)
                    .chain(a.im.iter().zip(&b.im))
                    .chain(a.wsum.iter().zip(&b.wsum))
                {
                    if x.to_bits() != y.to_bits() {
                        identical = false;
                        break 'planes;
                    }
                }
            }
        }
        if !identical {
            return Err(HegridError::Internal(
                "uv gridder disagrees with the direct-sum oracle".into(),
            ));
        }
        println!(
            "  oracle: bit-identical over {} cells × {} channels",
            uv.n_u * uv.n_v,
            res.planes.len()
        );
    }
    if let Some(prefix) = args.get("out-prefix") {
        if let Some(parent) = Path::new(prefix).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(HegridError::io(prefix.to_string()))?;
            }
        }
        let collect = |f: fn(&hegrid::grid::uv::UvPlanes) -> &Vec<f64>| -> Vec<Vec<f64>> {
            res.planes.iter().map(|p| f(p).clone()).collect()
        };
        for (suffix, planes, unit) in [
            ("re", collect(|p| &p.re), "JY"),
            ("im", collect(|p| &p.im), "JY"),
            ("wsum", collect(|p| &p.wsum), "WEIGHT"),
        ] {
            let path = format!("{prefix}_{suffix}.fits");
            hegrid::sky::fits::write_fits_cube(
                Path::new(&path),
                uv.n_u,
                uv.n_v,
                &planes,
                uv.cell_wavelengths,
                unit,
            )?;
        }
        println!("wrote {prefix}_re/im/wsum.fits NAXIS3 cubes");
    }
    Ok(())
}

fn cmd_simulate(args: &cli::Args) -> Result<()> {
    let preset = args.get_or("preset", "quick");
    let mut cfg = match preset {
        "quick" => SimConfig::quick_preset(),
        "simulated" => SimConfig::simulated(args.get_usize("points", 150_000)?),
        "observed" => SimConfig::observed(args.get_usize("channels", 50)?),
        "extended" => SimConfig::extended(
            args.get_f64("field", 5.0)?,
            args.get_f64("beam", 180.0)?,
            args.get_usize("points", 15_000)?,
        ),
        other => return Err(HegridError::Config(format!("unknown preset '{other}'"))),
    };
    if let Some(ch) = args.get("channels") {
        if preset != "observed" {
            cfg.channels = ch.parse().map_err(|_| HegridError::Config("bad --channels".into()))?;
        }
    }
    cfg.seed = args.get_usize("seed", cfg.seed as usize)? as u64;
    let out = PathBuf::from(args.get("out").unwrap_or("dataset.hgd"));
    let (dataset, dt) = hegrid::logging::timed(|| cfg.generate());
    dataset.save(&out)?;
    println!(
        "wrote {}: {} samples × {} channels ({:.1} MB) in {:.2}s",
        out.display(),
        dataset.n_samples(),
        dataset.n_channels(),
        dataset.nbytes() as f64 / 1e6,
        dt.as_secs_f64()
    );
    Ok(())
}

fn load_input(args: &cli::Args) -> Result<Dataset> {
    let input = args
        .get("input")
        .ok_or_else(|| HegridError::Config("--input <file.hgd> is required".into()))?;
    Dataset::load(Path::new(input))
}

fn cmd_grid(args: &cli::Args) -> Result<()> {
    let streaming = args.flag("streaming");
    let cfg = engine_config(args)?;
    if cfg.shard_procs > 0 {
        return cmd_grid_supervised(args, &cfg);
    }
    let engine = HegridEngine::new(cfg)?;
    let (maps, report, n_samples): (_, PipelineReport, usize) = if streaming {
        let input = args
            .get("input")
            .ok_or_else(|| HegridError::Config("--input <file.hgd> is required".into()))?;
        let source = HgdStreamSource::open(Path::new(input))?;
        let job = GriddingJob::for_source(&source, &engine.config)?;
        let n = source.n_samples();
        let (maps, report) = engine.grid_source(&source, &job)?;
        (maps, report, n)
    } else {
        let dataset = load_input(args)?;
        let n = dataset.n_samples();
        let (maps, report) = engine.grid_dataset(&dataset)?;
        (maps, report, n)
    };
    println!(
        "gridded {} channels × {} samples onto {} cells in {:.3}s",
        maps.len(),
        n_samples,
        maps[0].spec.n_cells(),
        report.wall.as_secs_f64()
    );
    println!(
        "  variant={} streams={} pipelines={} groups={} shards={} dispatches={}",
        report.variant,
        report.n_streams,
        report.n_pipelines,
        report.n_groups,
        report.n_shards,
        report.dispatches
    );
    for (stage, d, count) in report.stages.stages() {
        println!("  {stage:<22} {:>9.3}s  ×{count}", d.as_secs_f64());
    }
    println!(
        "  shared_builds={} overflow_groups={} adjacent_reuse={:.3} pool={}+{}",
        report.shared_builds,
        report.overflow_groups,
        report.adjacent_reuse,
        report.pool_alloc,
        report.pool_reused
    );
    println!(
        "  ingest: mode={} prefetch_depth={} io_workers={} io_busy={:.3}s \
         io/compute overlap={:.3}s",
        if streaming { "streaming" } else { "in-memory" },
        report.prefetch_depth,
        report.io_workers,
        report.io_busy_s,
        report.io_overlap_s
    );
    if report.tile_rows > 0 {
        println!(
            "  tiled: rows={} bands={} spill={:.1}MB merge={:.3}s skipped_groups={}",
            report.tile_rows,
            report.tile_bands,
            report.tile_spill_bytes as f64 / 1e6,
            report.tile_merge_s,
            report.groups_skipped
        );
    }
    {
        use hegrid::coordinator::PipeStage;
        let occ: Vec<String> = PipeStage::ALL
            .iter()
            .map(|s| format!("{}={:.2}", s.name(), report.stage_occupancy(*s)))
            .collect();
        println!(
            "  pipelines: width={} stage occupancy [{}] overlap(T1,T3)={:.3}s overlap(T0,T3)={:.3}s",
            report.n_pipelines,
            occ.join(" "),
            report.stage_overlap_s(PipeStage::T1Permute, PipeStage::T3Kernel),
            report.stage_overlap_s(PipeStage::T0Ingest, PipeStage::T3Kernel)
        );
        if report.width_auto {
            let trace: Vec<String> =
                report.width_trace.iter().map(|&(t, w)| format!("{w}@{t:.2}s")).collect();
            println!(
                "  adaptive width: trace [{}] numa_nodes={}",
                trace.join(" -> "),
                report.numa_nodes
            );
        }
    }
    if report.degradation.is_degraded() {
        println!(
            "  DEGRADED: {} channel group(s) quarantined, {} transient read retr{}",
            report.degradation.quarantined_groups.len(),
            report.degradation.retries,
            if report.degradation.retries == 1 { "y" } else { "ies" }
        );
        for (g, cause) in
            report.degradation.quarantined_groups.iter().zip(&report.degradation.causes)
        {
            println!("    group {g}: {cause}");
        }
    } else if report.degradation.retries > 0 {
        println!(
            "  recovered: {} transient read error(s) absorbed by retries",
            report.degradation.retries
        );
    }
    if let Some(prefix) = args.get("out-prefix") {
        if let Some(parent) = Path::new(prefix).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(HegridError::io(prefix.to_string()))?;
            }
        }
        for (c, map) in maps.iter().enumerate() {
            map.write_pgm(Path::new(&format!("{prefix}_ch{c:03}.pgm")))?;
        }
        println!("wrote {} PGM maps to {prefix}_chNNN.pgm", maps.len());
    }
    Ok(())
}

/// `hegrid grid --shard-procs N --checkpoint DIR`: the supervised
/// multi-process path (docs/distributed.md). The parent never grids; it
/// spawns `shard-worker` children over contiguous row ranges, restarts the
/// ones that die or hang, and concatenates the per-shard cubes.
fn cmd_grid_supervised(args: &cli::Args, cfg: &HegridConfig) -> Result<()> {
    let input = args
        .get("input")
        .ok_or_else(|| HegridError::Config("--input <file.hgd> is required".into()))?;
    let n_samples = HgdReader::open(Path::new(input))?.n_samples();
    let cancel = hegrid::coordinator::CancelFlag::default();
    let (cube, report) =
        hegrid::runtime::supervisor::run_supervised(cfg, Path::new(input), &cancel)?;
    let maps = cube.read_all_maps()?;
    println!(
        "gridded {} channels × {} samples onto {} cells in {:.3}s",
        maps.len(),
        n_samples,
        maps[0].spec.n_cells(),
        report.wall.as_secs_f64()
    );
    println!(
        "  supervised: shard_procs={} groups={} worker_restarts={} quarantined_shards={}",
        cfg.shard_procs,
        report.n_groups,
        report.degradation.worker_restarts,
        report.degradation.quarantined_shards.len()
    );
    for (stage, d, count) in report.stages.stages() {
        println!("  {stage:<22} {:>9.3}s  ×{count}", d.as_secs_f64());
    }
    if report.degradation.is_degraded() {
        println!(
            "  DEGRADED: {} channel group(s) quarantined, {} shard(s) quarantined",
            report.degradation.quarantined_groups.len(),
            report.degradation.quarantined_shards.len()
        );
        for cause in &report.degradation.causes {
            println!("    {cause}");
        }
    }
    if let Some(prefix) = args.get("out-prefix") {
        if let Some(parent) = Path::new(prefix).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(HegridError::io(prefix.to_string()))?;
            }
        }
        for (c, map) in maps.iter().enumerate() {
            map.write_pgm(Path::new(&format!("{prefix}_ch{c:03}.pgm")))?;
        }
        println!("wrote {} PGM maps to {prefix}_chNNN.pgm", maps.len());
    }
    Ok(())
}

/// `hegrid shard-worker`: internal — the child process body spawned by the
/// supervisor. Not part of the user-facing CLI surface; the flag spelling
/// is owned by [`hegrid::runtime::supervisor::monitor`].
fn cmd_shard_worker(args: &cli::Args) -> Result<()> {
    let input = args
        .get("input")
        .ok_or_else(|| HegridError::Config("shard-worker: --input is required".into()))?
        .to_string();
    let config = args
        .get("config")
        .ok_or_else(|| HegridError::Config("shard-worker: --config is required".into()))?
        .to_string();
    let shard = args.get_usize("shard-index", usize::MAX)?;
    let rows = args
        .get("shard-rows")
        .ok_or_else(|| HegridError::Config("shard-worker: --shard-rows lo:hi is required".into()))?;
    let (lo, hi) = rows
        .split_once(':')
        .and_then(|(a, b)| Some((a.parse::<usize>().ok()?, b.parse::<usize>().ok()?)))
        .ok_or_else(|| {
            HegridError::Config(format!("shard-worker: bad --shard-rows '{rows}' (want lo:hi)"))
        })?;
    if shard == usize::MAX {
        return Err(HegridError::Config("shard-worker: --shard-index is required".into()));
    }
    let attempt = args.get_usize("shard-attempt", 0)?;
    let cfg = HegridConfig::load(Path::new(&config))?;
    hegrid::runtime::supervisor::run_shard_worker(cfg, Path::new(&input), shard, (lo, hi), attempt)
}

fn cmd_inspect(args: &cli::Args) -> Result<()> {
    let input = args
        .get("input")
        .ok_or_else(|| HegridError::Config("--input <file.hgd> is required".into()))?;
    let r = HgdReader::open(Path::new(input))?;
    let m = r.meta();
    println!("{input}:");
    println!("  name         {}", m.name);
    println!("  samples      {}", r.n_samples());
    println!("  channels     {}", r.n_channels());
    println!("  beam         {}\"", m.beam_arcsec);
    println!("  center       ({}°, {}°)", m.center_deg.0, m.center_deg.1);
    println!("  extent       {}° × {}°", m.extent_deg.0, m.extent_deg.1);
    Ok(())
}

fn cmd_accuracy(args: &cli::Args) -> Result<()> {
    let dataset = load_input(args)?;
    let cfg = engine_config(args)?;
    let job = GriddingJob::for_dataset(&dataset, &cfg)?;
    let cpu_block = cfg.cpu_channel_block;
    let simd = cfg.simd();
    let engine = HegridEngine::new(cfg)?;
    let (he_maps, report) = engine.grid(&dataset, &job)?;
    let (cy_maps, cy_time) = CygridBaseline::new(hegrid::util::threads::default_parallelism())
        .with_channel_block(cpu_block)
        .with_simd(simd)
        .run(&dataset, &job)?;
    println!(
        "HEGrid {:.3}s vs Cygrid {:.3}s (speedup {:.2}x)",
        report.wall.as_secs_f64(),
        cy_time.as_secs_f64(),
        cy_time.as_secs_f64() / report.wall.as_secs_f64()
    );
    let mut worst_rms = 0.0f64;
    let mut worst_max = 0.0f64;
    for (c, (a, b)) in he_maps.iter().zip(&cy_maps).enumerate() {
        let d = a.diff_stats(b)?;
        worst_rms = worst_rms.max(d.rms);
        worst_max = worst_max.max(d.max_abs);
        if c < 3 {
            println!(
                "  ch{c}: compared={} max|Δ|={:.3e} rms={:.3e} onlyHE={} onlyCy={}",
                d.compared, d.max_abs, d.rms, d.only_a, d.only_b
            );
        }
    }
    println!("worst over {} channels: max|Δ|={worst_max:.3e} rms={worst_rms:.3e}", he_maps.len());
    if let Some(prefix) = args.get("out-prefix") {
        he_maps[0].write_pgm(Path::new(&format!("{prefix}_hegrid.pgm")))?;
        cy_maps[0].write_pgm(Path::new(&format!("{prefix}_cygrid.pgm")))?;
        println!("wrote {prefix}_hegrid.pgm / {prefix}_cygrid.pgm");
    }
    Ok(())
}

fn cmd_bench_gate(args: &cli::Args) -> Result<()> {
    use hegrid::benchkit::gate::{gate_files, GateOutcome, DEFAULT_THRESHOLD};
    let current = args
        .get("current")
        .ok_or_else(|| HegridError::Config("--current <BENCH_*.json> is required".into()))?
        .to_string();
    let baseline = args.get_or("baseline", "baseline/BENCH_cpu_gridding.json").to_string();
    let threshold = args.get_f64("threshold", DEFAULT_THRESHOLD)?;
    if !(0.0..1.0).contains(&threshold) {
        return Err(HegridError::Config(format!("--threshold {threshold} out of range [0, 1)")));
    }
    match gate_files(Path::new(&baseline), Path::new(&current), threshold)? {
        GateOutcome::NoBaseline | GateOutcome::Passed => Ok(()),
        GateOutcome::Failed => Err(HegridError::Config(format!(
            "bench-gate: throughput regressed more than {:.0}% vs {baseline}",
            threshold * 100.0
        ))),
    }
}

fn cmd_info(args: &cli::Args) -> Result<()> {
    let dir = engine_config(args)?.artifacts_dir;
    let manifest = Manifest::load(Path::new(&dir))?;
    println!("{} variants in {dir}:", manifest.variants.len());
    for v in &manifest.variants {
        println!(
            "  {:<45} m={:<5} bm={:<5} k={:<4} c={:<3} n={:<7} γ={} tags={:?}",
            v.name, v.m, v.bm, v.k, v.c, v.n, v.gamma, v.tags
        );
    }
    Ok(())
}
