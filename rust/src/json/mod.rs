//! Minimal JSON substrate (no `serde` in the offline crate set).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py` and
//! serialises configs/bench reports. Supports the full JSON grammar except
//! `\u` surrogate pairs are folded to the replacement character.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{HegridError, Result};

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- accessors ------------------------------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Typed field helpers that produce good error messages.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| HegridError::Format(format!("missing JSON field '{key}'")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| HegridError::Format(format!("field '{key}' is not a string")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| HegridError::Format(format!("field '{key}' is not a non-negative integer")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| HegridError::Format(format!("field '{key}' is not a number")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| HegridError::Format(format!("field '{key}' is not an array")))
    }

    // ----- construction helpers -------------------------------------------

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ----- serialisation ---------------------------------------------------

    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialisation with 2-space indents.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 9.0e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{n}");
        }
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Rejects trailing garbage.
pub fn parse(input: &str) -> Result<Json> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters after JSON value"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> HegridError {
        HegridError::Json { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected '{word}')")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid UTF-8 in string"))?;
                    // Invariant: `peek()` returned Some, so the remainder is
                    // non-empty and holds at least one code point.
                    let ch = rest.chars().next().expect("peeked byte implies a code point");
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // Invariant: the scanned slice contains only ASCII (`-0..9.eE+`).
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .expect("number slice is ASCII by construction");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.req_arr("a").unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn error_carries_offset() {
        match parse("[1, !]").unwrap_err() {
            HegridError::Json { offset, .. } => assert_eq!(offset, 4),
            other => panic!("wrong error {other}"),
        }
    }

    #[test]
    fn round_trip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"neg":-3}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_serialise_without_decimal_point() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"n": 7, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 7);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!((v.req_f64("f").unwrap() - 1.5).abs() < 1e-12);
        assert!(v.req_usize("f").is_err());
        assert!(v.req_str("missing").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = parse(&text).unwrap();
            assert!(v.req_arr("variants").unwrap().len() >= 15);
        }
    }
}
