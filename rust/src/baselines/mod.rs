//! Baseline gridding frameworks the paper compares against (Tables 3 & 4).
//!
//! * [`CygridBaseline`] — a faithful stand-in for Cygrid (Winkel et al.
//!   2016): multi-core **CPU-only** gather gridding over a HEALPix LUT, all
//!   channels accumulated in one sweep. `Cygrid-16` / `Cygrid-32` in Table 4
//!   are thread-count settings.
//! * [`HcgridBaseline`] — a stand-in for HCGrid (Wang et al. 2021), the
//!   authors' earlier CPU–GPU prototype: the same heterogeneous runtime as
//!   HEGrid but **one channel per dispatch, one pipeline, one stream, and no
//!   shared pre-processing** — per-channel LUT rebuild and re-upload. The gap
//!   between HCGrid and HEGrid isolates exactly what the paper contributes.

use std::time::{Duration, Instant};

use crate::config::HegridConfig;
use crate::coordinator::{GriddingJob, HegridEngine, PipelineReport};
use crate::data::Dataset;
use crate::grid::cpu::CpuGridder;
use crate::grid::prep::SharedComponent;
use crate::sky::SkyMap;
use crate::util::error::Result;

/// Cygrid stand-in: CPU-only, multi-threaded, single-pass multi-channel.
#[derive(Clone, Debug)]
pub struct CygridBaseline {
    pub threads: usize,
    /// Channel-block width forwarded to the CPU gridder (0 = default).
    pub channel_block: usize,
    /// SIMD ISA forwarded to the CPU gridder (default: auto dispatch).
    pub simd: crate::grid::simd::SimdIsa,
}

impl CygridBaseline {
    pub fn new(threads: usize) -> Self {
        CygridBaseline {
            threads: threads.max(1),
            channel_block: 0,
            simd: crate::grid::simd::SimdIsa::Auto,
        }
    }

    pub fn with_channel_block(mut self, block: usize) -> Self {
        self.channel_block = block;
        self
    }

    pub fn with_simd(mut self, isa: crate::grid::simd::SimdIsa) -> Self {
        self.simd = isa;
        self
    }

    /// Grid all channels; returns the maps and the wall time.
    pub fn run(&self, dataset: &Dataset, job: &GriddingJob) -> Result<(Vec<SkyMap>, Duration)> {
        let t0 = Instant::now();
        let shared = SharedComponent::build(
            &dataset.lons,
            &dataset.lats,
            job.kernel.support.max(1e-9),
            self.threads,
        )?;
        let maps = CpuGridder::new(job.spec.clone(), job.kernel.clone())
            .with_workers(self.threads)
            .with_channel_block(self.channel_block)
            .with_simd(self.simd)
            .grid_with_shared(&shared, &dataset.channels);
        Ok((maps, t0.elapsed()))
    }
}

/// HCGrid stand-in: heterogeneous but single-channel, serial pipelines,
/// no shared component.
pub struct HcgridBaseline {
    engine: HegridEngine,
}

impl HcgridBaseline {
    /// Build from a base config; concurrency and sharing are forced off and
    /// dispatches are single-channel, as in HCGrid.
    pub fn new(base: &HegridConfig) -> Result<Self> {
        let mut cfg = base.clone();
        cfg.streams = 1;
        cfg.pipelines = 1;
        cfg.pipeline_width = 1; // sequential: one group in flight, ever
        cfg.channels_per_dispatch = 1;
        cfg.share_preprocessing = false;
        cfg.gamma = 1;
        Ok(HcgridBaseline { engine: HegridEngine::new(cfg)? })
    }

    pub fn run(&self, dataset: &Dataset, job: &GriddingJob) -> Result<(Vec<SkyMap>, PipelineReport)> {
        self.engine.grid(dataset, job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;

    #[test]
    fn cygrid_threads_do_not_change_numerics() {
        let d = SimConfig::quick_preset().generate().take_channels(2);
        let cfg = HegridConfig::default();
        let job = GriddingJob::for_dataset(&d, &cfg).unwrap();
        let (a, _) = CygridBaseline::new(1).run(&d, &job).unwrap();
        let (b, _) = CygridBaseline::new(8).run(&d, &job).unwrap();
        for (ma, mb) in a.iter().zip(&b) {
            let stats = ma.diff_stats(mb).unwrap();
            assert_eq!(stats.max_abs, 0.0);
            assert_eq!(stats.only_a + stats.only_b, 0);
        }
    }

    #[test]
    fn hcgrid_config_is_locked_down() {
        // Construction requires artifacts; only validate config shaping here.
        let mut base = HegridConfig::default();
        base.streams = 8;
        base.channels_per_dispatch = 10;
        base.share_preprocessing = true;
        // Mirror the overrides applied in `new` without building the engine.
        let mut cfg = base.clone();
        cfg.streams = 1;
        cfg.pipelines = 1;
        cfg.pipeline_width = 1;
        cfg.channels_per_dispatch = 1;
        cfg.share_preprocessing = false;
        assert_eq!(cfg.effective_streams(), 1);
        assert_eq!(cfg.effective_pipelines(), 1);
        assert!(!cfg.share_preprocessing);
    }
}
