//! The HTTP server: accept loop, request routing, worker threads, and the
//! graceful-drain lifecycle.
//!
//! Thread model: one accept loop (non-blocking, polling the shutdown
//! flags), one short-lived thread per connection (the API is one request
//! per connection), and `service_workers` long-lived worker threads that
//! claim jobs from the [`JobQueue`] and run them on per-job
//! [`HegridEngine`]s. Every job's pipeline sweeps land on the one
//! process-global persistent executor, so job-level concurrency
//! time-shares the same parked worker pool a single CLI run uses — and a
//! job's output is byte-identical to the equivalent one-shot run, because
//! it *is* the same code path (`grid_source` / `grid`) under a per-job
//! config and engine.
//!
//! Shutdown: SIGTERM/SIGINT (or [`ServiceHandle::join`] in-process) stops
//! the accept loop, marks the queue draining (submits 503, queued jobs
//! still run), waits up to `service_drain_s` for the queue to go idle,
//! then trips every remaining job's cancel flag and joins the workers.
//! The process exits 0 on a drained *or* a timed-out-and-cancelled stop —
//! an operator's `systemctl stop` is not an error.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::HegridConfig;
use crate::coordinator::{CancelFlag, GriddingJob, HegridEngine, PipeStage, PipelineReport};
use crate::data::{Dataset, HgdStreamSource};
use crate::json::Json;
use crate::service::cache::PlanCache;
use crate::service::http::{Request, Response};
use crate::service::metrics::ServiceMetrics;
use crate::service::queue::{Cancelled, JobOutcome, JobQueue, JobResult, JobSpec, Submitted};
use crate::service::ServiceConfig;
use crate::sky::SkyMap;
use crate::util::error::{HegridError, Result};

/// Everything the connection handlers and workers share.
struct ServiceState {
    base: HegridConfig,
    scfg: ServiceConfig,
    queue: JobQueue,
    cache: Arc<PlanCache>,
    metrics: ServiceMetrics,
    started: Instant,
    /// In-process stop request ([`ServiceHandle`]); SIGTERM sets the
    /// process-global flag instead.
    shutdown: AtomicBool,
}

impl ServiceState {
    fn new(base: HegridConfig, scfg: ServiceConfig) -> ServiceState {
        ServiceState {
            queue: JobQueue::new(scfg.service_queue_max, scfg.service_keep_results),
            cache: Arc::new(PlanCache::new(scfg.service_cache_cap)),
            metrics: ServiceMetrics::new(),
            started: Instant::now(),
            shutdown: AtomicBool::new(false),
            base,
            scfg,
        }
    }

    /// Seconds on the server clock (job timestamps, uptime).
    fn now_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    fn draining(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || GLOBAL_SHUTDOWN.load(Ordering::SeqCst)
    }
}

/// SIGTERM/SIGINT land here; the accept loop polls it.
static GLOBAL_SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Install the termination handlers. Raw C-library `signal` declared
/// directly (the same no-libc-crate pattern as `util::threads`'
/// `sched_setaffinity`): the handler only stores to an atomic, which is
/// async-signal-safe.
#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        GLOBAL_SHUTDOWN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_signal);
        signal(SIGINT, on_signal);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// Run the server on the current thread until SIGTERM/SIGINT, then drain
/// (`hegrid serve`). Exits `Ok` after a graceful drain *or* a
/// drain-timeout cancellation.
pub fn serve(base: HegridConfig, scfg: ServiceConfig) -> Result<()> {
    let (state, listener) = setup(base, scfg)?;
    install_signal_handlers();
    let addr = listener.local_addr().map_err(HegridError::io("reading listen address"))?;
    println!(
        "hegrid serve: listening on {addr} (workers={}, queue_max={}, cache_cap={})",
        state.scfg.service_workers, state.scfg.service_queue_max, state.scfg.service_cache_cap
    );
    run(state, listener)
}

/// Shared construction + policy checks for [`serve`] and [`ServiceHandle::spawn`].
fn setup(base: HegridConfig, scfg: ServiceConfig) -> Result<(Arc<ServiceState>, TcpListener)> {
    scfg.validate()?;
    base.validate()?;
    if !base.faults.is_empty() {
        return Err(HegridError::Config(
            "`faults` is process-global and cannot be enabled on a multi-tenant server".into(),
        ));
    }
    let listener = TcpListener::bind(&scfg.service_listen)
        .map_err(HegridError::io(format!("binding {}", scfg.service_listen)))?;
    Ok((Arc::new(ServiceState::new(base, scfg)), listener))
}

/// An in-process server for integration tests: bound (use port 0 for an
/// ephemeral port), accept loop + workers on background threads.
pub struct ServiceHandle {
    addr: SocketAddr,
    state: Arc<ServiceState>,
    thread: Option<std::thread::JoinHandle<Result<()>>>,
}

impl ServiceHandle {
    /// Bind and start serving in the background. No signal handlers are
    /// installed — stop it with [`ServiceHandle::join`] (or drop).
    pub fn spawn(base: HegridConfig, scfg: ServiceConfig) -> Result<ServiceHandle> {
        let (state, listener) = setup(base, scfg)?;
        let addr = listener.local_addr().map_err(HegridError::io("reading listen address"))?;
        let run_state = Arc::clone(&state);
        let thread = std::thread::spawn(move || run(run_state, listener));
        Ok(ServiceHandle { addr, state, thread: Some(thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request the drain (the accept loop notices within one poll tick).
    pub fn begin_shutdown(&self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
    }

    /// Drain and stop the server, returning its exit result.
    pub fn join(mut self) -> Result<()> {
        self.begin_shutdown();
        match self.thread.take().expect("join called once").join() {
            Ok(r) => r,
            Err(_) => Err(HegridError::Internal("server thread panicked".into())),
        }
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        if let Some(thread) = self.thread.take() {
            self.begin_shutdown();
            let _ = thread.join();
        }
    }
}

/// Accept loop + workers + drain. The server's main body.
fn run(state: Arc<ServiceState>, listener: TcpListener) -> Result<()> {
    listener
        .set_nonblocking(true)
        .map_err(HegridError::io("setting the listener non-blocking"))?;
    let mut workers = Vec::with_capacity(state.scfg.service_workers);
    for _ in 0..state.scfg.service_workers {
        let st = Arc::clone(&state);
        workers.push(std::thread::spawn(move || worker_loop(&st)));
    }
    while !state.draining() {
        // Job-timeout watchdog, piggybacked on the accept loop: the 25ms
        // idle sleep bounds its granularity, far below the seconds-scale
        // timeouts it enforces. Metric increments happen in the worker
        // when the outcome lands (same as every other terminal counter).
        state.queue.mark_timeouts(state.scfg.service_job_timeout_s, state.now_s());
        match listener.accept() {
            Ok((stream, _peer)) => {
                let st = Arc::clone(&state);
                std::thread::spawn(move || handle_conn(&st, stream));
            }
            // WouldBlock is the idle case; transient accept errors (e.g.
            // ECONNABORTED) just mean that connection is gone.
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
    // ---- graceful drain --------------------------------------------------
    state.queue.shutdown();
    let deadline = Instant::now() + Duration::from_secs(state.scfg.service_drain_s as u64);
    while !state.queue.idle() && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(50));
    }
    if !state.queue.idle() {
        // Budget spent: cancel what is left. Running jobs stop at their
        // next group boundary; queued ones go terminal immediately.
        state.queue.cancel_all(state.now_s());
    }
    for worker in workers {
        let _ = worker.join();
    }
    Ok(())
}

/// One worker: claim → run → report, until the queue drains on shutdown.
/// `run_job` runs under `catch_unwind`: the coordinator already catches
/// per-group sweep panics, but a panic in job *setup* (engine or source
/// construction) must fail that one job, not kill the worker thread and
/// strand the job in `running`.
fn worker_loop(state: &ServiceState) {
    while let Some((id, spec, cancel)) = state.queue.claim(state.now_s()) {
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_job(state, id, &spec, &cancel)
        }))
        .unwrap_or_else(|payload| {
            Err(HegridError::Runtime(format!(
                "job panicked: {}",
                crate::util::threads::panic_message(payload.as_ref())
            )))
        });
        let outcome = match run {
            Ok((result, report)) => {
                state.metrics.record_report(&report);
                let report_json = report_json(&report);
                if report.degradation.is_degraded() {
                    state.metrics.jobs_degraded.fetch_add(1, Ordering::Relaxed);
                    JobOutcome::Degraded { result, report: report_json }
                } else {
                    state.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                    JobOutcome::Done { result, report: report_json }
                }
            }
            Err(HegridError::Cancelled) if state.queue.timed_out(id) => {
                state.metrics.jobs_timeout.fetch_add(1, Ordering::Relaxed);
                JobOutcome::TimedOut
            }
            Err(HegridError::Cancelled) => {
                state.metrics.jobs_cancelled.fetch_add(1, Ordering::Relaxed);
                JobOutcome::Cancelled
            }
            Err(e) => {
                state.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                JobOutcome::Failed { error: e.to_string() }
            }
        };
        state.queue.finish(id, outcome, state.now_s());
    }
}

/// Run one job exactly the way the one-shot CLI would: a fresh engine from
/// the merged config, the same ingest path, the same `GriddingJob`
/// derivation — plus the job's cancel flag and (optionally) the shared
/// plan cache, neither of which changes a single output byte.
fn run_job(
    state: &ServiceState,
    id: u64,
    spec: &JobSpec,
    cancel: &CancelFlag,
) -> Result<(JobResult, PipelineReport)> {
    let cfg = merged_config(&state.base, spec.overrides.as_ref())?;
    if cfg.shard_procs > 0 {
        return run_supervised_job(id, cfg, spec, cancel);
    }
    let mut engine = HegridEngine::new(cfg)?;
    if state.scfg.service_cache_cap > 0 {
        engine = engine.with_plan_cache(Arc::clone(&state.cache));
    }
    let (maps, report) = if spec.streaming {
        let source = HgdStreamSource::open(Path::new(&spec.input))?;
        let job = GriddingJob::for_source(&source, &engine.config)?.with_cancel(cancel.clone());
        engine.grid_source(&source, &job)?
    } else {
        let dataset = Dataset::load(Path::new(&spec.input))?;
        let job = GriddingJob::for_dataset(&dataset, &engine.config)?.with_cancel(cancel.clone());
        engine.grid(&dataset, &job)?
    };
    Ok((encode_result(&maps), report))
}

/// A job whose merged config selects supervised multi-process execution
/// (`shard_procs > 0`, settable per job — the server's base config must
/// carry the checkpoint root). Each job grids under its own
/// `<checkpoint_dir>/job-{id}` subtree so concurrent supervised jobs never
/// share shard state; the per-job `CancelFlag` maps onto the supervisor's
/// kill-all path, so DELETE and the job-timeout watchdog both work.
/// `streaming` is moot here — shard workers always stream their input.
fn run_supervised_job(
    id: u64,
    mut cfg: HegridConfig,
    spec: &JobSpec,
    cancel: &CancelFlag,
) -> Result<(JobResult, PipelineReport)> {
    cfg.checkpoint_dir = Path::new(&cfg.checkpoint_dir)
        .join(format!("job-{id}"))
        .display()
        .to_string();
    let (cube, report) =
        crate::runtime::supervisor::run_supervised(&cfg, Path::new(&spec.input), cancel)?;
    let maps = cube.read_all_maps()?;
    Ok((encode_result(&maps), report))
}

/// Overlay a job's partial config JSON on the server's base config.
/// Unknown fields are ignored (the same semantics as config files); the
/// merged result is fully re-validated.
fn merged_config(base: &HegridConfig, overrides: Option<&Json>) -> Result<HegridConfig> {
    let Some(over) = overrides else {
        return Ok(base.clone());
    };
    let mut obj = match base.to_json() {
        Json::Obj(map) => map,
        _ => return Err(HegridError::Internal("config JSON is not an object".into())),
    };
    let fields = over
        .as_obj()
        .ok_or_else(|| HegridError::Config("job 'config' must be an object".into()))?;
    for (key, value) in fields {
        obj.insert(key.clone(), value.clone());
    }
    let cfg = HegridConfig::from_json(&Json::Obj(obj))?;
    cfg.validate()?;
    Ok(cfg)
}

/// Serialise the output maps: `[n_channels][nlat][nlon]` f64 LE map
/// values, byte-identical to the CLI's maps for the same config.
fn encode_result(maps: &[SkyMap]) -> JobResult {
    let (nlon, nlat) = maps
        .first()
        .map(|m| (m.spec.nlon, m.spec.nlat))
        .unwrap_or((0, 0));
    let mut bytes = Vec::with_capacity(maps.len() * nlon * nlat * 8);
    for map in maps {
        for v in map.values() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    JobResult { n_channels: maps.len(), nlon, nlat, bytes }
}

/// The report summary carried in `GET /jobs/{id}`: run shape, cache
/// reuse, adaptive-width trace, per-stage occupancy, and the full
/// degradation accounting (the DEGRADED state's evidence).
fn report_json(r: &PipelineReport) -> Json {
    let width_trace: Vec<Json> = r
        .width_trace
        .iter()
        .map(|&(t, w)| Json::Arr(vec![Json::num(t), Json::num(w as f64)]))
        .collect();
    let occupancy: Vec<(&str, Json)> = PipeStage::ALL
        .iter()
        .map(|&s| (s.name(), Json::num(r.stage_occupancy(s))))
        .collect();
    Json::obj(vec![
        ("wall_s", Json::num(r.wall.as_secs_f64())),
        ("variant", Json::str(r.variant.clone())),
        ("n_groups", Json::num(r.n_groups as f64)),
        ("n_pipelines", Json::num(r.n_pipelines as f64)),
        ("n_streams", Json::num(r.n_streams as f64)),
        ("shared_builds", Json::num(r.shared_builds as f64)),
        ("plan_cache_hit", Json::Bool(r.plan_cache_hit)),
        ("width_auto", Json::Bool(r.width_auto)),
        ("width_trace", Json::Arr(width_trace)),
        ("numa_nodes", Json::num(r.numa_nodes as f64)),
        ("stage_occupancy", Json::obj(occupancy)),
        (
            "degradation",
            Json::obj(vec![
                ("degraded", Json::Bool(r.degradation.is_degraded())),
                (
                    "groups_skipped",
                    Json::num(r.degradation.quarantined_groups.len() as f64),
                ),
                (
                    "quarantined_groups",
                    Json::Arr(
                        r.degradation
                            .quarantined_groups
                            .iter()
                            .map(|&g| Json::num(g as f64))
                            .collect(),
                    ),
                ),
                ("retries", Json::num(r.degradation.retries as f64)),
                (
                    "quarantined_shards",
                    Json::Arr(
                        r.degradation
                            .quarantined_shards
                            .iter()
                            .map(|&s| Json::num(s as f64))
                            .collect(),
                    ),
                ),
                (
                    "worker_restarts",
                    Json::num(r.degradation.worker_restarts as f64),
                ),
                (
                    "causes",
                    Json::Arr(
                        r.degradation.causes.iter().map(|c| Json::str(c.clone())).collect(),
                    ),
                ),
            ]),
        ),
    ])
}

/// One connection: read one request, route it, answer, close.
fn handle_conn(state: &ServiceState, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_nodelay(true);
    let read_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(read_half);
    let response = match Request::read_from(&mut reader) {
        Ok(None) => return,
        Ok(Some(req)) => route(state, &req),
        Err(e) => Response::error(400, e.to_string()),
    };
    let mut writer = stream;
    let _ = response.write_to(&mut writer);
}

fn route(state: &ServiceState, req: &Request) -> Response {
    let segments = req.segments();
    match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => Response::text(200, "ok\n"),
        ("GET", ["metrics"]) => {
            let (queued, running) = state.queue.counts();
            Response::metrics(state.metrics.encode(
                queued,
                running,
                &state.cache.stats(),
                state.now_s(),
            ))
        }
        ("POST", ["jobs"]) => post_job(state, req),
        ("GET", ["jobs"]) => Response::json(200, &state.queue.list_json()),
        ("GET", ["jobs", id]) => match parse_id(id) {
            None => Response::error(400, "job id must be an integer"),
            Some(id) => match state.queue.status_json(id) {
                Some(status) => Response::json(200, &status),
                None => Response::error(404, format!("no job {id}")),
            },
        },
        ("GET", ["jobs", id, "result"]) => match parse_id(id) {
            None => Response::error(400, "job id must be an integer"),
            Some(id) => get_result(state, id),
        },
        ("DELETE", ["jobs", id]) => match parse_id(id) {
            None => Response::error(400, "job id must be an integer"),
            Some(id) => delete_job(state, id),
        },
        (_, ["healthz" | "metrics"]) | (_, ["jobs", ..]) => {
            Response::error(405, format!("method {} not allowed here", req.method))
        }
        _ => Response::error(404, format!("no such endpoint: {}", req.path)),
    }
}

fn parse_id(raw: &str) -> Option<u64> {
    raw.parse().ok()
}

fn post_job(state: &ServiceState, req: &Request) -> Response {
    if state.draining() {
        return Response::error(503, "server is draining");
    }
    let spec = match req.json().and_then(|v| JobSpec::from_json(&v)) {
        Ok(s) => s,
        Err(e) => return Response::error(400, e.to_string()),
    };
    // Pre-validate the merged config so a bad override is a 400 at submit
    // time, not a failed job later.
    if let Err(e) = merged_config(&state.base, spec.overrides.as_ref()) {
        return Response::error(400, e.to_string());
    }
    match state.queue.submit(spec, state.now_s()) {
        Ok(Submitted::Accepted(id)) => {
            state.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
            Response::json(
                201,
                &Json::obj(vec![
                    ("id", Json::num(id as f64)),
                    ("state", Json::str("queued")),
                ]),
            )
        }
        Ok(Submitted::QueueFull { depth, max }) => {
            state.metrics.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            // Scale the retry hint with how much work is already waiting:
            // depth × the recent mean job wall time (see
            // `ServiceMetrics::retry_after_s`), so clients back off harder
            // on a deep queue of slow jobs than a deep queue of quick ones.
            Response::error(429, format!("queue full: {depth} of {max} slots taken"))
                .with_header("Retry-After", state.metrics.retry_after_s(depth).to_string())
        }
        Err(e) => Response::error(503, e.to_string()),
    }
}

fn get_result(state: &ServiceState, id: u64) -> Response {
    match state.queue.result(id) {
        Ok(None) => Response::error(404, format!("no job {id}")),
        Err(status) => Response::error(
            409,
            format!("job {id} is {status}; no result cube is available"),
        ),
        Ok(Some(res)) => Response::bytes(200, res.bytes.clone())
            .with_header("X-Hegrid-Channels", res.n_channels.to_string())
            .with_header("X-Hegrid-Nlon", res.nlon.to_string())
            .with_header("X-Hegrid-Nlat", res.nlat.to_string())
            // FITS-style cube geometry (NAXIS1 fastest): lets clients
            // reshape the f64 payload without re-deriving it from the job
            // config, and mirrors the NAXIS3 cube writer's axis order.
            .with_header("X-Hegrid-Naxis1", res.nlon.to_string())
            .with_header("X-Hegrid-Naxis2", res.nlat.to_string())
            .with_header("X-Hegrid-Naxis3", res.n_channels.to_string()),
    }
}

fn delete_job(state: &ServiceState, id: u64) -> Response {
    match state.queue.cancel(id, state.now_s()) {
        Cancelled::NotFound => Response::error(404, format!("no job {id}")),
        Cancelled::Dequeued => Response::json(
            200,
            &Json::obj(vec![("id", Json::num(id as f64)), ("state", Json::str("cancelled"))]),
        ),
        Cancelled::Signalled => Response::json(
            202,
            &Json::obj(vec![("id", Json::num(id as f64)), ("state", Json::str("cancelling"))]),
        ),
        Cancelled::AlreadyTerminal => {
            Response::error(409, format!("job {id} already finished"))
        }
    }
}
