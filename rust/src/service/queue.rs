//! The bounded job queue and registry: admission control, the job state
//! machine, and cancellation.
//!
//! ```text
//!                    DELETE (queued)
//!            ┌──────────────────────────────► cancelled
//!            │                                    ▲
//!  POST ─► queued ──claim──► running ─────────────┤ DELETE (running,
//!   │                          │                  │  at the next group
//!   429 (queue full)           ├───► done         │  boundary)
//!                              ├───► degraded ────┘
//!                              ├───► failed
//!                              └───► timeout  (service_job_timeout_s
//!                                              exceeded; same cancel-flag
//!                                              mechanism, distinct state)
//! ```
//!
//! `queued → running` is a worker claiming the head of the FIFO;
//! everything after `running` is terminal. Admission control rejects a
//! submit once `service_queue_max` jobs are already queued (running jobs
//! don't count — the queue bounds *waiting* work, worker count bounds
//! running work). Terminal jobs are retained newest-first up to
//! `service_keep_results`, then evicted entirely (their id returns 404).
//!
//! All times are f64 seconds on the server's monotonic clock, passed in by
//! the caller so tests can drive the clock.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::CancelFlag;
use crate::json::Json;
use crate::util::error::{HegridError, Result};

/// The job state machine. Terminal states: `Done`, `Degraded`, `Failed`,
/// `Cancelled`, `TimedOut`. `Degraded` is a *successful* run that
/// quarantined channel groups — the result cube exists (quarantined planes
/// zeroed) and the status JSON carries the `DegradationReport`. `TimedOut`
/// is a cancellation the *server's* watchdog initiated because the run
/// exceeded `service_job_timeout_s` — kept distinct from `Cancelled` so
/// clients can tell "I asked for this" from "the server gave up on me".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Degraded,
    Failed,
    Cancelled,
    TimedOut,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Degraded => "degraded",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
            JobState::TimedOut => "timeout",
        }
    }

    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }
}

/// A validated `POST /jobs` body.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Path to the input HGD file, as visible to the server process.
    pub input: String,
    /// Streaming (prefetched, bounded-memory) ingest vs eager load.
    pub streaming: bool,
    /// Free-form client label, echoed in status responses.
    pub tag: String,
    /// Partial `HegridConfig` JSON merged over the server's base config.
    pub overrides: Option<Json>,
}

/// Config fields a job may not override: `faults` installs a
/// process-global fault plan (it would cross-contaminate concurrent
/// tenants), and checkpoint/resume bind a run to an on-disk directory two
/// concurrent jobs would corrupt. Tiled output still works per job via
/// `output_tile_rows` (anonymous spill).
const FORBIDDEN_OVERRIDES: [&str; 3] = ["faults", "checkpoint_dir", "resume"];

impl JobSpec {
    pub fn from_json(v: &Json) -> Result<JobSpec> {
        let obj = v
            .as_obj()
            .ok_or_else(|| HegridError::Config("job spec must be a JSON object".into()))?;
        for key in obj.keys() {
            if !matches!(key.as_str(), "input" | "streaming" | "tag" | "config") {
                return Err(HegridError::Config(format!("unknown job-spec field '{key}'")));
            }
        }
        let input = v.req_str("input")?.to_string();
        if input.is_empty() {
            return Err(HegridError::Config("job-spec 'input' must not be empty".into()));
        }
        let streaming = match v.get("streaming") {
            None => true,
            Some(b) => b
                .as_bool()
                .ok_or_else(|| HegridError::Config("job-spec 'streaming' must be a bool".into()))?,
        };
        let tag = match v.get("tag") {
            None => String::new(),
            Some(t) => t
                .as_str()
                .ok_or_else(|| HegridError::Config("job-spec 'tag' must be a string".into()))?
                .to_string(),
        };
        let overrides = match v.get("config") {
            None => None,
            Some(c) => {
                let fields = c.as_obj().ok_or_else(|| {
                    HegridError::Config("job-spec 'config' must be an object".into())
                })?;
                for banned in FORBIDDEN_OVERRIDES {
                    if fields.contains_key(banned) {
                        return Err(HegridError::Config(format!(
                            "config field '{banned}' cannot be set per job (see docs/service.md)"
                        )));
                    }
                }
                Some(c.clone())
            }
        };
        Ok(JobSpec { input, streaming, tag, overrides })
    }
}

/// A finished job's output cube: per-channel map values, row-major
/// `[n_channels][nlat][nlon]` f64 little-endian — byte-identical to the
/// maps the one-shot CLI produces for the same config.
#[derive(Debug)]
pub struct JobResult {
    pub n_channels: usize,
    pub nlon: usize,
    pub nlat: usize,
    pub bytes: Vec<u8>,
}

/// How a worker reports a finished run back to the queue.
#[derive(Debug)]
pub enum JobOutcome {
    Done { result: JobResult, report: Json },
    /// Run completed with quarantined groups (degrade mode); the report
    /// JSON carries the `DegradationReport` fields.
    Degraded { result: JobResult, report: Json },
    Failed { error: String },
    Cancelled,
    /// The run was stopped by the server's job-timeout watchdog.
    TimedOut,
}

struct JobRecord {
    id: u64,
    spec: JobSpec,
    state: JobState,
    cancel: CancelFlag,
    error: Option<String>,
    result: Option<Arc<JobResult>>,
    report: Option<Json>,
    queued_s: f64,
    started_s: Option<f64>,
    finished_s: Option<f64>,
    /// Set by the timeout watchdog: tells the worker that the `Cancelled`
    /// it is about to observe was really a timeout.
    timed_out: bool,
}

struct QueueState {
    next_id: u64,
    jobs: BTreeMap<u64, JobRecord>,
    pending: VecDeque<u64>,
    running: usize,
    /// Terminal job ids, oldest first — the eviction order.
    finished: VecDeque<u64>,
    shutdown: bool,
}

/// What `submit` decided.
#[derive(Debug)]
pub enum Submitted {
    Accepted(u64),
    /// Admission control: `depth` jobs already queued of `max` allowed.
    QueueFull { depth: usize, max: usize },
}

/// What `cancel` did.
#[derive(Debug, PartialEq, Eq)]
pub enum Cancelled {
    NotFound,
    /// The job was still queued: removed outright, now terminal.
    Dequeued,
    /// The job is running: its flag is tripped; it goes terminal at the
    /// next channel-group boundary.
    Signalled,
    AlreadyTerminal,
}

/// The service's job registry + FIFO. All methods take `now_s` (seconds on
/// the server clock) instead of reading a clock themselves.
pub struct JobQueue {
    queue_max: usize,
    keep_results: usize,
    state: Mutex<QueueState>,
    cond: Condvar,
}

impl JobQueue {
    pub fn new(queue_max: usize, keep_results: usize) -> JobQueue {
        JobQueue {
            queue_max,
            keep_results,
            state: Mutex::new(QueueState {
                next_id: 1,
                jobs: BTreeMap::new(),
                pending: VecDeque::new(),
                running: 0,
                finished: VecDeque::new(),
                shutdown: false,
            }),
            cond: Condvar::new(),
        }
    }

    /// Enqueue a job, or reject it when the queue is full (HTTP 429) or
    /// the server is draining (HTTP 503 via `Err`).
    pub fn submit(&self, spec: JobSpec, now_s: f64) -> Result<Submitted> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err(HegridError::Runtime("server is draining".into()));
        }
        if st.pending.len() >= self.queue_max {
            return Ok(Submitted::QueueFull { depth: st.pending.len(), max: self.queue_max });
        }
        let id = st.next_id;
        st.next_id += 1;
        st.jobs.insert(
            id,
            JobRecord {
                id,
                spec,
                state: JobState::Queued,
                cancel: CancelFlag::armed(),
                error: None,
                result: None,
                report: None,
                queued_s: now_s,
                started_s: None,
                finished_s: None,
                timed_out: false,
            },
        );
        st.pending.push_back(id);
        drop(st);
        self.cond.notify_one();
        Ok(Submitted::Accepted(id))
    }

    /// Block until a job is claimable; `None` once the queue is shut down
    /// *and* drained (workers exit on it). During a drain, still-queued
    /// jobs keep being claimed — that is what "graceful" means here.
    pub fn claim(&self, now_s: f64) -> Option<(u64, JobSpec, CancelFlag)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(id) = st.pending.pop_front() {
                let record = st.jobs.get_mut(&id).expect("pending id has a record");
                record.state = JobState::Running;
                record.started_s = Some(now_s);
                let claim = (id, record.spec.clone(), record.cancel.clone());
                st.running += 1;
                return Some(claim);
            }
            if st.shutdown {
                return None;
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    /// Record a claimed job's outcome and make it terminal.
    pub fn finish(&self, id: u64, outcome: JobOutcome, now_s: f64) {
        let mut st = self.state.lock().unwrap();
        st.running = st.running.saturating_sub(1);
        if let Some(record) = st.jobs.get_mut(&id) {
            record.finished_s = Some(now_s);
            match outcome {
                JobOutcome::Done { result, report } => {
                    record.state = JobState::Done;
                    record.result = Some(Arc::new(result));
                    record.report = Some(report);
                }
                JobOutcome::Degraded { result, report } => {
                    record.state = JobState::Degraded;
                    record.result = Some(Arc::new(result));
                    record.report = Some(report);
                }
                JobOutcome::Failed { error } => {
                    record.state = JobState::Failed;
                    record.error = Some(error);
                }
                JobOutcome::Cancelled => record.state = JobState::Cancelled,
                JobOutcome::TimedOut => record.state = JobState::TimedOut,
            }
            st.finished.push_back(id);
            while st.finished.len() > self.keep_results {
                if let Some(old) = st.finished.pop_front() {
                    st.jobs.remove(&old);
                }
            }
        }
        drop(st);
        // A drain waits on "no queued, no running" — wake its poll loop and
        // any worker blocked in claim().
        self.cond.notify_all();
    }

    /// `DELETE /jobs/{id}`.
    pub fn cancel(&self, id: u64, now_s: f64) -> Cancelled {
        let mut st = self.state.lock().unwrap();
        let Some(record) = st.jobs.get_mut(&id) else {
            return Cancelled::NotFound;
        };
        match record.state {
            JobState::Queued => {
                record.state = JobState::Cancelled;
                record.finished_s = Some(now_s);
                st.pending.retain(|&p| p != id);
                st.finished.push_back(id);
                while st.finished.len() > self.keep_results {
                    if let Some(old) = st.finished.pop_front() {
                        st.jobs.remove(&old);
                    }
                }
                Cancelled::Dequeued
            }
            JobState::Running => {
                record.cancel.cancel();
                Cancelled::Signalled
            }
            _ => Cancelled::AlreadyTerminal,
        }
    }

    /// The job-timeout watchdog: trip the cancel flag of every running job
    /// whose wall time has exceeded `timeout_s`, marking it timed-out so
    /// the worker reports [`JobOutcome::TimedOut`] instead of `Cancelled`.
    /// `timeout_s == 0` disables the watchdog. Returns the ids newly
    /// tripped this call (each job trips exactly once).
    pub fn mark_timeouts(&self, timeout_s: usize, now_s: f64) -> Vec<u64> {
        if timeout_s == 0 {
            return Vec::new();
        }
        let mut st = self.state.lock().unwrap();
        let mut tripped = Vec::new();
        for record in st.jobs.values_mut() {
            if record.state == JobState::Running && !record.timed_out {
                let started = record.started_s.unwrap_or(now_s);
                if now_s - started > timeout_s as f64 {
                    record.timed_out = true;
                    record.cancel.cancel();
                    tripped.push(record.id);
                }
            }
        }
        tripped
    }

    /// Did the watchdog time this job out? (Workers call this when a run
    /// returns `Cancelled` to pick the right terminal state.)
    pub fn timed_out(&self, id: u64) -> bool {
        self.state.lock().unwrap().jobs.get(&id).is_some_and(|r| r.timed_out)
    }

    /// Trip every live job's cancel flag (drain-timeout enforcement).
    pub fn cancel_all(&self, now_s: f64) {
        let ids: Vec<u64> = self.state.lock().unwrap().jobs.keys().copied().collect();
        for id in ids {
            self.cancel(id, now_s);
        }
    }

    /// Stop accepting submits and let `claim` return `None` once drained.
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cond.notify_all();
    }

    /// `(queued, running)` — the live-work gauge pair for `/metrics`.
    pub fn counts(&self) -> (usize, usize) {
        let st = self.state.lock().unwrap();
        (st.pending.len(), st.running)
    }

    /// No queued and no running jobs (drain completion).
    pub fn idle(&self) -> bool {
        let st = self.state.lock().unwrap();
        st.pending.is_empty() && st.running == 0
    }

    /// Status JSON for `GET /jobs/{id}`; `None` → 404.
    pub fn status_json(&self, id: u64) -> Option<Json> {
        let st = self.state.lock().unwrap();
        st.jobs.get(&id).map(record_json)
    }

    /// Summary list for `GET /jobs` (no reports, newest last).
    pub fn list_json(&self) -> Json {
        let st = self.state.lock().unwrap();
        let jobs: Vec<Json> = st
            .jobs
            .values()
            .map(|r| {
                Json::obj(vec![
                    ("id", Json::num(r.id as f64)),
                    ("state", Json::str(r.state.name())),
                    ("tag", Json::str(r.spec.tag.clone())),
                ])
            })
            .collect();
        Json::obj(vec![("jobs", Json::Arr(jobs))])
    }

    /// The result cube for `GET /jobs/{id}/result`; `Err` carries the
    /// non-ready state's name (409) and `Ok(None)` is a 404.
    pub fn result(&self, id: u64) -> std::result::Result<Option<Arc<JobResult>>, &'static str> {
        let st = self.state.lock().unwrap();
        match st.jobs.get(&id) {
            None => Ok(None),
            Some(r) => match (&r.result, r.state) {
                (Some(res), _) => Ok(Some(Arc::clone(res))),
                (None, state) => Err(state.name()),
            },
        }
    }
}

fn record_json(r: &JobRecord) -> Json {
    let opt_s = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
    Json::obj(vec![
        ("id", Json::num(r.id as f64)),
        ("state", Json::str(r.state.name())),
        ("input", Json::str(r.spec.input.clone())),
        ("streaming", Json::Bool(r.spec.streaming)),
        ("tag", Json::str(r.spec.tag.clone())),
        ("queued_s", Json::num(r.queued_s)),
        ("started_s", opt_s(r.started_s)),
        ("finished_s", opt_s(r.finished_s)),
        ("error", r.error.clone().map(Json::str).unwrap_or(Json::Null)),
        (
            "result",
            match &r.result {
                None => Json::Null,
                Some(res) => Json::obj(vec![
                    ("channels", Json::num(res.n_channels as f64)),
                    ("nlon", Json::num(res.nlon as f64)),
                    ("nlat", Json::num(res.nlat as f64)),
                    ("bytes", Json::num(res.bytes.len() as f64)),
                ]),
            },
        ),
        ("report", r.report.clone().unwrap_or(Json::Null)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(tag: &str) -> JobSpec {
        JobSpec { input: "x.hgd".into(), streaming: true, tag: tag.into(), overrides: None }
    }

    fn done_outcome() -> JobOutcome {
        JobOutcome::Done {
            result: JobResult { n_channels: 1, nlon: 2, nlat: 2, bytes: vec![0u8; 32] },
            report: Json::Null,
        }
    }

    #[test]
    fn admission_control_rejects_beyond_queue_max() {
        let q = JobQueue::new(2, 8);
        assert!(matches!(q.submit(spec("a"), 0.0).unwrap(), Submitted::Accepted(1)));
        assert!(matches!(q.submit(spec("b"), 0.0).unwrap(), Submitted::Accepted(2)));
        assert!(matches!(
            q.submit(spec("c"), 0.0).unwrap(),
            Submitted::QueueFull { depth: 2, max: 2 }
        ));
        // Claiming one (queued → running) frees a queue slot: admission
        // bounds waiting work only.
        let (id, _, _) = q.claim(0.1).unwrap();
        assert_eq!(id, 1);
        assert!(matches!(q.submit(spec("d"), 0.2).unwrap(), Submitted::Accepted(3)));
    }

    #[test]
    fn lifecycle_and_status_json() {
        let q = JobQueue::new(4, 8);
        q.submit(spec("t"), 1.0).unwrap();
        let (id, s, _) = q.claim(2.0).unwrap();
        assert_eq!(s.tag, "t");
        assert_eq!(q.counts(), (0, 1));
        q.finish(id, done_outcome(), 3.0);
        assert_eq!(q.counts(), (0, 0));
        assert!(q.idle());
        let status = q.status_json(id).unwrap();
        assert_eq!(status.req_str("state").unwrap(), "done");
        assert_eq!(status.req("result").unwrap().req_usize("bytes").unwrap(), 32);
        assert!(q.result(id).unwrap().is_some());
        assert!(q.status_json(99).is_none());
    }

    #[test]
    fn cancel_queued_dequeues_and_running_signals() {
        let q = JobQueue::new(4, 8);
        q.submit(spec("a"), 0.0).unwrap();
        q.submit(spec("b"), 0.0).unwrap();
        let (a, _, flag_a) = q.claim(0.1).unwrap();
        // b is queued: cancel removes it outright, and the next claim
        // would block (nothing pending).
        assert_eq!(q.cancel(2, 0.2), Cancelled::Dequeued);
        assert_eq!(q.status_json(2).unwrap().req_str("state").unwrap(), "cancelled");
        assert_eq!(q.counts(), (0, 1));
        // a is running: cancel trips its flag; the worker reports back.
        assert!(!flag_a.is_cancelled());
        assert_eq!(q.cancel(a, 0.3), Cancelled::Signalled);
        assert!(flag_a.is_cancelled());
        q.finish(a, JobOutcome::Cancelled, 0.4);
        assert_eq!(q.cancel(a, 0.5), Cancelled::AlreadyTerminal);
        assert_eq!(q.cancel(99, 0.5), Cancelled::NotFound);
    }

    #[test]
    fn keep_results_evicts_oldest_terminal_jobs() {
        let q = JobQueue::new(8, 2);
        for _ in 0..3 {
            let Submitted::Accepted(_) = q.submit(spec(""), 0.0).unwrap() else { panic!() };
            let (id, _, _) = q.claim(0.0).unwrap();
            q.finish(id, done_outcome(), 0.0);
        }
        assert!(q.status_json(1).is_none(), "oldest finished job evicted");
        assert!(q.status_json(2).is_some());
        assert!(q.status_json(3).is_some());
    }

    #[test]
    fn shutdown_drains_then_claim_returns_none() {
        let q = JobQueue::new(8, 8);
        q.submit(spec("a"), 0.0).unwrap();
        q.shutdown();
        assert!(q.submit(spec("b"), 0.0).is_err());
        // The queued job is still claimable during the drain.
        let (id, _, _) = q.claim(0.0).unwrap();
        q.finish(id, done_outcome(), 0.0);
        assert!(q.claim(0.0).is_none());
    }

    #[test]
    fn timeout_watchdog_trips_overdue_running_jobs_once() {
        let q = JobQueue::new(8, 8);
        q.submit(spec("slow"), 0.0).unwrap();
        q.submit(spec("young"), 0.0).unwrap();
        let (slow, _, slow_flag) = q.claim(1.0).unwrap();
        let (young, _, young_flag) = q.claim(9.0).unwrap();
        // Disabled watchdog never fires.
        assert!(q.mark_timeouts(0, 100.0).is_empty());
        // At t=12 only the job started at t=1 has exceeded 10s.
        assert_eq!(q.mark_timeouts(10, 12.0), vec![slow]);
        assert!(slow_flag.is_cancelled());
        assert!(!young_flag.is_cancelled());
        assert!(q.timed_out(slow));
        assert!(!q.timed_out(young));
        // Second sweep does not re-trip the same job.
        assert!(q.mark_timeouts(10, 13.0).is_empty());
        // The worker observes the cancellation and reports a timeout.
        q.finish(slow, JobOutcome::TimedOut, 13.5);
        assert_eq!(q.status_json(slow).unwrap().req_str("state").unwrap(), "timeout");
        q.finish(young, done_outcome(), 14.0);
        assert!(q.idle());
    }

    #[test]
    fn job_spec_validation() {
        let ok = crate::json::parse(r#"{"input": "d.hgd", "streaming": false, "tag": "x"}"#)
            .unwrap();
        let s = JobSpec::from_json(&ok).unwrap();
        assert!(!s.streaming);
        for bad in [
            r#"{}"#,
            r#"{"input": ""}"#,
            r#"{"input": "d.hgd", "bogus": 1}"#,
            r#"{"input": "d.hgd", "config": {"faults": "1:panic@0"}}"#,
            r#"{"input": "d.hgd", "config": {"checkpoint_dir": "/tmp/x"}}"#,
            r#"{"input": "d.hgd", "config": 5}"#,
        ] {
            let v = crate::json::parse(bad).unwrap();
            assert!(JobSpec::from_json(&v).is_err(), "accepted: {bad}");
        }
    }
}
