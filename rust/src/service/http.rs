//! Minimal HTTP/1.1 message parsing and serialisation over `std::io`.
//!
//! Just enough protocol for the job API: one request per connection
//! (`Connection: close` on every response), `Content-Length` bodies only
//! (no chunked transfer), bounded header and body sizes so a misbehaving
//! client cannot balloon server memory. Anything outside those bounds is a
//! parse error the server answers with 400.

use std::io::{BufRead, Read, Write};

use crate::json::Json;
use crate::util::error::{HegridError, Result};

/// Longest accepted request line or header line, bytes.
const MAX_LINE: usize = 8 * 1024;
/// Most accepted header lines.
const MAX_HEADERS: usize = 64;
/// Largest accepted request body, bytes (job specs are small JSON).
const MAX_BODY: usize = 1024 * 1024;

/// A parsed request: method, percent-decoded-free path (taken verbatim),
/// lower-cased header names, raw body bytes.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Read one request from `r`. `Ok(None)` on a clean EOF before any
    /// bytes (client closed an idle connection).
    pub fn read_from(r: &mut impl BufRead) -> Result<Option<Request>> {
        let line = match read_line(r)? {
            None => return Ok(None),
            Some(l) => l,
        };
        let mut parts = line.split_whitespace();
        let method = parts
            .next()
            .ok_or_else(|| HegridError::Format("empty request line".into()))?
            .to_string();
        let target = parts
            .next()
            .ok_or_else(|| HegridError::Format("request line missing target".into()))?;
        let version = parts
            .next()
            .ok_or_else(|| HegridError::Format("request line missing HTTP version".into()))?;
        if !version.starts_with("HTTP/1.") {
            return Err(HegridError::Format(format!("unsupported HTTP version '{version}'")));
        }
        // Strip any query string: the job API routes on the path alone.
        let path = target.split('?').next().unwrap_or(target).to_string();

        let mut headers = Vec::new();
        loop {
            let line = read_line(r)?
                .ok_or_else(|| HegridError::Format("EOF inside request headers".into()))?;
            if line.is_empty() {
                break;
            }
            if headers.len() >= MAX_HEADERS {
                return Err(HegridError::Format("too many request headers".into()));
            }
            let (name, value) = line
                .split_once(':')
                .ok_or_else(|| HegridError::Format(format!("malformed header line '{line}'")))?;
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }

        let content_length = match headers.iter().find(|(n, _)| n == "content-length") {
            None => 0,
            Some((_, v)) => v
                .parse::<usize>()
                .map_err(|_| HegridError::Format(format!("bad Content-Length '{v}'")))?,
        };
        if content_length > MAX_BODY {
            return Err(HegridError::Format(format!(
                "request body of {content_length} bytes exceeds the {MAX_BODY}-byte limit"
            )));
        }
        let mut body = vec![0u8; content_length];
        r.read_exact(&mut body).map_err(HegridError::io("reading request body"))?;
        Ok(Some(Request { method, path, headers, body }))
    }

    /// First value of header `name` (lower-case), if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    /// Path segments with the leading slash stripped: `/jobs/3/result` →
    /// `["jobs", "3", "result"]`.
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Json> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| HegridError::Format("request body is not UTF-8".into()))?;
        crate::json::parse(text)
    }
}

/// Read one CRLF- (or LF-) terminated line, without the terminator.
/// `Ok(None)` on EOF before any byte.
fn read_line(r: &mut impl BufRead) -> Result<Option<String>> {
    let mut buf = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                return Err(HegridError::Format("EOF inside an HTTP line".into()));
            }
            Ok(_) => {}
            Err(e) => return Err(HegridError::io("reading HTTP line")(e)),
        }
        if byte[0] == b'\n' {
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            let line = String::from_utf8(buf)
                .map_err(|_| HegridError::Format("HTTP line is not UTF-8".into()))?;
            return Ok(Some(line));
        }
        if buf.len() >= MAX_LINE {
            return Err(HegridError::Format("HTTP line exceeds the length limit".into()));
        }
        buf.push(byte[0]);
    }
}

/// A response under construction; always sent `Connection: close`.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    content_type: &'static str,
    extra_headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, value: &Json) -> Response {
        // `to_pretty` is newline-terminated already.
        let body = value.to_pretty().into_bytes();
        Response { status, content_type: "application/json", extra_headers: Vec::new(), body }
    }

    /// A JSON error envelope: `{"error": "<message>"}`.
    pub fn error(status: u16, message: impl Into<String>) -> Response {
        Response::json(status, &Json::obj(vec![("error", Json::str(message))]))
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            extra_headers: Vec::new(),
            body: body.into().into_bytes(),
        }
    }

    /// Prometheus text exposition (`GET /metrics`).
    pub fn metrics(body: String) -> Response {
        Response {
            status: 200,
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            extra_headers: Vec::new(),
            body: body.into_bytes(),
        }
    }

    pub fn bytes(status: u16, body: Vec<u8>) -> Response {
        let content_type = "application/octet-stream";
        Response { status, content_type, extra_headers: Vec::new(), body }
    }

    pub fn with_header(mut self, name: impl Into<String>, value: impl Into<String>) -> Response {
        self.extra_headers.push((name.into(), value.into()));
        self
    }

    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len()
        )?;
        for (name, value) in &self.extra_headers {
            write!(w, "{name}: {value}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Reason phrase for the status codes the job API uses.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &[u8]) -> Result<Option<Request>> {
        Request::read_from(&mut BufReader::new(raw))
    }

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 13\r\n\r\n{\"input\":\"a\"}";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/jobs");
        assert_eq!(req.segments(), vec!["jobs"]);
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.json().unwrap().req_str("input").unwrap(), "a");
    }

    #[test]
    fn parses_get_without_body_and_strips_query() {
        let raw = b"GET /jobs/3/result?x=1 HTTP/1.1\r\n\r\n";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.segments(), vec!["jobs", "3", "result"]);
        assert!(req.body.is_empty());
    }

    #[test]
    fn clean_eof_is_none_and_garbage_is_an_error() {
        assert!(parse(b"").unwrap().is_none());
        assert!(parse(b"NOT-HTTP\r\n\r\n").is_err());
        assert!(parse(b"GET / HTTP/1.1\r\nContent-Length: nope\r\n\r\n").is_err());
    }

    #[test]
    fn oversized_body_is_rejected() {
        let raw = format!("POST /jobs HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert!(parse(raw.as_bytes()).is_err());
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        Response::error(429, "queue full")
            .with_header("Retry-After", "1")
            .write_to(&mut out)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("{\n  \"error\": \"queue full\"\n}\n"));
    }
}
