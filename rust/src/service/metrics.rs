//! Prometheus text-format metrics for `GET /metrics`.
//!
//! Exposition format 0.0.4: `# HELP` / `# TYPE` comment pairs followed by
//! `name[{labels}] value` sample lines. Everything here is either a
//! process-lifetime counter (job outcomes, retries, quarantines — atomics
//! bumped by the worker threads) or a gauge snapshotted at scrape time
//! (queue depth, cache occupancy, and the last finished run's pipeline
//! telemetry: per-stage occupancy, peak adaptive width, NUMA node count).
//! The full width trace and span list stay in the job's status JSON
//! (`GET /jobs/{id}` → `report`) — a scrape wants current scalars, not
//! per-run series.

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::{PipeStage, PipelineReport};
use crate::service::cache::CacheStats;

/// Pipeline telemetry of the most recently finished job (gauges).
#[derive(Clone, Debug, Default)]
struct LastRun {
    /// `(stage name, mean concurrent pipelines in the stage)`.
    occupancy: Vec<(&'static str, f64)>,
    width_peak: usize,
    width_changes: usize,
    numa_nodes: usize,
    wall_s: f64,
}

/// Finished-job wall times kept for the 429 `Retry-After` estimate. Small
/// and recent beats large and stale: the queue's drain rate tracks what
/// the server is running *now*.
const WALL_WINDOW: usize = 16;

/// Counters + last-run gauges, shared by workers and the scrape handler.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_rejected: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_degraded: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub jobs_cancelled: AtomicU64,
    pub jobs_timeout: AtomicU64,
    pub retries: AtomicU64,
    pub quarantined_groups: AtomicU64,
    pub shard_restarts: AtomicU64,
    pub shard_quarantined: AtomicU64,
    last: Mutex<LastRun>,
    /// Rolling window of recent finished-job wall seconds (see
    /// [`ServiceMetrics::retry_after_s`]).
    walls: Mutex<VecDeque<f64>>,
}

impl ServiceMetrics {
    pub fn new() -> ServiceMetrics {
        ServiceMetrics::default()
    }

    /// Fold a finished run's report into the counters and last-run gauges.
    pub fn record_report(&self, report: &PipelineReport) {
        self.retries.fetch_add(report.degradation.retries as u64, Ordering::Relaxed);
        self.quarantined_groups
            .fetch_add(report.degradation.quarantined_groups.len() as u64, Ordering::Relaxed);
        self.shard_restarts
            .fetch_add(report.degradation.worker_restarts as u64, Ordering::Relaxed);
        self.shard_quarantined
            .fetch_add(report.degradation.quarantined_shards.len() as u64, Ordering::Relaxed);
        {
            let mut walls = self.walls.lock().unwrap();
            walls.push_back(report.wall.as_secs_f64());
            while walls.len() > WALL_WINDOW {
                walls.pop_front();
            }
        }
        let occupancy = PipeStage::ALL
            .iter()
            .map(|&s| (s.name(), report.stage_occupancy(s)))
            .collect();
        *self.last.lock().unwrap() = LastRun {
            occupancy,
            width_peak: report.width_trace.iter().map(|&(_, w)| w).max().unwrap_or(0),
            width_changes: report.width_trace.len().saturating_sub(1),
            numa_nodes: report.numa_nodes,
            wall_s: report.wall.as_secs_f64(),
        };
    }

    /// The `Retry-After` seconds for a 429: queue depth × the mean wall
    /// time of the recent finished jobs (default 1s before any job has
    /// finished), clamped to `[1, 600]`. A client obeying it comes back
    /// roughly when the backlog ahead of it has drained.
    pub fn retry_after_s(&self, depth: usize) -> u64 {
        let walls = self.walls.lock().unwrap();
        let mean = if walls.is_empty() {
            1.0
        } else {
            walls.iter().sum::<f64>() / walls.len() as f64
        };
        (depth as f64 * mean).ceil().clamp(1.0, 600.0) as u64
    }

    /// Render the full exposition. `queued`/`running` come from the queue,
    /// `cache` from the plan cache, `uptime_s` from the server clock.
    pub fn encode(
        &self,
        queued: usize,
        running: usize,
        cache: &CacheStats,
        uptime_s: f64,
    ) -> String {
        let mut out = String::with_capacity(2048);
        gauge(&mut out, "hegrid_uptime_seconds", "Seconds since the server started.", uptime_s);
        gauge(&mut out, "hegrid_queue_depth", "Jobs queued and not yet running.", queued as f64);
        gauge(&mut out, "hegrid_jobs_running", "Jobs currently running.", running as f64);
        for (name, help, counter) in [
            ("hegrid_jobs_submitted_total", "Jobs accepted by POST /jobs.", &self.jobs_submitted),
            (
                "hegrid_jobs_rejected_total",
                "Jobs rejected by admission control (HTTP 429).",
                &self.jobs_rejected,
            ),
            ("hegrid_jobs_completed_total", "Jobs finished done.", &self.jobs_completed),
            (
                "hegrid_jobs_degraded_total",
                "Jobs finished degraded (quarantined channel groups).",
                &self.jobs_degraded,
            ),
            ("hegrid_jobs_failed_total", "Jobs finished failed.", &self.jobs_failed),
            (
                "hegrid_jobs_cancelled_total",
                "Jobs cancelled by DELETE /jobs/{id}.",
                &self.jobs_cancelled,
            ),
            (
                "hegrid_retries_total",
                "Transient channel-read retries across all runs.",
                &self.retries,
            ),
            (
                "hegrid_jobs_timeout_total",
                "Jobs stopped by the service_job_timeout_s watchdog.",
                &self.jobs_timeout,
            ),
            (
                "hegrid_quarantined_groups_total",
                "Channel groups quarantined across all degrade-mode runs.",
                &self.quarantined_groups,
            ),
            (
                "hegrid_shard_restarts_total",
                "Supervised shard workers restarted after a crash or hang.",
                &self.shard_restarts,
            ),
            (
                "hegrid_shard_quarantined_total",
                "Supervised shards quarantined after exhausting restarts.",
                &self.shard_quarantined,
            ),
        ] {
            counter_line(&mut out, name, help, counter.load(Ordering::Relaxed));
        }
        for (name, help, value) in [
            ("hegrid_plan_cache_hits_total", "Plan-cache hits.", cache.hits),
            ("hegrid_plan_cache_misses_total", "Plan-cache misses (builds).", cache.misses),
            ("hegrid_plan_cache_evictions_total", "Plan-cache LRU evictions.", cache.evictions),
        ] {
            counter_line(&mut out, name, help, value);
        }
        gauge(
            &mut out,
            "hegrid_plan_cache_entries",
            "DispatchPlans currently cached.",
            cache.entries as f64,
        );

        let last = self.last.lock().unwrap().clone();
        header(
            &mut out,
            "hegrid_stage_occupancy",
            "Last run: mean concurrent pipelines per stage (T0..T4 + prep).",
            "gauge",
        );
        for (stage, occ) in &last.occupancy {
            let _ = writeln!(out, "hegrid_stage_occupancy{{stage=\"{stage}\"}} {}", fmt(*occ));
        }
        gauge(
            &mut out,
            "hegrid_pipeline_width_peak",
            "Last run: peak admitted pipeline width.",
            last.width_peak as f64,
        );
        gauge(
            &mut out,
            "hegrid_pipeline_width_changes",
            "Last run: adaptive width changes (0 for fixed width).",
            last.width_changes as f64,
        );
        gauge(
            &mut out,
            "hegrid_numa_nodes",
            "Last run: NUMA nodes detected on the host.",
            last.numa_nodes as f64,
        );
        gauge(
            &mut out,
            "hegrid_last_run_wall_seconds",
            "Last run: end-to-end wall time.",
            last.wall_s,
        );
        out
    }
}

fn header(out: &mut String, name: &str, help: &str, kind: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn gauge(out: &mut String, name: &str, help: &str, value: f64) {
    header(out, name, help, "gauge");
    let _ = writeln!(out, "{name} {}", fmt(value));
}

fn counter_line(out: &mut String, name: &str, help: &str, value: u64) {
    header(out, name, help, "counter");
    let _ = writeln!(out, "{name} {value}");
}

/// Finite decimal rendering (Rust's `f64` Display never emits exponents;
/// NaN/Inf cannot occur — occupancies and wall times are finite).
fn fmt(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every non-comment line must be `name[{labels}] value` — the
    /// well-formedness the CI smoke job also asserts with awk.
    fn assert_well_formed(text: &str) {
        for line in text.lines() {
            if line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("sample line has a value");
            assert!(!name.is_empty() && !value.is_empty(), "malformed: {line}");
            let bare = name.split('{').next().unwrap();
            assert!(
                bare.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name: {line}"
            );
            value.parse::<f64>().unwrap_or_else(|_| panic!("bad value in: {line}"));
        }
    }

    #[test]
    fn encode_is_well_formed_and_carries_counters() {
        let m = ServiceMetrics::new();
        m.jobs_submitted.store(3, Ordering::Relaxed);
        m.jobs_completed.store(2, Ordering::Relaxed);
        let report = PipelineReport {
            numa_nodes: 1,
            width_trace: vec![(0.0, 2), (0.5, 3)],
            wall: std::time::Duration::from_millis(1234),
            ..Default::default()
        };
        m.record_report(&report);
        let cache = CacheStats { hits: 1, misses: 2, evictions: 0, entries: 2 };
        let text = m.encode(4, 1, &cache, 12.5);
        assert_well_formed(&text);
        assert!(text.contains("hegrid_jobs_submitted_total 3\n"));
        assert!(text.contains("hegrid_queue_depth 4\n"));
        assert!(text.contains("hegrid_jobs_running 1\n"));
        assert!(text.contains("hegrid_plan_cache_hits_total 1\n"));
        assert!(text.contains("hegrid_plan_cache_entries 2\n"));
        assert!(text.contains("hegrid_pipeline_width_peak 3\n"));
        assert!(text.contains("hegrid_pipeline_width_changes 1\n"));
        assert!(text.contains("hegrid_stage_occupancy{stage=\"T3\"} "));
        assert!(text.contains("hegrid_uptime_seconds 12.5\n"));
        assert!(text.contains("hegrid_jobs_timeout_total 0\n"));
        assert!(text.contains("hegrid_shard_restarts_total 0\n"));
        assert!(text.contains("hegrid_shard_quarantined_total 0\n"));
    }

    #[test]
    fn retry_after_scales_with_depth_and_recent_wall_times() {
        let m = ServiceMetrics::new();
        // No history: 1s per queued job.
        assert_eq!(m.retry_after_s(0), 1);
        assert_eq!(m.retry_after_s(3), 3);
        // Three ~4s jobs: depth 3 → ceil(3 × 4) = 12.
        for _ in 0..3 {
            m.record_report(&PipelineReport {
                wall: std::time::Duration::from_secs(4),
                ..Default::default()
            });
        }
        assert_eq!(m.retry_after_s(3), 12);
        // Clamped at both ends.
        assert_eq!(m.retry_after_s(0), 1);
        assert_eq!(m.retry_after_s(100_000), 600);
        // The window forgets old jobs: 20 fast runs push the slow ones out.
        for _ in 0..20 {
            m.record_report(&PipelineReport {
                wall: std::time::Duration::from_millis(500),
                ..Default::default()
            });
        }
        assert_eq!(m.retry_after_s(4), 2);
    }

    #[test]
    fn record_report_folds_shard_accounting() {
        let m = ServiceMetrics::new();
        let mut report = PipelineReport::default();
        report.degradation.worker_restarts = 3;
        report.degradation.quarantined_shards = vec![1, 4];
        m.record_report(&report);
        assert_eq!(m.shard_restarts.load(Ordering::Relaxed), 3);
        assert_eq!(m.shard_quarantined.load(Ordering::Relaxed), 2);
    }
}
