//! Gridding-as-a-service: a long-lived multi-tenant job server over the
//! engine (`hegrid serve`).
//!
//! The paper's multi-pipeline concurrency (§4.2, Fig 8) keeps one machine
//! saturated across the channel groups of *one* run; this module points the
//! same machinery at many concurrent *jobs*. A hand-rolled HTTP/1.1 server
//! ([`server`], `std::net` only — no new dependencies) fronts a bounded
//! job queue with admission control ([`queue`]): `POST /jobs` enqueues a
//! JSON job spec, `service_workers` worker threads run the jobs on
//! per-job [`crate::coordinator::HegridEngine`]s, and every job's sweeps
//! schedule onto the one process-global persistent
//! [`crate::util::threads::PipelineExecutor`] — so a job is byte-identical
//! to the equivalent one-shot CLI run, while concurrent jobs time-share
//! the same parked worker pool.
//!
//! Cross-job reuse comes from the [`cache::PlanCache`]: the expensive
//! per-sky-setup shared component (`DispatchPlan` — sorted samples,
//! neighbour table, cell trig, staged unit-vector columns, permutation) is
//! keyed by a canonical hash of the sky setup ([`cache::plan_key`]) and
//! reused across jobs, with hit/miss/eviction counters exported at
//! `GET /metrics` (Prometheus text, [`metrics`]).
//!
//! Job lifecycle: `queued → running → done | degraded | failed |
//! cancelled` ([`queue::JobState`]). `DELETE /jobs/{id}` trips the job's
//! [`crate::coordinator::CancelFlag`], which the pipeline loop checks at
//! channel-group boundaries. A degrade-mode job whose run quarantined
//! groups finishes `degraded` (not `done`), and `GET /jobs/{id}` surfaces
//! the `DegradationReport` (skipped groups + causes). See docs/service.md
//! for the full API reference and operations runbook.

pub mod cache;
pub mod http;
pub mod metrics;
pub mod queue;
pub mod server;

pub use cache::{CacheStats, PlanCache};
pub use queue::{JobQueue, JobSpec, JobState};
pub use server::{serve, ServiceHandle};

use crate::util::error::{HegridError, Result};

/// Service-layer knobs (`hegrid serve`), separate from the per-job
/// [`crate::config::HegridConfig`]. Defaults → `HEGRID_SERVICE_*`
/// environment overrides ([`ServiceConfig::apply_env`]) → CLI flags, the
/// strongest last. Documented in docs/config-reference.md; the CI docs
/// gate greps this struct's fields against that table.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Listen address (`host:port`); port 0 binds an ephemeral port
    /// (loopback integration tests).
    pub service_listen: String,
    /// Admission control: maximum *queued* (not yet running) jobs; a
    /// `POST /jobs` beyond it is rejected with HTTP 429.
    pub service_queue_max: usize,
    /// Worker threads running jobs — the job-level concurrency. Each
    /// worker drives one engine run at a time; all of them share the one
    /// persistent executor.
    pub service_workers: usize,
    /// Plan-cache capacity in retained `DispatchPlan`s (LRU eviction
    /// beyond it). 0 disables cross-job plan sharing.
    pub service_cache_cap: usize,
    /// Finished jobs (results + reports) retained for `GET /jobs/{id}`;
    /// older finished jobs are evicted and return 404.
    pub service_keep_results: usize,
    /// Graceful-drain budget in seconds after SIGTERM/SIGINT: stop
    /// accepting, finish queued + running jobs, then cancel whatever is
    /// still running once the budget is spent. The process exits 0 either
    /// way.
    pub service_drain_s: usize,
    /// Per-job runtime bound in seconds: a job running longer has its
    /// cancel flag tripped and finishes in the terminal `timeout` state
    /// (counted by `jobs_timeout_total`). 0 = no bound.
    pub service_job_timeout_s: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            service_listen: "127.0.0.1:8780".to_string(),
            service_queue_max: 16,
            service_workers: 2,
            service_cache_cap: 4,
            service_keep_results: 8,
            service_drain_s: 30,
            service_job_timeout_s: 0,
        }
    }
}

impl ServiceConfig {
    /// Overlay `HEGRID_SERVICE_*` environment variables (unset ones keep
    /// the current value). Called before CLI flags so flags win.
    pub fn apply_env(&mut self) -> Result<()> {
        if let Ok(v) = std::env::var("HEGRID_SERVICE_LISTEN") {
            self.service_listen = v;
        }
        for (var, field) in [
            ("HEGRID_SERVICE_QUEUE_MAX", &mut self.service_queue_max),
            ("HEGRID_SERVICE_WORKERS", &mut self.service_workers),
            ("HEGRID_SERVICE_CACHE_CAP", &mut self.service_cache_cap),
            ("HEGRID_SERVICE_KEEP_RESULTS", &mut self.service_keep_results),
            ("HEGRID_SERVICE_DRAIN_S", &mut self.service_drain_s),
            ("HEGRID_SERVICE_JOB_TIMEOUT_S", &mut self.service_job_timeout_s),
        ] {
            if let Ok(v) = std::env::var(var) {
                *field = v.parse().map_err(|_| {
                    HegridError::Config(format!("{var} must be a non-negative integer, got '{v}'"))
                })?;
            }
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.service_listen.is_empty() {
            return Err(HegridError::Config("service_listen must not be empty".into()));
        }
        if self.service_queue_max == 0 || self.service_queue_max > 4096 {
            return Err(HegridError::Config(format!(
                "service_queue_max must be in 1..=4096, got {}",
                self.service_queue_max
            )));
        }
        if self.service_workers == 0 || self.service_workers > 64 {
            return Err(HegridError::Config(format!(
                "service_workers must be in 1..=64, got {}",
                self.service_workers
            )));
        }
        if self.service_cache_cap > 1024 {
            return Err(HegridError::Config(format!(
                "service_cache_cap must be at most 1024, got {}",
                self.service_cache_cap
            )));
        }
        if self.service_keep_results == 0 || self.service_keep_results > 4096 {
            return Err(HegridError::Config(format!(
                "service_keep_results must be in 1..=4096, got {}",
                self.service_keep_results
            )));
        }
        if self.service_drain_s > 3600 {
            return Err(HegridError::Config(format!(
                "service_drain_s must be at most 3600, got {}",
                self.service_drain_s
            )));
        }
        if self.service_job_timeout_s > 86_400 {
            return Err(HegridError::Config(format!(
                "service_job_timeout_s must be at most 86400, got {}",
                self.service_job_timeout_s
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ServiceConfig::default().validate().unwrap();
    }

    #[test]
    fn validation_rejects_out_of_range() {
        let c = ServiceConfig { service_queue_max: 0, ..ServiceConfig::default() };
        assert!(c.validate().is_err());
        let c = ServiceConfig { service_workers: 65, ..ServiceConfig::default() };
        assert!(c.validate().is_err());
        let c = ServiceConfig { service_listen: String::new(), ..ServiceConfig::default() };
        assert!(c.validate().is_err());
        let c = ServiceConfig { service_job_timeout_s: 86_401, ..ServiceConfig::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn job_timeout_defaults_off() {
        assert_eq!(ServiceConfig::default().service_job_timeout_s, 0);
    }
}
