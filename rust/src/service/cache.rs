//! The cross-job plan cache: the service's one piece of shared mutable
//! state beyond the queue.
//!
//! A [`crate::coordinator::DispatchPlan`] bundles everything expensive a
//! sky setup needs built exactly once — the sorted-sample permutation,
//! HEALPix neighbour table, cell trig, and staged unit-vector columns.
//! Within one run the coordinator already shares it across pipelines
//! (`share_preprocessing`); the service extends that sharing across *jobs*:
//! engines constructed with
//! [`crate::coordinator::HegridEngine::with_plan_cache`] look the plan up
//! by [`plan_key`] before building. Plans are immutable after construction
//! and epoch IDs are allocated process-globally, so a cached plan is safe
//! to use from any engine and any number of concurrent jobs.
//!
//! Concurrency: a miss marks the key *in-flight* and builds outside the
//! lock; a second job arriving on the same key waits on the build instead
//! of duplicating it, then counts as a hit. That makes the canonical
//! two-concurrent-identical-jobs case deterministic — one build, one hit —
//! which `/metrics` exposes as `hegrid_plan_cache_{hits,misses}_total`.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::{DispatchPlan, GriddingJob};
use crate::runtime::VariantInfo;
use crate::util::crc32::Crc32;
use crate::util::error::Result;

/// Counter snapshot for `/metrics` and tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
}

struct Entry<T> {
    value: Arc<T>,
    last_used: u64,
}

struct State<T> {
    entries: HashMap<String, Entry<T>>,
    /// Keys with a build in progress (misses wait instead of re-building).
    building: HashSet<String>,
    /// LRU clock: bumped on every access, stamped into `last_used`.
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// A bounded LRU cache of `Arc<T>` keyed by canonical strings, with
/// build-once semantics for concurrent misses. The service instantiates it
/// as [`PlanCache`]; tests use small payload types.
pub struct SharedCache<T> {
    cap: usize,
    state: Mutex<State<T>>,
    cond: Condvar,
}

/// The service's plan cache (see module docs).
pub type PlanCache = SharedCache<DispatchPlan>;

impl<T> SharedCache<T> {
    /// `cap` = retained entries (LRU eviction beyond it); 0 disables the
    /// cache (every lookup builds, nothing is retained or counted).
    pub fn new(cap: usize) -> SharedCache<T> {
        SharedCache {
            cap,
            state: Mutex::new(State {
                entries: HashMap::new(),
                building: HashSet::new(),
                tick: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
            cond: Condvar::new(),
        }
    }

    /// Look `key` up; on a miss run `build` (outside the lock) and insert.
    /// Returns the value and whether it was a cache hit. A concurrent
    /// caller on an in-flight key waits for that build and scores a hit; if
    /// the build fails, one waiter takes over building.
    pub fn get_or_build(
        &self,
        key: &str,
        build: impl FnOnce() -> Result<Arc<T>>,
    ) -> Result<(Arc<T>, bool)> {
        if self.cap == 0 {
            return build().map(|v| (v, false));
        }
        {
            let mut guard = self.state.lock().unwrap();
            loop {
                let st = &mut *guard;
                if let Some(e) = st.entries.get_mut(key) {
                    st.tick += 1;
                    e.last_used = st.tick;
                    st.hits += 1;
                    return Ok((Arc::clone(&e.value), true));
                }
                if st.building.contains(key) {
                    guard = self.cond.wait(guard).unwrap();
                    continue;
                }
                st.misses += 1;
                st.building.insert(key.to_string());
                break;
            }
        }
        // Build outside the lock — plan builds take real time and other
        // keys must stay servable. The guard clears the in-flight mark if
        // the build fails or unwinds, so waiters never deadlock; on success
        // the insert and the clear happen under one lock, so a woken waiter
        // always finds the entry (never a vanished in-flight mark that
        // would make it rebuild).
        let mut clear = ClearBuilding { cache: self, key, armed: true };
        let value = match build() {
            Ok(v) => v,
            Err(e) => {
                drop(clear); // clears in-flight + notifies waiters
                return Err(e);
            }
        };
        {
            let mut guard = self.state.lock().unwrap();
            let st = &mut *guard;
            st.building.remove(key);
            st.tick += 1;
            let tick = st.tick;
            st.entries
                .insert(key.to_string(), Entry { value: Arc::clone(&value), last_used: tick });
            while st.entries.len() > self.cap {
                let victim = st
                    .entries
                    .iter()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| k.clone())
                    .expect("non-empty cache over capacity");
                st.entries.remove(&victim);
                st.evictions += 1;
            }
        }
        clear.armed = false;
        self.cond.notify_all();
        Ok((value, false))
    }

    pub fn stats(&self) -> CacheStats {
        let st = self.state.lock().unwrap();
        CacheStats {
            hits: st.hits,
            misses: st.misses,
            evictions: st.evictions,
            entries: st.entries.len(),
        }
    }
}

struct ClearBuilding<'a, T> {
    cache: &'a SharedCache<T>,
    key: &'a str,
    armed: bool,
}

impl<T> Drop for ClearBuilding<'_, T> {
    fn drop(&mut self) {
        if self.armed {
            let mut st = self.cache.state.lock().unwrap();
            st.building.remove(self.key);
            drop(st);
            self.cache.cond.notify_all();
        }
    }
}

/// Canonical cache key of a sky setup: everything
/// [`crate::coordinator::DispatchPlan::build`] depends on — the artifact
/// variant, the job's grid geometry and kernel (exact `f64` bit patterns,
/// so "equal" means bit-equal, never approximately equal), and the
/// coordinate table (length + CRC32 of the raw bytes, cheap relative to a
/// plan build). The SIMD ISA is deliberately excluded: every backend is
/// bit-identical, so plans are shareable across it.
pub fn plan_key(lons: &[f64], lats: &[f64], job: &GriddingJob, variant: &VariantInfo) -> String {
    let mut key = String::with_capacity(192);
    key.push_str(&variant.name);
    key.push('|');
    key.push_str(job.kernel.type_name());
    for bits in [
        job.kernel.sigma.to_bits(),
        job.kernel.sigma2.to_bits(),
        job.kernel.support.to_bits(),
        job.spec.lon_c.to_bits(),
        job.spec.lat_c.to_bits(),
        job.spec.step.to_bits(),
    ] {
        key.push_str(&format!("|{bits:016x}"));
    }
    key.push_str(&format!("|{}x{}|n{}", job.spec.nlon, job.spec.nlat, lons.len()));
    key.push_str(&format!("|{:08x}|{:08x}", crc_f64(lons), crc_f64(lats)));
    key
}

fn crc_f64(values: &[f64]) -> u32 {
    let mut crc = Crc32::new();
    let mut buf = [0u8; 8 * 256];
    for chunk in values.chunks(256) {
        for (i, v) in chunk.iter().enumerate() {
            buf[i * 8..i * 8 + 8].copy_from_slice(&v.to_le_bytes());
        }
        crc.update(&buf[..chunk.len() * 8]);
    }
    crc.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    #[test]
    fn hit_miss_and_lru_eviction() {
        let cache: SharedCache<usize> = SharedCache::new(2);
        let build = |v: usize| move || Ok(Arc::new(v));
        assert_eq!(cache.get_or_build("a", build(1)).unwrap(), (Arc::new(1), false));
        assert_eq!(cache.get_or_build("a", build(9)).unwrap(), (Arc::new(1), true));
        cache.get_or_build("b", build(2)).unwrap();
        // Touch "a" so "b" is the LRU victim when "c" lands.
        cache.get_or_build("a", build(9)).unwrap();
        cache.get_or_build("c", build(3)).unwrap();
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.evictions, st.entries), (2, 3, 1, 2));
        assert_eq!(cache.get_or_build("b", build(4)).unwrap(), (Arc::new(4), false));
        assert_eq!(cache.get_or_build("a", build(9)).unwrap(), (Arc::new(1), true));
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let cache: SharedCache<usize> = SharedCache::new(0);
        assert_eq!(cache.get_or_build("a", || Ok(Arc::new(1))).unwrap(), (Arc::new(1), false));
        assert_eq!(cache.get_or_build("a", || Ok(Arc::new(2))).unwrap(), (Arc::new(2), false));
        assert_eq!(cache.stats(), CacheStats::default());
    }

    #[test]
    fn concurrent_same_key_builds_once() {
        let cache: SharedCache<usize> = SharedCache::new(4);
        let builds = AtomicUsize::new(0);
        let barrier = Barrier::new(4);
        let hits = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    barrier.wait();
                    let (v, hit) = cache
                        .get_or_build("k", || {
                            builds.fetch_add(1, Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_millis(20));
                            Ok(Arc::new(7))
                        })
                        .unwrap();
                    assert_eq!(*v, 7);
                    if hit {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
        });
        assert_eq!(builds.load(Ordering::SeqCst), 1);
        assert_eq!(hits.load(Ordering::SeqCst), 3);
        assert_eq!(cache.stats().hits, 3);
    }

    #[test]
    fn failed_build_hands_over_to_a_waiter() {
        let cache: SharedCache<usize> = SharedCache::new(4);
        let err = cache.get_or_build("k", || {
            Err(crate::util::error::HegridError::Internal("boom".into()))
        });
        assert!(err.is_err());
        // The in-flight mark is cleared, so a retry builds normally.
        assert_eq!(cache.get_or_build("k", || Ok(Arc::new(5))).unwrap(), (Arc::new(5), false));
    }
}
