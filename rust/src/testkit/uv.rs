//! Property-test case generation for the uv-plane gridder.
//!
//! A [`UvCase`] is a fully concrete, shrinkable recipe for a
//! [`UvDataset`] plus the gridder configuration used to grid it. The
//! generator keeps every placement (including hermitian conjugates)
//! strictly on-grid, so the weight-conservation property can demand
//! bit-exact equality between [`crate::grid::uv::UvResult::deposited`]
//! and an independent serial fold of the input weights.

use crate::grid::uv::{
    UvDataset, UvGridSpec, UvGridder, UvKernel, UvKernelType, SPEED_OF_LIGHT_M_S,
};
use crate::util::error::Result;

use super::{Gen, Shrink};

/// One visibility sample: baseline metres, weight, and per-channel
/// complex visibility (re, im).
#[derive(Clone, Debug)]
pub struct UvSample {
    pub u_m: f64,
    pub v_m: f64,
    pub weight: f32,
    pub vis: Vec<(f32, f32)>,
}

/// A concrete, shrinkable uv gridding test case.
#[derive(Clone, Debug)]
pub struct UvCase {
    pub n_u: usize,
    pub n_v: usize,
    pub cell_wavelengths: f64,
    pub freqs_hz: Vec<f64>,
    pub samples: Vec<UvSample>,
    pub gaussian: bool,
    pub support: usize,
    pub oversample: usize,
    pub hermitian: bool,
}

impl UvCase {
    pub fn n_channels(&self) -> usize {
        self.freqs_hz.len()
    }

    /// Materialize the dataset in the `[channel][sample]` layout.
    pub fn dataset(&self) -> UvDataset {
        let n_ch = self.n_channels();
        let mut ds = UvDataset {
            u_m: self.samples.iter().map(|s| s.u_m).collect(),
            v_m: self.samples.iter().map(|s| s.v_m).collect(),
            weights: self.samples.iter().map(|s| s.weight).collect(),
            freqs_hz: self.freqs_hz.clone(),
            re: vec![Vec::with_capacity(self.samples.len()); n_ch],
            im: vec![Vec::with_capacity(self.samples.len()); n_ch],
        };
        for s in &self.samples {
            for (c, &(re, im)) in s.vis.iter().enumerate() {
                ds.re[c].push(re);
                ds.im[c].push(im);
            }
        }
        ds
    }

    /// Build the gridder this case configures (workers/tiling left at
    /// defaults for the caller to vary).
    pub fn gridder(&self) -> Result<UvGridder> {
        let kind = if self.gaussian { UvKernelType::Gaussian } else { UvKernelType::Spheroidal };
        let kernel = UvKernel::new(kind, self.support, self.oversample, 1.0)?;
        Ok(UvGridder::new(
            UvGridSpec::new(self.n_u, self.n_v, self.cell_wavelengths),
            kernel,
        )
        .with_hermitian(self.hermitian))
    }

    /// The serial, placement-order fold of deposited weights the gridder
    /// promises to reproduce bit-for-bit (per channel, all channels equal
    /// because weights are shared and nothing clips).
    pub fn expected_deposit(&self) -> f64 {
        let per_sample = if self.hermitian { 2 } else { 1 };
        let mut fold = 0.0f64;
        for s in &self.samples {
            for _ in 0..per_sample {
                fold += s.weight as f64;
            }
        }
        fold
    }
}

impl Shrink for UvCase {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        // Fewer samples first (most aggressive).
        if !self.samples.is_empty() {
            let mut half = self.clone();
            half.samples.truncate(self.samples.len() / 2);
            out.push(half);
            let mut tail = self.clone();
            tail.samples.remove(0);
            out.push(tail);
            let mut init = self.clone();
            init.samples.pop();
            out.push(init);
        }
        // Fewer channels.
        if self.freqs_hz.len() > 1 {
            let mut one_ch = self.clone();
            one_ch.freqs_hz.truncate(1);
            for s in &mut one_ch.samples {
                s.vis.truncate(1);
            }
            out.push(one_ch);
        }
        // Simpler data: zero the first sample's visibilities.
        if let Some(s0) = self.samples.first() {
            if s0.vis.iter().any(|&(re, im)| re != 0.0 || im != 0.0) {
                let mut zeroed = self.clone();
                for v in &mut zeroed.samples[0].vis {
                    *v = (0.0, 0.0);
                }
                out.push(zeroed);
            }
        }
        out
    }
}

/// Draw a random [`UvCase`] whose placements are all strictly on-grid.
pub fn gen_uv_case(g: &mut Gen) -> UvCase {
    let n_u = *g.choose(&[16usize, 24, 32]);
    let n_v = *g.choose(&[12usize, 20, 40]);
    let cell_wavelengths = g.f64(20.0, 80.0);
    let n_ch = g.usize(1, 4);
    let freq0 = g.f64(1.0e9, 1.6e9);
    let step = g.f64(1.0e6, 2.0e7);
    let freqs_hz: Vec<f64> = (0..n_ch).map(|c| freq0 + step * c as f64).collect();
    // Keep |pixel offset| within half-width minus a margin at the HIGHEST
    // frequency (largest scale), so both the direct placement and its
    // hermitian mirror land on-grid in every channel — the clipped count
    // must stay zero for the exact deposit fold to hold.
    let scale_max = freqs_hz[n_ch - 1] / SPEED_OF_LIGHT_M_S / cell_wavelengths;
    let margin = 3.0;
    let bound_u = ((n_u / 2) as f64 - margin).max(1.0) / scale_max;
    let bound_v = ((n_v / 2) as f64 - margin).max(1.0) / scale_max;
    let n_samples = g.usize(1, 24);
    let samples = (0..n_samples)
        .map(|_| UvSample {
            u_m: g.f64(-bound_u, bound_u),
            v_m: g.f64(-bound_v, bound_v),
            weight: g.f64(0.05, 3.0) as f32,
            vis: (0..n_ch).map(|_| (g.f64(-2.0, 2.0) as f32, g.f64(-2.0, 2.0) as f32)).collect(),
        })
        .collect();
    UvCase {
        n_u,
        n_v,
        cell_wavelengths,
        freqs_hz,
        samples,
        gaussian: g.bool(),
        support: g.usize(1, 3),
        oversample: *g.choose(&[16usize, 64, 128]),
        hermitian: g.bool(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, PropResult, DEFAULT_CASES};

    fn planes_bits_eq(a: &crate::grid::uv::UvResult, b: &crate::grid::uv::UvResult) -> PropResult {
        for (c, (pa, pb)) in a.planes.iter().zip(&b.planes).enumerate() {
            for (name, xa, xb) in
                [("re", &pa.re, &pb.re), ("im", &pa.im, &pb.im), ("wsum", &pa.wsum, &pb.wsum)]
            {
                for (i, (x, y)) in xa.iter().zip(xb).enumerate() {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("channel {c} plane {name} cell {i}: {x:?} != {y:?}"));
                    }
                }
            }
        }
        Ok(())
    }

    #[test]
    fn uv_weight_conservation_is_exact_to_the_bit() {
        check(0x5EED_0001, DEFAULT_CASES, gen_uv_case, |case| {
            let gridder = case.gridder().map_err(|e| e.to_string())?.with_workers(1);
            let res = gridder.grid(&case.dataset()).map_err(|e| e.to_string())?;
            let want = case.expected_deposit();
            for c in 0..case.n_channels() {
                if res.clipped[c] != 0 {
                    return Err(format!(
                        "generator invariant broken: channel {c} clipped {}",
                        res.clipped[c]
                    ));
                }
                if res.deposited[c].to_bits() != want.to_bits() {
                    return Err(format!(
                        "channel {c}: deposited {} != serial fold {} (bitwise)",
                        res.deposited[c], want
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn uv_planes_are_bit_identical_across_worker_counts() {
        check(0x5EED_0002, DEFAULT_CASES, gen_uv_case, |case| {
            let gridder = case.gridder().map_err(|e| e.to_string())?;
            let ds = case.dataset();
            let base = gridder.clone().with_workers(1).grid(&ds).map_err(|e| e.to_string())?;
            for (workers, tile_rows) in [(3usize, 0usize), (5, 3)] {
                let alt = gridder
                    .clone()
                    .with_workers(workers)
                    .with_tile_rows(tile_rows)
                    .grid(&ds)
                    .map_err(|e| e.to_string())?;
                planes_bits_eq(&base, &alt)
                    .map_err(|e| format!("workers={workers} tile_rows={tile_rows}: {e}"))?;
                if alt.deposited != base.deposited || alt.clipped != base.clipped {
                    return Err(format!(
                        "workers={workers} tile_rows={tile_rows}: accounting differs"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn uv_case_shrinks_stay_valid_and_get_smaller() {
        let mut rng = crate::util::SplitMix64::new(9);
        let case = gen_uv_case(&mut crate::testkit::Gen::new(&mut rng));
        let shrinks = case.shrinks();
        assert!(!shrinks.is_empty());
        for s in &shrinks {
            // Every shrink still materializes a valid dataset.
            s.dataset().validate().unwrap();
            assert!(
                s.samples.len() < case.samples.len()
                    || s.n_channels() < case.n_channels()
                    || s.samples[0].vis.iter().all(|&(re, im)| re == 0.0 && im == 0.0)
            );
        }
    }
}
