//! Mini property-testing framework (no `proptest` in the offline crate set).
//!
//! A [`Gen`] draws random values from a [`SplitMix64`] stream; [`check`] runs
//! a property over many cases and, on failure, greedily shrinks the input via
//! the case's [`Shrink`] implementation before reporting. Deterministic: the
//! seed is fixed per call site, so failures reproduce.

use crate::util::SplitMix64;

pub mod uv;
pub use uv::{gen_uv_case, UvCase, UvSample};

/// Number of cases run by default.
pub const DEFAULT_CASES: usize = 100;

/// A generator of random test inputs.
pub struct Gen<'a> {
    rng: &'a mut SplitMix64,
}

impl<'a> Gen<'a> {
    pub fn new(rng: &'a mut SplitMix64) -> Self {
        Gen { rng }
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a non-empty slice.
    pub fn choose<'b, T>(&mut self, items: &'b [T]) -> &'b T {
        &items[self.usize(0, items.len() - 1)]
    }

    /// A vector of `len` draws.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64(lo, hi)).collect()
    }
}

/// Types that can propose strictly-smaller variants of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate shrinks, in decreasing order of aggressiveness.
    fn shrinks(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for u64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        (*self as u64).shrinks().into_iter().map(|v| v as usize).collect()
    }
}

impl Shrink for f64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(self[..self.len() / 2].to_vec()); // first half
            out.push(self[1..].to_vec()); // drop head
            let mut tail = self.clone();
            tail.pop(); // drop last
            out.push(tail);
            // shrink one element
            for (i, item) in self.iter().enumerate().take(4) {
                for s in item.shrinks().into_iter().take(1) {
                    let mut v = self.clone();
                    v[i] = s;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> =
            self.0.shrinks().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = Vec::new();
        out.extend(self.0.shrinks().into_iter().map(|a| (a, self.1.clone(), self.2.clone())));
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b, self.2.clone())));
        out.extend(self.2.shrinks().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)));
        out
    }
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `prop` over inputs drawn by `gen`. On failure,
/// shrink greedily (up to 200 steps) and panic with the minimal case found.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + std::fmt::Debug,
    G: FnMut(&mut Gen) -> T,
    P: Fn(&T) -> PropResult,
{
    let mut rng = SplitMix64::new(seed);
    for case_idx in 0..cases {
        let input = gen(&mut Gen::new(&mut rng));
        if let Err(msg) = prop(&input) {
            // Shrink.
            let mut best = input;
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < 200 {
                for cand in best.shrinks() {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if steps >= 200 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={seed}, case {case_idx}/{cases}):\n  input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Assert two floats agree within `rel` relative + `abs` absolute tolerance.
pub fn close(a: f64, b: f64, rel: f64, abs: f64) -> PropResult {
    let tol = abs + rel * a.abs().max(b.abs());
    if (a - b).abs() <= tol || (a.is_nan() && b.is_nan()) {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol})"))
    }
}

/// Assert two float slices agree elementwise.
pub fn all_close(a: &[f64], b: &[f64], rel: f64, abs: f64) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        close(x, y, rel, abs).map_err(|e| format!("at {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            1,
            50,
            |g| g.u64(0, 100),
            |_| {
                // counting via a Cell would need interior mutability; the
                // property itself must be pure, so count in the generator.
                Ok(())
            },
        );
        check(
            1,
            50,
            |g| {
                count += 1;
                g.u64(0, 100)
            },
            |_| Ok(()),
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(2, 100, |g| g.u64(0, 1000), |&x| if x < 900 { Ok(()) } else { Err("too big".into()) });
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        let result = std::panic::catch_unwind(|| {
            check(3, 200, |g| g.u64(0, 10_000), |&x| {
                if x < 500 {
                    Ok(())
                } else {
                    Err("x >= 500".into())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy halving from any failing x ≥ 500 lands at either 500..999.
        let input_line = msg.lines().find(|l| l.contains("input")).unwrap().to_string();
        let val: u64 = input_line.split(':').nth(1).unwrap().trim().parse().unwrap();
        assert!((500..1000).contains(&val), "not shrunk: {val}");
    }

    #[test]
    fn vec_shrinks_reduce_length_or_elements() {
        let v = vec![5u64, 6, 7, 8];
        let shrinks = v.shrinks();
        assert!(shrinks.iter().any(|s| s.len() < v.len()));
        assert!(shrinks.iter().any(|s| s.len() == v.len() && s != &v));
    }

    #[test]
    fn close_and_all_close() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, 0.0).is_ok());
        assert!(close(1.0, 2.0, 1e-9, 0.0).is_err());
        assert!(all_close(&[1.0, 2.0], &[1.0, 2.0], 0.0, 0.0).is_ok());
        assert!(all_close(&[1.0], &[1.0, 2.0], 0.0, 0.0).is_err());
        let err = all_close(&[1.0, 2.0], &[1.0, 3.0], 0.0, 0.0).unwrap_err();
        assert!(err.contains("at 1"));
    }

    #[test]
    fn gen_ranges_inclusive() {
        let mut rng = SplitMix64::new(4);
        let mut g = Gen::new(&mut rng);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let v = g.u64(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }
}
