//! Bench-regression gate: diff two `BENCH_*.json` payloads and fail on a
//! throughput regression.
//!
//! CI archives `BENCH_cpu_gridding.json` on every run. On pull requests the
//! gate downloads the most recent **non-expired artifact produced by a
//! `main` run** (so a PR cannot ratchet against its own earlier regressed
//! runs), re-runs the smoke bench, and compares:
//!
//! * **throughput metrics** (`cells_per_s`, `cells_per_s_1t`,
//!   `channel_samples_per_s`, …) — higher is better; a drop beyond the
//!   threshold (default 15%) **fails** the gate;
//! * **stage times** (`prep_s`, `grid_1t_s`, …) — lower is better; changes
//!   are reported for the PR author but never fail on their own (absolute
//!   stage times are too machine-sensitive for a hard gate);
//! * **workload identity** (`n_samples`, `n_channels`) — if the two runs
//!   measured different workloads the comparison is meaningless, so the gate
//!   reports `incomparable` and passes (the next merge re-baselines).
//!
//! A missing baseline (first run, expired artifact) soft-warns and passes —
//! the gate guards trajectories, not absolute numbers. The CLI entry point
//! is `hegrid bench-gate` (see `main.rs`); this module is the pure
//! comparator so the failure logic is unit-testable on canned payloads.
//!
//! Schema growth is **additive** by contract (ROADMAP's baseline rule):
//! metrics absent on either side are skipped, and unknown fields (e.g. the
//! `width_trace`/`numa_nodes` fields newer benches record) are ignored, so
//! old baselines stay comparable.
//!
//! ```
//! use hegrid::benchkit::gate::{compare, DEFAULT_THRESHOLD};
//!
//! let base = hegrid::json::parse(
//!     r#"{"n_samples": 100, "throughput": {"cells_per_s": 1000.0}}"#,
//! ).unwrap();
//! let cur = hegrid::json::parse(
//!     r#"{"n_samples": 100, "throughput": {"cells_per_s": 500.0}}"#,
//! ).unwrap();
//! let report = compare(&base, &cur, DEFAULT_THRESHOLD);
//! assert!(report.failed()); // a 50% throughput drop breaches the 15% gate
//! ```

use std::path::Path;

use crate::json::Json;
use crate::util::error::{HegridError, Result};

/// Default relative throughput drop that fails the gate.
pub const DEFAULT_THRESHOLD: f64 = 0.15;

/// Throughput metrics gated against the threshold (higher is better).
const THROUGHPUT_METRICS: &[&str] =
    &["cells_per_s", "cells_per_s_1t", "channel_samples_per_s", "channel_samples_per_s_1t"];

/// Stage times reported informationally (lower is better, never fatal).
const STAGE_METRICS: &[&str] = &["prep_s", "grid_1t_s", "grid_nt_s"];

/// Nested throughput metrics gated like `throughput.*` (higher is better).
/// Additive: payloads recorded before a leg existed simply skip its rows.
const NESTED_THROUGHPUT_METRICS: &[&[&str]] =
    &[&["survey", "cells_per_s"], &["uv", "cells_per_s"], &["uv", "vis_per_s"]];

/// Workload-identity fields; a mismatch makes the runs incomparable.
const IDENTITY_FIELDS: &[&str] = &["n_samples", "n_channels"];

/// String-valued identity fields. `simd_isa` is the dispatched SIMD backend:
/// a baseline recorded under a different ISA (another runner generation, a
/// forced-scalar run) measures different code and must not fail the gate —
/// it re-baselines instead. Absent on either side = pre-SIMD payload,
/// compared as before (fields stay additive).
const IDENTITY_STR_FIELDS: &[&str] = &["simd_isa"];

/// One compared metric.
#[derive(Clone, Debug)]
pub struct GateFinding {
    pub metric: String,
    pub baseline: f64,
    pub current: f64,
    /// Relative change, signed so that **negative is worse** for the reader:
    /// throughput drops and stage-time increases both come out negative.
    pub change: f64,
    /// This finding alone fails the gate.
    pub regressed: bool,
}

/// Outcome of one gate evaluation.
#[derive(Clone, Debug)]
pub struct GateReport {
    pub findings: Vec<GateFinding>,
    /// The two payloads measured different workloads; comparison skipped.
    pub incomparable: Option<String>,
    pub threshold: f64,
}

impl GateReport {
    pub fn failed(&self) -> bool {
        self.findings.iter().any(|f| f.regressed)
    }

    /// Human-readable summary lines (one per finding).
    pub fn lines(&self) -> Vec<String> {
        let mut out = Vec::new();
        if let Some(why) = &self.incomparable {
            out.push(format!("bench-gate: runs are incomparable ({why}); skipping"));
            return out;
        }
        for f in &self.findings {
            out.push(format!(
                "bench-gate: {:<28} baseline {:>12.4e}  current {:>12.4e}  {:+.1}%{}",
                f.metric,
                f.baseline,
                f.current,
                f.change * 100.0,
                if f.regressed {
                    format!("  REGRESSION (> {:.0}%)", self.threshold * 100.0)
                } else {
                    String::new()
                }
            ));
        }
        out
    }
}

fn num_at(payload: &Json, path: &[&str]) -> Option<f64> {
    let mut v = payload;
    for key in path {
        v = v.get(key)?;
    }
    v.as_f64()
}

/// Compare a fresh bench payload against a stored baseline.
///
/// Both payloads are expected in the `BENCH_cpu_gridding` schema
/// (`throughput.*`, `stages.*`, top-level identity fields); metrics absent
/// on either side are skipped, so schema growth never breaks old baselines.
pub fn compare(baseline: &Json, current: &Json, threshold: f64) -> GateReport {
    let mut report =
        GateReport { findings: Vec::new(), incomparable: None, threshold };

    for &field in IDENTITY_FIELDS {
        let (b, c) = (num_at(baseline, &[field]), num_at(current, &[field]));
        if let (Some(b), Some(c)) = (b, c) {
            if b != c {
                report.incomparable =
                    Some(format!("{field}: baseline {b} vs current {c}"));
                return report;
            }
        }
    }

    for &field in IDENTITY_STR_FIELDS {
        let b = baseline.get(field).and_then(|x| x.as_str());
        let c = current.get(field).and_then(|x| x.as_str());
        if let (Some(b), Some(c)) = (b, c) {
            if b != c {
                report.incomparable =
                    Some(format!("{field}: baseline '{b}' vs current '{c}'"));
                return report;
            }
        }
    }

    // A payload recorded with fault injection active measured a degraded run
    // (retry sleeps, zeroed planes), not the machine's real throughput.
    // Either side contaminated → incomparable pass; the next clean merge
    // re-baselines. Absent `faults` block = pre-robustness payload, fine.
    for (side, p) in [("baseline", baseline), ("current", current)] {
        let injected = num_at(p, &["faults", "injected"]).unwrap_or(0.0);
        let retried = num_at(p, &["faults", "retried"]).unwrap_or(0.0);
        let quarantined = num_at(p, &["faults", "quarantined"]).unwrap_or(0.0);
        if injected > 0.0 || retried > 0.0 || quarantined > 0.0 {
            report.incomparable = Some(format!(
                "{side} payload was recorded under fault injection \
                 (injected={injected}, retried={retried}, quarantined={quarantined})"
            ));
            return report;
        }
    }

    for &metric in THROUGHPUT_METRICS {
        let b = num_at(baseline, &["throughput", metric]);
        let c = num_at(current, &["throughput", metric]);
        if let (Some(b), Some(c)) = (b, c) {
            if b <= 0.0 || !b.is_finite() || !c.is_finite() {
                continue;
            }
            let change = (c - b) / b; // negative = slower
            report.findings.push(GateFinding {
                metric: format!("throughput.{metric}"),
                baseline: b,
                current: c,
                change,
                regressed: change < -threshold,
            });
        }
    }

    for path in NESTED_THROUGHPUT_METRICS {
        let b = num_at(baseline, path);
        let c = num_at(current, path);
        if let (Some(b), Some(c)) = (b, c) {
            if b <= 0.0 || !b.is_finite() || !c.is_finite() {
                continue;
            }
            let change = (c - b) / b; // negative = slower
            report.findings.push(GateFinding {
                metric: path.join("."),
                baseline: b,
                current: c,
                change,
                regressed: change < -threshold,
            });
        }
    }

    for &metric in STAGE_METRICS {
        let b = num_at(baseline, &["stages", metric]);
        let c = num_at(current, &["stages", metric]);
        if let (Some(b), Some(c)) = (b, c) {
            if b <= 0.0 || !b.is_finite() || !c.is_finite() {
                continue;
            }
            // Time: an increase is bad, so flip the sign (negative = worse).
            let change = (b - c) / b;
            report.findings.push(GateFinding {
                metric: format!("stages.{metric}"),
                baseline: b,
                current: c,
                change,
                regressed: false,
            });
        }
    }

    report
}

/// File-level gate outcome (what the CLI maps to an exit code).
#[derive(Debug, PartialEq, Eq)]
pub enum GateOutcome {
    /// No baseline on disk: soft-warn, pass (first run / expired artifact).
    NoBaseline,
    Passed,
    Failed,
}

/// Run the gate over two JSON files. `baseline` may be absent — that is the
/// "no prior artifact" path and passes with a warning. A missing or
/// unparsable *current* payload is a hard error: the bench that was supposed
/// to produce it did not run.
pub fn gate_files(baseline: &Path, current: &Path, threshold: f64) -> Result<GateOutcome> {
    let cur_text = std::fs::read_to_string(current)
        .map_err(HegridError::io(current.display().to_string()))?;
    let cur = crate::json::parse(&cur_text)?;
    if !baseline.exists() {
        eprintln!(
            "bench-gate: no baseline at {} — nothing to compare (first run?); passing",
            baseline.display()
        );
        return Ok(GateOutcome::NoBaseline);
    }
    let base_text = std::fs::read_to_string(baseline)
        .map_err(HegridError::io(baseline.display().to_string()))?;
    let base = crate::json::parse(&base_text)?;
    let report = compare(&base, &cur, threshold);
    for line in report.lines() {
        println!("{line}");
    }
    Ok(if report.failed() { GateOutcome::Failed } else { GateOutcome::Passed })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Canned payload in the `BENCH_cpu_gridding` schema.
    fn payload(cells_per_s: f64, cells_per_s_1t: f64, grid_1t_s: f64) -> Json {
        Json::obj(vec![
            ("bench", Json::str("cpu_gridding")),
            ("n_samples", Json::num(4000.0)),
            ("n_channels", Json::num(4.0)),
            (
                "throughput",
                Json::obj(vec![
                    ("cells_per_s", Json::num(cells_per_s)),
                    ("cells_per_s_1t", Json::num(cells_per_s_1t)),
                ]),
            ),
            ("stages", Json::obj(vec![("grid_1t_s", Json::num(grid_1t_s))])),
        ])
    }

    #[test]
    fn passes_within_threshold() {
        let base = payload(1.0e6, 2.5e5, 0.8);
        let cur = payload(0.9e6, 2.4e5, 0.9); // 10% / 4% drops, under 15%
        let r = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(!r.failed(), "{:?}", r.findings);
        assert!(r.incomparable.is_none());
        assert_eq!(r.findings.len(), 3);
        assert!(!r.lines().is_empty());
    }

    #[test]
    fn fails_synthetic_20_percent_regression() {
        let base = payload(1.0e6, 2.5e5, 0.8);
        let cur = payload(0.8e6, 2.5e5, 0.8); // 20% throughput drop
        let r = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.failed());
        let bad: Vec<_> = r.findings.iter().filter(|f| f.regressed).collect();
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].metric, "throughput.cells_per_s");
        assert!((bad[0].change + 0.2).abs() < 1e-12);
        assert!(r.lines().iter().any(|l| l.contains("REGRESSION")));
    }

    #[test]
    fn stage_time_blowup_reports_but_does_not_fail() {
        let base = payload(1.0e6, 2.5e5, 0.8);
        let cur = payload(1.0e6, 2.5e5, 8.0); // 10x slower stage time
        let r = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(!r.failed());
        let stage = r.findings.iter().find(|f| f.metric == "stages.grid_1t_s").unwrap();
        assert!(stage.change < 0.0, "slower stage reads as negative change");
    }

    #[test]
    fn different_workloads_are_incomparable() {
        let base = payload(1.0e6, 2.5e5, 0.8);
        let mut cur = payload(0.1e6, 2.5e5, 0.8);
        if let Json::Obj(fields) = &mut cur {
            fields.insert("n_samples".into(), Json::num(999.0));
        }
        let r = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.incomparable.is_some());
        assert!(!r.failed(), "incomparable runs must pass");
    }

    #[test]
    fn different_simd_isa_is_incomparable_pass_not_regression() {
        let set_isa = |mut p: Json, isa: &str| {
            if let Json::Obj(fields) = &mut p {
                fields.insert("simd_isa".into(), Json::str(isa));
            }
            p
        };
        // Baseline recorded under avx2, current forced scalar and 5x slower:
        // incomparable pass, never a regression.
        let base = set_isa(payload(1.0e6, 2.5e5, 0.8), "avx2");
        let cur = set_isa(payload(0.2e6, 0.5e5, 4.0), "scalar");
        let r = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.incomparable.is_some(), "{:?}", r.findings);
        assert!(!r.failed());
        assert!(r.lines()[0].contains("incomparable"));
        // Same ISA on both sides still gates normally.
        let cur_same = set_isa(payload(0.2e6, 0.5e5, 4.0), "avx2");
        assert!(compare(&base, &cur_same, DEFAULT_THRESHOLD).failed());
        // A pre-SIMD baseline (no simd_isa field) stays comparable — the
        // schema change is additive per ROADMAP's baseline rule.
        let old_base = payload(1.0e6, 2.5e5, 0.8);
        let r = compare(&old_base, &cur_same, DEFAULT_THRESHOLD);
        assert!(r.incomparable.is_none());
        assert!(r.failed());
    }

    #[test]
    fn additive_width_trace_and_numa_fields_stay_comparable() {
        // PR 5 benches add `width_trace` (adaptive-width controller trace)
        // and `numa_nodes` to the payload. A pre-PR5 baseline lacks both;
        // the comparison must neither fail nor go incomparable — the fields
        // are additive per ROADMAP's baseline rule.
        let base = payload(1.0e6, 2.5e5, 0.8);
        let mut cur = payload(0.95e6, 2.4e5, 0.85);
        if let Json::Obj(fields) = &mut cur {
            fields.insert("numa_nodes".into(), Json::num(2.0));
            fields.insert(
                "width_trace".into(),
                Json::Arr(vec![Json::obj(vec![
                    ("t_s", Json::num(0.0)),
                    ("width", Json::num(2.0)),
                ])]),
            );
        }
        let r = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.incomparable.is_none(), "{:?}", r.incomparable);
        assert!(!r.failed(), "{:?}", r.findings);
        assert_eq!(r.findings.len(), 3, "same metric set as without the new fields");
    }

    #[test]
    fn additive_survey_and_uv_rows_stay_comparable_and_gate_once_present() {
        // PR 10 benches add the `survey` and `uv` objects. A baseline
        // recorded before they existed lacks both; the comparison must
        // neither fail nor go incomparable, and the finding set is
        // unchanged — the rows are additive per ROADMAP's baseline rule.
        let add_rows = |mut p: Json, survey_cps: f64, uv_cps: f64, uv_vps: f64| {
            if let Json::Obj(fields) = &mut p {
                fields.insert(
                    "survey".into(),
                    Json::obj(vec![("cells_per_s", Json::num(survey_cps))]),
                );
                fields.insert(
                    "uv".into(),
                    Json::obj(vec![
                        ("cells_per_s", Json::num(uv_cps)),
                        ("vis_per_s", Json::num(uv_vps)),
                    ]),
                );
            }
            p
        };
        let base = payload(1.0e6, 2.5e5, 0.8);
        let cur = add_rows(payload(0.95e6, 2.4e5, 0.85), 3.0e6, 8.0e5, 1.0e4);
        let r = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.incomparable.is_none(), "{:?}", r.incomparable);
        assert!(!r.failed(), "{:?}", r.findings);
        assert_eq!(r.findings.len(), 3, "same metric set as without the new rows");

        // Once both sides carry the rows they gate like `throughput.*`:
        // a 50% uv drop fails, and the metric name is the dotted path.
        let base = add_rows(payload(1.0e6, 2.5e5, 0.8), 3.0e6, 8.0e5, 1.0e4);
        let cur = add_rows(payload(1.0e6, 2.5e5, 0.8), 3.0e6, 4.0e5, 1.0e4);
        let r = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.failed());
        let bad: Vec<_> = r.findings.iter().filter(|f| f.regressed).collect();
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].metric, "uv.cells_per_s");
        assert!(r.findings.iter().any(|f| f.metric == "survey.cells_per_s" && !f.regressed));
    }

    #[test]
    fn faulted_payload_is_incomparable_pass_not_regression() {
        let add_faults = |mut p: Json, injected: f64| {
            if let Json::Obj(fields) = &mut p {
                fields.insert(
                    "faults".into(),
                    Json::obj(vec![
                        ("injected", Json::num(injected)),
                        ("retried", Json::num(0.0)),
                        ("quarantined", Json::num(0.0)),
                    ]),
                );
            }
            p
        };
        // A fault-injected baseline measured a degraded run: even a 5x-slower
        // current must not fail the gate against it.
        let base = add_faults(payload(1.0e6, 2.5e5, 0.8), 3.0);
        let cur = add_faults(payload(0.2e6, 0.5e5, 4.0), 0.0);
        let r = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(r.incomparable.is_some(), "{:?}", r.findings);
        assert!(!r.failed());
        // A contaminated *current* is incomparable too.
        let r = compare(&cur, &base, DEFAULT_THRESHOLD);
        assert!(r.incomparable.is_some());
        // All-zero fault counters (the normal case) still gate normally.
        let clean_base = add_faults(payload(1.0e6, 2.5e5, 0.8), 0.0);
        let r = compare(&clean_base, &cur, DEFAULT_THRESHOLD);
        assert!(r.incomparable.is_none());
        assert!(r.failed(), "real regression still caught");
        // Pre-robustness payloads (no faults block) stay comparable.
        let r = compare(&payload(1.0e6, 2.5e5, 0.8), &payload(0.9e6, 2.4e5, 0.9), DEFAULT_THRESHOLD);
        assert!(r.incomparable.is_none());
    }

    #[test]
    fn missing_metrics_are_skipped_not_fatal() {
        let base = payload(1.0e6, 2.5e5, 0.8);
        let cur = Json::obj(vec![("bench", Json::str("cpu_gridding"))]);
        let r = compare(&base, &cur, DEFAULT_THRESHOLD);
        assert!(!r.failed());
        assert!(r.findings.is_empty());
    }

    #[test]
    fn gate_files_outcomes() {
        let dir = std::env::temp_dir().join("hegrid_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let cur_path = dir.join("current.json");
        let base_path = dir.join("baseline.json");
        let _ = std::fs::remove_file(&base_path);
        std::fs::write(&cur_path, payload(1.0e6, 2.5e5, 0.8).to_pretty()).unwrap();

        // No baseline: soft pass.
        assert_eq!(
            gate_files(&base_path, &cur_path, DEFAULT_THRESHOLD).unwrap(),
            GateOutcome::NoBaseline
        );
        // Healthy baseline: pass.
        std::fs::write(&base_path, payload(1.05e6, 2.5e5, 0.8).to_pretty()).unwrap();
        assert_eq!(
            gate_files(&base_path, &cur_path, DEFAULT_THRESHOLD).unwrap(),
            GateOutcome::Passed
        );
        // Fast baseline: the fresh run regressed. 1.0/1.3 ≈ 23% drop.
        std::fs::write(&base_path, payload(1.3e6, 2.5e5, 0.8).to_pretty()).unwrap();
        assert_eq!(
            gate_files(&base_path, &cur_path, DEFAULT_THRESHOLD).unwrap(),
            GateOutcome::Failed
        );
        // Missing current payload is a hard error.
        assert!(gate_files(&base_path, &dir.join("nope.json"), DEFAULT_THRESHOLD).is_err());
    }
}
