//! Shared plumbing for the `rust/benches/*` harnesses.
//!
//! Every bench regenerates one of the paper's tables/figures. Two common
//! needs live here: building engines against the repo's `artifacts/`
//! directory (wherever the bench is run from), and the warm-then-measure
//! protocol (compilation happens on first use per stream; the paper reports
//! steady-state times).

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::config::HegridConfig;
use crate::coordinator::{GriddingJob, HegridEngine, PipelineReport};
use crate::data::{Dataset, HgdStreamSource};
use crate::json::Json;

/// Locate the repo `artifacts/` directory from a bench binary.
pub fn artifacts_dir() -> String {
    for cand in [
        "artifacts",
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"),
    ] {
        if std::path::Path::new(cand).join("manifest.json").exists() {
            return cand.to_string();
        }
    }
    if crate::runtime::backend_name() == "native" {
        // No AOT artifacts on disk: the engine falls back to the built-in
        // native variant set, so benches still run (and say so).
        eprintln!("note: no artifacts/manifest.json — using the built-in native variant set");
        return "artifacts".to_string();
    }
    panic!("artifacts/manifest.json not found — run `make artifacts` first");
}

/// Default bench engine config (artifacts wired up).
pub fn bench_config() -> HegridConfig {
    HegridConfig { artifacts_dir: artifacts_dir(), ..HegridConfig::default() }
}

/// Build an engine, failing loudly (benches have no skip path).
pub fn engine(cfg: HegridConfig) -> HegridEngine {
    HegridEngine::new(cfg).expect("engine construction")
}

/// One warm run (compile + caches) then `iters` measured runs; returns the
/// per-run wall seconds and the last report (for stage calibration).
pub fn warm_and_measure(
    engine: &HegridEngine,
    dataset: &Dataset,
    job: &GriddingJob,
    iters: usize,
) -> (Vec<f64>, PipelineReport) {
    let _ = engine.grid(dataset, job).expect("warm run");
    let mut seconds = Vec::with_capacity(iters);
    let mut last = None;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        let (_, report) = engine.grid(dataset, job).expect("measured run");
        seconds.push(t0.elapsed().as_secs_f64());
        last = Some(report);
    }
    (seconds, last.expect("at least one iteration"))
}

/// Write `dataset` to a scratch HGD file and return its path — the on-disk
/// fixture for streaming-ingest benches.
pub fn hgd_fixture(dataset: &Dataset, name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("hegrid_bench_fixtures");
    std::fs::create_dir_all(&dir).expect("create fixture dir");
    let path = dir.join(name);
    dataset.save(&path).expect("write bench fixture");
    path
}

/// Streaming counterpart of [`warm_and_measure`]: one warm run (compile +
/// caches) then `iters` measured runs pulling channels from `path` through
/// the T0 prefetcher.
pub fn warm_and_measure_streaming(
    engine: &HegridEngine,
    path: &Path,
    job: &GriddingJob,
    iters: usize,
) -> (Vec<f64>, PipelineReport) {
    let source = HgdStreamSource::open(path).expect("open streaming source");
    let _ = engine.grid_source(&source, job).expect("warm run");
    let mut seconds = Vec::with_capacity(iters);
    let mut last = None;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        let (_, report) = engine.grid_source(&source, job).expect("measured run");
        seconds.push(t0.elapsed().as_secs_f64());
        last = Some(report);
    }
    (seconds, last.expect("at least one iteration"))
}

/// Median of a (small) sample.
pub fn median(mut xs: Vec<f64>) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Iteration count for benches: 2 by default, 1 under HEGRID_BENCH_FAST=1.
pub fn bench_iters() -> usize {
    if std::env::var("HEGRID_BENCH_FAST").map(|v| v == "1").unwrap_or(false) {
        1
    } else {
        2
    }
}

/// Write a bench's JSON payload to `BENCH_<name>.json` in the current
/// directory (or `$HEGRID_BENCH_DIR` if set) and return the path. This is
/// the machine-readable trajectory record CI archives per run — e.g.
/// `BENCH_cpu_gridding.json` from `cpu_throughput`.
pub fn write_bench_json(name: &str, payload: &Json) -> PathBuf {
    let dir = std::env::var("HEGRID_BENCH_DIR").unwrap_or_else(|_| ".".into());
    let path = Path::new(&dir).join(format!("BENCH_{name}.json"));
    std::fs::write(&path, payload.to_pretty()).expect("write bench json");
    eprintln!("wrote {}", path.display());
    path
}

/// Paper-scale disclaimer printed by every bench.
pub fn print_scale_note() {
    println!(
        "note: workloads run at 1/100 of the paper's sample counts with the field\n\
         scaled 1/10 linearly (density-preserving; see DESIGN.md). The \"device\" is\n\
         the XLA CPU PJRT client on a single-core host, so absolute times differ\n\
         from the paper's V100/MI50 testbed; shapes and who-wins are the target.\n"
    );
}
