//! Bench harness substrate (no `criterion` in the offline crate set).
//!
//! Each `rust/benches/*.rs` file is a `harness = false` binary that uses
//! [`Bencher`] for warmup + repeated timing with robust statistics, and the
//! table/series printers to emit rows shaped like the paper's tables and
//! figures. Results can also be dumped as JSON for EXPERIMENTS.md.

pub mod gate;
pub mod support;

use std::time::{Duration, Instant};

use crate::json::Json;
use crate::util::stats::Summary;

/// Timing configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Hard cap on total measurement time; stops early once exceeded
    /// (at least one measured iteration always runs).
    pub max_total: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 1, measure_iters: 5, max_total: Duration::from_secs(120) }
    }
}

impl BenchConfig {
    /// Honour `HEGRID_BENCH_FAST=1` (CI smoke mode: 0 warmup, 2 iters).
    pub fn from_env() -> Self {
        if std::env::var("HEGRID_BENCH_FAST").map(|v| v == "1").unwrap_or(false) {
            BenchConfig { warmup_iters: 0, measure_iters: 2, max_total: Duration::from_secs(30) }
        } else {
            BenchConfig::default()
        }
    }
}

/// One benchmark measurement: name + per-iteration seconds.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub seconds: Vec<f64>,
}

impl Measurement {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.seconds).expect("measurement has at least one iteration")
    }

    pub fn median(&self) -> f64 {
        self.summary().median
    }

    pub fn to_json(&self) -> Json {
        let s = self.summary();
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(s.n as f64)),
            ("median_s", Json::num(s.median)),
            ("mean_s", Json::num(s.mean)),
            ("mad_s", Json::num(s.mad)),
            ("min_s", Json::num(s.min)),
            ("max_s", Json::num(s.max)),
        ])
    }
}

/// Runs closures with warmup and repetition.
pub struct Bencher {
    config: BenchConfig,
    results: Vec<Measurement>,
}

impl Bencher {
    pub fn new(config: BenchConfig) -> Self {
        Bencher { config, results: Vec::new() }
    }

    pub fn from_env() -> Self {
        Self::new(BenchConfig::from_env())
    }

    /// Time `f` (which must do one full unit of work per call).
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        for _ in 0..self.config.warmup_iters {
            f();
        }
        let mut seconds = Vec::with_capacity(self.config.measure_iters);
        let started = Instant::now();
        for i in 0..self.config.measure_iters {
            let t0 = Instant::now();
            f();
            seconds.push(t0.elapsed().as_secs_f64());
            if started.elapsed() > self.config.max_total && i + 1 >= 1 {
                break;
            }
        }
        self.results.push(Measurement { name: name.to_string(), seconds });
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Dump all measurements as a JSON array (for EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.results.iter().map(|m| m.to_json()).collect())
    }
}

// ---------------------------------------------------------------------------
// Table / series printing
// ---------------------------------------------------------------------------

/// Fixed-width table printer shaped like the paper's tables: a header column
/// of row labels, one column per sweep point.
pub struct Table {
    title: String,
    col_labels: Vec<String>,
    rows: Vec<(String, Vec<String>)>,
}

impl Table {
    pub fn new(title: impl Into<String>, col_labels: Vec<String>) -> Self {
        Table { title: title.into(), col_labels, rows: Vec::new() }
    }

    pub fn row_f64(&mut self, label: impl Into<String>, values: &[f64]) {
        self.rows.push((
            label.into(),
            values.iter().map(|v| format!("{v:.2}")).collect(),
        ));
    }

    pub fn row_str(&mut self, label: impl Into<String>, values: Vec<String>) {
        self.rows.push((label.into(), values));
    }

    pub fn render(&self) -> String {
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(8))
            .max()
            .unwrap();
        let col_w = self
            .col_labels
            .iter()
            .map(|c| c.len())
            .chain(self.rows.iter().flat_map(|(_, vs)| vs.iter().map(|v| v.len())))
            .max()
            .unwrap_or(8)
            .max(8);
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&format!("{:label_w$}", ""));
        for c in &self.col_labels {
            out.push_str(&format!(" | {c:>col_w$}"));
        }
        out.push('\n');
        out.push_str(&"-".repeat(label_w + self.col_labels.len() * (col_w + 3)));
        out.push('\n');
        for (label, values) in &self.rows {
            out.push_str(&format!("{label:label_w$}"));
            for v in values {
                out.push_str(&format!(" | {v:>col_w$}"));
            }
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Print a figure-like series: `label: x=… y=…` lines plus an ASCII bar per
/// point, so "who wins / where's the crossover" is visible in a terminal.
pub struct Series {
    title: String,
    points: Vec<(String, f64)>,
}

impl Series {
    pub fn new(title: impl Into<String>) -> Self {
        Series { title: title.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: impl Into<String>, y: f64) {
        self.points.push((x.into(), y));
    }

    pub fn render(&self) -> String {
        let max = self.points.iter().map(|p| p.1).fold(f64::MIN, f64::max).max(1e-12);
        let label_w = self.points.iter().map(|p| p.0.len()).max().unwrap_or(4);
        let mut out = format!("-- {} --\n", self.title);
        for (x, y) in &self.points {
            let bar = "#".repeat(((y / max) * 40.0).round().max(0.0) as usize);
            out.push_str(&format!("{x:>label_w$}  {y:>10.4}  {bar}\n"));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// `speedup = baseline / candidate` guarded against division by ~zero.
pub fn speedup(baseline_s: f64, candidate_s: f64) -> f64 {
    if candidate_s <= 1e-12 {
        f64::INFINITY
    } else {
        baseline_s / candidate_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_runs_expected_iterations() {
        let mut count = 0usize;
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 2,
            measure_iters: 3,
            max_total: Duration::from_secs(60),
        });
        let m = b.run("t", || {
            count += 1;
        });
        assert_eq!(m.seconds.len(), 3);
        assert_eq!(count, 5); // 2 warmup + 3 measured
    }

    #[test]
    fn bencher_respects_time_cap() {
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 0,
            measure_iters: 1000,
            max_total: Duration::from_millis(30),
        });
        let m = b.run("slow", || std::thread::sleep(Duration::from_millis(20)));
        assert!(m.seconds.len() < 1000);
        assert!(!m.seconds.is_empty());
    }

    #[test]
    fn measurement_json_has_fields() {
        let m = Measurement { name: "x".into(), seconds: vec![1.0, 2.0, 3.0] };
        let j = m.to_json();
        assert_eq!(j.req_f64("median_s").unwrap(), 2.0);
        assert_eq!(j.req_str("name").unwrap(), "x");
    }

    #[test]
    fn table_renders_all_cells() {
        let mut t = Table::new("Table 3", vec!["1.5e5".into(), "1.9e5".into()]);
        t.row_f64("Cygrid", &[165.87, 194.6]);
        t.row_f64("HEGrid", &[30.21, 40.94]);
        let r = t.render();
        assert!(r.contains("Table 3"));
        assert!(r.contains("165.87"));
        assert!(r.contains("HEGrid"));
        assert_eq!(r.lines().count(), 5);
    }

    #[test]
    fn series_bars_scale() {
        let mut s = Series::new("fig");
        s.push("a", 1.0);
        s.push("b", 2.0);
        let r = s.render();
        let bars: Vec<usize> =
            r.lines().skip(1).map(|l| l.matches('#').count()).collect();
        assert_eq!(bars, vec![20, 40]);
    }

    #[test]
    fn speedup_guards() {
        assert_eq!(speedup(10.0, 2.0), 5.0);
        assert!(speedup(1.0, 0.0).is_infinite());
    }
}
