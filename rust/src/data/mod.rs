//! Dataset model + the HGD on-disk container.
//!
//! The paper stores multi-channel FAST data in HDF5: one shared coordinate
//! table (the receiver pointing is identical for every frequency channel) and
//! one value column per channel. No HDF5 implementation is vendored offline,
//! so HEGrid ships **HGD** — a little-endian binary container with the same
//! access pattern: header → shared coordinates → per-channel value blocks,
//! each CRC-32 protected, channel blocks independently seekable so pipelines
//! can stream one channel at a time (the T1 "load" stage of Fig 8).

pub mod checkpoint;
pub mod hgd;
pub mod source;

pub use checkpoint::{CheckpointManifest, CubeFile, CubeHandle};
pub use hgd::{HgdReader, HgdWriter};
pub use source::{ChannelSource, HgdStreamSource, InMemorySource};

use crate::util::error::{HegridError, Result};

/// Dataset metadata carried in the HGD header (JSON-encoded on disk).
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetMeta {
    pub name: String,
    /// Beam FWHM in arcsec (Table 2: 180" / 300").
    pub beam_arcsec: f64,
    /// Map center in degrees.
    pub center_deg: (f64, f64),
    /// Field extent (width, height) in degrees.
    pub extent_deg: (f64, f64),
}

impl DatasetMeta {
    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("beam_arcsec", Json::num(self.beam_arcsec)),
            ("center_lon_deg", Json::num(self.center_deg.0)),
            ("center_lat_deg", Json::num(self.center_deg.1)),
            ("extent_lon_deg", Json::num(self.extent_deg.0)),
            ("extent_lat_deg", Json::num(self.extent_deg.1)),
        ])
    }

    pub fn from_json(v: &crate::json::Json) -> Result<Self> {
        Ok(DatasetMeta {
            name: v.req_str("name")?.to_string(),
            beam_arcsec: v.req_f64("beam_arcsec")?,
            center_deg: (v.req_f64("center_lon_deg")?, v.req_f64("center_lat_deg")?),
            extent_deg: (v.req_f64("extent_lon_deg")?, v.req_f64("extent_lat_deg")?),
        })
    }
}

/// An in-memory multi-channel dataset: shared sample coordinates (radians)
/// plus one value vector per frequency channel.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub meta: DatasetMeta,
    /// Sample longitudes (right ascension), radians.
    pub lons: Vec<f64>,
    /// Sample latitudes (declination), radians.
    pub lats: Vec<f64>,
    /// `channels[c][i]` = sampled value of channel `c` at sample `i`.
    pub channels: Vec<Vec<f32>>,
}

impl Dataset {
    pub fn new(
        meta: DatasetMeta,
        lons: Vec<f64>,
        lats: Vec<f64>,
        channels: Vec<Vec<f32>>,
    ) -> Result<Self> {
        if lons.len() != lats.len() {
            return Err(HegridError::Format("lons/lats length mismatch".into()));
        }
        for (c, ch) in channels.iter().enumerate() {
            if ch.len() != lons.len() {
                return Err(HegridError::Format(format!(
                    "channel {c} has {} values for {} samples",
                    ch.len(),
                    lons.len()
                )));
            }
        }
        Ok(Dataset { meta, lons, lats, channels })
    }

    pub fn n_samples(&self) -> usize {
        self.lons.len()
    }

    pub fn n_channels(&self) -> usize {
        self.channels.len()
    }

    /// Restrict to the first `n` channels.
    pub fn take_channels(&self, n: usize) -> Dataset {
        Dataset {
            meta: self.meta.clone(),
            lons: self.lons.clone(),
            lats: self.lats.clone(),
            channels: self.channels[..n.min(self.channels.len())].to_vec(),
        }
    }

    /// Approximate in-memory size in bytes (coords + values).
    pub fn nbytes(&self) -> usize {
        self.lons.len() * 16 + self.channels.len() * self.lons.len() * 4
    }

    /// Write to an HGD file.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut w = HgdWriter::create(path, &self.meta, self.n_samples(), self.n_channels())?;
        w.write_coords(&self.lons, &self.lats)?;
        for ch in &self.channels {
            w.write_channel(ch)?;
        }
        w.finish()
    }

    /// Read a full HGD file into memory.
    pub fn load(path: &std::path::Path) -> Result<Dataset> {
        let mut r = HgdReader::open(path)?;
        let (lons, lats) = r.read_coords()?;
        let mut channels = Vec::with_capacity(r.n_channels());
        for c in 0..r.n_channels() {
            channels.push(r.read_channel(c)?);
        }
        Dataset::new(r.meta().clone(), lons, lats, channels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tiny_meta() -> DatasetMeta {
        DatasetMeta {
            name: "tiny".into(),
            beam_arcsec: 180.0,
            center_deg: (30.0, 41.0),
            extent_deg: (5.0, 5.0),
        }
    }

    #[test]
    fn meta_json_round_trip() {
        let m = tiny_meta();
        let j = m.to_json();
        let parsed = crate::json::parse(&j.to_string()).unwrap();
        assert_eq!(DatasetMeta::from_json(&parsed).unwrap(), m);
    }

    #[test]
    fn dataset_validation() {
        let m = tiny_meta();
        assert!(Dataset::new(m.clone(), vec![0.0; 3], vec![0.0; 2], vec![]).is_err());
        assert!(Dataset::new(m.clone(), vec![0.0; 3], vec![0.0; 3], vec![vec![0.0; 2]]).is_err());
        let d = Dataset::new(m, vec![0.0; 3], vec![0.0; 3], vec![vec![0.0; 3]; 2]).unwrap();
        assert_eq!(d.n_samples(), 3);
        assert_eq!(d.n_channels(), 2);
        assert_eq!(d.nbytes(), 3 * 16 + 2 * 3 * 4);
    }

    #[test]
    fn take_channels_subsets() {
        let m = tiny_meta();
        let d = Dataset::new(m, vec![0.0; 2], vec![0.0; 2], vec![vec![1.0; 2], vec![2.0; 2]])
            .unwrap();
        assert_eq!(d.take_channels(1).n_channels(), 1);
        assert_eq!(d.take_channels(5).n_channels(), 2);
    }
}
