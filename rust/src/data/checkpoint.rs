//! Spill + checkpoint I/O for the tiled output path.
//!
//! A tiled run reduces each channel group band by band and streams finished
//! bands into an on-disk **output cube** ([`CubeFile`]): raw f64 LE
//! accumulators, `[n_channels][n_cells]` of `acc` followed by `[n_cells]`
//! of `wsum`, exactly the buffers the untiled coordinator holds in memory —
//! so normalising a cube row reproduces the untiled map bit for bit.
//!
//! When a checkpoint directory is configured, the cube lives there as
//! `cube.bin` next to a [`CheckpointManifest`] (`manifest.json`): a CRC'd
//! record of the job identity and, per finished channel group, a streaming
//! CRC-32 over exactly the bytes that group wrote, in write order. A
//! `--resume` run reloads the manifest, fails with a typed
//! [`HegridError::Corrupt`] if its CRC does not match (never silently
//! re-grids), skips the groups it records, and re-verifies their cube bytes
//! band by band before trusting them.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::json::Json;
use crate::sky::{GridSpec, SkyMap};
use crate::util::crc32::{crc32, Crc32};
use crate::util::error::{HegridError, Result};

/// Manifest schema version.
const MANIFEST_VERSION: usize = 1;

/// File name of the spill cube inside a checkpoint directory.
pub const CUBE_FILE: &str = "cube.bin";

/// File name of the manifest inside a checkpoint directory.
pub const MANIFEST_FILE: &str = "manifest.json";

fn f64s_to_le(vals: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 8);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn le_to_f64s(bytes: &[u8], out: &mut Vec<f64>) {
    out.clear();
    out.reserve(bytes.len() / 8);
    for ch in bytes.chunks_exact(8) {
        // Invariant, not I/O: chunks_exact(8) yields exactly-8-byte slices.
        out.push(f64::from_le_bytes(ch.try_into().expect("chunks_exact(8) yields 8-byte slices")));
    }
}

/// The on-disk output cube: `[n_channels][n_cells]` f64 `acc` rows followed
/// by one `[n_cells]` f64 `wsum` row, all little-endian. Band writes from
/// concurrent pipelines target disjoint byte ranges (each group owns its
/// channels; `wsum` is written by the group that owns it), serialised
/// through one seek+write handle.
pub struct CubeFile {
    file: Mutex<File>,
    path: PathBuf,
    n_channels: usize,
    n_cells: usize,
    spill_bytes: AtomicU64,
}

impl CubeFile {
    /// Total cube size in bytes for a given shape.
    pub fn total_bytes(n_channels: usize, n_cells: usize) -> u64 {
        ((n_channels + 1) as u64) * (n_cells as u64) * 8
    }

    /// Create (or truncate) a cube of the given shape, preallocated to its
    /// final size so every later write is in-place.
    pub fn create(path: &Path, n_channels: usize, n_cells: usize) -> Result<CubeFile> {
        let ctx = path.display().to_string();
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .map_err(HegridError::io(ctx))?;
        file.set_len(Self::total_bytes(n_channels, n_cells))
            .map_err(HegridError::io(path.display().to_string()))?;
        Ok(CubeFile {
            file: Mutex::new(file),
            path: path.to_path_buf(),
            n_channels,
            n_cells,
            spill_bytes: AtomicU64::new(0),
        })
    }

    /// Open an existing cube (resume path), verifying its size matches the
    /// expected shape.
    pub fn open(path: &Path, n_channels: usize, n_cells: usize) -> Result<CubeFile> {
        let ctx = path.display().to_string();
        let file =
            OpenOptions::new().read(true).write(true).open(path).map_err(HegridError::io(ctx))?;
        let expected = Self::total_bytes(n_channels, n_cells);
        let actual = file.metadata().map_err(HegridError::io(path.display().to_string()))?.len();
        if actual != expected {
            return Err(HegridError::Corrupt(format!(
                "{}: checkpoint cube is {actual} bytes, expected {expected}",
                path.display()
            )));
        }
        Ok(CubeFile {
            file: Mutex::new(file),
            path: path.to_path_buf(),
            n_channels,
            n_cells,
            spill_bytes: AtomicU64::new(0),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn n_channels(&self) -> usize {
        self.n_channels
    }

    pub fn n_cells(&self) -> usize {
        self.n_cells
    }

    /// Bytes spilled through this handle so far (bench accounting).
    pub fn spill_bytes(&self) -> u64 {
        self.spill_bytes.load(Ordering::Relaxed)
    }

    fn acc_offset(&self, ch: usize, cell0: usize) -> u64 {
        debug_assert!(ch < self.n_channels && cell0 <= self.n_cells);
        ((ch * self.n_cells + cell0) as u64) * 8
    }

    fn wsum_offset(&self, cell0: usize) -> u64 {
        debug_assert!(cell0 <= self.n_cells);
        ((self.n_channels * self.n_cells + cell0) as u64) * 8
    }

    fn write_at(&self, offset: u64, vals: &[f64], digest: Option<&mut Crc32>) -> Result<()> {
        let bytes = f64s_to_le(vals);
        if let Some(d) = digest {
            d.update(&bytes);
        }
        // Poisoning-tolerant: every op re-seeks, so the inner File carries
        // no state a panicked holder could have corrupted — and aborting a
        // degrade-mode run over a poisoned lock would defeat quarantine.
        let mut f = self.file.lock().unwrap_or_else(|p| p.into_inner());
        f.seek(SeekFrom::Start(offset)).map_err(HegridError::io(self.path.display().to_string()))?;
        f.write_all(&bytes).map_err(HegridError::io(self.path.display().to_string()))?;
        self.spill_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn read_at(&self, offset: u64, len: usize, out: &mut Vec<f64>) -> Result<()> {
        let mut bytes = vec![0u8; len * 8];
        {
            let mut f = self.file.lock().unwrap_or_else(|p| p.into_inner());
            f.seek(SeekFrom::Start(offset))
                .map_err(HegridError::io(self.path.display().to_string()))?;
            f.read_exact(&mut bytes).map_err(HegridError::io(self.path.display().to_string()))?;
        }
        le_to_f64s(&bytes, out);
        Ok(())
    }

    /// Write a band `[cell0, cell0 + vals.len())` of channel `ch`'s
    /// accumulator row, feeding the written bytes into `digest` when given.
    pub fn write_channel_band(
        &self,
        ch: usize,
        cell0: usize,
        vals: &[f64],
        digest: Option<&mut Crc32>,
    ) -> Result<()> {
        assert!(cell0 + vals.len() <= self.n_cells, "band past the cube");
        self.write_at(self.acc_offset(ch, cell0), vals, digest)
    }

    /// Write a band of the weight-sum row.
    pub fn write_wsum_band(
        &self,
        cell0: usize,
        vals: &[f64],
        digest: Option<&mut Crc32>,
    ) -> Result<()> {
        assert!(cell0 + vals.len() <= self.n_cells, "band past the cube");
        self.write_at(self.wsum_offset(cell0), vals, digest)
    }

    /// Read `len` cells of channel `ch`'s accumulator row from `cell0`.
    pub fn read_channel_band(
        &self,
        ch: usize,
        cell0: usize,
        len: usize,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        assert!(cell0 + len <= self.n_cells, "band past the cube");
        self.read_at(self.acc_offset(ch, cell0), len, out)
    }

    /// Read `len` cells of the weight-sum row from `cell0`.
    pub fn read_wsum_band(&self, cell0: usize, len: usize, out: &mut Vec<f64>) -> Result<()> {
        assert!(cell0 + len <= self.n_cells, "band past the cube");
        self.read_at(self.wsum_offset(cell0), len, out)
    }
}

/// CRC'd record of a tiled run's progress: the job identity plus one
/// `(group, crc)` entry per finished channel group. Atomic persistence:
/// written to a temp file and renamed over `manifest.json` after every
/// finished group, so a crash leaves either the old or the new manifest,
/// never a torn one.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointManifest {
    /// Canonical job-identity string (grid geometry, kernel parameters,
    /// sample/channel counts, variant, tile height). Resume refuses to mix
    /// checkpoints across different identities.
    pub job: String,
    /// `(original group index, streaming CRC-32 of that group's cube bytes
    /// in write order)`, sorted by group.
    pub groups_done: Vec<(usize, u32)>,
    /// Quarantined groups of a degrade-mode run: `(original group index,
    /// terminal cause)`, sorted by group. Their cube planes were zeroed;
    /// `--resume` re-grids exactly these (plus any never-started groups).
    pub groups_failed: Vec<(usize, String)>,
}

impl CheckpointManifest {
    pub fn new(job: impl Into<String>) -> Self {
        CheckpointManifest { job: job.into(), groups_done: Vec::new(), groups_failed: Vec::new() }
    }

    pub fn job_crc(&self) -> u32 {
        crc32(self.job.as_bytes())
    }

    /// CRC of the finished-group's cube bytes, if the group is recorded.
    pub fn done_crc(&self, group: usize) -> Option<u32> {
        self.groups_done.iter().find(|(g, _)| *g == group).map(|&(_, c)| c)
    }

    pub fn is_done(&self, group: usize) -> bool {
        self.done_crc(group).is_some()
    }

    /// Record a finished group (idempotent; keeps the list sorted). A group
    /// that re-gridded successfully on resume stops being failed.
    pub fn record(&mut self, group: usize, crc: u32) {
        self.groups_failed.retain(|(g, _)| *g != group);
        match self.groups_done.binary_search_by_key(&group, |&(g, _)| g) {
            Ok(i) => self.groups_done[i] = (group, crc),
            Err(i) => self.groups_done.insert(i, (group, crc)),
        }
    }

    /// Whether the group is quarantined (failed in a degrade-mode run).
    pub fn is_failed(&self, group: usize) -> bool {
        self.groups_failed.iter().any(|(g, _)| *g == group)
    }

    /// Record a quarantined group (idempotent; keeps the list sorted).
    ///
    /// Demotes the group from `groups_done` if present: a torn manifest save
    /// *after* `record()` leaves the in-memory manifest claiming the group is
    /// done while the failure path quarantines it — the failure wins, so the
    /// next save (and `--resume`) re-grids the group instead of trusting it.
    pub fn record_failed(&mut self, group: usize, cause: &str) {
        self.groups_done.retain(|(g, _)| *g != group);
        match self.groups_failed.binary_search_by_key(&group, |(g, _)| *g) {
            Ok(i) => self.groups_failed[i] = (group, cause.to_string()),
            Err(i) => self.groups_failed.insert(i, (group, cause.to_string())),
        }
    }

    /// Canonical digest the manifest CRC covers: independent of JSON
    /// formatting, so a load + save round trip can never drift. Failed
    /// entries only contribute when present, so a manifest without any (the
    /// only kind older versions could write) keeps its old digest.
    fn digest(&self) -> u32 {
        let mut s = format!("hegrid-checkpoint-v{MANIFEST_VERSION}|{:08x}|", self.job_crc());
        for &(g, c) in &self.groups_done {
            s.push_str(&format!("g{g}:{c:08x}|"));
        }
        for (g, cause) in &self.groups_failed {
            s.push_str(&format!("f{g}:{:08x}|", crc32(cause.as_bytes())));
        }
        crc32(s.as_bytes())
    }

    fn to_json(&self) -> Json {
        let groups: Vec<Json> = self
            .groups_done
            .iter()
            .map(|&(g, c)| {
                Json::obj(vec![("group", Json::num(g as f64)), ("crc", Json::num(c as f64))])
            })
            .collect();
        let failed: Vec<Json> = self
            .groups_failed
            .iter()
            .map(|(g, cause)| {
                Json::obj(vec![
                    ("group", Json::num(*g as f64)),
                    ("cause", Json::str(cause.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::num(MANIFEST_VERSION as f64)),
            ("job", Json::str(self.job.clone())),
            ("job_crc", Json::num(self.job_crc() as f64)),
            ("groups_done", Json::Arr(groups)),
            ("groups_failed", Json::Arr(failed)),
            ("crc", Json::num(self.digest() as f64)),
        ])
    }

    /// Atomically persist to `dir/manifest.json` (temp file + rename).
    pub fn save(&self, dir: &Path) -> Result<()> {
        let path = dir.join(MANIFEST_FILE);
        let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
        let ctx = tmp.display().to_string();
        let bytes = self.to_json().to_pretty().into_bytes();
        if crate::util::faults::torn_checkpoint_write() {
            // Simulate a crash mid-write: half the bytes land in the temp
            // file and the rename never happens, so `manifest.json` keeps
            // its previous (still-valid) contents.
            let mut f = File::create(&tmp).map_err(HegridError::io(ctx.clone()))?;
            let _ = f.write_all(&bytes[..bytes.len() / 2]);
            return Err(HegridError::Io {
                context: ctx,
                source: std::io::Error::other("injected torn checkpoint write"),
            });
        }
        {
            let mut f = File::create(&tmp).map_err(HegridError::io(ctx.clone()))?;
            f.write_all(&bytes).map_err(HegridError::io(ctx.clone()))?;
            f.sync_all().map_err(HegridError::io(ctx.clone()))?;
        }
        std::fs::rename(&tmp, &path).map_err(HegridError::io(path.display().to_string()))
    }

    /// Load and CRC-verify `dir/manifest.json`. A digest mismatch is a typed
    /// [`HegridError::Corrupt`]: resume fails loudly instead of silently
    /// re-gridding (or trusting) a damaged checkpoint.
    pub fn load(dir: &Path) -> Result<CheckpointManifest> {
        let path = dir.join(MANIFEST_FILE);
        let ctx = path.display().to_string();
        let text = std::fs::read_to_string(&path).map_err(HegridError::io(ctx.clone()))?;
        let v = crate::json::parse(&text)?;
        let version = v.req_usize("version")?;
        if version != MANIFEST_VERSION {
            return Err(HegridError::Format(format!(
                "{ctx}: unsupported checkpoint manifest version {version}"
            )));
        }
        let job = v.req_str("job")?.to_string();
        let mut groups_done = Vec::new();
        for e in v.req_arr("groups_done")? {
            let g = e.req_usize("group")?;
            let c = e.req_usize("crc")? as u32;
            groups_done.push((g, c));
        }
        groups_done.sort_unstable_by_key(|&(g, _)| g);
        // Optional for manifests written before quarantine support existed.
        let mut groups_failed = Vec::new();
        if let Some(arr) = v.get("groups_failed") {
            let arr = arr
                .as_arr()
                .ok_or_else(|| HegridError::Format("field 'groups_failed' is not an array".into()))?;
            for e in arr {
                let g = e.req_usize("group")?;
                let cause = e.req_str("cause")?.to_string();
                groups_failed.push((g, cause));
            }
            groups_failed.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        }
        let manifest = CheckpointManifest { job, groups_done, groups_failed };
        let stored = v.req_usize("crc")? as u32;
        if stored != manifest.digest() {
            return Err(HegridError::Corrupt(format!(
                "{ctx}: checkpoint manifest CRC mismatch (stored {stored:#010x}, computed {:#010x})",
                manifest.digest()
            )));
        }
        let stored_job = v.req_usize("job_crc")? as u32;
        if stored_job != manifest.job_crc() {
            return Err(HegridError::Corrupt(format!(
                "{ctx}: checkpoint manifest job CRC mismatch"
            )));
        }
        Ok(manifest)
    }
}

/// Monotonic counter for anonymous spill-cube names (no clock, no RNG).
static ANON_CUBES: AtomicU64 = AtomicU64::new(0);

/// Path for an anonymous (non-checkpointed) spill cube, unique per process.
pub fn anonymous_cube_path() -> PathBuf {
    let n = ANON_CUBES.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("hegrid_cube_{}_{n}.bin", std::process::id()))
}

/// A finished tiled run's output cube, ready to be normalised into
/// [`SkyMap`]s one channel at a time (bounded memory: one acc row + the
/// wsum row resident per read). Anonymous cubes are deleted on drop;
/// checkpointed cubes are kept.
pub struct CubeHandle {
    cube: CubeFile,
    spec: GridSpec,
    cleanup: bool,
}

impl CubeHandle {
    pub fn new(cube: CubeFile, spec: GridSpec, cleanup: bool) -> CubeHandle {
        debug_assert_eq!(cube.n_cells(), spec.n_cells());
        CubeHandle { cube, spec, cleanup }
    }

    pub fn path(&self) -> &Path {
        self.cube.path()
    }

    pub fn spec(&self) -> &GridSpec {
        &self.spec
    }

    pub fn n_channels(&self) -> usize {
        self.cube.n_channels()
    }

    /// Bytes spilled into the cube by the run that produced this handle.
    pub fn spill_bytes(&self) -> u64 {
        self.cube.spill_bytes()
    }

    /// Normalise channel `ch` into a map — the same
    /// [`SkyMap::from_accumulators`] arithmetic as the untiled path, so the
    /// result is bit-identical to it.
    pub fn read_map(&self, ch: usize) -> Result<SkyMap> {
        let n = self.cube.n_cells();
        let mut acc = Vec::new();
        let mut wsum = Vec::new();
        self.cube.read_channel_band(ch, 0, n, &mut acc)?;
        self.cube.read_wsum_band(0, n, &mut wsum)?;
        SkyMap::from_accumulators(self.spec.clone(), &acc, &wsum)
    }

    /// All channels as maps (materialises the full output — callers that
    /// only need per-channel access should iterate [`CubeHandle::read_map`]).
    pub fn read_all_maps(&self) -> Result<Vec<SkyMap>> {
        (0..self.n_channels()).map(|c| self.read_map(c)).collect()
    }

    /// Keep the cube on disk (disarm anonymous cleanup) and return its path.
    pub fn keep(mut self) -> PathBuf {
        self.cleanup = false;
        self.cube.path().to_path_buf()
    }
}

impl Drop for CubeHandle {
    fn drop(&mut self) {
        if self.cleanup {
            let _ = std::fs::remove_file(self.cube.path());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("hegrid_checkpoint_test").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn cube_bands_round_trip() {
        let dir = tmp_dir("cube");
        let path = dir.join(CUBE_FILE);
        let cube = CubeFile::create(&path, 2, 10).unwrap();
        assert_eq!(CubeFile::total_bytes(2, 10), 3 * 10 * 8);
        let mut digest = Crc32::new();
        cube.write_channel_band(0, 0, &[1.0, 2.0, 3.0], Some(&mut digest)).unwrap();
        cube.write_channel_band(1, 4, &[4.0, 5.0], None).unwrap();
        cube.write_wsum_band(8, &[0.5, 0.25], None).unwrap();
        let mut out = Vec::new();
        cube.read_channel_band(0, 0, 4, &mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0, 0.0]);
        cube.read_channel_band(1, 4, 2, &mut out).unwrap();
        assert_eq!(out, vec![4.0, 5.0]);
        cube.read_wsum_band(7, 3, &mut out).unwrap();
        assert_eq!(out, vec![0.0, 0.5, 0.25]);
        assert_eq!(cube.spill_bytes(), (3 + 2 + 2) * 8);
        // The digest saw exactly the written bytes.
        assert_eq!(digest.finalize(), crc32(&f64s_to_le(&[1.0, 2.0, 3.0])));
        // Reopen with the right/wrong shape.
        drop(cube);
        CubeFile::open(&path, 2, 10).unwrap();
        match CubeFile::open(&path, 3, 10) {
            Err(HegridError::Corrupt(m)) => assert!(m.contains("expected")),
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn manifest_round_trip_and_corruption() {
        let dir = tmp_dir("manifest");
        let mut m = CheckpointManifest::new("job-identity-v1");
        m.record(2, 0xDEAD_BEEF);
        m.record(0, 17);
        m.record(2, 0xBEEF_DEAD); // overwrite keeps one entry
        assert_eq!(m.groups_done, vec![(0, 17), (2, 0xBEEF_DEAD)]);
        assert!(m.is_done(0) && !m.is_done(1));
        m.save(&dir).unwrap();
        let back = CheckpointManifest::load(&dir).unwrap();
        assert_eq!(back, m);

        // Flip a byte inside the stored CRC value: typed Corrupt.
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        let bad = text.replacen("\"job\": \"job-identity-v1\"", "\"job\": \"job-identity-v2\"", 1);
        assert_ne!(text, bad, "substitution must hit");
        std::fs::write(&path, bad).unwrap();
        match CheckpointManifest::load(&dir) {
            Err(HegridError::Corrupt(msg)) => assert!(msg.contains("CRC"), "{msg}"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn manifest_failed_groups_round_trip_and_demotion() {
        let dir = tmp_dir("manifest_failed");
        let mut m = CheckpointManifest::new("job-identity-v1");
        m.record(0, 17);
        m.record(1, 23);
        m.record_failed(3, "injected transient read error");
        m.record_failed(1, "worker panicked"); // demotes a done group
        assert_eq!(m.groups_done, vec![(0, 17)]);
        assert!(m.is_failed(1) && m.is_failed(3) && !m.is_failed(0));
        m.save(&dir).unwrap();
        let back = CheckpointManifest::load(&dir).unwrap();
        assert_eq!(back, m);

        // A successful re-grid clears the quarantine entry.
        m.record(1, 42);
        assert!(!m.is_failed(1) && m.is_done(1));
        assert_eq!(m.groups_failed, vec![(3, "injected transient read error".to_string())]);
    }

    #[test]
    fn manifest_without_failed_field_still_loads() {
        // Manifests written before quarantine support carry no
        // `groups_failed`; with none failed the digest is unchanged, so the
        // old JSON (minus the field) must load verbatim.
        let dir = tmp_dir("manifest_compat");
        let mut m = CheckpointManifest::new("job-identity-v1");
        m.record(5, 99);
        m.save(&dir).unwrap();
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        let stripped = text.replacen(",\n  \"groups_failed\": []", "", 1);
        assert_ne!(text, stripped, "substitution must hit");
        std::fs::write(&path, stripped).unwrap();
        let back = CheckpointManifest::load(&dir).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn cube_handle_cleanup_and_keep() {
        let spec = GridSpec::centered(30.0, 41.0, 4, 3, 0.25);
        let path = anonymous_cube_path();
        let cube = CubeFile::create(&path, 1, spec.n_cells()).unwrap();
        cube.write_channel_band(0, 0, &[2.0; 12], None).unwrap();
        cube.write_wsum_band(0, &[2.0; 12], None).unwrap();
        let handle = CubeHandle::new(cube, spec.clone(), true);
        let map = handle.read_map(0).unwrap();
        assert!(map.values().iter().all(|&v| v == 1.0));
        drop(handle);
        assert!(!path.exists(), "anonymous cube removed on drop");

        let path2 = anonymous_cube_path();
        assert_ne!(path, path2, "anonymous paths are unique");
        let cube = CubeFile::create(&path2, 1, spec.n_cells()).unwrap();
        let handle = CubeHandle::new(cube, spec, true);
        let kept = handle.keep();
        assert!(kept.exists(), "kept cube survives drop");
        std::fs::remove_file(kept).unwrap();
    }
}
