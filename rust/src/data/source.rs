//! Channel ingest abstraction: where the coordinator's pipelines get their
//! per-channel values from.
//!
//! The paper's third co-optimization (§4.3) hides host I/O behind device
//! compute. That is only possible if the engine does **not** require the
//! whole multi-channel dataset in memory up front, so the data→coordinator
//! contract is this trait instead of a materialized [`Dataset`]:
//!
//! * [`InMemorySource`] — wraps an existing [`Dataset`]; reads are memcpys.
//!   The eager path every caller used before streaming existed.
//! * [`HgdStreamSource`] — reads channels lazily from an HGD file through a
//!   small pool of [`HgdReader`]s; at no point are more than the prefetch
//!   window's channels resident, so datasets larger than RAM grid fine.
//! * `sim::SimSource` — deterministic on-demand synthesis for tests and
//!   benches (lives in [`crate::sim`]).
//!
//! Sources are consumed by the I/O workers of
//! [`crate::runtime::prefetch::Prefetcher`], which is why every method takes
//! `&self` and implementations must be `Sync`.
//!
//! ```
//! use hegrid::data::{ChannelSource, InMemorySource};
//!
//! let dataset = hegrid::sim::SimConfig::quick_preset().generate();
//! let source = InMemorySource::new(&dataset);
//! assert_eq!(source.n_channels(), dataset.n_channels());
//! assert_eq!(source.coords().unwrap().0, dataset.lons.as_slice());
//!
//! // Reads land in a caller-owned buffer (the prefetcher recycles pooled
//! // ones) and round-trip the channel exactly.
//! let mut buf = Vec::new();
//! source.read_channel_into(0, &mut buf).unwrap();
//! assert_eq!(buf, dataset.channels[0]);
//! ```

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use super::{Dataset, DatasetMeta, HgdReader};
use crate::util::error::Result;

/// A multi-channel dataset whose channel values are produced on demand.
pub trait ChannelSource: Sync {
    /// Dataset metadata (map geometry is derived from this).
    fn meta(&self) -> &DatasetMeta;

    /// Samples per channel.
    fn n_samples(&self) -> usize;

    /// Total number of channels.
    fn n_channels(&self) -> usize;

    /// The shared sample coordinates (radians), borrowed from the source
    /// (no copy — the gridding run only needs them for the duration of the
    /// call that borrowed the source).
    fn coords(&self) -> Result<(&[f64], &[f64])>;

    /// Read channel `c`'s values into `out` (cleared first; exactly
    /// `n_samples` values on success). Must be callable concurrently from
    /// multiple I/O worker threads.
    fn read_channel_into(&self, c: usize, out: &mut Vec<f32>) -> Result<()>;
}

/// Eager source over a borrowed [`Dataset`] — the pre-streaming behaviour.
/// Fully zero-copy on coordinates; channel values are copied once into the
/// prefetch ring's pooled buffers.
pub struct InMemorySource<'a> {
    dataset: &'a Dataset,
}

impl<'a> InMemorySource<'a> {
    pub fn new(dataset: &'a Dataset) -> Self {
        InMemorySource { dataset }
    }
}

impl ChannelSource for InMemorySource<'_> {
    fn meta(&self) -> &DatasetMeta {
        &self.dataset.meta
    }

    fn n_samples(&self) -> usize {
        self.dataset.n_samples()
    }

    fn n_channels(&self) -> usize {
        self.dataset.n_channels()
    }

    fn coords(&self) -> Result<(&[f64], &[f64])> {
        Ok((&self.dataset.lons, &self.dataset.lats))
    }

    fn read_channel_into(&self, c: usize, out: &mut Vec<f32>) -> Result<()> {
        out.clear();
        out.extend_from_slice(&self.dataset.channels[c]);
        Ok(())
    }
}

/// Streaming source over an HGD file: channels are read from disk on
/// demand. Concurrent reads check a reader out of a bounded pool (each
/// reader owns its own file handle + position), so `io_workers` readers can
/// stream different channels of the same file in parallel.
pub struct HgdStreamSource {
    path: PathBuf,
    meta: DatasetMeta,
    n_samples: usize,
    n_channels: usize,
    lons: Vec<f64>,
    lats: Vec<f64>,
    readers: Mutex<Vec<HgdReader>>,
    max_readers: usize,
}

impl HgdStreamSource {
    /// Open the file, validate its header, and load the shared coordinate
    /// table (the only part of the payload a streaming run keeps resident).
    pub fn open(path: &Path) -> Result<HgdStreamSource> {
        let mut reader = HgdReader::open(path)?;
        let (lons, lats) = reader.read_coords()?;
        Ok(HgdStreamSource {
            path: path.to_path_buf(),
            meta: reader.meta().clone(),
            n_samples: reader.n_samples(),
            n_channels: reader.n_channels(),
            lons,
            lats,
            readers: Mutex::new(vec![reader]),
            max_readers: 8,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn checkout(&self) -> Result<HgdReader> {
        if let Some(r) = self.readers.lock().unwrap().pop() {
            return Ok(r);
        }
        // Pool miss: `open` already length-validated this path, so the
        // fresh handle skips the per-open truncation stat (a resumed
        // many-group run would otherwise re-stat once per group).
        HgdReader::reopen_validated(&self.path)
    }

    fn checkin(&self, reader: HgdReader) {
        let mut pool = self.readers.lock().unwrap();
        if pool.len() < self.max_readers {
            pool.push(reader);
        }
    }
}

impl ChannelSource for HgdStreamSource {
    fn meta(&self) -> &DatasetMeta {
        &self.meta
    }

    fn n_samples(&self) -> usize {
        self.n_samples
    }

    fn n_channels(&self) -> usize {
        self.n_channels
    }

    fn coords(&self) -> Result<(&[f64], &[f64])> {
        Ok((&self.lons, &self.lats))
    }

    fn read_channel_into(&self, c: usize, out: &mut Vec<f32>) -> Result<()> {
        let mut reader = self.checkout()?;
        let res = reader.read_channel_into(c, out);
        // Return the reader even after a failed read: the handle is fine,
        // only this block's payload was bad.
        self.checkin(reader);
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("hegrid_source_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn in_memory_source_mirrors_dataset() {
        let d = SimConfig::quick_preset().generate();
        let s = InMemorySource::new(&d);
        assert_eq!(s.n_samples(), d.n_samples());
        assert_eq!(s.n_channels(), d.n_channels());
        let (lons, lats) = s.coords().unwrap();
        assert_eq!(lons, d.lons.as_slice());
        assert_eq!(lats, d.lats.as_slice());
        let mut buf = Vec::new();
        for c in 0..d.n_channels() {
            s.read_channel_into(c, &mut buf).unwrap();
            assert_eq!(buf, d.channels[c]);
        }
    }

    #[test]
    fn hgd_stream_source_reads_lazily_and_concurrently() {
        let d = SimConfig::quick_preset().generate();
        let path = tmp("stream.hgd");
        d.save(&path).unwrap();
        let s = HgdStreamSource::open(&path).unwrap();
        assert_eq!(s.meta(), &d.meta);
        assert_eq!(s.n_samples(), d.n_samples());
        let (lons, _) = s.coords().unwrap();
        assert_eq!(lons, d.lons.as_slice());
        // Concurrent reads from several threads must all round-trip.
        std::thread::scope(|scope| {
            for t in 0..4 {
                let (s, d) = (&s, &d);
                scope.spawn(move || {
                    let mut buf = Vec::new();
                    for c in (0..d.n_channels()).rev() {
                        s.read_channel_into((c + t) % d.n_channels(), &mut buf).unwrap();
                        assert_eq!(buf, d.channels[(c + t) % d.n_channels()]);
                    }
                });
            }
        });
    }

    #[test]
    fn hgd_stream_source_surfaces_corruption() {
        let d = SimConfig::quick_preset().generate();
        let path = tmp("corrupt_stream.hgd");
        d.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let idx = bytes.len() - 10; // inside the last channel block
        bytes[idx] ^= 0xFF;
        std::fs::write(&path, bytes).unwrap();
        let s = HgdStreamSource::open(&path).unwrap();
        let mut buf = Vec::new();
        s.read_channel_into(0, &mut buf).unwrap();
        let last = d.n_channels() - 1;
        assert!(matches!(
            s.read_channel_into(last, &mut buf),
            Err(crate::util::error::HegridError::Corrupt(_))
        ));
    }
}
