//! HGD container: the HDF5 stand-in (see `data` module docs).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset 0:  magic  b"HGD1"
//!            version u32 (=1)
//!            n_samples u64
//!            n_channels u32
//!            meta_len u32, meta JSON (UTF-8)
//! coords:    lons f64[n], lats f64[n], crc32 u32   (crc over both arrays)
//! channel c: values f32[n], crc32 u32              (independently seekable)
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use super::DatasetMeta;
use crate::util::crc32::Crc32;
use crate::util::error::{HegridError, Result};

const MAGIC: &[u8; 4] = b"HGD1";
const VERSION: u32 = 1;

/// Streaming writer. Channels must be written in order after the coords.
pub struct HgdWriter {
    out: BufWriter<File>,
    path: String,
    n_samples: usize,
    n_channels: usize,
    coords_written: bool,
    channels_written: usize,
}

impl HgdWriter {
    pub fn create(
        path: &Path,
        meta: &DatasetMeta,
        n_samples: usize,
        n_channels: usize,
    ) -> Result<HgdWriter> {
        let file = File::create(path).map_err(HegridError::io(path.display().to_string()))?;
        let mut out = BufWriter::new(file);
        let meta_json = meta.to_json().to_string().into_bytes();
        let ctx = path.display().to_string();
        (|| -> std::io::Result<()> {
            out.write_all(MAGIC)?;
            out.write_all(&VERSION.to_le_bytes())?;
            out.write_all(&(n_samples as u64).to_le_bytes())?;
            out.write_all(&(n_channels as u32).to_le_bytes())?;
            out.write_all(&(meta_json.len() as u32).to_le_bytes())?;
            out.write_all(&meta_json)
        })()
        .map_err(HegridError::io(ctx.clone()))?;
        Ok(HgdWriter {
            out,
            path: ctx,
            n_samples,
            n_channels,
            coords_written: false,
            channels_written: 0,
        })
    }

    pub fn write_coords(&mut self, lons: &[f64], lats: &[f64]) -> Result<()> {
        if self.coords_written {
            return Err(HegridError::Internal("coords written twice".into()));
        }
        if lons.len() != self.n_samples || lats.len() != self.n_samples {
            return Err(HegridError::Format(format!(
                "coords length {} != declared n_samples {}",
                lons.len(),
                self.n_samples
            )));
        }
        let mut crc = Crc32::new();
        for arr in [lons, lats] {
            let bytes = f64s_to_le_bytes(arr);
            crc.update(&bytes);
            self.out.write_all(&bytes).map_err(HegridError::io(self.path.clone()))?;
        }
        self.out
            .write_all(&crc.finalize().to_le_bytes())
            .map_err(HegridError::io(self.path.clone()))?;
        self.coords_written = true;
        Ok(())
    }

    pub fn write_channel(&mut self, values: &[f32]) -> Result<()> {
        if !self.coords_written {
            return Err(HegridError::Internal("write coords before channels".into()));
        }
        if self.channels_written >= self.n_channels {
            return Err(HegridError::Internal("too many channels written".into()));
        }
        if values.len() != self.n_samples {
            return Err(HegridError::Format(format!(
                "channel length {} != n_samples {}",
                values.len(),
                self.n_samples
            )));
        }
        let bytes = f32s_to_le_bytes(values);
        let mut crc = Crc32::new();
        crc.update(&bytes);
        self.out.write_all(&bytes).map_err(HegridError::io(self.path.clone()))?;
        self.out
            .write_all(&crc.finalize().to_le_bytes())
            .map_err(HegridError::io(self.path.clone()))?;
        self.channels_written += 1;
        Ok(())
    }

    /// Flush and validate that the declared channel count was written.
    pub fn finish(mut self) -> Result<()> {
        if self.channels_written != self.n_channels {
            return Err(HegridError::Format(format!(
                "wrote {} of {} declared channels",
                self.channels_written, self.n_channels
            )));
        }
        self.out.flush().map_err(HegridError::io(self.path.clone()))
    }
}

/// Random-access reader; channel blocks can be read in any order — the
/// coordinator's pipelines stream channels independently. Sequential
/// channel reads skip the per-call seek (keeping the read-ahead buffer
/// warm), which is the common pattern of the streaming ingest path.
pub struct HgdReader {
    file: BufReader<File>,
    path: String,
    meta: DatasetMeta,
    n_samples: usize,
    n_channels: usize,
    coords_offset: u64,
    /// Current stream position; all reads go through helpers that keep it
    /// exact, so redundant seeks (which discard the BufReader buffer) can
    /// be elided.
    pos: u64,
}

impl HgdReader {
    pub fn open(path: &Path) -> Result<HgdReader> {
        Self::open_inner(path, true)
    }

    /// Reopen a path that an earlier [`HgdReader::open`] already
    /// length-validated, skipping the file-length stat. This is the pooled
    /// reader-miss path of [`crate::data::HgdStreamSource`]: without it a
    /// resumed many-group run re-stats the dataset once per pool miss (up
    /// to once per channel group). Every block read still verifies its CRC,
    /// so a file truncated *after* the validated open surfaces as a typed
    /// read/CRC error instead of going unnoticed.
    pub(crate) fn reopen_validated(path: &Path) -> Result<HgdReader> {
        Self::open_inner(path, false)
    }

    fn open_inner(path: &Path, check_len: bool) -> Result<HgdReader> {
        let ctx = path.display().to_string();
        let file = File::open(path).map_err(HegridError::io(ctx.clone()))?;
        let mut file = BufReader::new(file);

        let mut magic = [0u8; 4];
        file.read_exact(&mut magic).map_err(HegridError::io(ctx.clone()))?;
        if &magic != MAGIC {
            return Err(HegridError::Format(format!("{ctx}: not an HGD file (bad magic)")));
        }
        let version = read_u32(&mut file, &ctx)?;
        if version != VERSION {
            return Err(HegridError::Format(format!("{ctx}: unsupported HGD version {version}")));
        }
        let n_samples = read_u64(&mut file, &ctx)? as usize;
        let n_channels = read_u32(&mut file, &ctx)? as usize;
        let meta_len = read_u32(&mut file, &ctx)? as usize;
        if meta_len > 1 << 20 {
            return Err(HegridError::Format(format!("{ctx}: implausible meta length {meta_len}")));
        }
        let mut meta_buf = vec![0u8; meta_len];
        file.read_exact(&mut meta_buf).map_err(HegridError::io(ctx.clone()))?;
        let meta_text = String::from_utf8(meta_buf)
            .map_err(|_| HegridError::Format(format!("{ctx}: meta is not UTF-8")))?;
        let meta = DatasetMeta::from_json(&crate::json::parse(&meta_text)?)?;
        let coords_offset = 4 + 4 + 8 + 4 + 4 + meta_len as u64;
        // Cheap up-front integrity check: the header promises a fixed layout,
        // so a short file can be diagnosed now instead of as a read error
        // mid-stream. Widened arithmetic: n_samples/n_channels come straight
        // from the (possibly hostile) header, so the product must not wrap.
        // Validated re-opens (`reopen_validated`) skip the stat — the first
        // open of the path already ran it.
        if check_len {
            let expected = coords_offset as u128
                + (n_samples as u128 * 16 + 4)
                + n_channels as u128 * (n_samples as u128 * 4 + 4);
            let actual = file
                .get_ref()
                .metadata()
                .map_err(HegridError::io(ctx.clone()))?
                .len();
            if (actual as u128) < expected {
                return Err(HegridError::Corrupt(format!(
                    "{ctx}: truncated HGD file ({actual} bytes, header declares {expected})"
                )));
            }
        }
        Ok(HgdReader {
            file,
            path: ctx,
            meta,
            n_samples,
            n_channels,
            coords_offset,
            pos: coords_offset,
        })
    }

    /// Position the stream at `offset`, skipping the syscall (and keeping the
    /// BufReader's read-ahead) when already there.
    fn seek_to(&mut self, offset: u64) -> Result<()> {
        if self.pos != offset {
            self.file
                .seek(SeekFrom::Start(offset))
                .map_err(HegridError::io(self.path.clone()))?;
            self.pos = offset;
        }
        Ok(())
    }

    fn read_exact_tracked(&mut self, buf: &mut [u8]) -> Result<()> {
        if let Err(e) = self.file.read_exact(buf) {
            // The OS cursor may have advanced an unknown amount: poison the
            // tracked position so the next access re-seeks instead of
            // trusting a stale elision (readers are pooled and reused).
            self.pos = u64::MAX;
            return Err(HegridError::io(self.path.clone())(e));
        }
        self.pos += buf.len() as u64;
        Ok(())
    }

    pub fn meta(&self) -> &DatasetMeta {
        &self.meta
    }

    pub fn n_samples(&self) -> usize {
        self.n_samples
    }

    pub fn n_channels(&self) -> usize {
        self.n_channels
    }

    fn coords_block_len(&self) -> u64 {
        (self.n_samples * 16 + 4) as u64
    }

    fn channel_block_len(&self) -> u64 {
        (self.n_samples * 4 + 4) as u64
    }

    /// Read the shared coordinate table (radians), verifying its CRC.
    pub fn read_coords(&mut self) -> Result<(Vec<f64>, Vec<f64>)> {
        self.seek_to(self.coords_offset)?;
        let mut buf = vec![0u8; self.n_samples * 16];
        self.read_exact_tracked(&mut buf)?;
        let mut stored = [0u8; 4];
        self.read_exact_tracked(&mut stored)?;
        let mut crc = Crc32::new();
        crc.update(&buf);
        if crc.finalize() != u32::from_le_bytes(stored) {
            return Err(HegridError::Corrupt(format!("{}: coords CRC mismatch", self.path)));
        }
        let lons = le_bytes_to_f64s(&buf[..self.n_samples * 8]);
        let lats = le_bytes_to_f64s(&buf[self.n_samples * 8..]);
        Ok((lons, lats))
    }

    /// Read channel `c`'s value block, verifying its CRC.
    pub fn read_channel(&mut self, c: usize) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.read_channel_into(c, &mut out)?;
        Ok(out)
    }

    /// Read channel `c` into a caller-provided buffer (cleared first),
    /// verifying its CRC. Reusing `out` across calls avoids the per-channel
    /// allocation on the streaming ingest path, and consecutive channels are
    /// read without an intervening seek.
    pub fn read_channel_into(&mut self, c: usize, out: &mut Vec<f32>) -> Result<()> {
        if let Some(e) = crate::util::faults::channel_read_fault(c) {
            return Err(e);
        }
        if c >= self.n_channels {
            return Err(HegridError::Format(format!(
                "channel {c} out of range ({} channels)",
                self.n_channels
            )));
        }
        let offset =
            self.coords_offset + self.coords_block_len() + c as u64 * self.channel_block_len();
        self.seek_to(offset)?;
        let mut buf = vec![0u8; self.n_samples * 4];
        self.read_exact_tracked(&mut buf)?;
        let mut stored = [0u8; 4];
        self.read_exact_tracked(&mut stored)?;
        let mut crc = Crc32::new();
        crc.update(&buf);
        if crc.finalize() != u32::from_le_bytes(stored) {
            return Err(HegridError::Corrupt(format!(
                "{}: channel {c} CRC mismatch",
                self.path
            )));
        }
        out.clear();
        out.reserve(self.n_samples);
        out.extend(buf.chunks_exact(4).map(|b| {
            // Invariant, not I/O: chunks_exact(4) yields exactly-4-byte slices.
            f32::from_le_bytes(b.try_into().expect("chunks_exact(4) yields 4-byte slices"))
        }));
        Ok(())
    }
}

// ---- byte helpers ---------------------------------------------------------

fn f64s_to_le_bytes(v: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn f32s_to_le_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn le_bytes_to_f64s(b: &[u8]) -> Vec<f64> {
    // Invariant, not I/O: chunks_exact(8) yields exactly-8-byte slices.
    b.chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().expect("chunks_exact(8) yields 8-byte slices")))
        .collect()
}

fn read_u32<R: Read>(r: &mut R, ctx: &str) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).map_err(HegridError::io(ctx.to_string()))?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R, ctx: &str) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).map_err(HegridError::io(ctx.to_string()))?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::super::{Dataset, DatasetMeta};
    use super::*;
    use crate::util::SplitMix64;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hegrid_hgd_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_dataset(n: usize, c: usize) -> Dataset {
        let mut rng = SplitMix64::new(5);
        let lons: Vec<f64> = (0..n).map(|_| rng.uniform(0.5, 0.6)).collect();
        let lats: Vec<f64> = (0..n).map(|_| rng.uniform(0.7, 0.8)).collect();
        let channels: Vec<Vec<f32>> =
            (0..c).map(|_| (0..n).map(|_| rng.normal() as f32).collect()).collect();
        let meta = DatasetMeta {
            name: "roundtrip".into(),
            beam_arcsec: 300.0,
            center_deg: (30.0, 41.0),
            extent_deg: (10.0, 10.0),
        };
        Dataset::new(meta, lons, lats, channels).unwrap()
    }

    #[test]
    fn round_trip_full_file() {
        let d = sample_dataset(1000, 5);
        let path = tmp("rt.hgd");
        d.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(back.meta, d.meta);
        assert_eq!(back.lons, d.lons);
        assert_eq!(back.lats, d.lats);
        assert_eq!(back.channels, d.channels);
    }

    #[test]
    fn random_access_channels_out_of_order() {
        let d = sample_dataset(257, 4);
        let path = tmp("ooo.hgd");
        d.save(&path).unwrap();
        let mut r = HgdReader::open(&path).unwrap();
        assert_eq!(r.n_samples(), 257);
        assert_eq!(r.n_channels(), 4);
        // Read channels in reverse order without touching coords first.
        for c in (0..4).rev() {
            assert_eq!(r.read_channel(c).unwrap(), d.channels[c]);
        }
        let (lons, _) = r.read_coords().unwrap();
        assert_eq!(lons, d.lons);
    }

    #[test]
    fn corrupted_channel_detected() {
        let d = sample_dataset(64, 2);
        let path = tmp("corrupt.hgd");
        d.save(&path).unwrap();
        // Flip one byte inside channel 1's value block.
        let mut bytes = std::fs::read(&path).unwrap();
        let idx = bytes.len() - 10; // inside the last channel block
        bytes[idx] ^= 0xFF;
        std::fs::write(&path, bytes).unwrap();
        let mut r = HgdReader::open(&path).unwrap();
        assert_eq!(r.read_channel(0).unwrap(), d.channels[0]);
        assert!(matches!(r.read_channel(1), Err(HegridError::Corrupt(_))));
    }

    #[test]
    fn truncated_file_detected_at_open() {
        let d = sample_dataset(64, 2);
        let path = tmp("short.hgd");
        d.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Drop the tail of the last channel block (header stays intact).
        std::fs::write(&path, &bytes[..bytes.len() - 16]).unwrap();
        assert!(matches!(HgdReader::open(&path), Err(HegridError::Corrupt(_))));
    }

    #[test]
    fn read_channel_into_reuses_buffer_and_streams_sequentially() {
        let d = sample_dataset(128, 3);
        let path = tmp("seq.hgd");
        d.save(&path).unwrap();
        let mut r = HgdReader::open(&path).unwrap();
        let mut buf = Vec::new();
        for c in 0..3 {
            r.read_channel_into(c, &mut buf).unwrap();
            assert_eq!(buf, d.channels[c]);
        }
        let cap = buf.capacity();
        // Re-reading into the same buffer must not reallocate.
        r.read_channel_into(0, &mut buf).unwrap();
        assert_eq!(buf.capacity(), cap);
        assert_eq!(buf, d.channels[0]);
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("bad.hgd");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(matches!(HgdReader::open(&path), Err(HegridError::Format(_))));
    }

    #[test]
    fn channel_out_of_range_rejected() {
        let d = sample_dataset(16, 1);
        let path = tmp("range.hgd");
        d.save(&path).unwrap();
        let mut r = HgdReader::open(&path).unwrap();
        assert!(r.read_channel(1).is_err());
    }

    #[test]
    fn writer_enforces_declared_counts() {
        let meta = sample_dataset(4, 1).meta;
        let path = tmp("counts.hgd");
        let mut w = HgdWriter::create(&path, &meta, 4, 2).unwrap();
        // channel before coords
        assert!(w.write_channel(&[0.0; 4]).is_err());
        w.write_coords(&vec![0.0; 4], &vec![0.0; 4]).unwrap();
        // wrong lengths
        assert!(w.write_channel(&[0.0; 3]).is_err());
        w.write_channel(&[0.0; 4]).unwrap();
        // finish with a missing channel
        assert!(w.finish().is_err());
    }

    #[test]
    fn zero_samples_and_channels() {
        let meta = sample_dataset(1, 1).meta;
        let d = Dataset::new(meta, vec![], vec![], vec![]).unwrap();
        let path = tmp("empty.hgd");
        d.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(back.n_samples(), 0);
        assert_eq!(back.n_channels(), 0);
    }
}
