//! Minimal FITS image writer for [`SkyMap`]s.
//!
//! Astronomy toolchains (DS9, astropy, CARTA) consume FITS, not PGM; the
//! paper's outputs feed exactly such tools. This writes a standards-
//! conforming single-HDU primary image: BITPIX = -32 (IEEE f32, big
//! endian), two axes, and a CAR (plate carrée) WCS matching [`GridSpec`].
//! Blank cells are written as NaN, which FITS viewers render as blank.
//!
//! Scope: writer only (HEGrid emits maps, it does not read them back);
//! 2880-byte logical records, mandatory keywords, END padding — enough for
//! `astropy.io.fits.open` to round-trip the pixels and WCS.

use std::io::Write;
use std::path::Path;

use super::SkyMap;
use crate::util::error::{HegridError, Result};
use crate::util::rad2deg;

const RECORD: usize = 2880;
const CARD: usize = 80;

/// Format one header card: `KEYWORD = value / comment`, padded to 80 bytes.
fn card(keyword: &str, value: &str, comment: &str) -> [u8; CARD] {
    let mut out = [b' '; CARD];
    let text = if value.is_empty() {
        keyword.to_string()
    } else {
        format!("{keyword:<8}= {value:>20} / {comment}")
    };
    let bytes = text.as_bytes();
    let n = bytes.len().min(CARD);
    out[..n].copy_from_slice(&bytes[..n]);
    out
}

fn fcard(keyword: &str, value: f64, comment: &str) -> [u8; CARD] {
    card(keyword, &format!("{value:.10E}"), comment)
}

fn icard(keyword: &str, value: i64, comment: &str) -> [u8; CARD] {
    card(keyword, &value.to_string(), comment)
}

fn scard(keyword: &str, value: &str, comment: &str) -> [u8; CARD] {
    card(keyword, &format!("'{value:<8}'"), comment)
}

impl SkyMap {
    /// Write the map as a FITS primary image with a CAR WCS.
    pub fn write_fits(&self, path: &Path) -> Result<()> {
        let spec = &self.spec;
        let (nlon, nlat) = (spec.nlon, spec.nlat);

        // ---- header ---------------------------------------------------------
        let mut header: Vec<u8> = Vec::with_capacity(RECORD);
        let cards = [
            card("SIMPLE", "T", "conforms to FITS standard"),
            icard("BITPIX", -32, "IEEE single-precision float"),
            icard("NAXIS", 2, "number of axes"),
            icard("NAXIS1", nlon as i64, "longitude (RA) axis"),
            icard("NAXIS2", nlat as i64, "latitude (Dec) axis"),
            scard("CTYPE1", "RA---CAR", "plate carree projection"),
            scard("CTYPE2", "DEC--CAR", "plate carree projection"),
            // FITS pixel indices are 1-based; CRPIX at the map center.
            fcard("CRPIX1", (nlon as f64 + 1.0) / 2.0, "reference pixel (lon)"),
            fcard("CRPIX2", (nlat as f64 + 1.0) / 2.0, "reference pixel (lat)"),
            fcard("CRVAL1", rad2deg(spec.lon_c), "deg at reference pixel"),
            fcard("CRVAL2", rad2deg(spec.lat_c), "deg at reference pixel"),
            fcard("CDELT1", rad2deg(spec.step), "deg per pixel"),
            fcard("CDELT2", rad2deg(spec.step), "deg per pixel"),
            scard("BUNIT", "K", "brightness (arbitrary K)"),
            scard("ORIGIN", "HEGrid-RS", "github.com/HPCAstroAtTJU/HEGrid repro"),
            card("END", "", ""),
        ];
        for c in &cards {
            header.extend_from_slice(c);
        }
        header.resize(header.len().div_ceil(RECORD) * RECORD, b' ');

        // ---- data: f32 big-endian, row-major from the first (southern) row —
        // FITS NAXIS1 varies fastest, matching our row-major layout.
        let values = self.values();
        let weights = self.weights();
        let mut data = Vec::with_capacity(values.len() * 4);
        for i in 0..values.len() {
            let v = if weights[i] > 0.0 { values[i] as f32 } else { f32::NAN };
            data.extend_from_slice(&v.to_be_bytes());
        }
        data.resize(data.len().div_ceil(RECORD) * RECORD, 0);

        let mut file = std::fs::File::create(path)
            .map_err(HegridError::io(path.display().to_string()))?;
        file.write_all(&header).map_err(HegridError::io(path.display().to_string()))?;
        file.write_all(&data).map_err(HegridError::io(path.display().to_string()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::GridSpec;
    use super::*;

    fn sample_map() -> SkyMap {
        let spec = GridSpec::centered(30.0, 41.0, 6, 4, 0.5);
        let n = spec.n_cells();
        let acc: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let mut w = vec![1.0; n];
        w[5] = 0.0; // one blank cell
        SkyMap::from_accumulators(spec, &acc, &w).unwrap()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hegrid_fits");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn structure_is_record_aligned() {
        let path = tmp("s.fits");
        sample_map().write_fits(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len() % RECORD, 0);
        assert_eq!(bytes.len(), RECORD + RECORD); // 1 header + 1 data record
        assert!(bytes.starts_with(b"SIMPLE  ="));
    }

    #[test]
    fn header_has_mandatory_cards_in_order() {
        let path = tmp("h.fits");
        sample_map().write_fits(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let header = &bytes[..RECORD];
        let kw = |i: usize| String::from_utf8_lossy(&header[i * CARD..i * CARD + 8]).to_string();
        assert_eq!(kw(0).trim(), "SIMPLE");
        assert_eq!(kw(1).trim(), "BITPIX");
        assert_eq!(kw(2).trim(), "NAXIS");
        assert_eq!(kw(3).trim(), "NAXIS1");
        assert_eq!(kw(4).trim(), "NAXIS2");
        let text = String::from_utf8_lossy(header);
        assert!(text.contains("END"));
        assert!(text.contains("RA---CAR"));
        assert!(text.contains("NAXIS1  =                    6"));
        assert!(text.contains("NAXIS2  =                    4"));
    }

    #[test]
    fn data_round_trips_big_endian() {
        let map = sample_map();
        let path = tmp("d.fits");
        map.write_fits(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let data = &bytes[RECORD..];
        let px = |i: usize| f32::from_be_bytes(data[i * 4..i * 4 + 4].try_into().unwrap());
        assert_eq!(px(0), 0.0);
        assert_eq!(px(1), 1.0);
        assert!(px(5).is_nan(), "blank cell must be NaN");
        assert_eq!(px(23), 23.0);
        // padding after the 24 pixels is zero
        assert_eq!(px(24), 0.0);
    }

    #[test]
    fn astropy_reads_it_if_available() {
        // Best-effort cross-validation against astropy when present.
        let map = sample_map();
        let path = tmp("a.fits");
        map.write_fits(&path).unwrap();
        let script = format!(
            "import sys\n\
             try:\n    from astropy.io import fits\nexcept Exception:\n    sys.exit(0)\n\
             h = fits.open('{}')[0]\n\
             assert h.data.shape == (4, 6), h.data.shape\n\
             assert abs(h.data[0][1] - 1.0) < 1e-6\n\
             assert h.header['CTYPE1'].startswith('RA---CAR')\n\
             print('astropy OK')\n",
            path.display()
        );
        let out = std::process::Command::new("python3").arg("-c").arg(&script).output();
        if let Ok(out) = out {
            assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        }
    }
}
