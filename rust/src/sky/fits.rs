//! Minimal FITS image writer for [`SkyMap`]s.
//!
//! Astronomy toolchains (DS9, astropy, CARTA) consume FITS, not PGM; the
//! paper's outputs feed exactly such tools. This writes a standards-
//! conforming single-HDU primary image: BITPIX = -32 (IEEE f32, big
//! endian), two axes, and a CAR (plate carrée) WCS matching [`GridSpec`].
//! Blank cells are written as NaN, which FITS viewers render as blank.
//!
//! Scope: writer only (HEGrid emits maps, it does not read them back);
//! 2880-byte logical records, mandatory keywords, END padding — enough for
//! `astropy.io.fits.open` to round-trip the pixels and WCS.

use std::io::Write;
use std::path::Path;

use super::SkyMap;
use crate::util::error::{HegridError, Result};
use crate::util::rad2deg;

const RECORD: usize = 2880;
const CARD: usize = 80;

/// Format one header card: `KEYWORD = value / comment`, padded to 80 bytes.
fn card(keyword: &str, value: &str, comment: &str) -> [u8; CARD] {
    let mut out = [b' '; CARD];
    let text = if value.is_empty() {
        keyword.to_string()
    } else {
        format!("{keyword:<8}= {value:>20} / {comment}")
    };
    let bytes = text.as_bytes();
    let n = bytes.len().min(CARD);
    out[..n].copy_from_slice(&bytes[..n]);
    out
}

fn fcard(keyword: &str, value: f64, comment: &str) -> [u8; CARD] {
    card(keyword, &format!("{value:.10E}"), comment)
}

fn icard(keyword: &str, value: i64, comment: &str) -> [u8; CARD] {
    card(keyword, &value.to_string(), comment)
}

fn scard(keyword: &str, value: &str, comment: &str) -> [u8; CARD] {
    card(keyword, &format!("'{value:<8}'"), comment)
}

/// Write a stack of equally-shaped planes as a FITS NAXIS3 primary image
/// cube: BITPIX = -32 (IEEE f32 big endian), `n_x` the fastest axis, one
/// plane per NAXIS3 slice, a linear uv WCS (CTYPE 'UU'/'VV', CDELT = `cell`,
/// reference pixel at the grid origin `n/2`, CRVAL 0).
///
/// This is the output path of `hegrid uv-grid` (one cube per re/im/wsum
/// plane stack, NAXIS3 = channels). The byte layout is pinned by a CRC32
/// golden test — header card drift or an endianness regression fails it.
pub fn write_fits_cube(
    path: &Path,
    n_x: usize,
    n_y: usize,
    planes: &[Vec<f64>],
    cell: f64,
    bunit: &str,
) -> Result<()> {
    if planes.is_empty() {
        return Err(HegridError::Format("FITS cube needs at least one plane".into()));
    }
    for (i, p) in planes.iter().enumerate() {
        if p.len() != n_x * n_y {
            return Err(HegridError::Format(format!(
                "FITS cube plane {i} has {} cells, expected {}",
                p.len(),
                n_x * n_y
            )));
        }
    }

    let mut header: Vec<u8> = Vec::with_capacity(RECORD);
    let cards = [
        card("SIMPLE", "T", "conforms to FITS standard"),
        icard("BITPIX", -32, "IEEE single-precision float"),
        icard("NAXIS", 3, "number of axes"),
        icard("NAXIS1", n_x as i64, "u axis (fastest)"),
        icard("NAXIS2", n_y as i64, "v axis"),
        icard("NAXIS3", planes.len() as i64, "plane (channel) axis"),
        scard("CTYPE1", "UU", "baseline u, wavelengths"),
        scard("CTYPE2", "VV", "baseline v, wavelengths"),
        // FITS pixel indices are 1-based; the uv origin lives at 0-based
        // pixel n/2, i.e. 1-based n/2 + 1.
        fcard("CRPIX1", (n_x / 2) as f64 + 1.0, "reference pixel (u = 0)"),
        fcard("CRPIX2", (n_y / 2) as f64 + 1.0, "reference pixel (v = 0)"),
        fcard("CRVAL1", 0.0, "wavelengths at reference pixel"),
        fcard("CRVAL2", 0.0, "wavelengths at reference pixel"),
        fcard("CDELT1", cell, "wavelengths per pixel"),
        fcard("CDELT2", cell, "wavelengths per pixel"),
        scard("BUNIT", bunit, "plane units"),
        scard("ORIGIN", "HEGrid-RS", "github.com/HPCAstroAtTJU/HEGrid repro"),
        card("END", "", ""),
    ];
    for c in &cards {
        header.extend_from_slice(c);
    }
    header.resize(header.len().div_ceil(RECORD) * RECORD, b' ');

    let mut data = Vec::with_capacity(planes.len() * n_x * n_y * 4);
    for p in planes {
        for &v in p {
            data.extend_from_slice(&(v as f32).to_be_bytes());
        }
    }
    data.resize(data.len().div_ceil(RECORD) * RECORD, 0);

    let mut file =
        std::fs::File::create(path).map_err(HegridError::io(path.display().to_string()))?;
    file.write_all(&header).map_err(HegridError::io(path.display().to_string()))?;
    file.write_all(&data).map_err(HegridError::io(path.display().to_string()))?;
    Ok(())
}

impl SkyMap {
    /// Write the map as a FITS primary image with a CAR WCS.
    pub fn write_fits(&self, path: &Path) -> Result<()> {
        let spec = &self.spec;
        let (nlon, nlat) = (spec.nlon, spec.nlat);

        // ---- header ---------------------------------------------------------
        let mut header: Vec<u8> = Vec::with_capacity(RECORD);
        let cards = [
            card("SIMPLE", "T", "conforms to FITS standard"),
            icard("BITPIX", -32, "IEEE single-precision float"),
            icard("NAXIS", 2, "number of axes"),
            icard("NAXIS1", nlon as i64, "longitude (RA) axis"),
            icard("NAXIS2", nlat as i64, "latitude (Dec) axis"),
            scard("CTYPE1", "RA---CAR", "plate carree projection"),
            scard("CTYPE2", "DEC--CAR", "plate carree projection"),
            // FITS pixel indices are 1-based; CRPIX at the map center.
            fcard("CRPIX1", (nlon as f64 + 1.0) / 2.0, "reference pixel (lon)"),
            fcard("CRPIX2", (nlat as f64 + 1.0) / 2.0, "reference pixel (lat)"),
            fcard("CRVAL1", rad2deg(spec.lon_c), "deg at reference pixel"),
            fcard("CRVAL2", rad2deg(spec.lat_c), "deg at reference pixel"),
            fcard("CDELT1", rad2deg(spec.step), "deg per pixel"),
            fcard("CDELT2", rad2deg(spec.step), "deg per pixel"),
            scard("BUNIT", "K", "brightness (arbitrary K)"),
            scard("ORIGIN", "HEGrid-RS", "github.com/HPCAstroAtTJU/HEGrid repro"),
            card("END", "", ""),
        ];
        for c in &cards {
            header.extend_from_slice(c);
        }
        header.resize(header.len().div_ceil(RECORD) * RECORD, b' ');

        // ---- data: f32 big-endian, row-major from the first (southern) row —
        // FITS NAXIS1 varies fastest, matching our row-major layout.
        let values = self.values();
        let weights = self.weights();
        let mut data = Vec::with_capacity(values.len() * 4);
        for i in 0..values.len() {
            let v = if weights[i] > 0.0 { values[i] as f32 } else { f32::NAN };
            data.extend_from_slice(&v.to_be_bytes());
        }
        data.resize(data.len().div_ceil(RECORD) * RECORD, 0);

        let mut file = std::fs::File::create(path)
            .map_err(HegridError::io(path.display().to_string()))?;
        file.write_all(&header).map_err(HegridError::io(path.display().to_string()))?;
        file.write_all(&data).map_err(HegridError::io(path.display().to_string()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::GridSpec;
    use super::*;

    fn sample_map() -> SkyMap {
        let spec = GridSpec::centered(30.0, 41.0, 6, 4, 0.5);
        let n = spec.n_cells();
        let acc: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let mut w = vec![1.0; n];
        w[5] = 0.0; // one blank cell
        SkyMap::from_accumulators(spec, &acc, &w).unwrap()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hegrid_fits");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn structure_is_record_aligned() {
        let path = tmp("s.fits");
        sample_map().write_fits(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len() % RECORD, 0);
        assert_eq!(bytes.len(), RECORD + RECORD); // 1 header + 1 data record
        assert!(bytes.starts_with(b"SIMPLE  ="));
    }

    #[test]
    fn header_has_mandatory_cards_in_order() {
        let path = tmp("h.fits");
        sample_map().write_fits(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let header = &bytes[..RECORD];
        let kw = |i: usize| String::from_utf8_lossy(&header[i * CARD..i * CARD + 8]).to_string();
        assert_eq!(kw(0).trim(), "SIMPLE");
        assert_eq!(kw(1).trim(), "BITPIX");
        assert_eq!(kw(2).trim(), "NAXIS");
        assert_eq!(kw(3).trim(), "NAXIS1");
        assert_eq!(kw(4).trim(), "NAXIS2");
        let text = String::from_utf8_lossy(header);
        assert!(text.contains("END"));
        assert!(text.contains("RA---CAR"));
        assert!(text.contains("NAXIS1  =                    6"));
        assert!(text.contains("NAXIS2  =                    4"));
    }

    #[test]
    fn data_round_trips_big_endian() {
        let map = sample_map();
        let path = tmp("d.fits");
        map.write_fits(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let data = &bytes[RECORD..];
        let px = |i: usize| f32::from_be_bytes(data[i * 4..i * 4 + 4].try_into().unwrap());
        assert_eq!(px(0), 0.0);
        assert_eq!(px(1), 1.0);
        assert!(px(5).is_nan(), "blank cell must be NaN");
        assert_eq!(px(23), 23.0);
        // padding after the 24 pixels is zero
        assert_eq!(px(24), 0.0);
    }

    fn sample_cube() -> (usize, usize, Vec<Vec<f64>>) {
        // f32-exact values so the golden bytes are identical on every host.
        let plane0: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let plane1: Vec<f64> = (0..12).map(|i| i as f64 * 0.5).collect();
        (4, 3, vec![plane0, plane1])
    }

    #[test]
    fn cube_golden_crc_and_header_cards() {
        // Byte-level pin of the NAXIS3 cube writer: any header card drift,
        // format change, or endianness regression changes the CRC.
        let (n_x, n_y, planes) = sample_cube();
        let path = tmp("c.fits");
        write_fits_cube(&path, n_x, n_y, &planes, 25.0, "JY").unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len(), 2 * RECORD); // 1 header + 1 data record
        assert_eq!(crate::util::crc32::crc32(&bytes), 0x1107_D971, "cube byte layout drifted");
        let header = std::str::from_utf8(&bytes[..RECORD]).unwrap();
        let card_at = |i: usize| &header[i * CARD..(i + 1) * CARD];
        assert_eq!(
            card_at(2),
            format!("{:<80}", "NAXIS   =                    3 / number of axes")
        );
        assert_eq!(
            card_at(5),
            format!("{:<80}", "NAXIS3  =                    2 / plane (channel) axis")
        );
        assert_eq!(
            card_at(8),
            format!("{:<80}", "CRPIX1  =       3.0000000000E0 / reference pixel (u = 0)")
        );
        assert_eq!(
            card_at(13),
            format!("{:<80}", "CDELT2  =       2.5000000000E1 / wavelengths per pixel")
        );
        assert!(header.contains("'UU      '") && header.contains("'VV      '"));
    }

    #[test]
    fn cube_pixels_round_trip_per_plane() {
        let (n_x, n_y, planes) = sample_cube();
        let path = tmp("c2.fits");
        write_fits_cube(&path, n_x, n_y, &planes, 25.0, "JY").unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let data = &bytes[RECORD..];
        let px = |i: usize| f32::from_be_bytes(data[i * 4..i * 4 + 4].try_into().unwrap());
        // Plane 0 then plane 1, each row-major with NAXIS1 fastest.
        assert_eq!(px(0), 0.0);
        assert_eq!(px(11), 11.0);
        assert_eq!(px(12), 0.0);
        assert_eq!(px(13), 0.5);
        assert_eq!(px(23), 5.5);
        assert_eq!(px(24), 0.0, "zero padding after the last plane");
    }

    #[test]
    fn cube_rejects_bad_shapes() {
        let path = tmp("c3.fits");
        assert!(write_fits_cube(&path, 4, 3, &[], 25.0, "JY").is_err());
        assert!(write_fits_cube(&path, 4, 3, &[vec![0.0; 11]], 25.0, "JY").is_err());
    }

    #[test]
    fn astropy_reads_the_cube_if_available() {
        let (n_x, n_y, planes) = sample_cube();
        let path = tmp("c4.fits");
        write_fits_cube(&path, n_x, n_y, &planes, 25.0, "JY").unwrap();
        let script = format!(
            "import sys\n\
             try:\n    from astropy.io import fits\nexcept Exception:\n    sys.exit(0)\n\
             h = fits.open('{}')[0]\n\
             assert h.data.shape == (2, 3, 4), h.data.shape\n\
             assert abs(h.data[1][0][1] - 0.5) < 1e-6\n\
             assert h.header['NAXIS3'] == 2\n\
             print('astropy cube OK')\n",
            path.display()
        );
        let out = std::process::Command::new("python3").arg("-c").arg(&script).output();
        if let Ok(out) = out {
            assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        }
    }

    #[test]
    fn astropy_reads_it_if_available() {
        // Best-effort cross-validation against astropy when present.
        let map = sample_map();
        let path = tmp("a.fits");
        map.write_fits(&path).unwrap();
        let script = format!(
            "import sys\n\
             try:\n    from astropy.io import fits\nexcept Exception:\n    sys.exit(0)\n\
             h = fits.open('{}')[0]\n\
             assert h.data.shape == (4, 6), h.data.shape\n\
             assert abs(h.data[0][1] - 1.0) < 1e-6\n\
             assert h.header['CTYPE1'].startswith('RA---CAR')\n\
             print('astropy OK')\n",
            path.display()
        );
        let out = std::process::Command::new("python3").arg("-c").arg(&script).output();
        if let Ok(out) = out {
            assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        }
    }
}
