//! Sky geometry: target-map specification (WCS-lite), sky maps, and beams.
//!
//! HEGrid grids onto a plate-carrée (CAR) target map — uniform steps in
//! longitude (right ascension) and latitude (declination) — matching the
//! paper's 60°×20° FAST map centred at (30°, 41°). Cells are addressed
//! row-major, `idx = row·nlon + col`, rows running south→north.

pub mod fits;

use crate::util::error::{HegridError, Result};
use crate::util::{deg2rad, rad2deg};

/// Target grid map geometry. Angles are stored in radians internally;
/// constructors take degrees (the unit used throughout the paper).
#[derive(Clone, Debug, PartialEq)]
pub struct GridSpec {
    /// Map center longitude (rad).
    pub lon_c: f64,
    /// Map center latitude (rad).
    pub lat_c: f64,
    /// Number of cells along longitude.
    pub nlon: usize,
    /// Number of cells along latitude.
    pub nlat: usize,
    /// Cell step (rad), identical in both axes.
    pub step: f64,
}

impl GridSpec {
    /// Map centred at `(lon_deg, lat_deg)` with `nlon × nlat` cells of
    /// `cell_deg` degrees.
    pub fn centered(lon_deg: f64, lat_deg: f64, nlon: usize, nlat: usize, cell_deg: f64) -> Self {
        assert!(nlon > 0 && nlat > 0, "empty grid");
        assert!(cell_deg > 0.0, "cell size must be positive");
        GridSpec {
            lon_c: deg2rad(lon_deg),
            lat_c: deg2rad(lat_deg),
            nlon,
            nlat,
            step: deg2rad(cell_deg),
        }
    }

    /// Map covering `width_deg × height_deg` centred at `(lon_deg, lat_deg)`
    /// with a cell size derived from the beam (beam/`oversample` per cell —
    /// the paper's "output resolution" knob: smaller beams ⇒ more cells).
    pub fn for_field(
        lon_deg: f64,
        lat_deg: f64,
        width_deg: f64,
        height_deg: f64,
        beam_deg: f64,
        oversample: f64,
    ) -> Self {
        assert!(oversample > 0.0);
        let cell_deg = beam_deg / oversample;
        let nlon = (width_deg / cell_deg).ceil().max(1.0) as usize;
        let nlat = (height_deg / cell_deg).ceil().max(1.0) as usize;
        Self::centered(lon_deg, lat_deg, nlon, nlat, cell_deg)
    }

    pub fn n_cells(&self) -> usize {
        self.nlon * self.nlat
    }

    /// World coordinates (lon, lat) in radians of cell `(row, col)`.
    pub fn cell_center(&self, row: usize, col: usize) -> (f64, f64) {
        debug_assert!(row < self.nlat && col < self.nlon);
        let lon = self.lon_c + (col as f64 - (self.nlon as f64 - 1.0) / 2.0) * self.step;
        let lat = self.lat_c + (row as f64 - (self.nlat as f64 - 1.0) / 2.0) * self.step;
        (lon, lat)
    }

    /// Center of the flattened cell `idx` (row-major).
    pub fn cell_center_flat(&self, idx: usize) -> (f64, f64) {
        self.cell_center(idx / self.nlon, idx % self.nlon)
    }

    /// All cell centers, flattened row-major, as `(lons, lats)` in radians.
    pub fn cell_centers(&self) -> (Vec<f64>, Vec<f64>) {
        let n = self.n_cells();
        let mut lons = Vec::with_capacity(n);
        let mut lats = Vec::with_capacity(n);
        for row in 0..self.nlat {
            for col in 0..self.nlon {
                let (lon, lat) = self.cell_center(row, col);
                lons.push(lon);
                lats.push(lat);
            }
        }
        (lons, lats)
    }

    /// Extent bounds `(lon_min, lon_max, lat_min, lat_max)` in radians,
    /// including the half-cell margin.
    pub fn bounds(&self) -> (f64, f64, f64, f64) {
        let half_w = self.nlon as f64 / 2.0 * self.step;
        let half_h = self.nlat as f64 / 2.0 * self.step;
        (self.lon_c - half_w, self.lon_c + half_w, self.lat_c - half_h, self.lat_c + half_h)
    }

    /// Width × height in degrees.
    pub fn extent_deg(&self) -> (f64, f64) {
        (rad2deg(self.step) * self.nlon as f64, rad2deg(self.step) * self.nlat as f64)
    }

    /// Precompute the per-row / per-column trig tables of this grid
    /// ([`CellTrig`]) for the gridding hot loops.
    pub fn trig(&self) -> CellTrig {
        CellTrig::new(self)
    }
}

/// Per-row and per-column trig tables of a [`GridSpec`].
///
/// A plate-carrée grid is separable: every cell in row `r` shares
/// `(lat, sin lat, cos lat)` and every cell in column `c` shares
/// `(lon, sin lon, cos lon)`, so `nlat + nlon` `sin_cos` calls replace the
/// `nlat · nlon` per-cell evaluations the gridder and neighbour builder used
/// to pay. [`CellTrig::unit`] combines the cached values with exactly the
/// operations of [`crate::healpix::unit_vec`], so everything derived from the
/// table is bit-identical to the per-cell recomputation (pinned by tests).
#[derive(Clone, Debug)]
pub struct CellTrig {
    nlon: usize,
    /// Per row: (lat, sin lat, cos lat).
    rows: Vec<(f64, f64, f64)>,
    /// Per column: (lon, sin lon, cos lon).
    cols: Vec<(f64, f64, f64)>,
}

impl CellTrig {
    pub fn new(spec: &GridSpec) -> CellTrig {
        let rows = (0..spec.nlat)
            .map(|r| {
                let (_, lat) = spec.cell_center(r, 0);
                let (s, c) = lat.sin_cos();
                (lat, s, c)
            })
            .collect();
        let cols = (0..spec.nlon)
            .map(|c| {
                let (lon, _) = spec.cell_center(0, c);
                let (s, co) = lon.sin_cos();
                (lon, s, co)
            })
            .collect();
        CellTrig { nlon: spec.nlon, rows, cols }
    }

    /// World coordinates of flattened cell `idx` (row-major), bit-identical
    /// to [`GridSpec::cell_center_flat`].
    #[inline]
    pub fn lonlat(&self, idx: usize) -> (f64, f64) {
        (self.cols[idx % self.nlon].0, self.rows[idx / self.nlon].0)
    }

    /// `cos(lat)` of the cell's row (the longitude-offset scale of the
    /// kernel evaluation), bit-identical to `lat.cos()`.
    #[inline]
    pub fn cos_lat(&self, idx: usize) -> f64 {
        self.rows[idx / self.nlon].2
    }

    /// Unit 3-vector of the cell center — same combination of the cached
    /// sin/cos values as [`crate::healpix::unit_vec`], hence bit-identical.
    #[inline]
    pub fn unit(&self, idx: usize) -> [f64; 3] {
        let (_, sin_lat, cos_lat) = self.rows[idx / self.nlon];
        let (_, sin_lon, cos_lon) = self.cols[idx % self.nlon];
        [cos_lat * cos_lon, cos_lat * sin_lon, sin_lat]
    }
}

/// A gridded sky image for one channel: values and accumulated weights.
/// Cells with `weight == 0` have no data (NaN value on read-out).
#[derive(Clone, Debug)]
pub struct SkyMap {
    pub spec: GridSpec,
    /// Normalised cell values, row-major; NaN where weight == 0.
    values: Vec<f64>,
    weights: Vec<f64>,
}

impl SkyMap {
    pub fn new(spec: GridSpec) -> Self {
        let n = spec.n_cells();
        SkyMap { spec, values: vec![f64::NAN; n], weights: vec![0.0; n] }
    }

    /// Build from already-normalised values + weights (e.g. kernel output).
    pub fn from_parts(spec: GridSpec, values: Vec<f64>, weights: Vec<f64>) -> Result<Self> {
        if values.len() != spec.n_cells() || weights.len() != spec.n_cells() {
            return Err(HegridError::Internal(format!(
                "map size mismatch: {} values, {} weights, {} cells",
                values.len(),
                weights.len(),
                spec.n_cells()
            )));
        }
        Ok(SkyMap { spec, values, weights })
    }

    /// Build by normalising accumulated sums: `value = acc / wsum`.
    pub fn from_accumulators(spec: GridSpec, acc: &[f64], wsum: &[f64]) -> Result<Self> {
        if acc.len() != spec.n_cells() || wsum.len() != spec.n_cells() {
            return Err(HegridError::Internal("accumulator size mismatch".into()));
        }
        let values = acc
            .iter()
            .zip(wsum)
            .map(|(&a, &w)| if w > 0.0 { a / w } else { f64::NAN })
            .collect();
        Ok(SkyMap { spec, values, weights: wsum.to_vec() })
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    pub fn get(&self, row: usize, col: usize) -> f64 {
        self.values[row * self.spec.nlon + col]
    }

    /// Fraction of cells that received any data.
    pub fn coverage(&self) -> f64 {
        let hit = self.weights.iter().filter(|&&w| w > 0.0).count();
        hit as f64 / self.weights.len().max(1) as f64
    }

    /// Mean over covered cells.
    pub fn mean(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for (&v, &w) in self.values.iter().zip(&self.weights) {
            if w > 0.0 {
                sum += v;
                n += 1;
            }
        }
        if n == 0 {
            f64::NAN
        } else {
            sum / n as f64
        }
    }

    /// Comparison statistics against another map on the same spec
    /// (Fig 17's HEGrid-vs-Cygrid difference panel).
    pub fn diff_stats(&self, other: &SkyMap) -> Result<DiffStats> {
        if self.spec != other.spec {
            return Err(HegridError::Config("diff_stats: mismatched grid specs".into()));
        }
        let mut max_abs: f64 = 0.0;
        let mut sum2 = 0.0;
        let mut n = 0usize;
        let mut only_a = 0usize;
        let mut only_b = 0usize;
        for i in 0..self.values.len() {
            let (wa, wb) = (self.weights[i] > 0.0, other.weights[i] > 0.0);
            match (wa, wb) {
                (true, true) => {
                    let d = self.values[i] - other.values[i];
                    max_abs = max_abs.max(d.abs());
                    sum2 += d * d;
                    n += 1;
                }
                (true, false) => only_a += 1,
                (false, true) => only_b += 1,
                (false, false) => {}
            }
        }
        Ok(DiffStats {
            compared: n,
            max_abs,
            rms: if n > 0 { (sum2 / n as f64).sqrt() } else { 0.0 },
            only_a,
            only_b,
        })
    }

    /// Write an 8-bit PGM image (for Fig-17-style visual comparison).
    /// Values are linearly scaled between the covered min/max; empty cells
    /// render black. Row 0 (southernmost) is the bottom of the image.
    pub fn write_pgm(&self, path: &std::path::Path) -> Result<()> {
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for (&v, &w) in self.values.iter().zip(&self.weights) {
            if w > 0.0 {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
        if !lo.is_finite() || hi <= lo {
            lo = 0.0;
            hi = 1.0;
        }
        let scale = 254.0 / (hi - lo);
        let mut buf = format!("P5\n{} {}\n255\n", self.spec.nlon, self.spec.nlat).into_bytes();
        for row in (0..self.spec.nlat).rev() {
            for col in 0..self.spec.nlon {
                let i = row * self.spec.nlon + col;
                let px = if self.weights[i] > 0.0 {
                    1 + ((self.values[i] - lo) * scale) as u8
                } else {
                    0u8
                };
                buf.push(px);
            }
        }
        std::fs::write(path, buf).map_err(HegridError::io(path.display().to_string()))
    }

    /// Write `lon_deg,lat_deg,value,weight` CSV (empty cells included with
    /// `NaN`). Intended for small maps / debugging.
    pub fn write_csv(&self, path: &std::path::Path) -> Result<()> {
        let mut out = String::from("lon_deg,lat_deg,value,weight\n");
        for row in 0..self.spec.nlat {
            for col in 0..self.spec.nlon {
                let (lon, lat) = self.spec.cell_center(row, col);
                let i = row * self.spec.nlon + col;
                out.push_str(&format!(
                    "{:.6},{:.6},{},{}\n",
                    rad2deg(lon),
                    rad2deg(lat),
                    self.values[i],
                    self.weights[i]
                ));
            }
        }
        std::fs::write(path, out).map_err(HegridError::io(path.display().to_string()))
    }
}

/// Result of [`SkyMap::diff_stats`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DiffStats {
    /// Cells covered in both maps.
    pub compared: usize,
    pub max_abs: f64,
    pub rms: f64,
    /// Cells covered only in `self` / only in `other`.
    pub only_a: usize,
    pub only_b: usize,
}

/// A Gaussian telescope beam, specified by FWHM.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GaussianBeam {
    /// Full width at half maximum, radians.
    pub fwhm: f64,
}

impl GaussianBeam {
    pub fn from_fwhm_deg(fwhm_deg: f64) -> Self {
        assert!(fwhm_deg > 0.0);
        GaussianBeam { fwhm: deg2rad(fwhm_deg) }
    }

    pub fn from_fwhm_arcsec(fwhm_arcsec: f64) -> Self {
        Self::from_fwhm_deg(fwhm_arcsec / 3600.0)
    }

    /// Gaussian σ = FWHM / (2·sqrt(2·ln 2)).
    pub fn sigma(&self) -> f64 {
        self.fwhm / (2.0 * (2.0f64.ln() * 2.0).sqrt())
    }

    /// Beam response at angular distance `d` (peak-normalised).
    pub fn response(&self, d: f64) -> f64 {
        let s = self.sigma();
        (-0.5 * (d / s) * (d / s)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec_small() -> GridSpec {
        GridSpec::centered(30.0, 41.0, 8, 4, 0.5)
    }

    #[test]
    fn grid_center_symmetry() {
        let s = spec_small();
        // Mean of all cell centers equals the map center.
        let (lons, lats) = s.cell_centers();
        let mlon = lons.iter().sum::<f64>() / lons.len() as f64;
        let mlat = lats.iter().sum::<f64>() / lats.len() as f64;
        assert!((mlon - s.lon_c).abs() < 1e-12);
        assert!((mlat - s.lat_c).abs() < 1e-12);
        assert_eq!(lons.len(), s.n_cells());
    }

    #[test]
    fn cell_center_flat_matches_2d() {
        let s = spec_small();
        for idx in 0..s.n_cells() {
            let a = s.cell_center_flat(idx);
            let b = s.cell_center(idx / s.nlon, idx % s.nlon);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn cell_trig_tables_are_bit_identical_to_per_cell_trig() {
        let s = spec_small();
        let trig = s.trig();
        for idx in 0..s.n_cells() {
            let (lon, lat) = s.cell_center_flat(idx);
            assert_eq!(trig.lonlat(idx), (lon, lat), "cell {idx}");
            assert_eq!(trig.cos_lat(idx).to_bits(), lat.cos().to_bits(), "cell {idx}");
            let u = crate::healpix::unit_vec(lon, lat);
            let t = trig.unit(idx);
            for k in 0..3 {
                assert_eq!(t[k].to_bits(), u[k].to_bits(), "cell {idx} axis {k}");
            }
        }
    }

    #[test]
    fn adjacent_cells_are_one_step_apart() {
        let s = spec_small();
        let (a, _) = s.cell_center(0, 0);
        let (b, _) = s.cell_center(0, 1);
        assert!((b - a - s.step).abs() < 1e-15);
        let (_, c) = s.cell_center(0, 0);
        let (_, d) = s.cell_center(1, 0);
        assert!((d - c - s.step).abs() < 1e-15);
    }

    #[test]
    fn for_field_respects_beam_oversample() {
        let s = GridSpec::for_field(30.0, 41.0, 60.0, 20.0, 300.0 / 3600.0, 2.0);
        let (w, h) = s.extent_deg();
        assert!(w >= 60.0 && w < 60.2);
        assert!(h >= 20.0 && h < 20.2);
        assert!((rad2deg(s.step) - 300.0 / 3600.0 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn bounds_contain_all_cells() {
        let s = spec_small();
        let (lo, hi, blo, bhi) = s.bounds();
        let (lons, lats) = s.cell_centers();
        for (&lon, &lat) in lons.iter().zip(&lats) {
            assert!(lon > lo && lon < hi);
            assert!(lat > blo && lat < bhi);
        }
    }

    #[test]
    fn skymap_from_accumulators_normalises() {
        let s = GridSpec::centered(0.0, 0.0, 2, 2, 1.0);
        let map =
            SkyMap::from_accumulators(s, &[2.0, 0.0, 6.0, 1.0], &[1.0, 0.0, 2.0, 4.0]).unwrap();
        assert_eq!(map.values()[0], 2.0);
        assert!(map.values()[1].is_nan());
        assert_eq!(map.values()[2], 3.0);
        assert_eq!(map.values()[3], 0.25);
        assert!((map.coverage() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn skymap_size_mismatch_rejected() {
        let s = GridSpec::centered(0.0, 0.0, 2, 2, 1.0);
        assert!(SkyMap::from_accumulators(s.clone(), &[1.0], &[1.0]).is_err());
        assert!(SkyMap::from_parts(s, vec![0.0; 4], vec![0.0; 3]).is_err());
    }

    #[test]
    fn diff_stats_identical_and_perturbed() {
        let s = GridSpec::centered(0.0, 0.0, 2, 2, 1.0);
        let a = SkyMap::from_accumulators(s.clone(), &[1.0, 2.0, 3.0, 0.0], &[1.0, 1.0, 1.0, 0.0])
            .unwrap();
        let d = a.diff_stats(&a).unwrap();
        assert_eq!(d.max_abs, 0.0);
        assert_eq!(d.compared, 3);
        let b =
            SkyMap::from_accumulators(s, &[1.0, 2.5, 3.0, 1.0], &[1.0, 1.0, 1.0, 1.0]).unwrap();
        let d = a.diff_stats(&b).unwrap();
        assert!((d.max_abs - 0.5).abs() < 1e-12);
        assert_eq!(d.only_b, 1);
    }

    #[test]
    fn diff_stats_spec_mismatch_rejected() {
        let a = SkyMap::new(GridSpec::centered(0.0, 0.0, 2, 2, 1.0));
        let b = SkyMap::new(GridSpec::centered(0.0, 0.0, 3, 2, 1.0));
        assert!(a.diff_stats(&b).is_err());
    }

    #[test]
    fn pgm_and_csv_written() {
        let dir = std::env::temp_dir().join("hegrid_sky_test");
        std::fs::create_dir_all(&dir).unwrap();
        let s = GridSpec::centered(0.0, 0.0, 4, 2, 1.0);
        let map = SkyMap::from_accumulators(
            s,
            &[1.0, 2.0, 3.0, 4.0, 0.0, 5.0, 6.0, 7.0],
            &[1.0; 8],
        )
        .unwrap();
        let pgm = dir.join("m.pgm");
        let csv = dir.join("m.csv");
        map.write_pgm(&pgm).unwrap();
        map.write_csv(&csv).unwrap();
        let bytes = std::fs::read(&pgm).unwrap();
        assert!(bytes.starts_with(b"P5\n4 2\n255\n"));
        assert_eq!(bytes.len(), b"P5\n4 2\n255\n".len() + 8);
        let text = std::fs::read_to_string(&csv).unwrap();
        assert_eq!(text.lines().count(), 9);
    }

    #[test]
    fn beam_fwhm_semantics() {
        let beam = GaussianBeam::from_fwhm_arcsec(180.0);
        // Response at half the FWHM from center is 0.5 by definition.
        let r = beam.response(beam.fwhm / 2.0);
        assert!((r - 0.5).abs() < 1e-9, "r={r}");
        assert!(beam.response(0.0) == 1.0);
        assert!(beam.response(3.0 * beam.sigma()) < 0.012);
    }
}
