//! Leveled logger + stage-scoped timers.
//!
//! The coordinator instruments every pipeline stage (the paper's T1..T4 in
//! Fig 8) through [`StageTimer`]; the logger itself is a tiny stderr writer
//! with an env-controlled level (`HEGRID_LOG=debug|info|warn|error|off`).

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
    Off = 4,
}

impl Level {
    fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            "off" | "none" => Some(Level::Off),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
            Level::Off => "OFF  ",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialised

fn current_level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let level = std::env::var("HEGRID_LOG")
            .ok()
            .and_then(|s| Level::from_str(&s))
            .unwrap_or(Level::Warn);
        LEVEL.store(level as u8, Ordering::Relaxed);
        return level;
    }
    match raw {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        3 => Level::Error,
        _ => Level::Off,
    }
}

/// Programmatically override the log level (tests, CLI `--verbose`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level >= current_level() && current_level() != Level::Off
}

#[doc(hidden)]
pub fn log_at(level: Level, args: fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[hegrid {}] {}", level.tag().trim_end(), args);
    }
}

#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::logging::log_at($crate::logging::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::logging::log_at($crate::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::logging::log_at($crate::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::logging::log_at($crate::logging::Level::Error, format_args!($($t)*)) } }

/// Accumulates wall-clock duration per named stage; cheap enough to keep on
/// in production. Backs the Fig-8 timeline bench and `PipelineReport`.
#[derive(Clone, Debug, Default)]
pub struct StageTimes {
    entries: Vec<(String, Duration, u64)>, // (stage, total, count)
}

impl StageTimes {
    pub fn add(&mut self, stage: &str, d: Duration) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == stage) {
            e.1 += d;
            e.2 += 1;
        } else {
            self.entries.push((stage.to_string(), d, 1));
        }
    }

    pub fn merge(&mut self, other: &StageTimes) {
        for (stage, d, c) in &other.entries {
            if let Some(e) = self.entries.iter_mut().find(|e| &e.0 == stage) {
                e.1 += *d;
                e.2 += *c;
            } else {
                self.entries.push((stage.clone(), *d, *c));
            }
        }
    }

    pub fn total(&self, stage: &str) -> Duration {
        self.entries
            .iter()
            .find(|e| e.0 == stage)
            .map(|e| e.1)
            .unwrap_or_default()
    }

    pub fn count(&self, stage: &str) -> u64 {
        self.entries.iter().find(|e| e.0 == stage).map(|e| e.2).unwrap_or(0)
    }

    pub fn stages(&self) -> impl Iterator<Item = (&str, Duration, u64)> {
        self.entries.iter().map(|(s, d, c)| (s.as_str(), *d, *c))
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// RAII timer: records elapsed time into a [`StageTimes`] on drop.
pub struct StageTimer<'a> {
    times: &'a mut StageTimes,
    stage: &'a str,
    start: Instant,
}

impl<'a> StageTimer<'a> {
    pub fn start(times: &'a mut StageTimes, stage: &'a str) -> Self {
        Self { times, stage, start: Instant::now() }
    }
}

impl Drop for StageTimer<'_> {
    fn drop(&mut self) {
        self.times.add(self.stage, self.start.elapsed());
    }
}

/// Time a closure, returning `(result, elapsed)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::from_str("warning"), Some(Level::Warn));
        assert_eq!(Level::from_str("off"), Some(Level::Off));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn stage_times_accumulate_and_merge() {
        let mut a = StageTimes::default();
        a.add("prep", Duration::from_millis(5));
        a.add("prep", Duration::from_millis(7));
        a.add("h2d", Duration::from_millis(1));
        assert_eq!(a.total("prep"), Duration::from_millis(12));
        assert_eq!(a.count("prep"), 2);

        let mut b = StageTimes::default();
        b.add("prep", Duration::from_millis(3));
        b.add("kernel", Duration::from_millis(9));
        a.merge(&b);
        assert_eq!(a.total("prep"), Duration::from_millis(15));
        assert_eq!(a.total("kernel"), Duration::from_millis(9));
        assert_eq!(a.count("prep"), 3);
    }

    #[test]
    fn stage_timer_records_on_drop() {
        let mut t = StageTimes::default();
        {
            let _g = StageTimer::start(&mut t, "work");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(t.total("work") >= Duration::from_millis(1));
        assert_eq!(t.count("work"), 1);
    }

    #[test]
    fn timed_returns_result() {
        let (x, d) = timed(|| 41 + 1);
        assert_eq!(x, 42);
        assert!(d < Duration::from_secs(1));
    }
}
