//! # HEGrid-RS
//!
//! A high-efficiency multi-channel radio-astronomical data gridding framework,
//! reproducing Wang et al., *"HEGrid: A High Efficient Multi-Channel Radio
//! Astronomical Data Gridding Framework in Heterogeneous Computing
//! Environments"* (2022) on a Rust + JAX + Pallas stack (AOT via XLA/PJRT).
//!
//! Layering (Python never runs on the request path):
//!
//! * **L3** — this crate: the paper's coordination contribution. Multi-pipeline
//!   concurrency over frequency channels ([`coordinator`]), CPU pre-processing
//!   with a HEALPix-backed look-up table ([`grid`]), FIFO scheduling, the
//!   shared pre-processing component, and a reusable device-buffer pool.
//! * **L2** — `python/compile/model.py`: the JAX dispatch graph, lowered
//!   ahead-of-time to HLO text, one artifact per shape variant.
//! * **L1** — `python/compile/kernels/gridding.py`: the Pallas cell-update
//!   kernel (Algorithm 1 of the paper, re-tiled for a VMEM/MXU machine).
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API and
//! executes them on a pool of stream slots — the stand-in for the paper's
//! CUDA/HIP streams (see DESIGN.md for the substitution table).
//!
//! ## Quick start
//!
//! ```no_run
//! use hegrid::prelude::*;
//!
//! let dataset = hegrid::sim::SimConfig::quick_preset().generate();
//! let spec = GridSpec::centered(30.0, 41.0, 64, 64, 300.0 / 3600.0);
//! let kernel = ConvKernel::gauss1d_for_beam(300.0 / 3600.0);
//! let cpu = hegrid::grid::cpu::CpuGridder::new(spec.clone(), kernel.clone());
//! let maps = cpu.grid_dataset(&dataset);
//! assert_eq!(maps.len(), dataset.n_channels());
//! ```

pub mod baselines;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod grid;
pub mod healpix;
pub mod json;
pub mod logging;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod sky;
pub mod testkit;
pub mod util;

/// Commonly used types, re-exported for examples and downstream users.
pub mod prelude {
    pub use crate::config::{DeviceProfile, HegridConfig};
    pub use crate::coordinator::{GriddingJob, HegridEngine, PipelineReport};
    pub use crate::data::{ChannelSource, Dataset, HgdStreamSource, InMemorySource};
    pub use crate::grid::kernels::ConvKernel;
    pub use crate::grid::prep::SharedComponent;
    pub use crate::service::{ServiceConfig, ServiceHandle};
    pub use crate::sky::{GridSpec, SkyMap};
    pub use crate::util::error::{HegridError, Result};
}

/// Crate version string (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
