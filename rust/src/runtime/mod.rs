//! PJRT runtime: artifact manifest, the stream pool (per-thread PJRT clients
//! executing AOT HLO), and the reusable host staging-buffer pool.
//!
//! This is the layer that makes the Rust coordinator self-contained after
//! `make artifacts`: HLO text is loaded via `HloModuleProto::from_text_file`,
//! compiled once per (stream, variant), and executed with device-resident
//! shared inputs. Python never runs here.

pub mod manifest;
pub mod pool;
pub mod stream;

pub use manifest::{Manifest, VariantInfo, VariantQuery};
pub use pool::{MemoryPool, PooledBuf};
pub use stream::{ExecuteRequest, ExecuteResponse, StreamPool};
