//! PJRT runtime: artifact manifest, the stream pool (per-thread PJRT clients
//! executing AOT HLO), and the reusable host staging-buffer pool.
//!
//! This is the layer that makes the Rust coordinator self-contained after
//! `make artifacts`: HLO text is loaded via `HloModuleProto::from_text_file`,
//! compiled once per (stream, variant), and executed with device-resident
//! shared inputs. Python never runs here.

pub mod manifest;
pub mod pool;
pub mod prefetch;
pub mod stream;
pub mod supervisor;

pub use manifest::{Manifest, VariantInfo, VariantQuery};
pub use pool::{MemoryPool, PooledBuf};
pub use prefetch::{overlap_seconds, GroupBatch, PrefetchStats, Prefetcher};
pub use stream::{ExecuteRequest, ExecuteResponse, StreamPool};

/// Which executor backs the stream pool in this build: `"pjrt"` (AOT HLO
/// through the PJRT C API; requires the `pjrt` feature + vendored `xla`
/// crate) or `"native"` (the built-in CPU executor with identical dispatch
/// semantics). Tests use this to decide whether missing artifacts mean
/// "skip" or "run on the builtin manifest".
pub fn backend_name() -> &'static str {
    if cfg!(feature = "pjrt") {
        "pjrt"
    } else {
        "native"
    }
}
