//! Artifact manifest: the contract between `python/compile/aot.py` (build
//! time) and the Rust runtime (request time).

use std::path::{Path, PathBuf};

use crate::json::{parse, Json};
use crate::util::error::{HegridError, Result};

/// One AOT-compiled gridding variant (shapes + provenance).
#[derive(Clone, Debug, PartialEq)]
pub struct VariantInfo {
    pub name: String,
    /// Absolute path of the HLO text file.
    pub path: PathBuf,
    pub kernel_type: String,
    /// Cells per dispatch tile.
    pub m: usize,
    /// Pallas block size.
    pub bm: usize,
    /// Max candidates per neighbour group.
    pub k: usize,
    /// Channels per dispatch.
    pub c: usize,
    /// Sample-shard capacity.
    pub n: usize,
    /// Reuse factor γ.
    pub gamma: usize,
    /// Neighbour groups per tile (= m / γ).
    pub groups: usize,
    pub tags: Vec<String>,
}

impl VariantInfo {
    fn from_json(dir: &Path, v: &Json) -> Result<Self> {
        let info = VariantInfo {
            name: v.req_str("name")?.to_string(),
            path: dir.join(v.req_str("file")?),
            kernel_type: v.req_str("kernel_type")?.to_string(),
            m: v.req_usize("m")?,
            bm: v.req_usize("bm")?,
            k: v.req_usize("k")?,
            c: v.req_usize("c")?,
            n: v.req_usize("n")?,
            gamma: v.req_usize("gamma")?,
            groups: v.req_usize("groups")?,
            tags: v
                .req_arr("tags")?
                .iter()
                .filter_map(|t| t.as_str().map(String::from))
                .collect(),
        };
        if info.groups * info.gamma != info.m {
            return Err(HegridError::Format(format!(
                "variant {}: groups·gamma != m",
                info.name
            )));
        }
        Ok(info)
    }

    /// Number of dispatch tiles needed for a map with `n_cells` cells.
    pub fn tiles_for(&self, n_cells: usize) -> usize {
        n_cells.div_ceil(self.m).max(1)
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: Vec<VariantInfo>,
}

/// Variant-selection request (see [`Manifest::select`]).
#[derive(Clone, Debug)]
pub struct VariantQuery {
    pub kernel_type: String,
    pub gamma: usize,
    /// Desired channels per dispatch (exact match preferred, then largest ≤).
    pub channels: usize,
    /// Samples that must fit a shard (smallest n ≥ this preferred; the
    /// largest available n is returned otherwise — the caller shards).
    pub n_samples: usize,
    /// Preferred Pallas block size (0 = no preference).
    pub block: usize,
    /// Expected candidate-list length (0 = no preference): the smallest
    /// variant `k` ≥ this is preferred, shrinking the fixed-shape gather
    /// (K-padding) the device kernel pays regardless of true density.
    pub k_hint: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(HegridError::io(format!(
            "{} (run `make artifacts` first)",
            path.display()
        )))?;
        let v = parse(&text)?;
        let variants = v
            .req_arr("variants")?
            .iter()
            .map(|e| VariantInfo::from_json(dir, e))
            .collect::<Result<Vec<_>>>()?;
        if variants.is_empty() {
            return Err(HegridError::Format("manifest has no variants".into()));
        }
        Ok(Manifest { dir: dir.to_path_buf(), variants })
    }

    pub fn get(&self, name: &str) -> Result<&VariantInfo> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| HegridError::Config(format!("no artifact variant named '{name}'")))
    }

    /// Pick the best variant for a query. Hard constraints: kernel type and
    /// γ. Soft preferences, in order: channels (exact, then largest ≤, then
    /// smallest ≥), shard capacity (smallest n ≥ n_samples, else largest n),
    /// block size (exact match if requested).
    pub fn select(&self, q: &VariantQuery) -> Result<&VariantInfo> {
        let candidates: Vec<&VariantInfo> = self
            .variants
            .iter()
            .filter(|v| v.kernel_type == q.kernel_type && v.gamma == q.gamma)
            .collect();
        if candidates.is_empty() {
            return Err(HegridError::Config(format!(
                "no artifact variant for kernel '{}' γ={} — extend python/compile/configs.json",
                q.kernel_type, q.gamma
            )));
        }
        let best = candidates
            .into_iter()
            .min_by_key(|v| {
                // Channel preference.
                let ch = if v.c == q.channels {
                    0usize
                } else if v.c < q.channels {
                    // fewer channels per dispatch ⇒ more dispatch groups
                    1000 + (q.channels - v.c)
                } else {
                    2000 + (v.c - q.channels)
                };
                // Candidate-capacity preference: smallest k that still fits.
                let kfit = if q.k_hint == 0 {
                    0
                } else if v.k >= q.k_hint {
                    (v.k - q.k_hint) / 16
                } else {
                    1000 + (q.k_hint - v.k) / 16 // undersized ⇒ truncation risk
                };
                // Shard-capacity preference.
                let nfit = if v.n >= q.n_samples {
                    (v.n - q.n_samples) / 4096 // prefer snug fit
                } else {
                    500_000 + (q.n_samples - v.n) / 4096 // sharding needed
                };
                // Block preference.
                let blk = if q.block == 0 || v.bm == q.block { 0 } else { 1 };
                ch * 100_000_000 + kfit * 50_000 + nfit * 10 + blk
            })
            .expect("candidates non-empty");
        Ok(best)
    }

    /// All variants carrying a tag (e.g. `fig13`).
    pub fn with_tag(&self, tag: &str) -> Vec<&VariantInfo> {
        self.variants.iter().filter(|v| v.tags.iter().any(|t| t == tag)).collect()
    }

    /// Built-in variant set for the native executor. The native backend
    /// interprets dispatches from `VariantInfo` shapes alone — no HLO files
    /// are opened — so an engine can run without `make artifacts`. Names
    /// follow `python/compile/aot.py::variant_name`
    /// (`{ktype}_m{m}_b{bm}_k{k}_c{c}_g{gamma}_n{n}`) and the set mirrors
    /// `configs.json`: a channel/k/n spread per kernel type, the Fig-13
    /// block sweep, and the Fig-16 γ family the benches pin by name.
    pub fn native_default(dir: &Path) -> Manifest {
        fn v(
            dir: &Path,
            kernel_type: &str,
            m: usize,
            bm: usize,
            k: usize,
            c: usize,
            gamma: usize,
            n: usize,
            tags: &[&str],
        ) -> VariantInfo {
            let name = format!("{kernel_type}_m{m}_b{bm}_k{k}_c{c}_g{gamma}_n{n}");
            VariantInfo {
                path: dir.join(format!("{name}.hlo.txt")),
                name,
                kernel_type: kernel_type.to_string(),
                m,
                bm,
                k,
                c,
                n,
                gamma,
                groups: m / gamma,
                tags: tags.iter().map(|t| t.to_string()).collect(),
            }
        }
        let mut variants = Vec::new();
        for ktype in ["gauss1d", "gauss2d", "tapered_sinc"] {
            // Channel / candidate-capacity / shard spread (γ = 1).
            variants.push(v(dir, ktype, 1024, 256, 64, 10, 1, 32_768, &[]));
            variants.push(v(dir, ktype, 1024, 256, 256, 10, 1, 32_768, &[]));
            variants.push(v(dir, ktype, 2048, 256, 256, 10, 1, 262_144, &[]));
            variants.push(v(dir, ktype, 512, 128, 128, 4, 1, 4_096, &["tiny"]));
            variants.push(v(dir, ktype, 1024, 256, 256, 1, 1, 32_768, &["hcgrid"]));
            variants.push(v(dir, ktype, 1024, 256, 256, 5, 1, 262_144, &["fig15"]));
        }
        // Fig-13 block-size sweep (pinned by name in the bench).
        for bm in [32, 64, 128, 256, 512, 1024, 2048] {
            variants.push(v(dir, "gauss1d", 2048, bm, 64, 10, 1, 262_144, &["fig13"]));
        }
        // Fig-16 γ family (m = 1920 divides evenly by every γ; k grows with
        // γ because one candidate list serves γ cells' combined support).
        for (gamma, k) in [(1usize, 256usize), (2, 512), (3, 768)] {
            variants.push(v(dir, "gauss1d", 1920, 240, k, 10, gamma, 262_144, &["fig16"]));
        }
        Manifest { dir: dir.to_path_buf(), variants }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_repo_manifest() {
        let Some(m) = repo_manifest() else { return };
        assert!(m.variants.len() >= 15);
        for v in &m.variants {
            assert!(v.path.exists(), "{} missing", v.path.display());
            assert_eq!(v.groups * v.gamma, v.m);
            assert!(v.m % v.bm == 0);
        }
    }

    #[test]
    fn select_prefers_exact_channels_and_snug_n() {
        let Some(m) = repo_manifest() else { return };
        let v = m
            .select(&VariantQuery {
                kernel_type: "gauss1d".into(),
                gamma: 1,
                channels: 10,
                n_samples: 30_000,
                block: 256,
                k_hint: 0,
            })
            .unwrap();
        assert_eq!(v.c, 10);
        assert_eq!(v.n, 32_768, "smallest shard ≥ 30k");
        assert_eq!(v.bm, 256);
    }

    #[test]
    fn select_single_channel_variant() {
        let Some(m) = repo_manifest() else { return };
        let v = m
            .select(&VariantQuery {
                kernel_type: "gauss1d".into(),
                gamma: 1,
                channels: 1,
                n_samples: 1000,
                block: 0,
                k_hint: 0,
            })
            .unwrap();
        assert_eq!(v.c, 1);
    }

    #[test]
    fn select_gamma_and_ktype_are_hard() {
        let Some(m) = repo_manifest() else { return };
        assert!(m
            .select(&VariantQuery {
                kernel_type: "gauss1d".into(),
                gamma: 7,
                channels: 10,
                n_samples: 10,
                block: 0,
                k_hint: 0,
            })
            .is_err());
        let v = m
            .select(&VariantQuery {
                kernel_type: "tapered_sinc".into(),
                gamma: 1,
                channels: 10,
                n_samples: 10,
                block: 0,
                k_hint: 0,
            })
            .unwrap();
        assert_eq!(v.kernel_type, "tapered_sinc");
    }

    #[test]
    fn with_tag_finds_sweeps() {
        let Some(m) = repo_manifest() else { return };
        let fig13 = m.with_tag("fig13");
        assert!(fig13.len() >= 5);
        assert!(m.with_tag("fig16").len() >= 3);
        assert!(m.with_tag("nope").is_empty());
    }

    #[test]
    fn missing_dir_is_good_error() {
        let err = Manifest::load(Path::new("/nonexistent/artifacts")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn native_default_is_well_formed() {
        let m = Manifest::native_default(Path::new("artifacts"));
        assert!(m.variants.len() >= 15);
        for v in &m.variants {
            assert_eq!(v.groups * v.gamma, v.m, "{}", v.name);
            assert!(v.m % v.bm == 0, "{}", v.name);
        }
        // Names are unique (get() must be unambiguous).
        let mut names: Vec<&str> = m.variants.iter().map(|v| v.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), m.variants.len());
        // The sweeps the benches rely on exist.
        assert!(m.with_tag("fig13").len() >= 5);
        assert!(m.with_tag("fig16").len() >= 3);
        // Selection covers every kernel type and the γ sweep.
        for ktype in ["gauss1d", "gauss2d", "tapered_sinc"] {
            let q = VariantQuery {
                kernel_type: ktype.into(),
                gamma: 1,
                channels: 10,
                n_samples: 28_300,
                block: 0,
                k_hint: 30,
            };
            let v = m.select(&q).unwrap();
            assert_eq!(v.kernel_type, ktype);
            assert_eq!(v.c, 10);
            assert!(v.n >= 28_300);
        }
        let g2 = m
            .select(&VariantQuery {
                kernel_type: "gauss1d".into(),
                gamma: 2,
                channels: 10,
                n_samples: 4000,
                block: 0,
                k_hint: 0,
            })
            .unwrap();
        assert_eq!(g2.gamma, 2);
        assert!(g2.name.contains("_g2_"));
    }
}
