//! The PJRT stream pool — this reproduction's stand-in for CUDA/HIP streams.
//!
//! Each stream slot is a dedicated OS thread owning its **own** `PjRtClient`
//! and executable cache. Rationale: the `xla` crate's `PjRtClient` is
//! `Rc`-based (not `Send`), and giving every stream its own client both
//! satisfies the type system and mirrors how the paper provisions per-stream
//! GPU resources. Work arrives over a per-stream FIFO channel; replies go
//! back through one-shot channels, so a pipeline can keep multiple dispatches
//! in flight (the asynchronous transfer/compute overlap of §4.3.2).
//!
//! Device residency: stream threads cache input buffers by `(epoch, role)` —
//! sorted coordinates are uploaded once per shared-component epoch and
//! per-channel-group values once per group, then reused across all tile
//! dispatches (the "loaded only once from the host to the device" part of
//! the shared component, §4.3.1).
//!
//! Two backends sit behind the same pool API:
//!
//! * `pjrt` feature **on** — AOT HLO artifacts executed through the PJRT C
//!   API via the `xla` crate (requires vendoring it; see Cargo.toml).
//! * `pjrt` feature **off** (the offline default) — a native CPU executor
//!   with identical dispatch semantics (`python/compile/kernels/ref.py`
//!   transliterated), including the emulated device-buffer cache so H2D
//!   cache-hit behaviour and timings keep the same shape.

// The PJRT backend needs the (unpublished-offline) `xla` crate: vendor
// xla-rs, add `xla = { path = "vendor/xla" }` to [dependencies], and build
// with `--features pjrt`. This line turns the otherwise-cryptic E0433 into
// a pointer at that step.
#[cfg(feature = "pjrt")]
extern crate xla;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::runtime::manifest::Manifest;
use crate::util::error::{HegridError, Result};

/// Identifies a cached device-resident input.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum BufferKey {
    /// Sorted sample coordinates: one per shared-component epoch.
    SampleCoords { epoch: u64, axis: u8, n: usize },
    /// Per-channel-group sorted values: `[c, n]`.
    GroupValues { epoch: u64, group: u64, c: usize, n: usize },
}

/// Host-side input arrays for one dispatch (one tile × one channel group).
pub struct ExecuteRequest {
    pub variant: String,
    /// Shared-component epoch (bump when samples change).
    pub epoch: u64,
    /// Channel-group id within the epoch.
    pub group: u64,
    pub cell_lon: Arc<Vec<f32>>,
    pub cell_lat: Arc<Vec<f32>>,
    /// `[groups, k]` flattened.
    pub nbr: Arc<Vec<i32>>,
    /// Sorted sample coordinates, padded to the variant's `n`. Still shipped
    /// for the anisotropic (gauss2d) weight terms and the fixed AOT artifact
    /// ABI; the isotropic distance itself comes from `sunit`.
    pub slon: Arc<Vec<f32>>,
    pub slat: Arc<Vec<f32>>,
    /// Staged per-sample unit-vector columns `[3, n]` (x | y | z planes),
    /// precomputed once in the shared component (T2 ships columns instead of
    /// deriving per-pair trig from raw lon/lat on the device).
    pub sunit: Arc<Vec<f32>>,
    /// Sorted, padded channel values `[c, n]` flattened.
    pub sval: Arc<Vec<f32>>,
    pub kparam: [f32; 4],
}

/// Result of one dispatch.
pub struct ExecuteResponse {
    /// `[c, m]` flattened accumulated weighted sums.
    pub acc: Vec<f32>,
    /// `[m]` weight sums.
    pub wsum: Vec<f32>,
    /// Host→device staging time (cache misses only).
    pub t_h2d: Duration,
    /// Kernel execution time.
    pub t_exec: Duration,
    /// Device→host readback time.
    pub t_d2h: Duration,
}

enum Msg {
    Execute(ExecuteRequest, Sender<Result<ExecuteResponse>>),
    /// Pre-compile a variant (warm the executable cache).
    Warm(String, Sender<Result<()>>),
}

/// A pool of `streams` PJRT execution slots.
pub struct StreamPool {
    senders: Vec<Sender<Msg>>,
    handles: Vec<JoinHandle<()>>,
    /// Round-robin cursor for `any_stream`.
    cursor: AtomicU64,
    in_flight: Arc<Mutex<usize>>,
}

impl StreamPool {
    /// Spawn `streams` worker threads against `manifest`.
    pub fn new(manifest: Arc<Manifest>, streams: usize) -> Result<StreamPool> {
        // Quieten XLA's per-client INFO chatter unless the user asked for it.
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let streams = streams.max(1);
        let mut senders = Vec::with_capacity(streams);
        let mut handles = Vec::with_capacity(streams);
        let in_flight = Arc::new(Mutex::new(0usize));
        for s in 0..streams {
            let (tx, rx) = channel::<Msg>();
            let manifest = Arc::clone(&manifest);
            let handle = std::thread::Builder::new()
                .name(format!("pjrt-stream-{s}"))
                .spawn(move || stream_main(manifest, rx))
                .map_err(|e| HegridError::Runtime(format!("spawn stream: {e}")))?;
            senders.push(tx);
            handles.push(handle);
        }
        Ok(StreamPool { senders, handles, cursor: AtomicU64::new(0), in_flight })
    }

    pub fn n_streams(&self) -> usize {
        self.senders.len()
    }

    /// Submit to a specific stream (pipelines pin their dispatches to one
    /// stream so group-value buffers stay resident). Returns the reply port.
    pub fn submit(&self, stream: usize, req: ExecuteRequest) -> Receiver<Result<ExecuteResponse>> {
        let (tx, rx) = channel();
        *self.in_flight.lock().unwrap() += 1;
        let msg = Msg::Execute(req, tx);
        if self.senders[stream % self.senders.len()].send(msg).is_err() {
            // Stream thread died; the reply port will error on recv.
        }
        rx
    }

    /// Submit to the next stream round-robin.
    pub fn submit_any(&self, req: ExecuteRequest) -> (usize, Receiver<Result<ExecuteResponse>>) {
        let s = (self.cursor.fetch_add(1, Ordering::Relaxed) as usize) % self.senders.len();
        (s, self.submit(s, req))
    }

    /// Block until `rx` yields, decrementing the in-flight counter.
    pub fn wait(&self, rx: Receiver<Result<ExecuteResponse>>) -> Result<ExecuteResponse> {
        let out = rx
            .recv()
            .map_err(|_| HegridError::Runtime("stream thread terminated".into()))?;
        *self.in_flight.lock().unwrap() -= 1;
        out
    }

    /// Compile `variant` on every stream up front (excluded from timings).
    pub fn warm(&self, variant: &str) -> Result<()> {
        let mut ports = Vec::new();
        for tx in &self.senders {
            let (rtx, rrx) = channel();
            tx.send(Msg::Warm(variant.to_string(), rtx))
                .map_err(|_| HegridError::Runtime("stream thread terminated".into()))?;
            ports.push(rrx);
        }
        for p in ports {
            p.recv().map_err(|_| HegridError::Runtime("stream thread terminated".into()))??;
        }
        Ok(())
    }
}

impl Drop for StreamPool {
    fn drop(&mut self) {
        self.senders.clear(); // close channels; threads drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Per-stream worker (PJRT): own client, executable cache, device-buffer
/// cache.
#[cfg(feature = "pjrt")]
fn stream_main(manifest: Arc<Manifest>, rx: Receiver<Msg>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            crate::log_error!("stream: PJRT client creation failed: {e}");
            // Drain requests with errors so callers unblock.
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Execute(_, reply) => {
                        let _ = reply.send(Err(HegridError::Runtime("no PJRT client".into())));
                    }
                    Msg::Warm(_, reply) => {
                        let _ = reply.send(Err(HegridError::Runtime("no PJRT client".into())));
                    }
                }
            }
            return;
        }
    };
    let mut executables: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut buffers: HashMap<BufferKey, xla::PjRtBuffer> = HashMap::new();
    // Evict stale epochs/groups: keep at most this many group-value buffers
    // and coordinate epochs (LRU each).
    const MAX_GROUP_BUFFERS: usize = 4;
    const MAX_COORD_EPOCHS: usize = 8;
    let mut group_lru: Vec<BufferKey> = Vec::new();
    let mut coord_epochs: Vec<u64> = Vec::new();

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Warm(name, reply) => {
                let _ = reply.send(compile_variant(&client, &manifest, &mut executables, &name)
                    .map(|_| ()));
            }
            Msg::Execute(req, reply) => {
                let out = run_one(
                    &client,
                    &manifest,
                    &mut executables,
                    &mut buffers,
                    &mut group_lru,
                    MAX_GROUP_BUFFERS,
                    &mut coord_epochs,
                    MAX_COORD_EPOCHS,
                    &req,
                );
                let _ = reply.send(out);
            }
        }
    }
}

#[cfg(feature = "pjrt")]
fn compile_variant<'a>(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &'a mut HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
) -> Result<&'a xla::PjRtLoadedExecutable> {
    if !cache.contains_key(name) {
        let info = manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(&info.path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        cache.insert(name.to_string(), exe);
        crate::log_debug!("stream compiled variant {name}");
    }
    Ok(cache.get(name).expect("just inserted"))
}

#[cfg(feature = "pjrt")]
#[allow(clippy::too_many_arguments)]
fn run_one(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    executables: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    buffers: &mut HashMap<BufferKey, xla::PjRtBuffer>,
    group_lru: &mut Vec<BufferKey>,
    max_groups: usize,
    coord_epochs: &mut Vec<u64>,
    max_epochs: usize,
    req: &ExecuteRequest,
) -> Result<ExecuteResponse> {
    let info = manifest.get(&req.variant)?.clone();
    // NOTE: the AOT HLO artifacts predate the staged unit-vector columns —
    // this backend uploads raw lon/lat only and ignores `req.sunit` until
    // the artifacts are regenerated with the 8-input signature. Warn once,
    // loudly: anyone benchmarking this path is measuring the degraded
    // per-pair-haversine kernel, not the chord-dot one the native backend
    // runs (docs/architecture.md, "PJRT sunit limitation").
    {
        static SUNIT_IGNORED: std::sync::Once = std::sync::Once::new();
        if !req.sunit.is_empty() {
            SUNIT_IGNORED.call_once(|| {
                crate::log_warn!(
                    "pjrt backend ignores the staged unit-vector columns ({} floats/dispatch): \
                     the 7-input AOT artifacts predate them — regenerate with \
                     `python python/compile/aot.py` to benchmark the chord-dot kernel",
                    req.sunit.len()
                );
            });
        }
    }
    // Shape validation up front — shape bugs become errors, not UB.
    if req.cell_lon.len() != info.m
        || req.cell_lat.len() != info.m
        || req.nbr.len() != info.groups * info.k
        || req.slon.len() != info.n
        || req.slat.len() != info.n
        || req.sval.len() != info.c * info.n
    {
        return Err(HegridError::Internal(format!(
            "dispatch shapes do not match variant {}: cells {}/{}, nbr {}/{}, samples {}/{}, sval {}/{}",
            info.name,
            req.cell_lon.len(),
            info.m,
            req.nbr.len(),
            info.groups * info.k,
            req.slon.len(),
            info.n,
            req.sval.len(),
            info.c * info.n
        )));
    }
    compile_variant(client, manifest, executables, &req.variant)?;

    // ---- H2D: per-tile inputs always, shared inputs on cache miss --------
    let t0 = Instant::now();
    let cell_lon = client.buffer_from_host_buffer::<f32>(&req.cell_lon, &[info.m], None)?;
    let cell_lat = client.buffer_from_host_buffer::<f32>(&req.cell_lat, &[info.m], None)?;
    let nbr = client.buffer_from_host_buffer::<i32>(&req.nbr, &[info.groups, info.k], None)?;
    let kparam = client.buffer_from_host_buffer::<f32>(&req.kparam[..], &[4], None)?;

    let coord_key = |axis: u8| BufferKey::SampleCoords { epoch: req.epoch, axis, n: info.n };
    // LRU (touch-on-use) over resident epochs: multi-shard plans at
    // pipeline_width ≥ 2 interleave shard epochs on one stream, and
    // exact-epoch eviction would re-upload shared inputs on every switch.
    if let Some(pos) = coord_epochs.iter().position(|&e| e == req.epoch) {
        let e = coord_epochs.remove(pos);
        coord_epochs.push(e);
    } else {
        coord_epochs.push(req.epoch);
        while coord_epochs.len() > max_epochs {
            let gone = coord_epochs.remove(0);
            buffers.retain(|k, _| !matches!(k, BufferKey::SampleCoords { epoch, .. } | BufferKey::GroupValues { epoch, .. } if *epoch == gone));
            group_lru
                .retain(|k| !matches!(k, BufferKey::GroupValues { epoch, .. } if *epoch == gone));
        }
        let slon = client.buffer_from_host_buffer::<f32>(&req.slon, &[info.n], None)?;
        let slat = client.buffer_from_host_buffer::<f32>(&req.slat, &[info.n], None)?;
        buffers.insert(coord_key(0), slon);
        buffers.insert(coord_key(1), slat);
    }
    let gkey = BufferKey::GroupValues { epoch: req.epoch, group: req.group, c: info.c, n: info.n };
    if !buffers.contains_key(&gkey) {
        let sval = client.buffer_from_host_buffer::<f32>(&req.sval, &[info.c, info.n], None)?;
        buffers.insert(gkey.clone(), sval);
        group_lru.push(gkey.clone());
        while group_lru.len() > max_groups {
            let evict = group_lru.remove(0);
            buffers.remove(&evict);
        }
    }
    let t_h2d = t0.elapsed();

    // ---- execute ----------------------------------------------------------
    let t1 = Instant::now();
    let exe = executables.get(&req.variant).expect("compiled above");
    let slon_buf = buffers.get(&coord_key(0)).expect("resident");
    let slat_buf = buffers.get(&coord_key(1)).expect("resident");
    let sval_buf = buffers.get(&gkey).expect("resident");
    let args: [&xla::PjRtBuffer; 7] =
        [&cell_lon, &cell_lat, &nbr, slon_buf, slat_buf, sval_buf, &kparam];
    let outputs = exe.execute_b(&args)?;
    let t_exec = t1.elapsed();

    // ---- D2H ---------------------------------------------------------------
    let t2 = Instant::now();
    let result = outputs[0][0].to_literal_sync()?;
    let (acc_lit, wsum_lit) = result.to_tuple2()?;
    let acc = acc_lit.to_vec::<f32>()?;
    let wsum = wsum_lit.to_vec::<f32>()?;
    let t_d2h = t2.elapsed();

    if acc.len() != info.c * info.m || wsum.len() != info.m {
        return Err(HegridError::Runtime(format!(
            "unexpected output shapes: acc {} wsum {} for variant {}",
            acc.len(),
            wsum.len(),
            info.name
        )));
    }
    Ok(ExecuteResponse { acc, wsum, t_h2d, t_exec, t_d2h })
}

/// Per-stream worker (native backend): same message loop and buffer-cache
/// semantics as the PJRT path, with the dispatch executed by
/// `native::run_one` on this thread.
#[cfg(not(feature = "pjrt"))]
fn stream_main(manifest: Arc<Manifest>, rx: Receiver<Msg>) {
    let mut buffers: HashMap<BufferKey, Arc<Vec<f32>>> = HashMap::new();
    const MAX_GROUP_BUFFERS: usize = 4;
    // Coordinate epochs resident per stream: large enough that a
    // many-shard plan interleaved across pipelines does not evict the
    // epoch it is about to revisit (coords are 5n f32 per epoch — cheap
    // next to the thrash they prevent).
    const MAX_COORD_EPOCHS: usize = 8;
    let mut group_lru: Vec<BufferKey> = Vec::new();
    let mut coord_epochs: Vec<u64> = Vec::new();

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Warm(name, reply) => {
                let _ = reply.send(manifest.get(&name).map(|_| ()));
            }
            Msg::Execute(req, reply) => {
                let out = native::run_one(
                    &manifest,
                    &mut buffers,
                    &mut group_lru,
                    MAX_GROUP_BUFFERS,
                    &mut coord_epochs,
                    MAX_COORD_EPOCHS,
                    &req,
                );
                let _ = reply.send(out);
            }
        }
    }
}

/// Native CPU executor: `python/compile/kernels/ref.py` transliterated.
/// Weight semantics are identical to [`crate::grid::kernels::ConvKernel`],
/// but evaluated from the dispatch's `kparam` array exactly as the device
/// kernel would — the offline stand-in for AOT Pallas + PJRT.
///
/// Per-pair distances use the **staged unit-vector columns** (`sunit`,
/// uploaded once per epoch like the coordinates): one squared-chord dot
/// product + `asin` per pair, with the cell's unit vector derived once per
/// cell — no per-pair haversine trig from raw lon/lat.
#[cfg(not(feature = "pjrt"))]
mod native {
    use super::*;
    use crate::grid::kernels::ConvKernelType;
    use crate::healpix::{chord2_to_arc, unit_vec};

    #[allow(clippy::too_many_arguments)]
    pub(super) fn run_one(
        manifest: &Manifest,
        buffers: &mut HashMap<BufferKey, Arc<Vec<f32>>>,
        group_lru: &mut Vec<BufferKey>,
        max_groups: usize,
        coord_epochs: &mut Vec<u64>,
        max_epochs: usize,
        req: &ExecuteRequest,
    ) -> Result<ExecuteResponse> {
        let info = manifest.get(&req.variant)?.clone();
        if req.cell_lon.len() != info.m
            || req.cell_lat.len() != info.m
            || req.nbr.len() != info.groups * info.k
            || req.slon.len() != info.n
            || req.slat.len() != info.n
            || req.sunit.len() != 3 * info.n
            || req.sval.len() != info.c * info.n
        {
            return Err(HegridError::Internal(format!(
                "dispatch shapes do not match variant {}: cells {}/{}, nbr {}/{}, samples {}/{}, sunit {}/{}, sval {}/{}",
                info.name,
                req.cell_lon.len(),
                info.m,
                req.nbr.len(),
                info.groups * info.k,
                req.slon.len(),
                info.n,
                req.sunit.len(),
                3 * info.n,
                req.sval.len(),
                info.c * info.n
            )));
        }
        let ktype = ConvKernelType::from_name(&info.kernel_type)?;

        // ---- emulated H2D: copy shared inputs into the cache on miss -----
        let t0 = Instant::now();
        let coord_key = |axis: u8| BufferKey::SampleCoords { epoch: req.epoch, axis, n: info.n };
        // Recent epochs stay resident under an LRU (touch-on-use) instead of
        // exact-epoch eviction: with `pipeline_width` ≥ 2 and a multi-shard
        // plan, one stream interleaves dispatches from different shard
        // epochs, and evicting everything that isn't `req.epoch` would
        // re-upload coordinates + group values on every switch.
        if let Some(pos) = coord_epochs.iter().position(|&e| e == req.epoch) {
            let e = coord_epochs.remove(pos);
            coord_epochs.push(e);
        } else {
            coord_epochs.push(req.epoch);
            while coord_epochs.len() > max_epochs {
                let gone = coord_epochs.remove(0);
                buffers.retain(|k, _| !matches!(k, BufferKey::SampleCoords { epoch, .. } | BufferKey::GroupValues { epoch, .. } if *epoch == gone));
                group_lru.retain(
                    |k| !matches!(k, BufferKey::GroupValues { epoch, .. } if *epoch == gone),
                );
            }
            buffers.insert(coord_key(0), Arc::new(req.slon.to_vec()));
            buffers.insert(coord_key(1), Arc::new(req.slat.to_vec()));
            // Axis 2: the staged `[3, n]` unit-vector planes, resident per
            // epoch exactly like the coordinate columns.
            buffers.insert(coord_key(2), Arc::new(req.sunit.to_vec()));
        }
        let gkey =
            BufferKey::GroupValues { epoch: req.epoch, group: req.group, c: info.c, n: info.n };
        if !buffers.contains_key(&gkey) {
            buffers.insert(gkey.clone(), Arc::new(req.sval.to_vec()));
            group_lru.push(gkey.clone());
            while group_lru.len() > max_groups {
                let evict = group_lru.remove(0);
                buffers.remove(&evict);
            }
        }
        let slon = Arc::clone(buffers.get(&coord_key(0)).expect("resident"));
        let slat = Arc::clone(buffers.get(&coord_key(1)).expect("resident"));
        let sunit = Arc::clone(buffers.get(&coord_key(2)).expect("resident"));
        let sval = Arc::clone(buffers.get(&gkey).expect("resident"));
        let t_h2d = t0.elapsed();

        // ---- execute ------------------------------------------------------
        let t1 = Instant::now();
        let kp = [
            req.kparam[0] as f64,
            req.kparam[1] as f64,
            req.kparam[2] as f64,
            req.kparam[3] as f64,
        ];
        let (m, k, c, n, gamma) = (info.m, info.k, info.c, info.n, info.gamma.max(1));
        let mut acc64 = vec![0.0f64; c * m];
        let mut wsum64 = vec![0.0f64; m];
        let (sux, suy, suz) = (&sunit[..n], &sunit[n..2 * n], &sunit[2 * n..3 * n]);
        for i in 0..m {
            let clon = req.cell_lon[i] as f64;
            let clat = req.cell_lat[i] as f64;
            let clat_cos = clat.cos();
            let cu = unit_vec(clon, clat);
            let g = i / gamma;
            for &j in &req.nbr[g * k..(g + 1) * k] {
                if j < 0 {
                    continue;
                }
                let j = j as usize;
                if j >= n {
                    continue; // padded gather index: out-of-shard, no effect
                }
                let dx = cu[0] - sux[j] as f64;
                let dy = cu[1] - suy[j] as f64;
                let dz = cu[2] - suz[j] as f64;
                let d = chord2_to_arc(dx * dx + dy * dy + dz * dz);
                let d2 = d * d;
                let (w, r2) = match ktype {
                    ConvKernelType::Gauss1d => ((-d2 * kp[0]).exp(), kp[1]),
                    ConvKernelType::Gauss2d => {
                        // Anisotropic terms still need the raw coordinates.
                        let dlon_cos = (slon[j] as f64 - clon) * clat_cos;
                        let dlat = slat[j] as f64 - clat;
                        ((-dlon_cos * dlon_cos * kp[0] - dlat * dlat * kp[1]).exp(), kp[2])
                    }
                    ConvKernelType::TaperedSinc => {
                        let dd = d2.sqrt();
                        let x = dd * kp[0];
                        let sinc = if x.abs() < 1e-12 { 1.0 } else { x.sin() / x };
                        let t = dd * kp[1];
                        (sinc * (-t * t).exp(), kp[2])
                    }
                };
                if d2 <= r2 {
                    wsum64[i] += w;
                    for ci in 0..c {
                        acc64[ci * m + i] += w * sval[ci * n + j] as f64;
                    }
                }
            }
        }
        let t_exec = t1.elapsed();

        // ---- emulated D2H -------------------------------------------------
        let t2 = Instant::now();
        let acc: Vec<f32> = acc64.iter().map(|&v| v as f32).collect();
        let wsum: Vec<f32> = wsum64.iter().map(|&v| v as f32).collect();
        let t_d2h = t2.elapsed();
        Ok(ExecuteResponse { acc, wsum, t_h2d, t_exec, t_d2h })
    }
}
