//! Read-ahead channel ingest: the T0 stage that overlaps disk I/O with the
//! T1–T4 pipeline stages (the paper's §4.3 I/O/compute co-optimization,
//! Fig 8's "load" bars sliding under the compute bars).
//!
//! A [`Prefetcher`] coordinates a small pool of I/O worker threads (spawned
//! by the caller inside its own `thread::scope`, so sources can be borrowed)
//! with the coordinator's pipeline workers:
//!
//! * workers **claim** the next channel group FIFO, read its channels from a
//!   [`ChannelSource`] into pooled buffers, and push the finished
//!   [`GroupBatch`] onto a bounded ready ring;
//! * at most `depth` groups are in flight (being read + ready) at any time —
//!   when pipelines fall behind, workers block (**backpressure**). A batch a
//!   consumer has already pulled no longer counts against the window, so a
//!   full run's peak residency is `depth` + one batch per consumer;
//! * pipelines **pull** batches with [`Prefetcher::next`], blocking while
//!   the ring is empty (starvation — measurable as missing overlap).
//!
//! Every read records its wall-clock interval; after the run,
//! [`overlap_seconds`] intersects the merged I/O intervals with the merged
//! compute intervals to report the *measured* I/O/compute overlap window —
//! the number `fig8_timeline` prints, nonzero whenever `depth ≥ 2` gives
//! the workers room to read ahead.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::coordinator::plan::ChannelGroups;
use crate::data::ChannelSource;
use crate::runtime::pool::{MemoryPool, PooledBuf};
use crate::util::error::{HegridError, Result};

/// One prefetched channel group, ready for a pipeline to stage.
pub struct GroupBatch {
    /// Group index within the run's [`ChannelGroups`].
    pub group: usize,
    /// Channel ids of the group's members.
    pub channels: Vec<usize>,
    /// Per-member value vectors (`n_samples` each); pooled, recycled on drop.
    pub values: Vec<PooledBuf>,
}

/// Post-run ingest accounting.
#[derive(Clone, Debug, Default)]
pub struct PrefetchStats {
    /// Total time I/O workers spent reading (sum over groups).
    pub io_busy_s: f64,
    /// Per-group read intervals (seconds relative to the prefetcher clock).
    pub read_intervals: Vec<(f64, f64)>,
    /// Groups fully read.
    pub groups_read: usize,
    /// Largest observed in-flight window (reading + ready); ≤ depth always.
    pub peak_window: usize,
}

struct State {
    next_group: usize,
    reading: usize,
    ready: VecDeque<GroupBatch>,
    error: Option<HegridError>,
    failed: bool,
    io_busy: f64,
    intervals: Vec<(f64, f64)>,
    groups_read: usize,
    peak_window: usize,
}

/// Bounded read-ahead ring shared between I/O workers and pipelines.
pub struct Prefetcher {
    n_groups: usize,
    depth: usize,
    state: Mutex<State>,
    cond: Condvar,
    t0: Instant,
}

impl Prefetcher {
    /// `depth` bounds the in-flight window (groups being read + ready);
    /// clamped to ≥ 1.
    pub fn new(n_groups: usize, depth: usize) -> Prefetcher {
        Prefetcher {
            n_groups,
            depth: depth.max(1),
            state: Mutex::new(State {
                next_group: 0,
                reading: 0,
                ready: VecDeque::new(),
                error: None,
                failed: false,
                io_busy: 0.0,
                intervals: Vec::new(),
                groups_read: 0,
                peak_window: 0,
            }),
            cond: Condvar::new(),
            t0: Instant::now(),
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Seconds elapsed on the prefetcher clock (the time base of the
    /// read/compute intervals fed to [`overlap_seconds`]).
    pub fn now_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// I/O worker body: claim groups FIFO, read, push. Call from one or more
    /// threads inside the caller's scope; returns when every group is
    /// claimed or the run failed.
    pub fn run_worker(
        &self,
        source: &dyn ChannelSource,
        groups: &ChannelGroups,
        pool: &MemoryPool,
    ) {
        let n_samples = source.n_samples();
        loop {
            // ---- claim (with backpressure) -------------------------------
            let g = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.failed || st.next_group >= self.n_groups {
                        return;
                    }
                    if st.ready.len() + st.reading < self.depth {
                        let g = st.next_group;
                        st.next_group += 1;
                        st.reading += 1;
                        st.peak_window = st.peak_window.max(st.ready.len() + st.reading);
                        break g;
                    }
                    st = self.cond.wait(st).unwrap();
                }
            };

            // ---- read (no locks held) ------------------------------------
            let channels: Vec<usize> = groups.members(g).to_vec();
            let start = self.now_s();
            let mut values = Vec::with_capacity(channels.len());
            let mut failure: Option<HegridError> = None;
            for &ch in &channels {
                let mut buf = pool.take(n_samples);
                if let Err(e) = source.read_channel_into(ch, &mut buf) {
                    failure = Some(e);
                    break;
                }
                if buf.len() != n_samples {
                    failure = Some(HegridError::Internal(format!(
                        "source produced {} values for channel {ch}, expected {n_samples}",
                        buf.len()
                    )));
                    break;
                }
                values.push(buf);
            }
            let end = self.now_s();

            // ---- publish -------------------------------------------------
            let mut st = self.state.lock().unwrap();
            st.reading -= 1;
            match failure {
                Some(e) => {
                    if st.error.is_none() {
                        st.error = Some(e);
                    }
                    st.failed = true;
                    self.cond.notify_all();
                    return;
                }
                None if st.failed => {
                    // The run was aborted while this read was in flight:
                    // drop the straggler batch (its buffers recycle) so no
                    // consumer processes work after the failure.
                    self.cond.notify_all();
                    return;
                }
                None => {
                    st.io_busy += end - start;
                    st.intervals.push((start, end));
                    st.groups_read += 1;
                    st.ready.push_back(GroupBatch { group: g, channels, values });
                    self.cond.notify_all();
                }
            }
        }
    }

    /// Pull the next prefetched group; blocks while the ring is empty.
    /// `None` once every group has been delivered (or after a failure has
    /// been reported). The first caller to observe a failure gets
    /// `Some(Err(..))`; later callers get `None`.
    pub fn next(&self) -> Option<Result<GroupBatch>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(batch) = st.ready.pop_front() {
                // A window slot freed up: wake a blocked I/O worker.
                self.cond.notify_all();
                return Some(Ok(batch));
            }
            if st.failed {
                return st.error.take().map(Err);
            }
            if st.next_group >= self.n_groups && st.reading == 0 {
                return None;
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    /// Stop the run early (consumer-side failure): workers stop claiming,
    /// blocked parties wake, pending `next` calls drain to `None`. Any
    /// batches already in the ring are dropped (their buffers recycle).
    pub fn abort(&self) {
        let mut st = self.state.lock().unwrap();
        st.failed = true;
        st.ready.clear();
        self.cond.notify_all();
    }

    /// Ingest accounting; call after the workers have finished.
    pub fn stats(&self) -> PrefetchStats {
        let st = self.state.lock().unwrap();
        PrefetchStats {
            io_busy_s: st.io_busy,
            read_intervals: st.intervals.clone(),
            groups_read: st.groups_read,
            peak_window: st.peak_window,
        }
    }
}

/// Merge possibly-overlapping intervals into a sorted disjoint set.
pub fn merge_intervals(mut iv: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    iv.retain(|(a, b)| b > a);
    iv.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(iv.len());
    for (a, b) in iv {
        match out.last_mut() {
            Some(last) if a <= last.1 => last.1 = last.1.max(b),
            _ => out.push((a, b)),
        }
    }
    out
}

/// Total time during which both interval sets are active — the measured
/// I/O/compute overlap window. Inputs need not be sorted or disjoint.
pub fn overlap_seconds(io: &[(f64, f64)], compute: &[(f64, f64)]) -> f64 {
    let a = merge_intervals(io.to_vec());
    let b = merge_intervals(compute.to_vec());
    let (mut i, mut j) = (0, 0);
    let mut total = 0.0;
    while i < a.len() && j < b.len() {
        let lo = a[i].0.max(b[j].0);
        let hi = a[i].1.min(b[j].1);
        if hi > lo {
            total += hi - lo;
        }
        if a[i].1 <= b[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::InMemorySource;
    use crate::sim::SimConfig;

    #[test]
    fn merge_intervals_basic() {
        assert_eq!(merge_intervals(vec![]), vec![]);
        assert_eq!(
            merge_intervals(vec![(3.0, 4.0), (1.0, 2.0)]),
            vec![(1.0, 2.0), (3.0, 4.0)]
        );
        assert_eq!(
            merge_intervals(vec![(1.0, 2.5), (2.0, 3.0), (3.0, 4.0)]),
            vec![(1.0, 4.0)]
        );
        // Degenerate/inverted intervals are dropped.
        assert_eq!(merge_intervals(vec![(2.0, 2.0), (5.0, 4.0)]), vec![]);
    }

    #[test]
    fn overlap_seconds_cases() {
        assert_eq!(overlap_seconds(&[], &[(0.0, 1.0)]), 0.0);
        assert_eq!(overlap_seconds(&[(0.0, 1.0)], &[(2.0, 3.0)]), 0.0);
        let io = [(0.0, 2.0), (4.0, 6.0)];
        let cp = [(1.0, 5.0)];
        assert!((overlap_seconds(&io, &cp) - 2.0).abs() < 1e-12);
        // Unsorted, overlapping inputs.
        let io = [(3.0, 4.0), (0.0, 2.0), (1.0, 3.5)];
        let cp = [(0.5, 1.0), (0.75, 3.0)];
        assert!((overlap_seconds(&io, &cp) - 2.5).abs() < 1e-12);
    }

    fn drain_all(pf: &Prefetcher) -> Vec<GroupBatch> {
        let mut out = Vec::new();
        while let Some(b) = pf.next() {
            out.push(b.expect("no failure expected"));
        }
        out
    }

    #[test]
    fn delivers_every_group_exactly_once() {
        let d = SimConfig::quick_preset().generate();
        let source = InMemorySource::new(&d);
        let groups = ChannelGroups::new(d.n_channels(), 3); // 4 channels → 2 groups
        for depth in [1usize, 2, 8] {
            for workers in [1usize, 2] {
                let pf = Prefetcher::new(groups.len(), depth);
                let pool = MemoryPool::new();
                let batches = std::thread::scope(|s| {
                    for _ in 0..workers {
                        s.spawn(|| pf.run_worker(&source, &groups, &pool));
                    }
                    drain_all(&pf)
                });
                assert_eq!(batches.len(), groups.len());
                let mut seen: Vec<usize> = batches.iter().map(|b| b.group).collect();
                seen.sort_unstable();
                assert_eq!(seen, (0..groups.len()).collect::<Vec<_>>());
                for b in &batches {
                    assert_eq!(b.channels, groups.members(b.group));
                    for (ci, &ch) in b.channels.iter().enumerate() {
                        assert_eq!(*b.values[ci], d.channels[ch], "group {} ch {ch}", b.group);
                    }
                }
                let stats = pf.stats();
                assert_eq!(stats.groups_read, groups.len());
                assert!(stats.peak_window <= depth, "window {} > depth {depth}", stats.peak_window);
            }
        }
    }

    #[test]
    fn backpressure_caps_the_window_at_depth_one() {
        let d = SimConfig::quick_preset().generate();
        let source = InMemorySource::new(&d);
        let groups = ChannelGroups::new(d.n_channels(), 1); // 4 groups
        let pf = Prefetcher::new(groups.len(), 1);
        let pool = MemoryPool::new();
        std::thread::scope(|s| {
            s.spawn(|| pf.run_worker(&source, &groups, &pool));
            s.spawn(|| pf.run_worker(&source, &groups, &pool));
            let got = drain_all(&pf);
            assert_eq!(got.len(), 4);
        });
        assert_eq!(pf.stats().peak_window, 1);
    }

    #[test]
    fn source_failure_is_reported_once_then_ends() {
        struct Failing;
        impl ChannelSource for Failing {
            fn meta(&self) -> &crate::data::DatasetMeta {
                unreachable!("prefetcher never asks the source for metadata")
            }
            fn n_samples(&self) -> usize {
                8
            }
            fn n_channels(&self) -> usize {
                4
            }
            fn coords(&self) -> Result<(&[f64], &[f64])> {
                unreachable!("prefetcher never asks the source for coords")
            }
            fn read_channel_into(&self, c: usize, out: &mut Vec<f32>) -> Result<()> {
                if c >= 2 {
                    return Err(HegridError::Corrupt(format!("channel {c} bad")));
                }
                out.clear();
                out.resize(8, 1.0);
                Ok(())
            }
        }
        let groups = ChannelGroups::new(4, 1);
        let pf = Prefetcher::new(groups.len(), 4);
        let pool = MemoryPool::new();
        let (ok, errs, nones) = std::thread::scope(|s| {
            s.spawn(|| pf.run_worker(&Failing, &groups, &pool));
            let (mut ok, mut errs) = (0, 0);
            while let Some(r) = pf.next() {
                match r {
                    Ok(_) => ok += 1,
                    Err(e) => {
                        assert!(matches!(e, HegridError::Corrupt(_)));
                        errs += 1;
                    }
                }
            }
            // After the error, the stream is over.
            let nones = usize::from(pf.next().is_none());
            (ok, errs, nones)
        });
        assert_eq!(ok, 2);
        assert_eq!(errs, 1);
        assert_eq!(nones, 1);
    }
}
