//! Read-ahead channel ingest: the T0 stage that overlaps disk I/O with the
//! T1–T4 pipeline stages (the paper's §4.3 I/O/compute co-optimization,
//! Fig 8's "load" bars sliding under the compute bars).
//!
//! A [`Prefetcher`] coordinates a small pool of I/O worker threads (spawned
//! by the caller inside its own `thread::scope`, so sources can be borrowed)
//! with the coordinator's pipeline workers:
//!
//! * workers **claim** the next channel group FIFO, read its channels from a
//!   [`ChannelSource`] into pooled buffers, and push the finished
//!   [`GroupBatch`] onto a bounded ready ring;
//! * at most `depth` groups are in flight (being read + ready) at any time —
//!   when pipelines fall behind, workers block (**backpressure**). A batch a
//!   consumer has already pulled no longer counts against the window, so a
//!   full run's peak residency is `depth` + one batch per consumer;
//! * pipelines **pull** batches with [`Prefetcher::next`], blocking while
//!   the ring is empty (starvation — measurable as missing overlap).
//!
//! Every read records its wall-clock interval; after the run,
//! [`overlap_seconds`] intersects the merged I/O intervals with the merged
//! compute intervals to report the *measured* I/O/compute overlap window —
//! the number `fig8_timeline` prints, nonzero whenever `depth ≥ 2` gives
//! the workers room to read ahead.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::coordinator::plan::ChannelGroups;
use crate::data::ChannelSource;
use crate::runtime::pool::{MemoryPool, PooledBuf};
use crate::util::error::{HegridError, Result};

/// One prefetched channel group, ready for a pipeline to stage.
pub struct GroupBatch {
    /// Group index within the run's [`ChannelGroups`].
    pub group: usize,
    /// Channel ids of the group's members.
    pub channels: Vec<usize>,
    /// Per-member value vectors (`n_samples` each); pooled, recycled on drop.
    pub values: Vec<PooledBuf>,
}

/// Post-run ingest accounting.
#[derive(Clone, Debug, Default)]
pub struct PrefetchStats {
    /// Total time I/O workers spent reading (sum over groups).
    pub io_busy_s: f64,
    /// Per-group read intervals (seconds relative to the prefetcher clock).
    pub read_intervals: Vec<(f64, f64)>,
    /// Groups fully read.
    pub groups_read: usize,
    /// Largest observed in-flight window (reading + ready); ≤ depth always.
    pub peak_window: usize,
    /// Channel-read retries performed (transient errors that were retried,
    /// whether or not the retry eventually succeeded).
    pub retries: usize,
    /// Degrade mode only: groups skipped after their reads failed
    /// post-retry, with the terminal cause. Empty in fail-fast mode.
    pub failed_groups: Vec<(usize, String)>,
}

/// How the I/O workers respond to failed channel reads.
#[derive(Clone, Copy, Debug)]
pub struct ReadPolicy {
    /// Retries after the first failure of a channel read (transient I/O and
    /// corruption errors only). 0 = fail immediately.
    pub retries: usize,
    /// Base backoff between retries, doubled per attempt. 0 = no sleep.
    pub backoff_ms: u64,
    /// `true`: a group whose read fails post-retry is recorded in
    /// `failed_groups` and skipped, and ingest continues with the next
    /// group. `false` (default): the first terminal error fails the stream.
    pub degrade: bool,
}

impl Default for ReadPolicy {
    fn default() -> Self {
        ReadPolicy { retries: 0, backoff_ms: 0, degrade: false }
    }
}

struct State {
    next_group: usize,
    reading: usize,
    ready: VecDeque<GroupBatch>,
    error: Option<HegridError>,
    failed: bool,
    /// Formatted terminal cause; `next()` synthesizes errors from it for
    /// every caller after the first (HegridError is not Clone).
    cause: Option<String>,
    io_busy: f64,
    intervals: Vec<(f64, f64)>,
    groups_read: usize,
    peak_window: usize,
    retries: usize,
    failed_groups: Vec<(usize, String)>,
}

/// Bounded read-ahead ring shared between I/O workers and pipelines.
pub struct Prefetcher {
    n_groups: usize,
    depth: usize,
    policy: ReadPolicy,
    state: Mutex<State>,
    cond: Condvar,
    t0: Instant,
}

impl Prefetcher {
    /// `depth` bounds the in-flight window (groups being read + ready);
    /// clamped to ≥ 1.
    pub fn new(n_groups: usize, depth: usize) -> Prefetcher {
        Prefetcher {
            n_groups,
            depth: depth.max(1),
            policy: ReadPolicy::default(),
            state: Mutex::new(State {
                next_group: 0,
                reading: 0,
                ready: VecDeque::new(),
                error: None,
                failed: false,
                cause: None,
                io_busy: 0.0,
                intervals: Vec::new(),
                groups_read: 0,
                peak_window: 0,
                retries: 0,
                failed_groups: Vec::new(),
            }),
            cond: Condvar::new(),
            t0: Instant::now(),
        }
    }

    /// Set the retry/degrade policy of the I/O workers (builder style).
    pub fn with_read_policy(mut self, policy: ReadPolicy) -> Prefetcher {
        self.policy = policy;
        self
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Seconds elapsed on the prefetcher clock (the time base of the
    /// read/compute intervals fed to [`overlap_seconds`]).
    pub fn now_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// I/O worker body: claim groups FIFO, read, push. Call from one or more
    /// threads inside the caller's scope; returns when every group is
    /// claimed or the run failed.
    pub fn run_worker(
        &self,
        source: &dyn ChannelSource,
        groups: &ChannelGroups,
        pool: &MemoryPool,
    ) {
        let n_samples = source.n_samples();
        loop {
            // ---- claim (with backpressure) -------------------------------
            let g = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.failed || st.next_group >= self.n_groups {
                        return;
                    }
                    if st.ready.len() + st.reading < self.depth {
                        let g = st.next_group;
                        st.next_group += 1;
                        st.reading += 1;
                        st.peak_window = st.peak_window.max(st.ready.len() + st.reading);
                        break g;
                    }
                    st = self.cond.wait(st).unwrap();
                }
            };

            // ---- read (no locks held) ------------------------------------
            crate::util::faults::prefetch_stall(g);
            let channels: Vec<usize> = groups.members(g).to_vec();
            let start = self.now_s();
            let mut values = Vec::with_capacity(channels.len());
            let mut failure: Option<HegridError> = None;
            let mut retries_here = 0usize;
            for &ch in &channels {
                let mut buf = pool.take(n_samples);
                let mut attempt = 0usize;
                let outcome = loop {
                    match source.read_channel_into(ch, &mut buf) {
                        Ok(()) => break Ok(()),
                        Err(e) if attempt < self.policy.retries && retryable(&e) => {
                            attempt += 1;
                            retries_here += 1;
                            let ms = self
                                .policy
                                .backoff_ms
                                .saturating_mul(1u64 << (attempt - 1).min(10));
                            if ms > 0 {
                                std::thread::sleep(std::time::Duration::from_millis(ms));
                            }
                        }
                        Err(e) => break Err(e),
                    }
                };
                if let Err(e) = outcome {
                    failure = Some(e);
                    break;
                }
                if buf.len() != n_samples {
                    failure = Some(HegridError::Internal(format!(
                        "source produced {} values for channel {ch}, expected {n_samples}",
                        buf.len()
                    )));
                    break;
                }
                values.push(buf);
            }
            let end = self.now_s();

            // ---- publish -------------------------------------------------
            let mut st = self.state.lock().unwrap();
            st.reading -= 1;
            st.retries += retries_here;
            match failure {
                Some(e) if self.policy.degrade => {
                    // Degrade: quarantine the group and keep ingesting. The
                    // coordinator folds `failed_groups` into its
                    // DegradationReport after the run.
                    st.failed_groups.push((g, format!("{e}")));
                    self.cond.notify_all();
                }
                Some(e) => {
                    if st.cause.is_none() {
                        st.cause = Some(format!("{e}"));
                    }
                    if st.error.is_none() {
                        st.error = Some(e);
                    }
                    st.failed = true;
                    self.cond.notify_all();
                    return;
                }
                None if st.failed => {
                    // The run was aborted while this read was in flight:
                    // drop the straggler batch (its buffers recycle) so no
                    // consumer processes work after the failure.
                    self.cond.notify_all();
                    return;
                }
                None => {
                    st.io_busy += end - start;
                    st.intervals.push((start, end));
                    st.groups_read += 1;
                    st.ready.push_back(GroupBatch { group: g, channels, values });
                    self.cond.notify_all();
                }
            }
        }
    }

    /// Pull the next prefetched group; blocks while the ring is empty.
    /// `None` once every group has been delivered. After a failure the
    /// terminal error is **sticky**: the first caller gets the original
    /// error and every later caller gets a synthesized error naming the
    /// same cause — never `None`, so no consumer can mistake an aborted
    /// stream for a clean end-of-stream. Callers must stop pulling once
    /// they observe `Some(Err(..))`.
    pub fn next(&self) -> Option<Result<GroupBatch>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(batch) = st.ready.pop_front() {
                // A window slot freed up: wake a blocked I/O worker.
                self.cond.notify_all();
                return Some(Ok(batch));
            }
            if st.failed {
                if let Some(e) = st.error.take() {
                    return Some(Err(e));
                }
                let cause = st.cause.as_deref().unwrap_or("no cause recorded");
                return Some(Err(HegridError::Runtime(format!(
                    "prefetcher terminated: {cause}"
                ))));
            }
            if st.next_group >= self.n_groups && st.reading == 0 {
                return None;
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    /// Stop the run early (consumer-side failure): workers stop claiming,
    /// blocked parties wake, and every pending or future `next` call
    /// observes a terminal error. Any batches already in the ring are
    /// dropped (their buffers recycle).
    pub fn abort(&self) {
        let mut st = self.state.lock().unwrap();
        st.failed = true;
        if st.cause.is_none() {
            st.cause = Some("aborted by the coordinator after a pipeline failure".into());
        }
        st.ready.clear();
        self.cond.notify_all();
    }

    /// Ingest accounting; call after the workers have finished.
    pub fn stats(&self) -> PrefetchStats {
        let st = self.state.lock().unwrap();
        PrefetchStats {
            io_busy_s: st.io_busy,
            read_intervals: st.intervals.clone(),
            groups_read: st.groups_read,
            peak_window: st.peak_window,
            retries: st.retries,
            failed_groups: st.failed_groups.clone(),
        }
    }
}

/// Errors worth retrying: transient I/O and corruption (a torn read can
/// produce either). Format/config/internal errors are deterministic — a
/// retry would just fail again.
fn retryable(e: &HegridError) -> bool {
    matches!(e, HegridError::Io { .. } | HegridError::Corrupt(_))
}

/// Merge possibly-overlapping intervals into a sorted disjoint set.
pub fn merge_intervals(mut iv: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    iv.retain(|(a, b)| b > a);
    iv.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(iv.len());
    for (a, b) in iv {
        match out.last_mut() {
            Some(last) if a <= last.1 => last.1 = last.1.max(b),
            _ => out.push((a, b)),
        }
    }
    out
}

/// Total time during which both interval sets are active — the measured
/// I/O/compute overlap window. Inputs need not be sorted or disjoint.
pub fn overlap_seconds(io: &[(f64, f64)], compute: &[(f64, f64)]) -> f64 {
    let a = merge_intervals(io.to_vec());
    let b = merge_intervals(compute.to_vec());
    let (mut i, mut j) = (0, 0);
    let mut total = 0.0;
    while i < a.len() && j < b.len() {
        let lo = a[i].0.max(b[j].0);
        let hi = a[i].1.min(b[j].1);
        if hi > lo {
            total += hi - lo;
        }
        if a[i].1 <= b[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::InMemorySource;
    use crate::sim::SimConfig;

    #[test]
    fn merge_intervals_basic() {
        assert_eq!(merge_intervals(vec![]), vec![]);
        assert_eq!(
            merge_intervals(vec![(3.0, 4.0), (1.0, 2.0)]),
            vec![(1.0, 2.0), (3.0, 4.0)]
        );
        assert_eq!(
            merge_intervals(vec![(1.0, 2.5), (2.0, 3.0), (3.0, 4.0)]),
            vec![(1.0, 4.0)]
        );
        // Degenerate/inverted intervals are dropped.
        assert_eq!(merge_intervals(vec![(2.0, 2.0), (5.0, 4.0)]), vec![]);
    }

    #[test]
    fn overlap_seconds_cases() {
        assert_eq!(overlap_seconds(&[], &[(0.0, 1.0)]), 0.0);
        assert_eq!(overlap_seconds(&[(0.0, 1.0)], &[(2.0, 3.0)]), 0.0);
        let io = [(0.0, 2.0), (4.0, 6.0)];
        let cp = [(1.0, 5.0)];
        assert!((overlap_seconds(&io, &cp) - 2.0).abs() < 1e-12);
        // Unsorted, overlapping inputs.
        let io = [(3.0, 4.0), (0.0, 2.0), (1.0, 3.5)];
        let cp = [(0.5, 1.0), (0.75, 3.0)];
        assert!((overlap_seconds(&io, &cp) - 2.5).abs() < 1e-12);
    }

    fn drain_all(pf: &Prefetcher) -> Vec<GroupBatch> {
        let mut out = Vec::new();
        while let Some(b) = pf.next() {
            out.push(b.expect("no failure expected"));
        }
        out
    }

    #[test]
    fn delivers_every_group_exactly_once() {
        let d = SimConfig::quick_preset().generate();
        let source = InMemorySource::new(&d);
        let groups = ChannelGroups::new(d.n_channels(), 3); // 4 channels → 2 groups
        for depth in [1usize, 2, 8] {
            for workers in [1usize, 2] {
                let pf = Prefetcher::new(groups.len(), depth);
                let pool = MemoryPool::new();
                let batches = std::thread::scope(|s| {
                    for _ in 0..workers {
                        s.spawn(|| pf.run_worker(&source, &groups, &pool));
                    }
                    drain_all(&pf)
                });
                assert_eq!(batches.len(), groups.len());
                let mut seen: Vec<usize> = batches.iter().map(|b| b.group).collect();
                seen.sort_unstable();
                assert_eq!(seen, (0..groups.len()).collect::<Vec<_>>());
                for b in &batches {
                    assert_eq!(b.channels, groups.members(b.group));
                    for (ci, &ch) in b.channels.iter().enumerate() {
                        assert_eq!(*b.values[ci], d.channels[ch], "group {} ch {ch}", b.group);
                    }
                }
                let stats = pf.stats();
                assert_eq!(stats.groups_read, groups.len());
                assert!(stats.peak_window <= depth, "window {} > depth {depth}", stats.peak_window);
            }
        }
    }

    #[test]
    fn backpressure_caps_the_window_at_depth_one() {
        let d = SimConfig::quick_preset().generate();
        let source = InMemorySource::new(&d);
        let groups = ChannelGroups::new(d.n_channels(), 1); // 4 groups
        let pf = Prefetcher::new(groups.len(), 1);
        let pool = MemoryPool::new();
        std::thread::scope(|s| {
            s.spawn(|| pf.run_worker(&source, &groups, &pool));
            s.spawn(|| pf.run_worker(&source, &groups, &pool));
            let got = drain_all(&pf);
            assert_eq!(got.len(), 4);
        });
        assert_eq!(pf.stats().peak_window, 1);
    }

    /// Fails every read of channels ≥ `bad_from`; earlier channels succeed.
    /// With `transient_failures > 0`, *every* channel fails that many times
    /// before succeeding (exercises retry).
    struct Flaky {
        bad_from: usize,
        transient_failures: usize,
        attempts: Mutex<std::collections::HashMap<usize, usize>>,
    }

    impl Flaky {
        fn permanent(bad_from: usize) -> Flaky {
            Flaky { bad_from, transient_failures: 0, attempts: Mutex::new(Default::default()) }
        }
        fn transient(failures: usize) -> Flaky {
            Flaky {
                bad_from: usize::MAX,
                transient_failures: failures,
                attempts: Mutex::new(Default::default()),
            }
        }
    }

    impl ChannelSource for Flaky {
        fn meta(&self) -> &crate::data::DatasetMeta {
            unreachable!("prefetcher never asks the source for metadata")
        }
        fn n_samples(&self) -> usize {
            8
        }
        fn n_channels(&self) -> usize {
            4
        }
        fn coords(&self) -> Result<(&[f64], &[f64])> {
            unreachable!("prefetcher never asks the source for coords")
        }
        fn read_channel_into(&self, c: usize, out: &mut Vec<f32>) -> Result<()> {
            if c >= self.bad_from {
                return Err(HegridError::Corrupt(format!("channel {c} bad")));
            }
            let mut attempts = self.attempts.lock().unwrap();
            let n = attempts.entry(c).or_insert(0);
            *n += 1;
            if *n <= self.transient_failures {
                return Err(HegridError::Io {
                    context: format!("channel {c}"),
                    source: std::io::Error::other("transient"),
                });
            }
            out.clear();
            out.resize(8, c as f32);
            Ok(())
        }
    }

    #[test]
    fn source_failure_is_sticky_for_every_consumer() {
        let groups = ChannelGroups::new(4, 1);
        let pf = Prefetcher::new(groups.len(), 4);
        let pool = MemoryPool::new();
        let (ok, first_err) = std::thread::scope(|s| {
            s.spawn(|| pf.run_worker(&Flaky::permanent(2), &groups, &pool));
            let mut ok = 0;
            let first_err = loop {
                match pf.next() {
                    Some(Ok(_)) => ok += 1,
                    Some(Err(e)) => break e,
                    None => panic!("stream must not end cleanly after a failure"),
                }
            };
            (ok, first_err)
        });
        assert_eq!(ok, 2);
        assert!(matches!(first_err, HegridError::Corrupt(_)), "{first_err}");
        // Later callers keep observing the terminal error (never None): a
        // coordinator slot arriving after the failure can't mistake the
        // aborted stream for clean end-of-input.
        for _ in 0..3 {
            match pf.next() {
                Some(Err(e)) => assert!(format!("{e}").contains("channel 2 bad"), "{e}"),
                other => panic!("expected sticky error, got {:?}", other.map(|r| r.is_ok())),
            }
        }
    }

    #[test]
    fn abort_is_sticky_and_drains_workers() {
        let d = SimConfig::quick_preset().generate();
        let source = InMemorySource::new(&d);
        let groups = ChannelGroups::new(d.n_channels(), 1);
        let pf = Prefetcher::new(groups.len(), 1);
        let pool = MemoryPool::new();
        std::thread::scope(|s| {
            s.spawn(|| pf.run_worker(&source, &groups, &pool));
            let first = pf.next().expect("at least one batch");
            assert!(first.is_ok());
            pf.abort();
            // Workers return (scope would deadlock otherwise) and every
            // subsequent pull reports the abort.
            for _ in 0..2 {
                match pf.next() {
                    Some(Err(e)) => assert!(format!("{e}").contains("aborted"), "{e}"),
                    other => panic!("expected abort error, got {:?}", other.map(|r| r.is_ok())),
                }
            }
        });
    }

    #[test]
    fn transient_read_errors_are_retried() {
        let groups = ChannelGroups::new(4, 2); // 2 groups of 2 channels
        let source = Flaky::transient(2);
        let pf = Prefetcher::new(groups.len(), 2)
            .with_read_policy(ReadPolicy { retries: 2, backoff_ms: 0, degrade: false });
        let pool = MemoryPool::new();
        let batches = std::thread::scope(|s| {
            s.spawn(|| pf.run_worker(&source, &groups, &pool));
            let mut out = Vec::new();
            while let Some(b) = pf.next() {
                out.push(b.expect("retries absorb the transient failures"));
            }
            out
        });
        assert_eq!(batches.len(), 2);
        let stats = pf.stats();
        assert_eq!(stats.retries, 8, "2 retries x 4 channels");
        assert!(stats.failed_groups.is_empty());
    }

    #[test]
    fn insufficient_retries_still_fail() {
        let groups = ChannelGroups::new(2, 2);
        let source = Flaky::transient(3);
        let pf = Prefetcher::new(groups.len(), 2)
            .with_read_policy(ReadPolicy { retries: 2, backoff_ms: 0, degrade: false });
        let pool = MemoryPool::new();
        std::thread::scope(|s| {
            s.spawn(|| pf.run_worker(&source, &groups, &pool));
            match pf.next() {
                Some(Err(HegridError::Io { .. })) => {}
                other => panic!("expected Io error, got {:?}", other.map(|r| r.is_ok())),
            }
        });
        assert_eq!(pf.stats().retries, 2);
    }

    #[test]
    fn degrade_mode_skips_failed_groups_and_ends_cleanly() {
        let groups = ChannelGroups::new(4, 1); // 4 groups of 1 channel
        let pf = Prefetcher::new(groups.len(), 2)
            .with_read_policy(ReadPolicy { retries: 1, backoff_ms: 0, degrade: true });
        let pool = MemoryPool::new();
        let batches = std::thread::scope(|s| {
            s.spawn(|| pf.run_worker(&Flaky::permanent(2), &groups, &pool));
            let mut out = Vec::new();
            while let Some(b) = pf.next() {
                out.push(b.expect("degrade mode never surfaces stream errors"));
            }
            out
        });
        let mut seen: Vec<usize> = batches.iter().map(|b| b.group).collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1], "surviving groups delivered");
        let stats = pf.stats();
        let mut failed: Vec<usize> = stats.failed_groups.iter().map(|f| f.0).collect();
        failed.sort_unstable();
        assert_eq!(failed, vec![2, 3]);
        for (_, cause) in &stats.failed_groups {
            assert!(cause.contains("bad"), "{cause}");
        }
    }
}
