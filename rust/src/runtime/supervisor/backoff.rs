//! Bounded exponential restart backoff for shard workers.
//!
//! A worker that dies instantly on every attempt (bad input, poisoned
//! checkpoint, broken accelerator) must not be respawned in a tight loop:
//! each restart re-reads the shard checkpoint and re-opens the dataset,
//! and a fork bomb of doomed workers starves the healthy shards' I/O. The
//! delay doubles per restart from `shard_restart_backoff_ms` and is capped
//! at [`CAP_MS`]; `shard_max_restarts` bounds the total attempts, after
//! which the shard is quarantined (see [`super::monitor`]).

use std::time::Duration;

/// Upper bound on a single restart delay. Mirrors the config doc for
/// `shard_restart_backoff_ms` ("doubled per restart, capped at 30s").
pub const CAP_MS: u64 = 30_000;

/// Delay before restart number `restart` (0-based: the first restart after
/// the initial attempt waits `base_ms`). `base_ms = 0` disables the wait —
/// tests restart instantly.
pub fn restart_delay(base_ms: usize, restart: usize) -> Duration {
    if base_ms == 0 {
        return Duration::ZERO;
    }
    // Shift saturates well past the cap; 1 << 63 would already overflow
    // any sane base, so clamp the exponent first.
    let shift = restart.min(20) as u32;
    let ms = (base_ms as u64).saturating_mul(1u64 << shift).min(CAP_MS);
    Duration::from_millis(ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doubles_then_caps() {
        assert_eq!(restart_delay(200, 0), Duration::from_millis(200));
        assert_eq!(restart_delay(200, 1), Duration::from_millis(400));
        assert_eq!(restart_delay(200, 2), Duration::from_millis(800));
        assert_eq!(restart_delay(200, 7), Duration::from_millis(25_600));
        assert_eq!(restart_delay(200, 8), Duration::from_millis(CAP_MS));
        assert_eq!(restart_delay(200, 63), Duration::from_millis(CAP_MS));
    }

    #[test]
    fn zero_base_disables_backoff() {
        for restart in [0, 1, 10] {
            assert_eq!(restart_delay(0, restart), Duration::ZERO);
        }
    }

    #[test]
    fn huge_base_saturates_at_cap() {
        assert_eq!(restart_delay(60_000, 0), Duration::from_millis(CAP_MS));
        assert_eq!(restart_delay(usize::MAX, 3), Duration::from_millis(CAP_MS));
    }
}
