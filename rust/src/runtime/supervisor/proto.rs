//! The worker → supervisor heartbeat protocol: one text frame per line on
//! the child's stdout pipe.
//!
//! Frames are prefixed `HEGRID-FRAME ` so anything else a worker (or a
//! library it calls) prints is ignored rather than corrupting the stream.
//! The format is deliberately line-oriented plain text: a torn final line
//! from a SIGKILLed worker fails to parse and is dropped, which is exactly
//! the right behaviour — progress is trusted only from the shard's CRC'd
//! checkpoint manifest, never from the heartbeat stream.
//!
//! ```text
//! HEGRID-FRAME PING <seq>
//! HEGRID-FRAME GROUP <group> <crc-hex>
//! HEGRID-FRAME STAGE <secs> <stage name...>
//! HEGRID-FRAME DONE <groups_done> <retries> <quarantined csv | ->
//! HEGRID-FRAME FATAL <message...>
//! ```
//!
//! `PING` is pure liveness (every [`HEARTBEAT_MS`]); `GROUP` announces a
//! channel group recorded done in the shard manifest (also counts as a
//! heartbeat); `STAGE` carries the worker's per-stage wall seconds for the
//! parent's merged report; `DONE` is the success epilogue; `FATAL` carries
//! the error message ahead of a nonzero exit so the supervisor can record
//! a cause better than "exit status 1".

use std::fmt::Write as _;

/// Worker heartbeat period in milliseconds. The liveness timeout
/// (`shard_heartbeat_timeout_s`, seconds) is bounded well above this, so a
/// healthy worker can never be mistaken for a hung one.
pub const HEARTBEAT_MS: u64 = 200;

/// Line prefix marking a protocol frame.
pub const FRAME_PREFIX: &str = "HEGRID-FRAME ";

/// One protocol frame. See the module docs for the wire format.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Liveness tick; `seq` increments monotonically per worker attempt.
    Ping { seq: u64 },
    /// Channel group `group` is recorded done in the shard manifest with
    /// cube-byte CRC `crc`.
    Group { group: usize, crc: u32 },
    /// `secs` of wall time attributed to pipeline stage `name`.
    Stage { secs: f64, name: String },
    /// Success epilogue: groups done, T0 read retries absorbed, and the
    /// channel groups this worker quarantined (degrade mode).
    Done { groups: usize, retries: usize, quarantined: Vec<usize> },
    /// Failure epilogue: the error message, emitted just before a nonzero
    /// exit.
    Fatal { message: String },
}

impl Frame {
    /// Render the frame as one line (no trailing newline).
    pub fn encode(&self) -> String {
        let mut s = String::from(FRAME_PREFIX);
        match self {
            Frame::Ping { seq } => {
                let _ = write!(s, "PING {seq}");
            }
            Frame::Group { group, crc } => {
                let _ = write!(s, "GROUP {group} {crc:08x}");
            }
            Frame::Stage { secs, name } => {
                // The stage name goes last: it may contain spaces
                // ("T3 kernel(+wait)") and parses as rest-of-line.
                let _ = write!(s, "STAGE {secs} {name}");
            }
            Frame::Done { groups, retries, quarantined } => {
                let q = if quarantined.is_empty() {
                    "-".to_string()
                } else {
                    quarantined
                        .iter()
                        .map(|g| g.to_string())
                        .collect::<Vec<_>>()
                        .join(",")
                };
                let _ = write!(s, "DONE {groups} {retries} {q}");
            }
            Frame::Fatal { message } => {
                // Newlines would split the frame across lines; flatten them.
                let _ = write!(s, "FATAL {}", message.replace('\n', " | "));
            }
        }
        s
    }

    /// Parse one stdout line. `None` for non-frame lines *and* malformed
    /// frames (e.g. a line torn mid-write by a SIGKILL) — both are
    /// ignorable by design.
    pub fn parse(line: &str) -> Option<Frame> {
        let body = line.strip_prefix(FRAME_PREFIX)?;
        let (kind, rest) = match body.split_once(' ') {
            Some((k, r)) => (k, r),
            None => (body, ""),
        };
        match kind {
            "PING" => Some(Frame::Ping { seq: rest.trim().parse().ok()? }),
            "GROUP" => {
                let (g, crc) = rest.trim().split_once(' ')?;
                Some(Frame::Group {
                    group: g.parse().ok()?,
                    crc: u32::from_str_radix(crc, 16).ok()?,
                })
            }
            "STAGE" => {
                let (secs, name) = rest.split_once(' ')?;
                let secs: f64 = secs.parse().ok()?;
                if !secs.is_finite() || name.is_empty() {
                    return None;
                }
                Some(Frame::Stage { secs, name: name.to_string() })
            }
            "DONE" => {
                let mut it = rest.trim().split(' ');
                let groups = it.next()?.parse().ok()?;
                let retries = it.next()?.parse().ok()?;
                let q = it.next()?;
                if it.next().is_some() {
                    return None;
                }
                let quarantined = if q == "-" {
                    Vec::new()
                } else {
                    q.split(',')
                        .map(|g| g.parse::<usize>())
                        .collect::<std::result::Result<Vec<_>, _>>()
                        .ok()?
                };
                Some(Frame::Done { groups, retries, quarantined })
            }
            "FATAL" => Some(Frame::Fatal { message: rest.to_string() }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips() {
        let frames = [
            Frame::Ping { seq: 0 },
            Frame::Ping { seq: 12345 },
            Frame::Group { group: 7, crc: 0xdead_beef },
            Frame::Stage { secs: 0.125, name: "T3 kernel(+wait)".into() },
            Frame::Done { groups: 5, retries: 2, quarantined: vec![] },
            Frame::Done { groups: 5, retries: 0, quarantined: vec![1, 3] },
            Frame::Fatal { message: "I/O error (channel 3): injected".into() },
        ];
        for f in frames {
            let line = f.encode();
            assert!(line.starts_with(FRAME_PREFIX), "{line}");
            assert!(!line.contains('\n'), "{line}");
            assert_eq!(Frame::parse(&line), Some(f.clone()), "{line}");
        }
    }

    #[test]
    fn fatal_flattens_newlines() {
        let f = Frame::Fatal { message: "line one\nline two".into() };
        let line = f.encode();
        assert!(!line.contains('\n'));
        match Frame::parse(&line).unwrap() {
            Frame::Fatal { message } => assert_eq!(message, "line one | line two"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn torn_and_foreign_lines_are_ignored() {
        for bad in [
            "",
            "not a frame",
            "HEGRID-FRAME",
            "HEGRID-FRAME PING",
            "HEGRID-FRAME PING x",
            "HEGRID-FRAME GROUP 3",
            "HEGRID-FRAME GROUP 3 zz",
            "HEGRID-FRAME DONE 5 2",
            "HEGRID-FRAME DONE 5 2 1,x",
            "HEGRID-FRAME STAGE nan T3",
            "HEGRID-FRAME NOPE 1 2",
            // A PING torn mid-write by SIGKILL:
            "HEGRID-FRAME PI",
        ] {
            assert_eq!(Frame::parse(bad), None, "accepted: {bad:?}");
        }
    }
}
