//! Deterministic reduce of per-shard partial cubes into the final cube.
//!
//! Each shard's cube holds exactly its row range `[row_lo, row_hi)` of
//! every channel plane plus the wsum plane, already fully accumulated (a
//! worker grids all samples against its rows). The merge is therefore a
//! pure **concatenation**, not an addition: shards ascending, channels
//! ascending, offsets ascending, chunked reads so memory stays bounded.
//! Every byte of the output is copied verbatim from exactly one shard
//! cube, so the merged cube is byte-identical to a single-process run
//! independent of shard count, tile height, or how often workers were
//! killed and restarted.
//!
//! Quarantined shards are skipped: `CubeFile::create` zero-fills, so their
//! rows read as honest blanks — the same semantics as a quarantined
//! channel group's zeroed planes.

use std::path::Path;

use crate::coordinator::SkyPartition;
use crate::data::checkpoint::{CubeFile, CUBE_FILE};
use crate::util::error::Result;

/// Cells copied per read/write call — 512 KiB of f64, small enough to be
/// irrelevant next to the band accumulators, large enough to amortize the
/// syscalls.
const CHUNK_CELLS: usize = 1 << 16;

/// Concatenate the shard cubes under `dir` (see [`super::shard_dir`]) into
/// `dir/cube.bin`, shards ascending. Shards listed in `skip` (quarantined)
/// contribute zeros. Returns the full-map cube.
pub fn merge_shards(
    dir: &Path,
    partition: &SkyPartition,
    skip: &[usize],
    n_channels: usize,
    nlon: usize,
    nlat: usize,
) -> Result<CubeFile> {
    let full = CubeFile::create(&dir.join(CUBE_FILE), n_channels, nlon * nlat)?;
    let mut buf: Vec<f64> = Vec::new();
    for s in 0..partition.len() {
        if skip.contains(&s) {
            continue;
        }
        let (row_lo, row_hi) = partition.rows(s);
        let local_cells = (row_hi - row_lo) * nlon;
        let cell_base = row_lo * nlon;
        let part =
            CubeFile::open(&super::shard_dir(dir, s).join(CUBE_FILE), n_channels, local_cells)?;
        for ch in 0..n_channels {
            let mut c0 = 0usize;
            while c0 < local_cells {
                let len = CHUNK_CELLS.min(local_cells - c0);
                part.read_channel_band(ch, c0, len, &mut buf)?;
                full.write_channel_band(ch, cell_base + c0, &buf, None)?;
                c0 += len;
            }
        }
        let mut c0 = 0usize;
        while c0 < local_cells {
            let len = CHUNK_CELLS.min(local_cells - c0);
            part.read_wsum_band(c0, len, &mut buf)?;
            full.write_wsum_band(cell_base + c0, &buf, None)?;
            c0 += len;
        }
    }
    Ok(full)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("hegrid_merge_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Distinct, position-dependent value so any mis-placed cell is caught.
    fn val(ch: usize, cell: usize) -> f64 {
        (ch * 100_000 + cell) as f64 + 0.25
    }

    /// Build the shard cubes by hand, merge, and compare against a
    /// directly-written full cube — no engine involved, so this pins the
    /// concatenation arithmetic (offsets, chunking, wsum) in isolation.
    #[test]
    fn concatenation_reproduces_the_full_cube() {
        let dir = tmp("concat");
        let (n_ch, nlon, nlat) = (3usize, 8usize, 11usize);
        let partition = SkyPartition::split(nlat, 3); // 4 + 4 + 3 rows
        for s in 0..partition.len() {
            let (lo, hi) = partition.rows(s);
            let local = (hi - lo) * nlon;
            let sdir = crate::runtime::supervisor::shard_dir(&dir, s);
            std::fs::create_dir_all(&sdir).unwrap();
            let cube = CubeFile::create(&sdir.join(CUBE_FILE), n_ch, local).unwrap();
            for ch in 0..n_ch {
                let vals: Vec<f64> =
                    (0..local).map(|c| val(ch, lo * nlon + c)).collect();
                cube.write_channel_band(ch, 0, &vals, None).unwrap();
            }
            let wsum: Vec<f64> = (0..local).map(|c| val(99, lo * nlon + c)).collect();
            cube.write_wsum_band(0, &wsum, None).unwrap();
        }

        let merged = merge_shards(&dir, &partition, &[], n_ch, nlon, nlat).unwrap();
        let n_cells = nlon * nlat;
        let mut buf = Vec::new();
        for ch in 0..n_ch {
            merged.read_channel_band(ch, 0, n_cells, &mut buf).unwrap();
            for (c, &v) in buf.iter().enumerate() {
                assert_eq!(v.to_bits(), val(ch, c).to_bits(), "ch {ch} cell {c}");
            }
        }
        merged.read_wsum_band(0, n_cells, &mut buf).unwrap();
        for (c, &v) in buf.iter().enumerate() {
            assert_eq!(v.to_bits(), val(99, c).to_bits(), "wsum cell {c}");
        }
    }

    /// A skipped (quarantined) shard's rows stay zero; the others are
    /// copied untouched.
    #[test]
    fn skipped_shard_rows_are_zero() {
        let dir = tmp("skip");
        let (n_ch, nlon, nlat) = (1usize, 4usize, 6usize);
        let partition = SkyPartition::split(nlat, 2); // rows 0..3, 3..6
        for s in 0..2 {
            let (lo, hi) = partition.rows(s);
            let local = (hi - lo) * nlon;
            let sdir = crate::runtime::supervisor::shard_dir(&dir, s);
            std::fs::create_dir_all(&sdir).unwrap();
            let cube = CubeFile::create(&sdir.join(CUBE_FILE), n_ch, local).unwrap();
            cube.write_channel_band(0, 0, &vec![7.5; local], None).unwrap();
            cube.write_wsum_band(0, &vec![1.5; local], None).unwrap();
        }
        let merged = merge_shards(&dir, &partition, &[0], n_ch, nlon, nlat).unwrap();
        let mut buf = Vec::new();
        merged.read_channel_band(0, 0, nlon * nlat, &mut buf).unwrap();
        let half = 3 * nlon;
        assert!(buf[..half].iter().all(|&v| v == 0.0), "quarantined rows zeroed");
        assert!(buf[half..].iter().all(|&v| v == 7.5), "healthy rows copied");
    }
}
