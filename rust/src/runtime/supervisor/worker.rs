//! The `hegrid shard-worker` process body: grid one shard's row range to
//! a per-shard checkpoint, heartbeating over stdout.
//!
//! A worker is intentionally just the in-process engine pointed at a
//! narrowed output window: it opens the same dataset, builds the same
//! dispatch plan, and runs the same tiled pipelines — only the accumulate
//! / spill window is its [`crate::coordinator::SkyPartition`] row range.
//! All crash-robustness it needs already exists in the checkpoint layer:
//!
//! * **Auto-resume** — if the shard directory holds a manifest, the worker
//!   resumes it; finished groups are CRC-verified and skipped, so a
//!   restarted worker re-grids only what its predecessor hadn't finished.
//! * **Self-heal** — a torn or corrupt shard checkpoint (SIGKILL mid-save,
//!   truncated cube) or one written by a different job fails the resume
//!   *load*; the worker wipes the shard directory and re-grids it from
//!   scratch instead of dying in a restart loop.
//! * **Orphan exit** — heartbeats go to stdout, which is the supervisor's
//!   pipe. If the parent died, the write fails (Rust leaves SIGPIPE
//!   ignored, so it surfaces as `EPIPE`, not a kill) and the worker exits
//!   with code [`ORPHAN_EXIT_CODE`] rather than gridding for nobody.
//!
//! The heartbeat ticker doubles as the progress reporter (it diffs the
//! shard manifest and announces newly finished groups) and as the
//! deterministic trigger point for the `kill@shard` / `hang@shard` fault
//! sites ([`crate::util::faults::shard_fault_tick`]).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::proto::{Frame, HEARTBEAT_MS};
use super::shard_dir;
use crate::config::HegridConfig;
use crate::coordinator::{GriddingJob, HegridEngine};
use crate::data::checkpoint::{CheckpointManifest, MANIFEST_FILE};
use crate::data::HgdStreamSource;
use crate::util::error::{HegridError, Result};

/// Exit code for "my supervisor is gone" (stdout pipe broke). Distinct
/// from 1 (gridding error) so a supervisor that *is* alive but lost the
/// pipe some other way can tell the two apart in logs.
pub const ORPHAN_EXIT_CODE: i32 = 3;

/// Write one frame line to the supervisor pipe; exit as an orphan if the
/// pipe is gone.
fn emit(frame: &Frame) {
    use std::io::Write as _;
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    if writeln!(out, "{}", frame.encode()).and_then(|_| out.flush()).is_err() {
        std::process::exit(ORPHAN_EXIT_CODE);
    }
}

/// Run one shard worker to completion. `rows` is the shard's output row
/// range `[lo, hi)`, `attempt` the supervisor's restart counter for this
/// shard (the fault-site cursor). Returns `Ok` after the shard checkpoint
/// is complete and the `DONE` epilogue is emitted; the caller exits 0.
pub fn run_shard_worker(
    mut cfg: HegridConfig,
    input: &Path,
    shard: usize,
    rows: (usize, usize),
    attempt: usize,
) -> Result<()> {
    if cfg.checkpoint_dir.is_empty() {
        return Err(HegridError::Config(
            "shard-worker needs a checkpoint_dir in its --config".into(),
        ));
    }
    let sdir = shard_dir(Path::new(&cfg.checkpoint_dir), shard);
    std::fs::create_dir_all(&sdir).map_err(HegridError::io(sdir.display().to_string()))?;
    // The worker is a single-process run over the shard directory; the
    // parent-level sharding knob must not recurse.
    cfg.checkpoint_dir = sdir.display().to_string();
    cfg.shard_procs = 0;

    let stop = Arc::new(AtomicBool::new(false));
    let ticker = start_ticker(sdir.clone(), shard, attempt, Arc::clone(&stop));

    let result = grid_with_self_heal(&cfg, input, &sdir, rows);

    stop.store(true, Ordering::SeqCst);
    let _ = ticker.join(); // final manifest sweep runs before it returns

    match result {
        Ok(report) => {
            for (stage, d, _count) in report.stages.stages() {
                emit(&Frame::Stage { secs: d.as_secs_f64(), name: stage.to_string() });
            }
            let groups = CheckpointManifest::load(&sdir)
                .map(|m| m.groups_done.len())
                .unwrap_or(0);
            emit(&Frame::Done {
                groups,
                retries: report.degradation.retries,
                quarantined: report.degradation.quarantined_groups.clone(),
            });
            Ok(())
        }
        Err(e) => {
            emit(&Frame::Fatal { message: e.to_string() });
            Err(e)
        }
    }
}

/// Grid the shard's rows, resuming an existing checkpoint when one is
/// present. If the resume *load* fails — torn manifest, corrupt cube
/// bytes, or a checkpoint from a different job — wipe the shard directory
/// and re-grid from scratch (once; a second failure is real).
fn grid_with_self_heal(
    cfg: &HegridConfig,
    input: &Path,
    sdir: &Path,
    rows: (usize, usize),
) -> Result<crate::coordinator::PipelineReport> {
    let mut resume = sdir.join(MANIFEST_FILE).exists();
    loop {
        let mut run_cfg = cfg.clone();
        run_cfg.resume = resume;
        let engine = HegridEngine::new(run_cfg)?;
        let source = HgdStreamSource::open(input)?;
        let job = GriddingJob::for_source(&source, &engine.config)?;
        match engine.grid_source_to_cube_rows(&source, &job, Some(rows)) {
            Ok((_cube, report, _cleanup)) => return Ok(report),
            Err(e) if resume && resume_load_failed(&e) => {
                crate::logging::log_at(
                    crate::logging::Level::Warn,
                    format_args!(
                        "shard-worker: discarding unusable checkpoint at {} ({e}); re-gridding",
                        sdir.display()
                    ),
                );
                std::fs::remove_dir_all(sdir)
                    .map_err(HegridError::io(sdir.display().to_string()))?;
                std::fs::create_dir_all(sdir)
                    .map_err(HegridError::io(sdir.display().to_string()))?;
                resume = false;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Errors that mean "this checkpoint cannot be resumed" rather than "this
/// run failed": manifest CRC / cube-byte corruption, a manifest torn
/// mid-write (JSON parse failure), or an identity mismatch.
fn resume_load_failed(e: &HegridError) -> bool {
    match e {
        HegridError::Corrupt(_) | HegridError::Json { .. } | HegridError::Format(_) => true,
        HegridError::Config(msg) => msg.contains("--resume checkpoint"),
        _ => false,
    }
}

/// The heartbeat ticker thread: every [`HEARTBEAT_MS`] emit a `PING`,
/// announce channel groups newly recorded in the shard manifest, and give
/// the `kill@shard` / `hang@shard` fault sites their deterministic firing
/// point. After `stop` is set it performs one final manifest sweep (so no
/// finished group goes unannounced) and returns.
fn start_ticker(
    sdir: PathBuf,
    shard: usize,
    attempt: usize,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut seq = 0u64;
        let mut announced = std::collections::HashSet::new();
        loop {
            let last = stop.load(Ordering::SeqCst);
            emit(&Frame::Ping { seq });
            seq += 1;
            if let Ok(m) = CheckpointManifest::load(&sdir) {
                for &(g, crc) in &m.groups_done {
                    if announced.insert(g) {
                        emit(&Frame::Group { group: g, crc });
                    }
                }
                // Deterministic fault point: fires only mid-run (once at
                // least one group is checkpointed) and only while this
                // attempt number is below the site's count — see
                // `util::faults`.
                crate::util::faults::shard_fault_tick(shard, attempt, m.groups_done.len());
            }
            if last {
                return;
            }
            std::thread::sleep(Duration::from_millis(HEARTBEAT_MS));
        }
    })
}
