//! Supervised multi-process shard gridding: crash-tolerant worker
//! processes, heartbeats, bounded-backoff restart, and a deterministic
//! merge (`hegrid grid --shard-procs N`).
//!
//! The in-process robustness layer (retries, group quarantine, checkpoints
//! — docs/robustness.md) survives everything *except* the process dying:
//! a SIGKILL, an OOM kill, or a wedged accelerator runtime takes the whole
//! run with it. This module adds the process-level tier on top:
//!
//! * The sky is split into [`crate::coordinator::SkyPartition`] contiguous
//!   row ranges, one per shard.
//! * The parent re-execs itself as `hegrid shard-worker` once per shard
//!   ([`worker`]). Each worker grids **all samples and all channels** but
//!   accumulates only its output rows
//!   ([`crate::coordinator::HegridEngine::grid_source_to_cube`]'s
//!   row-restricted core), writing a per-shard partial cube + CRC'd
//!   manifest in `checkpoint_dir/shard-NNN/` — the PR-6 checkpoint format
//!   verbatim, so a restarted worker `--resume`s its own shard and never
//!   re-grids a finished group.
//! * Workers speak a line-frame heartbeat protocol over their stdout pipe
//!   ([`proto`]); the parent's supervisor loop ([`monitor`]) tracks
//!   liveness, restarts dead / hung / nonzero-exit workers under bounded
//!   exponential backoff ([`backoff`]), and quarantines a shard that
//!   exhausts `shard_max_restarts` exactly like a degraded channel group
//!   (rows zeroed, cause recorded; `--fail-fast` aborts instead).
//! * Finished partial cubes are concatenated shards-ascending ([`merge`])
//!   into `checkpoint_dir/cube.bin`. Because per-cell contribution order
//!   inside a worker is identical to a single-process run (tiles are
//!   dispatched globally; only the clip window narrows), the merged cube
//!   is **byte-identical** to an unsupervised run for every shard count,
//!   tile height, and kill schedule — pinned by
//!   `rust/tests/shard_supervision.rs`.
//!
//! See docs/distributed.md for the process model, the failure-mode table,
//! and the on-disk layout.

pub mod backoff;
pub mod merge;
pub mod monitor;
pub mod proto;
pub mod worker;

use std::path::{Path, PathBuf};

pub use monitor::run_supervised;
pub use worker::run_shard_worker;

/// Per-shard checkpoint directory under the supervised run's
/// `checkpoint_dir`. Both sides (parent spawn/merge, worker checkpoint)
/// derive it from the shard index through this one function so the layout
/// cannot drift.
pub fn shard_dir(checkpoint_dir: &Path, shard: usize) -> PathBuf {
    checkpoint_dir.join(format!("shard-{shard:03}"))
}

/// File name of the serialized engine config the parent writes into
/// `checkpoint_dir` and hands to every worker via `--config` — one file,
/// re-read on every (re)spawn, instead of a fragile flag-by-flag re-encode
/// of the whole [`crate::config::HegridConfig`].
pub const WORKER_CONFIG_FILE: &str = "worker-config.json";

/// Environment override for the worker executable. The supervisor normally
/// re-execs `std::env::current_exe()` — correct for `hegrid grid` and
/// `hegrid serve` — but a test harness or embedding library is *not* the
/// `hegrid` binary; they point this at one.
pub const WORKER_BIN_ENV: &str = "HEGRID_WORKER_BIN";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_dir_is_stable_and_sortable() {
        let base = Path::new("/tmp/ckpt");
        assert_eq!(shard_dir(base, 0), Path::new("/tmp/ckpt/shard-000"));
        assert_eq!(shard_dir(base, 12), Path::new("/tmp/ckpt/shard-012"));
        // Zero-padding keeps lexicographic listing = shard order.
        assert!(shard_dir(base, 2) < shard_dir(base, 10));
    }
}
