//! The parent-side supervisor loop: spawn one worker process per shard,
//! track liveness through the [`super::proto`] heartbeat stream, restart
//! failures under bounded exponential backoff, quarantine shards that
//! exhaust their restart budget, and finish with the deterministic
//! [`super::merge`].
//!
//! Failure detection is two-pronged:
//!
//! * **Exit** — `try_wait` catches a worker that died (nonzero exit,
//!   SIGKILL, panic-abort). The recorded cause prefers the worker's last
//!   `FATAL` frame over the bare exit status.
//! * **Hang** — a worker that is alive but silent (SIGSTOP, a wedged
//!   accelerator call, an NFS stall) sends no heartbeats; after
//!   `shard_heartbeat_timeout_s` without a frame the supervisor SIGKILLs
//!   it and treats it like a death. `0` disables the liveness timeout.
//!
//! A restarted worker re-runs `hegrid shard-worker` with the *same* shard
//! checkpoint directory; it auto-resumes the CRC'd manifest, so finished
//! channel groups are never re-gridded. Restart attempt numbers are passed
//! on the worker command line — they are also the cursor the
//! `kill@shard` / `hang@shard` fault sites count against, which is what
//! makes kill schedules deterministic across runs.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use super::backoff::restart_delay;
use super::proto::Frame;
use super::{shard_dir, WORKER_BIN_ENV, WORKER_CONFIG_FILE};
use crate::config::HegridConfig;
use crate::coordinator::{CancelFlag, GriddingJob, PipelineReport, SkyPartition};
use crate::data::checkpoint::CubeHandle;
use crate::data::{ChannelSource, HgdStreamSource};
use crate::util::error::{HegridError, Result};

/// Supervisor poll period: frame-drain timeout and the granularity of
/// exit / liveness / backoff checks.
const POLL_MS: u64 = 100;

/// Per-shard supervisor state.
enum SlotState {
    Running { child: Child, last_beat: Instant },
    Backoff { until: Instant },
    Done,
    Quarantined,
}

struct Slot {
    state: SlotState,
    /// Restarts performed so far; the next spawn's `--shard-attempt`.
    restarts: usize,
    /// Channel groups announced done (deduplicated — a restarted worker's
    /// ticker re-announces the groups it resumed past).
    done_groups: std::collections::HashSet<usize>,
    /// Last FATAL frame seen — a better cause than "exit status: 1".
    last_fatal: Option<String>,
    /// The DONE epilogue: `(groups, retries, worker-quarantined groups)`.
    done_stats: Option<(usize, usize, Vec<usize>)>,
}

/// What [`fail_shard`] decided for a failed attempt.
enum FailAction {
    Restart,
    Quarantine(String),
    Abort(String),
}

/// Run a supervised multi-process gridding of `input` under `cfg`
/// (`cfg.shard_procs` workers). Returns the merged full-map cube (left on
/// disk at `checkpoint_dir/cube.bin`) and a report whose degradation
/// section carries the shard-level accounting.
pub fn run_supervised(
    cfg: &HegridConfig,
    input: &Path,
    cancel: &CancelFlag,
) -> Result<(CubeHandle, PipelineReport)> {
    let wall0 = Instant::now();
    if cfg.shard_procs == 0 {
        return Err(HegridError::Config("run_supervised needs shard_procs > 0".into()));
    }
    if cfg.checkpoint_dir.is_empty() {
        return Err(HegridError::Config(
            "supervised sharding needs checkpoint_dir (per-shard partial cubes live there)".into(),
        ));
    }
    // Geometry only: derive the job spec from the input's metadata, then
    // drop the source — the parent never reads channel data.
    let source = HgdStreamSource::open(input)?;
    let n_channels = source.n_channels();
    let job = GriddingJob::for_source(&source, cfg)?;
    drop(source);
    let spec = job.spec;
    let partition = SkyPartition::split(spec.nlat, cfg.shard_procs);
    let n_shards = partition.len();

    let ckpt = PathBuf::from(&cfg.checkpoint_dir);
    std::fs::create_dir_all(&ckpt).map_err(HegridError::io(ckpt.display().to_string()))?;
    let cfg_path = ckpt.join(WORKER_CONFIG_FILE);
    std::fs::write(&cfg_path, cfg.to_json().to_pretty())
        .map_err(HegridError::io(cfg_path.display().to_string()))?;

    let bin = worker_bin()?;
    let (tx, rx) = channel::<(usize, Frame)>();
    let mut report = PipelineReport {
        variant: "supervised".to_string(),
        n_pipelines: cfg.shard_procs,
        ..Default::default()
    };
    let mut slots: Vec<Slot> = (0..n_shards)
        .map(|_| Slot {
            state: SlotState::Backoff { until: Instant::now() },
            restarts: 0,
            done_groups: std::collections::HashSet::new(),
            last_fatal: None,
            done_stats: None,
        })
        .collect();

    let spawn = |shard: usize, attempt: usize, tx: &Sender<(usize, Frame)>| -> Result<Child> {
        spawn_worker(&bin, &cfg_path, input, shard, partition.rows(shard), attempt, tx)
    };

    loop {
        drain_frames(&rx, &mut slots, &mut report);
        if cancel.is_cancelled() {
            kill_all(&mut slots);
            return Err(HegridError::Cancelled);
        }
        let now = Instant::now();
        for s in 0..n_shards {
            match &mut slots[s].state {
                SlotState::Running { child, last_beat } => {
                    match child.try_wait() {
                        Ok(Some(status)) if status.success() => {
                            slots[s].state = SlotState::Done;
                        }
                        Ok(Some(status)) => {
                            let cause = slots[s]
                                .last_fatal
                                .take()
                                .unwrap_or_else(|| format!("worker exited with {status}"));
                            apply_failure(&mut slots, s, cause, cfg, &mut report)?;
                        }
                        Ok(None) => {
                            let timeout = cfg.shard_heartbeat_timeout_s;
                            if timeout > 0
                                && last_beat.elapsed() > Duration::from_secs(timeout as u64)
                            {
                                // SIGKILL works on a stopped (SIGSTOP)
                                // process too, which is how hung workers
                                // frozen mid-syscall get reaped.
                                let _ = child.kill();
                                let _ = child.wait();
                                let cause =
                                    format!("no heartbeat for {timeout}s (hung worker killed)");
                                apply_failure(&mut slots, s, cause, cfg, &mut report)?;
                            }
                        }
                        Err(e) => {
                            let cause = format!("waiting on worker failed: {e}");
                            apply_failure(&mut slots, s, cause, cfg, &mut report)?;
                        }
                    }
                }
                SlotState::Backoff { until } if now >= *until => {
                    let attempt = slots[s].restarts;
                    match spawn(s, attempt, &tx) {
                        Ok(child) => {
                            slots[s].state =
                                SlotState::Running { child, last_beat: Instant::now() };
                        }
                        Err(e) => {
                            apply_failure(&mut slots, s, e.to_string(), cfg, &mut report)?;
                        }
                    }
                }
                _ => {}
            }
        }
        let settled = slots
            .iter()
            .all(|sl| matches!(sl.state, SlotState::Done | SlotState::Quarantined));
        if settled {
            // One final drain: DONE/STAGE frames may still be in flight
            // behind the exit we observed.
            drain_frames(&rx, &mut slots, &mut report);
            break;
        }
    }

    fold_outcomes(&slots, &mut report);
    let quarantined = report.degradation.quarantined_shards.clone();
    let cube =
        merge_cube(&ckpt, &partition, &quarantined, n_channels, spec.nlon, spec.nlat)?;
    report.wall = wall0.elapsed();
    Ok((CubeHandle::new(cube, spec, false), report))
}

/// The worker executable: [`WORKER_BIN_ENV`] override, else this binary.
fn worker_bin() -> Result<PathBuf> {
    if let Ok(p) = std::env::var(WORKER_BIN_ENV) {
        return Ok(PathBuf::from(p));
    }
    std::env::current_exe().map_err(HegridError::io("locating the hegrid executable"))
}

/// Spawn one `hegrid shard-worker` with a piped stdout and a reader thread
/// forwarding its parsed frames into the supervisor's channel. The reader
/// exits on EOF (worker death closes the pipe) and detaches.
fn spawn_worker(
    bin: &Path,
    cfg_path: &Path,
    input: &Path,
    shard: usize,
    rows: (usize, usize),
    attempt: usize,
    tx: &Sender<(usize, Frame)>,
) -> Result<Child> {
    let mut child = Command::new(bin)
        .arg("shard-worker")
        .arg("--input")
        .arg(input)
        .arg("--config")
        .arg(cfg_path)
        .arg(format!("--shard-index={shard}"))
        .arg(format!("--shard-rows={}:{}", rows.0, rows.1))
        .arg(format!("--shard-attempt={attempt}"))
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .map_err(HegridError::io(format!("spawning shard {shard} worker")))?;
    let stdout = child.stdout.take().expect("stdout was piped");
    let tx = tx.clone();
    std::thread::spawn(move || {
        for line in BufReader::new(stdout).lines() {
            let Ok(line) = line else { break };
            if let Some(frame) = Frame::parse(&line) {
                if tx.send((shard, frame)).is_err() {
                    break;
                }
            }
        }
    });
    Ok(child)
}

/// Pull every queued frame (waiting at most [`POLL_MS`] for the first) and
/// fold it into the slot / report state. Any frame counts as a heartbeat.
fn drain_frames(
    rx: &Receiver<(usize, Frame)>,
    slots: &mut [Slot],
    report: &mut PipelineReport,
) {
    // Timeout and Disconnected both mean "nothing to fold right now".
    let mut next = rx.recv_timeout(Duration::from_millis(POLL_MS)).ok();
    while let Some((shard, frame)) = next {
        let slot = &mut slots[shard];
        if let SlotState::Running { last_beat, .. } = &mut slot.state {
            *last_beat = Instant::now();
        }
        match frame {
            Frame::Ping { .. } => {}
            Frame::Group { group, .. } => {
                slot.done_groups.insert(group);
            }
            Frame::Stage { secs, name } => {
                report.stages.add(&name, Duration::from_secs_f64(secs));
            }
            Frame::Done { groups, retries, quarantined } => {
                slot.done_stats = Some((groups, retries, quarantined));
            }
            Frame::Fatal { message } => {
                slot.last_fatal = Some(message);
            }
        }
        next = rx.try_recv().ok();
    }
}

/// A worker attempt for shard `s` failed with `cause`: restart it under
/// backoff, or — once `shard_max_restarts` attempts have already been
/// burned — quarantine the shard (degrade mode) / abort the run
/// (fail-fast).
fn apply_failure(
    slots: &mut [Slot],
    s: usize,
    cause: String,
    cfg: &HegridConfig,
    report: &mut PipelineReport,
) -> Result<()> {
    match decide_failure(slots[s].restarts, cfg, &cause, s) {
        FailAction::Restart => {
            let delay = restart_delay(cfg.shard_restart_backoff_ms, slots[s].restarts);
            slots[s].restarts += 1;
            report.degradation.worker_restarts += 1;
            crate::logging::log_at(
                crate::logging::Level::Info,
                format_args!(
                    "supervisor: shard {s} failed ({cause}); restart {} of {} in {:?}",
                    slots[s].restarts, cfg.shard_max_restarts, delay
                ),
            );
            slots[s].state = SlotState::Backoff { until: Instant::now() + delay };
            Ok(())
        }
        FailAction::Quarantine(cause) => {
            slots[s].state = SlotState::Quarantined;
            report.degradation.quarantined_shards.push(s);
            report.degradation.causes.push(cause);
            Ok(())
        }
        FailAction::Abort(msg) => {
            kill_all(slots);
            Err(HegridError::Runtime(msg))
        }
    }
}

fn decide_failure(restarts: usize, cfg: &HegridConfig, cause: &str, s: usize) -> FailAction {
    if restarts < cfg.shard_max_restarts {
        return FailAction::Restart;
    }
    let summary = format!(
        "shard {s}: {cause} (gave up after {} restart{})",
        restarts,
        if restarts == 1 { "" } else { "s" }
    );
    if cfg.fail_fast {
        FailAction::Abort(format!("{summary}; aborting (fail-fast)"))
    } else {
        FailAction::Quarantine(summary)
    }
}

/// SIGKILL and reap every still-running worker (cancel / fail-fast exit).
fn kill_all(slots: &mut [Slot]) {
    for slot in slots {
        if let SlotState::Running { child, .. } = &mut slot.state {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// Fold the per-shard DONE epilogues into the report: retries, group
/// counts, and worker-level quarantined channel groups (kept parallel to
/// their causes, shard-level causes appended after — the order
/// [`crate::coordinator::DegradationReport`] documents).
fn fold_outcomes(slots: &[Slot], report: &mut PipelineReport) {
    let mut group_quarantine: Vec<(usize, String)> = Vec::new();
    for (s, slot) in slots.iter().enumerate() {
        if let Some((groups, retries, quarantined)) = &slot.done_stats {
            report.degradation.retries += retries;
            report.n_groups = report.n_groups.max(groups + quarantined.len());
            for &g in quarantined {
                if !group_quarantine.iter().any(|(gg, _)| *gg == g) {
                    group_quarantine
                        .push((g, format!("shard {s}: channel group quarantined in worker")));
                }
            }
        }
        report.n_groups = report.n_groups.max(slot.done_groups.len());
    }
    group_quarantine.sort_by_key(|&(g, _)| g);
    // Group causes lead (parallel to quarantined_groups), shard causes —
    // already pushed by apply_failure — follow.
    let shard_causes = std::mem::take(&mut report.degradation.causes);
    for (g, cause) in group_quarantine {
        report.degradation.quarantined_groups.push(g);
        report.degradation.causes.push(cause);
    }
    report.degradation.causes.extend(shard_causes);
    report.degradation.quarantined_shards.sort_unstable();
}

/// The final deterministic reduce — thin wrapper so the orchestration
/// above reads top-to-bottom.
fn merge_cube(
    ckpt: &Path,
    partition: &SkyPartition,
    quarantined: &[usize],
    n_channels: usize,
    nlon: usize,
    nlat: usize,
) -> Result<crate::data::checkpoint::CubeFile> {
    super::merge::merge_shards(ckpt, partition, quarantined, n_channels, nlon, nlat)
}
