//! Reusable host staging-buffer pool (§4.3.2's memory pool).
//!
//! Pipelines stage sorted/padded channel values into large `Vec<f32>`
//! buffers before upload. Allocating multi-megabyte vectors per dispatch
//! group shows up hard in profiles, so buffers are recycled through a
//! size-classed free list. `PooledBuf` returns its storage on drop.

use std::sync::{Arc, Mutex};

/// Size-classed pool of `Vec<f32>` staging buffers.
#[derive(Clone, Default)]
pub struct MemoryPool {
    inner: Arc<Mutex<PoolInner>>,
}

struct PoolInner {
    /// Free buffers, any capacity; small list, linear scan is fine.
    free: Vec<Vec<f32>>,
    allocated: usize,
    reused: usize,
    /// Max buffers kept on the free list (hoarding bound).
    limit: usize,
}

impl Default for PoolInner {
    fn default() -> Self {
        // 16 buffers is plenty for pipelines × in-flight dispatches at our
        // scales; streaming prefetch rings size their own pools.
        PoolInner { free: Vec::new(), allocated: 0, reused: 0, limit: 16 }
    }
}

impl MemoryPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// A pool keeping up to `limit` free buffers — the prefetcher's channel
    /// ring sizes this as `depth × channels-per-group` so a full in-flight
    /// window recycles without dropping storage.
    pub fn with_limit(limit: usize) -> Self {
        let pool = Self::default();
        pool.inner.lock().unwrap().limit = limit.max(1);
        pool
    }

    /// Take a zero-length buffer with at least `capacity` reserved.
    pub fn take(&self, capacity: usize) -> PooledBuf {
        let mut inner = self.inner.lock().unwrap();
        // Best-fit: the smallest free buffer with enough capacity.
        let mut best: Option<(usize, usize)> = None;
        for (i, b) in inner.free.iter().enumerate() {
            if b.capacity() >= capacity {
                let c = b.capacity();
                if best.map(|(_, bc)| c < bc).unwrap_or(true) {
                    best = Some((i, c));
                }
            }
        }
        let mut vec = match best {
            Some((i, _)) => {
                inner.reused += 1;
                inner.free.swap_remove(i)
            }
            None => {
                inner.allocated += 1;
                Vec::with_capacity(capacity)
            }
        };
        vec.clear();
        PooledBuf { vec, pool: Arc::clone(&self.inner) }
    }

    /// (allocations, reuses) counters — §Perf evidence that pooling works.
    pub fn stats(&self) -> (usize, usize) {
        let inner = self.inner.lock().unwrap();
        (inner.allocated, inner.reused)
    }
}

/// A pooled `Vec<f32>`; dereferences to the vector, returns to the pool on
/// drop.
pub struct PooledBuf {
    vec: Vec<f32>,
    pool: Arc<Mutex<PoolInner>>,
}

impl PooledBuf {
    /// Detach the vector from the pool (e.g. to wrap in an `Arc`).
    pub fn into_inner(mut self) -> Vec<f32> {
        std::mem::take(&mut self.vec)
    }
}

impl std::ops::Deref for PooledBuf {
    type Target = Vec<f32>;
    fn deref(&self) -> &Vec<f32> {
        &self.vec
    }
}

impl std::ops::DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut Vec<f32> {
        &mut self.vec
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if self.vec.capacity() > 0 {
            let mut inner = self.pool.lock().unwrap();
            if inner.free.len() < inner.limit {
                inner.free.push(std::mem::take(&mut self.vec));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_buffers() {
        let pool = MemoryPool::new();
        let ptr;
        {
            let mut b = pool.take(1024);
            b.extend_from_slice(&[1.0; 100]);
            ptr = b.as_ptr() as usize;
        } // returned
        let b2 = pool.take(512);
        assert_eq!(b2.as_ptr() as usize, ptr, "buffer not recycled");
        assert_eq!(b2.len(), 0, "recycled buffer must be cleared");
        assert_eq!(pool.stats(), (1, 1));
    }

    #[test]
    fn best_fit_prefers_smallest_adequate() {
        let pool = MemoryPool::new();
        let small = pool.take(100).into_inner(); // detached, never returned
        drop(small);
        {
            let _a = pool.take(100);
            let _b = pool.take(10_000);
        } // both returned: free = [100-cap, 10000-cap]
        let c = pool.take(50);
        assert!(c.capacity() < 10_000, "picked the big buffer unnecessarily");
    }

    #[test]
    fn into_inner_detaches() {
        let pool = MemoryPool::new();
        {
            let mut b = pool.take(64);
            b.push(1.0);
            let v = b.into_inner();
            assert_eq!(v, vec![1.0]);
        }
        // Nothing returned to the pool.
        let (alloc, reused) = pool.stats();
        assert_eq!((alloc, reused), (1, 0));
        let b2 = pool.take(64);
        assert_eq!(pool.stats(), (2, 0));
        drop(b2);
    }

    #[test]
    fn concurrent_use_is_safe() {
        let pool = MemoryPool::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let pool = pool.clone();
                s.spawn(move || {
                    for i in 0..200 {
                        let mut b = pool.take(256 + i);
                        b.push(i as f32);
                    }
                });
            }
        });
        let (alloc, reused) = pool.stats();
        assert_eq!(alloc + reused, 8 * 200);
        assert!(reused > 0);
    }
}
