//! CLI argument-parsing substrate (no `clap` in the offline crate set).
//!
//! Grammar: `hegrid <subcommand> [--key value | --flag] [positional...]`.
//! Typed accessors with defaults + an unknown-option check keep the binary's
//! UX honest without a dependency.

use std::collections::BTreeMap;

use crate::util::error::{HegridError, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// `--key value` options.
    options: BTreeMap<String, String>,
    /// `--flag` booleans (no value).
    flags: Vec<String>,
    /// Remaining positionals after the subcommand.
    pub positionals: Vec<String>,
    /// Keys the program has looked up (for unknown-option detection).
    consumed: std::cell::RefCell<Vec<String>>,
}

/// Option names that take a value; everything else starting with `--` is a flag.
pub fn parse(argv: &[String], value_options: &[&str]) -> Result<Args> {
    let mut args = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let tok = &argv[i];
        if let Some(name) = tok.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                args.options.insert(k.to_string(), v.to_string());
            } else if value_options.contains(&name) {
                i += 1;
                let v = argv.get(i).ok_or_else(|| {
                    HegridError::Config(format!("option --{name} requires a value"))
                })?;
                args.options.insert(name.to_string(), v.clone());
            } else {
                args.flags.push(name.to_string());
            }
        } else if args.command.is_none() {
            args.command = Some(tok.clone());
        } else {
            args.positionals.push(tok.clone());
        }
        i += 1;
    }
    Ok(args)
}

impl Args {
    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn flag(&self, name: &str) -> bool {
        self.mark(name);
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.mark(name);
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                HegridError::Config(format!("option --{name} expects an integer, got '{v}'"))
            }),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                HegridError::Config(format!("option --{name} expects a number, got '{v}'"))
            }),
        }
    }

    /// Comma-separated list of integers, e.g. `--sizes 1,2,4`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim().parse().map_err(|_| {
                        HegridError::Config(format!("option --{name}: bad integer '{s}'"))
                    })
                })
                .collect(),
        }
    }

    /// Error if any `--option` was supplied that the program never consulted.
    pub fn check_unknown(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for key in self.options.keys().chain(self.flags.iter()) {
            if !consumed.iter().any(|c| c == key) {
                return Err(HegridError::Config(format!("unknown option --{key}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_options_flags_positionals() {
        let a = parse(&argv("grid --input x.hgd --streams 4 --verbose out.pgm"), &["input", "streams"])
            .unwrap();
        assert_eq!(a.command.as_deref(), Some("grid"));
        assert_eq!(a.get("input"), Some("x.hgd"));
        assert_eq!(a.get_usize("streams", 1).unwrap(), 4);
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["out.pgm"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&argv("bench --sizes=1,2,3"), &[]).unwrap();
        assert_eq!(a.get_usize_list("sizes", &[]).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&argv("grid --input"), &["input"]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&argv("grid --streams abc"), &["streams"]).unwrap();
        assert!(a.get_usize("streams", 1).is_err());
        assert!(a.get_f64("streams", 1.0).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&argv("grid"), &[]).unwrap();
        assert_eq!(a.get_usize("streams", 7).unwrap(), 7);
        assert_eq!(a.get_or("kernel", "gauss1d"), "gauss1d");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn unknown_option_detection() {
        let a = parse(&argv("grid --bogus 1 --known 2"), &["bogus", "known"]).unwrap();
        let _ = a.get("known");
        assert!(a.check_unknown().is_err());
        let _ = a.get("bogus");
        assert!(a.check_unknown().is_ok());
    }
}
