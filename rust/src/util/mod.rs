//! Small shared utilities: error type, PRNG, statistics, CRC32, thread
//! helpers, NUMA topology + first-touch placement.
//!
//! These exist because the offline crate set vendors only the `xla` closure —
//! no `rand`, no `thiserror`, no `rayon` — so HEGrid ships its own minimal,
//! well-tested equivalents (see DESIGN.md "Substituted substrates").

pub mod crc32;
pub mod error;
pub mod faults;
pub mod numa;
pub mod prng;
pub mod stats;
pub mod threads;

pub use error::{HegridError, Result};
pub use prng::SplitMix64;

/// Degrees → radians.
#[inline]
pub fn deg2rad(d: f64) -> f64 {
    d * std::f64::consts::PI / 180.0
}

/// Radians → degrees.
#[inline]
pub fn rad2deg(r: f64) -> f64 {
    r * 180.0 / std::f64::consts::PI
}

/// Arcseconds → radians.
#[inline]
pub fn arcsec2rad(a: f64) -> f64 {
    deg2rad(a / 3600.0)
}

/// Round `x` up to the next multiple of `m` (m > 0).
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Normalise an angle in radians to `[0, 2π)`.
#[inline]
pub fn wrap_2pi(mut phi: f64) -> f64 {
    use std::f64::consts::TAU;
    phi %= TAU;
    if phi < 0.0 {
        phi += TAU;
    }
    // `-1e-30 % TAU` can round back to TAU; fold it to 0.
    if phi >= TAU {
        phi = 0.0;
    }
    phi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_round_trip() {
        assert!((rad2deg(deg2rad(123.456)) - 123.456).abs() < 1e-12);
        assert!((arcsec2rad(3600.0) - deg2rad(1.0)).abs() < 1e-15);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn wrap_2pi_ranges() {
        use std::f64::consts::{PI, TAU};
        assert!((wrap_2pi(-PI) - PI).abs() < 1e-12);
        assert!((wrap_2pi(TAU + 0.5) - 0.5).abs() < 1e-12);
        assert_eq!(wrap_2pi(0.0), 0.0);
        let w = wrap_2pi(-1e-30);
        assert!((0.0..TAU).contains(&w));
    }
}
