//! Robust summary statistics used by the bench harness and metrics.

/// Summary of a sample of observations (e.g. per-iteration runtimes).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    /// Median absolute deviation, scaled to be σ-consistent (×1.4826).
    pub mad: f64,
    pub p10: f64,
    pub p90: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let median = percentile_sorted(&sorted, 50.0);
        let mut dev: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
            mad: percentile_sorted(&dev, 50.0) * 1.4826,
            p10: percentile_sorted(&sorted, 10.0),
            p90: percentile_sorted(&sorted, 90.0),
        })
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online mean/variance accumulator (Welford). Used by long-running metrics.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        // MAD of [2,1,0,1,2] -> median 1 -> *1.4826
        assert!((s.mad - 1.4826).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_single() {
        assert!(Summary::of(&[]).is_none());
        let s = Summary::of(&[7.0]).unwrap();
        assert_eq!(s.median, 7.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile_sorted(&v, 0.0), 10.0);
        assert_eq!(percentile_sorted(&v, 100.0), 40.0);
        assert!((percentile_sorted(&v, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std() - s.std).abs() < 1e-12);
    }
}
