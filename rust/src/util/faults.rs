//! Deterministic fault injection (`--features fault-injection`).
//!
//! A [`FaultPlan`] is a seeded list of directives that make named sites in
//! the pipeline fail on purpose, so the graceful-degradation machinery
//! (retries, per-group quarantine, checkpoint `failed` records) can be
//! exercised deterministically in tests and CI. With the `fault-injection`
//! feature **off** (the default) every hook in this module compiles to an
//! inlined no-op — production builds carry no fault-injection branches
//! beyond one dead function call that the optimiser deletes.
//!
//! ## Spec grammar
//!
//! Configured by the `faults` config field / `--faults` CLI flag, or the
//! `HEGRID_FAULTS` environment variable when the field is empty:
//!
//! ```text
//! spec      := <seed> ':' directive (',' directive)*
//! directive := site '@' target ['x' count] ['%' prob]
//! site      := read-err | crc | stall | torn | panic | panic-cell | kill | hang
//! target    := non-negative integer | '*'          (any target)
//! count     := max firings of this directive        (default 1)
//! prob      := firing probability in (0, 1], drawn from a per-directive
//!              stream seeded by <seed> (omitted = always fire)
//! ```
//!
//! | site         | target meaning      | effect at the site |
//! |--------------|---------------------|--------------------|
//! | `read-err`   | channel index       | `HgdReader::read_channel_into` returns an injected I/O error |
//! | `crc`        | channel index       | `HgdReader::read_channel_into` returns an injected `Corrupt` |
//! | `stall`      | channel-group index | the T0 worker sleeps 25 ms before reading the group |
//! | `torn`       | manifest-save ordinal (0-based) | `CheckpointManifest::save` writes half the payload to the temp file and fails (rename never happens) |
//! | `panic`      | original group index | the pipeline slot panics at the start of the group's sweep |
//! | `panic-cell` | output cell index   | a gridding sweep worker panics while processing that cell |
//! | `kill`       | shard index         | the chosen shard-worker *process* SIGKILLs itself after its first finished group (supervised runs) |
//! | `hang`       | shard index         | the chosen shard-worker process SIGSTOPs itself (heartbeats cease; the supervisor's liveness timeout must reap it) |
//!
//! The process-level sites (`kill`, `hang`) count differently from the
//! in-process ones: each worker re-installs the plan on exec, so a
//! decrement-on-fire count would reset with every restart and kill the
//! shard forever. Instead the directive's `count` is compared against the
//! worker's restart *attempt* (passed on its command line): `kill@1x2`
//! kills shard 1's worker on attempts 0 and 1, and attempt 2 runs clean —
//! exactly `count` kills per run, no shared mutable state across
//! processes. A count at or above `shard_max_restarts + 1` therefore
//! drives the shard to quarantine. `%prob` is ignored for these sites.
//!
//! Example: `HEGRID_FAULTS=42:read-err@3x2,panic@1` — the first two reads
//! of channel 3 fail with an I/O error (a retrying ingest recovers on the
//! third attempt), and channel group 1's sweep panics once.
//!
//! Determinism: counts are exact, and probabilistic directives draw from a
//! [`SplitMix64`] stream derived from the spec seed and the directive text,
//! so the same spec injects the same faults on every run (modulo which
//! concurrent worker reaches a shared `'*'` count first).

#[cfg(feature = "fault-injection")]
pub use imp::*;

#[cfg(feature = "fault-injection")]
mod imp {
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex, OnceLock};

    use crate::util::crc32::crc32;
    use crate::util::error::{HegridError, Result};
    use crate::util::prng::SplitMix64;

    /// Named injection site (see the module docs for the grammar).
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum FaultSite {
        /// Injected I/O error on an HGD channel read.
        ReadErr,
        /// Injected CRC corruption on an HGD channel read.
        ReadCrc,
        /// Transient T0 ring stall before a group's read.
        Stall,
        /// Torn checkpoint-manifest write (partial temp file, no rename).
        TornWrite,
        /// Pipeline-slot panic at the start of a group's sweep.
        SweepPanic,
        /// Executor-worker panic inside a gridding sweep, per cell.
        CellPanic,
        /// Shard-worker process SIGKILLs itself (supervised runs).
        KillShard,
        /// Shard-worker process SIGSTOPs itself (liveness-timeout path).
        HangShard,
    }

    struct Directive {
        site: FaultSite,
        /// `None` = `'*'` (any target).
        target: Option<usize>,
        remaining: AtomicUsize,
        prob: Option<f64>,
        rng: Mutex<SplitMix64>,
    }

    /// A parsed, seeded fault plan. Install with [`install`] /
    /// [`install_from_spec`]; sites consult the installed plan through the
    /// hook functions below.
    pub struct FaultPlan {
        directives: Vec<Directive>,
        /// Total faults fired so far (bench `faults.injected`).
        injected: AtomicUsize,
        /// Manifest saves seen so far (the `torn` site's target ordinal).
        saves: AtomicUsize,
    }

    impl FaultPlan {
        /// Parse `<seed>:<directive>(,<directive>)*`.
        pub fn parse(spec: &str) -> Result<FaultPlan> {
            let bad = |m: String| HegridError::Config(format!("fault spec '{spec}': {m}"));
            let (seed_s, rest) = spec
                .split_once(':')
                .ok_or_else(|| bad("expected '<seed>:<directives>'".into()))?;
            let seed: u64 = seed_s
                .trim()
                .parse()
                .map_err(|_| bad(format!("seed '{seed_s}' is not a non-negative integer")))?;
            let mut directives = Vec::new();
            for part in rest.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                let (site_s, tail) = part
                    .split_once('@')
                    .ok_or_else(|| bad(format!("directive '{part}' lacks '@target'")))?;
                let site = match site_s {
                    "read-err" => FaultSite::ReadErr,
                    "crc" => FaultSite::ReadCrc,
                    "stall" => FaultSite::Stall,
                    "torn" => FaultSite::TornWrite,
                    "panic" => FaultSite::SweepPanic,
                    "panic-cell" => FaultSite::CellPanic,
                    "kill" => FaultSite::KillShard,
                    "hang" => FaultSite::HangShard,
                    other => return Err(bad(format!("unknown site '{other}'"))),
                };
                let (tail, prob) = match tail.split_once('%') {
                    Some((a, p)) => {
                        let p: f64 = p
                            .parse()
                            .map_err(|_| bad(format!("probability '{p}' is not a number")))?;
                        if !(p > 0.0 && p <= 1.0) {
                            return Err(bad(format!("probability {p} out of range (0, 1]")));
                        }
                        (a, Some(p))
                    }
                    None => (tail, None),
                };
                let (target_s, count) = match tail.split_once('x') {
                    Some((a, c)) => (
                        a,
                        c.parse::<usize>()
                            .map_err(|_| bad(format!("count '{c}' is not an integer")))?,
                    ),
                    None => (tail, 1),
                };
                if count == 0 {
                    return Err(bad("count must be >= 1".into()));
                }
                let target = if target_s == "*" {
                    None
                } else {
                    Some(target_s.parse::<usize>().map_err(|_| {
                        bad(format!("target '{target_s}' is not an integer or '*'"))
                    })?)
                };
                // Per-directive stream: the spec seed mixed with the
                // directive text, so adding a directive never shifts the
                // draws of another.
                let dseed = seed.wrapping_add(crc32(part.as_bytes()) as u64);
                directives.push(Directive {
                    site,
                    target,
                    remaining: AtomicUsize::new(count),
                    prob,
                    rng: Mutex::new(SplitMix64::new(dseed)),
                });
            }
            if directives.is_empty() {
                return Err(bad("no directives".into()));
            }
            Ok(FaultPlan {
                directives,
                injected: AtomicUsize::new(0),
                saves: AtomicUsize::new(0),
            })
        }

        /// Should a fault fire at `site` for `target`? Decrements the
        /// matching directive's count on fire.
        fn fire(&self, site: FaultSite, target: usize) -> bool {
            for d in &self.directives {
                if d.site != site || d.target.is_some_and(|t| t != target) {
                    continue;
                }
                if let Some(p) = d.prob {
                    if d.rng.lock().unwrap().next_f64() >= p {
                        continue;
                    }
                }
                if d.remaining
                    .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
                    .is_ok()
                {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
            }
            false
        }
    }

    /// Fast-path gate: hooks bail on one relaxed load when no plan is
    /// installed, so per-cell sites stay cheap even in instrumented builds.
    static ENABLED: AtomicBool = AtomicBool::new(false);

    fn slot() -> &'static Mutex<Option<Arc<FaultPlan>>> {
        static SLOT: OnceLock<Mutex<Option<Arc<FaultPlan>>>> = OnceLock::new();
        SLOT.get_or_init(|| Mutex::new(None))
    }

    /// Install (or clear, with `None`) the process-wide fault plan.
    pub fn install(plan: Option<FaultPlan>) {
        let mut s = slot().lock().unwrap();
        ENABLED.store(plan.is_some(), Ordering::Release);
        *s = plan.map(Arc::new);
    }

    /// Install from a spec string; an empty spec falls back to the
    /// `HEGRID_FAULTS` environment variable, and an empty result clears the
    /// plan. Called by `HegridEngine::new` with the `faults` config field.
    pub fn install_from_spec(spec: &str) -> Result<()> {
        let from_env;
        let spec = if spec.is_empty() {
            from_env = std::env::var("HEGRID_FAULTS").unwrap_or_default();
            from_env.as_str()
        } else {
            spec
        };
        if spec.is_empty() {
            install(None);
            return Ok(());
        }
        install(Some(FaultPlan::parse(spec)?));
        Ok(())
    }

    fn active() -> Option<Arc<FaultPlan>> {
        if !ENABLED.load(Ordering::Acquire) {
            return None;
        }
        slot().lock().unwrap().clone()
    }

    /// Faults fired so far by the installed plan (bench `faults.injected`).
    pub fn injected_total() -> usize {
        active().map_or(0, |p| p.injected.load(Ordering::Relaxed))
    }

    /// `read-err` / `crc` site: called by `HgdReader::read_channel_into`.
    pub fn channel_read_fault(ch: usize) -> Option<HegridError> {
        let plan = active()?;
        if plan.fire(FaultSite::ReadErr, ch) {
            return Some(HegridError::Io {
                context: format!("fault-injection: channel {ch}"),
                source: std::io::Error::other("injected transient read error"),
            });
        }
        if plan.fire(FaultSite::ReadCrc, ch) {
            return Some(HegridError::Corrupt(format!(
                "fault-injection: channel {ch} CRC corrupted"
            )));
        }
        None
    }

    /// `stall` site: called by the T0 worker before reading group `g`.
    pub fn prefetch_stall(g: usize) {
        if let Some(plan) = active() {
            if plan.fire(FaultSite::Stall, g) {
                std::thread::sleep(std::time::Duration::from_millis(25));
            }
        }
    }

    /// `torn` site: called by `CheckpointManifest::save`; `true` = tear this
    /// save (the ordinal of saves since install is the directive target).
    pub fn torn_checkpoint_write() -> bool {
        match active() {
            Some(plan) => {
                let k = plan.saves.fetch_add(1, Ordering::Relaxed);
                plan.fire(FaultSite::TornWrite, k)
            }
            None => false,
        }
    }

    /// `panic` site: called at the start of a group's pipeline sweep.
    pub fn sweep_panic_point(group: usize) {
        if let Some(plan) = active() {
            if plan.fire(FaultSite::SweepPanic, group) {
                panic!("fault-injection: forced worker panic in channel group {group}");
            }
        }
    }

    /// `panic-cell` site: called per output cell inside gridding sweeps.
    pub fn sweep_panic_cell(cell: usize) {
        if ENABLED.load(Ordering::Acquire) {
            if let Some(plan) = active() {
                if plan.fire(FaultSite::CellPanic, cell) {
                    panic!("fault-injection: forced worker panic at cell {cell}");
                }
            }
        }
    }

    impl FaultPlan {
        /// Count of a process-level shard directive matching `(site, shard)`,
        /// read without decrementing — the cross-process counting scheme the
        /// module docs describe (the worker's restart attempt is the cursor,
        /// not shared state).
        fn shard_site_count(&self, site: FaultSite, shard: usize) -> Option<usize> {
            self.directives
                .iter()
                .find(|d| d.site == site && !d.target.is_some_and(|t| t != shard))
                .map(|d| d.remaining.load(Ordering::Relaxed))
        }
    }

    /// `kill` / `hang` site: called by the shard worker after every finished
    /// channel group. `attempt` is the worker's restart ordinal (0 = first
    /// launch), `groups_done` the groups committed to its checkpoint so far.
    /// A matching `kill` directive with `attempt < count` SIGKILLs the
    /// process; a matching `hang` directive SIGSTOPs it (freezing the
    /// heartbeat thread with it, so only the supervisor's liveness timeout
    /// can reap the worker). Firing waits for `groups_done >= 1` so a
    /// restart always has checkpointed progress to resume from.
    pub fn shard_fault_tick(shard: usize, attempt: usize, groups_done: usize) {
        let Some(plan) = active() else { return };
        if groups_done == 0 {
            return;
        }
        #[cfg(unix)]
        {
            extern "C" {
                fn raise(sig: i32) -> i32;
            }
            const SIGKILL: i32 = 9;
            const SIGSTOP: i32 = 19;
            if plan.shard_site_count(FaultSite::KillShard, shard).is_some_and(|c| attempt < c) {
                plan.injected.fetch_add(1, Ordering::Relaxed);
                unsafe { raise(SIGKILL) };
            }
            if plan.shard_site_count(FaultSite::HangShard, shard).is_some_and(|c| attempt < c) {
                plan.injected.fetch_add(1, Ordering::Relaxed);
                unsafe { raise(SIGSTOP) };
            }
        }
        #[cfg(not(unix))]
        {
            let _ = plan;
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn parse_and_fire_counts() {
            let p = FaultPlan::parse("7:read-err@3x2,crc@1,panic@0").unwrap();
            assert!(p.fire(FaultSite::ReadErr, 3));
            assert!(p.fire(FaultSite::ReadErr, 3));
            assert!(!p.fire(FaultSite::ReadErr, 3), "count exhausted");
            assert!(!p.fire(FaultSite::ReadErr, 4), "wrong target");
            assert!(p.fire(FaultSite::ReadCrc, 1));
            assert!(p.fire(FaultSite::SweepPanic, 0));
            assert!(!p.fire(FaultSite::SweepPanic, 0));
            assert_eq!(p.injected.load(Ordering::Relaxed), 4);
        }

        #[test]
        fn wildcard_target_matches_everything() {
            let p = FaultPlan::parse("1:stall@*x3").unwrap();
            assert!(p.fire(FaultSite::Stall, 0));
            assert!(p.fire(FaultSite::Stall, 17));
            assert!(p.fire(FaultSite::Stall, 2));
            assert!(!p.fire(FaultSite::Stall, 2), "shared count exhausted");
        }

        #[test]
        fn probabilistic_directives_are_seed_deterministic() {
            let draws = |seed: u64| -> Vec<bool> {
                let p = FaultPlan::parse(&format!("{seed}:crc@*x1000000%0.5")).unwrap();
                (0..64).map(|i| p.fire(FaultSite::ReadCrc, i)).collect()
            };
            assert_eq!(draws(11), draws(11), "same seed, same firing pattern");
            assert_ne!(draws(11), draws(12), "different seed diverges");
            let fired = draws(11).iter().filter(|&&b| b).count();
            assert!((8..=56).contains(&fired), "p=0.5 fired {fired}/64");
        }

        #[test]
        fn bad_specs_rejected() {
            for bad in [
                "", "7", "7:", "x:read-err@1", "7:read-err", "7:bogus@1", "7:read-err@q",
                "7:read-err@1x0", "7:read-err@1%1.5", "7:read-err@1%x",
            ] {
                assert!(FaultPlan::parse(bad).is_err(), "spec '{bad}' should fail");
            }
            assert!(FaultPlan::parse("7:read-err@1x3%0.5,torn@0").is_ok());
        }

        #[test]
        fn shard_sites_parse_and_count_without_decrement() {
            let p = FaultPlan::parse("7:kill@1x2,hang@0").unwrap();
            // Reading the count must not consume it (attempt-based firing).
            assert_eq!(p.shard_site_count(FaultSite::KillShard, 1), Some(2));
            assert_eq!(p.shard_site_count(FaultSite::KillShard, 1), Some(2));
            assert_eq!(p.shard_site_count(FaultSite::KillShard, 0), None);
            assert_eq!(p.shard_site_count(FaultSite::HangShard, 0), Some(1));
            let p = FaultPlan::parse("7:kill@*x3").unwrap();
            assert_eq!(p.shard_site_count(FaultSite::KillShard, 9), Some(3));
            // A tick on a shard no directive targets is a no-op.
            install(Some(FaultPlan::parse("7:kill@1x2").unwrap()));
            shard_fault_tick(0, 0, 5);
            assert_eq!(injected_total(), 0);
            // groups_done == 0 never fires, even on a matching shard.
            shard_fault_tick(1, 5, 0);
            assert_eq!(injected_total(), 0);
            // attempt >= count runs clean.
            shard_fault_tick(1, 2, 5);
            assert_eq!(injected_total(), 0);
            install(None);
        }

        #[test]
        fn install_round_trip() {
            install(Some(FaultPlan::parse("3:panic@5").unwrap()));
            assert_eq!(injected_total(), 0);
            let caught = std::panic::catch_unwind(|| sweep_panic_point(5));
            assert!(caught.is_err(), "installed plan fires");
            assert_eq!(injected_total(), 1);
            sweep_panic_point(5); // exhausted: no second panic
            install(None);
            assert_eq!(injected_total(), 0);
            sweep_panic_point(5); // cleared: inert
        }
    }
}

/// No-op stubs: the whole subsystem compiles away without the
/// `fault-injection` feature. Signatures mirror the real hooks so call
/// sites need no `cfg` of their own.
#[cfg(not(feature = "fault-injection"))]
mod stub {
    use crate::util::error::{HegridError, Result};

    /// Inert without the feature; a non-empty `faults` config field is
    /// already rejected by `HegridConfig::validate` before this is reached.
    #[inline(always)]
    pub fn install_from_spec(_spec: &str) -> Result<()> {
        Ok(())
    }

    #[inline(always)]
    pub fn injected_total() -> usize {
        0
    }

    #[inline(always)]
    pub fn channel_read_fault(_ch: usize) -> Option<HegridError> {
        None
    }

    #[inline(always)]
    pub fn prefetch_stall(_g: usize) {}

    #[inline(always)]
    pub fn torn_checkpoint_write() -> bool {
        false
    }

    #[inline(always)]
    pub fn sweep_panic_point(_group: usize) {}

    #[inline(always)]
    pub fn sweep_panic_cell(_cell: usize) {}

    #[inline(always)]
    pub fn shard_fault_tick(_shard: usize, _attempt: usize, _groups_done: usize) {}
}

#[cfg(not(feature = "fault-injection"))]
pub use stub::*;
