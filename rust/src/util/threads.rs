//! Thread-pool substrate: a persistent [`PipelineExecutor`] with parked
//! workers plus the scoped parallel-iteration helpers built on it. Stands in
//! for `rayon` (not vendored). Used by pre-processing (parallel pixel_idx
//! computation / radix sort), the CPU baselines, and the coordinator's
//! channel-group pipelines.
//!
//! The helpers all run as **sweeps** on the process-wide executor: the
//! calling thread participates (so a busy pool degrades, never deadlocks)
//! and each participant gets per-sweep scratch from `init()` — the vehicle
//! for the hot loops' worker-local buffers, and (under `--affinity` on
//! multi-node hosts) for NUMA-local scratch placement via first-touch
//! (see [`crate::util::numa`]).
//!
//! ```
//! use hegrid::util::threads::{adaptive_claim_block, parallel_items_scoped};
//! use std::sync::atomic::{AtomicUsize, Ordering};
//!
//! let n = 1000;
//! let sum = AtomicUsize::new(0);
//! parallel_items_scoped(
//!     n,
//!     4,                            // at most 4 participants (caller included)
//!     adaptive_claim_block(n, 4),   // items claimed per cursor fetch_add
//!     || 0usize,                    // per-worker scratch, built once per sweep
//!     |scratch, i| {
//!         *scratch += 1; // worker-local: no synchronisation needed
//!         sum.fetch_add(i, Ordering::Relaxed);
//!     },
//! );
//! assert_eq!(sum.load(Ordering::Relaxed), n * (n - 1) / 2);
//! ```

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

/// Number of worker threads to use by default (logical cores, capped).
/// Queried from the OS once and cached — this sits on per-call paths
/// (`SharedComponent::for_kernel`, config accessors, gridder constructors).
pub fn default_parallelism() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED
        .get_or_init(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(32))
}

/// Claim-block size for a sweep of `n_items` across `workers` participants:
/// aim for ~8 blocks per worker (dynamic balancing headroom), clamped so
/// tiny sweeps still fan out item-by-item and huge sweeps don't pay one
/// cursor `fetch_add` per handful of items.
///
/// Replaces the old fixed `CELL_CLAIM_BLOCK`/`GROUP_CLAIM_BLOCK` constants:
/// a fixed block serialised small maps on one claim (e.g. 128 cells in
/// blocks of 16 keeps at most 8 workers busy) while charging big maps a
/// cursor round-trip every 16 cells.
pub fn adaptive_claim_block(n_items: usize, workers: usize) -> usize {
    (n_items / (workers.max(1) * 8)).clamp(1, 64)
}

/// Core-affinity policy for the executor's pool workers
/// (config `executor_affinity` / CLI `--affinity`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AffinityMode {
    /// No pinning (workers migrate freely; the OS default).
    #[default]
    None,
    /// Worker `i` → core `i % n_cpus`: pack workers onto the lowest cores,
    /// maximising shared-cache locality of the lane-widened gridding loops.
    Compact,
    /// Worker `i` → core `i · (n_cpus / workers)`: space workers out across
    /// the topology (sockets/CCXs enumerate contiguously on Linux),
    /// maximising per-worker cache and memory bandwidth.
    Spread,
}

impl AffinityMode {
    pub fn name(&self) -> &'static str {
        match self {
            AffinityMode::None => "none",
            AffinityMode::Compact => "compact",
            AffinityMode::Spread => "spread",
        }
    }

    pub fn from_name(s: &str) -> crate::util::error::Result<Self> {
        match s {
            "none" | "" => Ok(AffinityMode::None),
            "compact" => Ok(AffinityMode::Compact),
            "spread" => Ok(AffinityMode::Spread),
            _ => Err(crate::util::error::HegridError::Config(format!(
                "unknown affinity mode '{s}' (expected none|compact|spread)"
            ))),
        }
    }
}

/// Process-wide affinity request: `generation << 8 | mode`. Workers compare
/// the generation against the one they last applied and re-pin themselves on
/// the next sweep they join, so the policy can change after the global
/// executor has spawned (it is created lazily on first parallel call, which
/// can precede config parsing).
static AFFINITY: AtomicU64 = AtomicU64::new(0);

/// Request an executor-worker affinity policy. Takes effect on each pool
/// worker the next time it joins a sweep; the submitting thread (sweep
/// participant 0) is never pinned — it belongs to the caller.
pub fn set_executor_affinity(mode: AffinityMode) {
    let cur = AFFINITY.load(Ordering::Relaxed);
    if (cur & 0xff) == mode as u64 {
        return; // unchanged — don't force a no-op re-pin of every worker
    }
    let generation = (cur >> 8) + 1;
    AFFINITY.store((generation << 8) | mode as u64, Ordering::Release);
}

/// Currently requested affinity policy (test/report accessor).
pub fn executor_affinity() -> AffinityMode {
    match AFFINITY.load(Ordering::Acquire) & 0xff {
        1 => AffinityMode::Compact,
        2 => AffinityMode::Spread,
        _ => AffinityMode::None,
    }
}

/// Pin the calling pool worker according to `mode`. Linux-only (via the
/// C library's `sched_setaffinity`, declared directly so the offline crate
/// set stays dependency-free) behind the default-on `affinity` feature;
/// a no-op elsewhere. Best effort: failures are ignored — pinning is a
/// performance hint, never a correctness requirement.
///
/// The worker→CPU map is NUMA-aware (`NumaTopology::cpu_for` in
/// [`crate::util::numa`]): `compact` fills node 0's CPUs before spilling to
/// node 1, `spread` round-robins workers across nodes first. On single-node
/// hosts both collapse to the historical modulo/stride placement.
#[cfg(all(target_os = "linux", feature = "affinity"))]
fn apply_affinity(worker: usize, pool_workers: usize, mode: AffinityMode) {
    const SET_BITS: usize = 1024;
    #[repr(C)]
    struct CpuSet {
        bits: [u64; SET_BITS / 64],
    }
    extern "C" {
        // pid 0 = the calling thread.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const CpuSet) -> i32;
    }
    let mut set = CpuSet { bits: [0; SET_BITS / 64] };
    match crate::util::numa::topology().cpu_for(worker, pool_workers, mode) {
        None => {
            // Reset to every CPU we can name; the kernel intersects with the
            // online set.
            set.bits = [u64::MAX; SET_BITS / 64];
        }
        Some(cpu) if cpu < SET_BITS => {
            set.bits[cpu / 64] |= 1 << (cpu % 64);
        }
        Some(_) => return, // CPU id beyond the fixed mask: skip pinning
    }
    unsafe {
        let _ = sched_setaffinity(0, std::mem::size_of::<CpuSet>(), &set);
    }
}

#[cfg(not(all(target_os = "linux", feature = "affinity")))]
fn apply_affinity(_worker: usize, _pool_workers: usize, _mode: AffinityMode) {}

/// Run `f(chunk_index, start, end)` over `n` items split into ~`workers`
/// contiguous chunks, in parallel, on the shared [`PipelineExecutor`].
/// Blocks until done.
///
/// `f` must be `Sync` — chunks are disjoint so data races are the caller's
/// responsibility to avoid via disjoint output slices or atomics.
pub fn parallel_chunks<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        f(0, 0, n);
        return;
    }
    // Same partition as the historical scoped-spawn version: chunk w covers
    // [w·chunk, (w+1)·chunk) ∩ [0, n). Chunks are claimed dynamically but
    // each runs exactly once with its own index, which is all the callers
    // (radix-sort histograms, disjoint fills) rely on.
    let chunk = n.div_ceil(workers);
    let n_chunks = n.div_ceil(chunk);
    PipelineExecutor::global().run(n_chunks, n_chunks, 1, || (), |_, w| {
        f(w, w * chunk, ((w + 1) * chunk).min(n));
    });
}

/// Dynamic work-stealing loop: workers repeatedly claim the next index until
/// `n` items are consumed. For irregular per-item cost (e.g. per-cell
/// neighbour search where sampling density varies across the map).
pub fn parallel_items<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_items_scoped(n, workers, 1, || (), |_, i| f(i));
}

/// Work-stealing loop with **per-worker state** and **block claiming**: each
/// participating worker calls `init()` once, then repeatedly claims
/// `claim_block` contiguous indices from a shared cursor (one `fetch_add` per
/// block instead of one per item) and runs `f(&mut state, i)` for each.
///
/// This is the substrate for hot loops that need reusable scratch buffers
/// (ring ranges, contributor lists, channel-block accumulators): the former
/// per-item allocations become per-worker allocations made once. Block
/// claiming keeps the cursor off the coherence hot path when items are cheap;
/// irregular per-item cost still balances because blocks are claimed
/// dynamically.
///
/// Runs on the process-wide [`PipelineExecutor`]: the calling thread always
/// participates (progress is never blocked on pool availability) and parked
/// pool workers join as helpers, so a sweep no longer pays a thread spawn.
pub fn parallel_items_scoped<S, I, F>(n: usize, workers: usize, claim_block: usize, init: I, f: F)
where
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) + Sync,
{
    PipelineExecutor::global().run(n, workers, claim_block, init, f);
}

/// Cumulative counters of a [`PipelineExecutor`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecutorStats {
    /// Multi-participant sweeps executed (single-participant sweeps run
    /// inline on the caller and are not counted).
    pub sweeps: u64,
    /// Times a parked pool worker joined a sweep as a helper.
    pub helper_joins: u64,
}

/// A long-lived pool of parked worker threads executing **sweeps** — the
/// persistent replacement for the scoped thread spawn every parallel
/// iteration used to pay.
///
/// A sweep is `n` items claimed in blocks from a shared cursor, with a
/// per-participant scratch slot created by `init()` at sweep entry (and
/// dropped at sweep exit, so no state leaks between sweeps). The submitting
/// thread always participates as worker 0; parked pool workers join as
/// helpers up to the sweep's participant cap. Because the caller always
/// makes progress on its own sweep, nested sweeps (a sweep body submitting
/// another sweep) and concurrent sweeps from independent threads cannot
/// deadlock — a busy pool only degrades a sweep toward caller-only
/// execution.
///
/// The coordinator runs its channel-group pipelines as the items of one
/// sweep (`pipeline_width` of them in flight), and the gridding hot loops
/// ([`parallel_items_scoped`], [`parallel_chunks`]) run as fine-grained
/// sweeps, so the whole engine shares one set of parked workers.
pub struct PipelineExecutor {
    inner: Arc<ExecInner>,
    handles: Vec<thread::JoinHandle<()>>,
}

struct ExecInner {
    reg: Mutex<Registry>,
    /// Signalled when a sweep is registered (workers wait here while idle).
    work: Condvar,
    /// Signalled when a participant leaves a sweep (submitters wait here).
    done: Condvar,
    sweeps: AtomicU64,
    helper_joins: AtomicU64,
}

struct Registry {
    shutdown: bool,
    entries: Vec<EntryPtr>,
}

/// Raw pointer to a sweep descriptor living on a submitting thread's stack.
/// Valid while the entry is registered or a participant holds `active` —
/// see the join protocol in [`PipelineExecutor::run`].
struct EntryPtr(*const SweepEntry);
unsafe impl Send for EntryPtr {}

struct SweepEntry {
    /// Shared item cursor (lives next to the entry on the submitter stack).
    cursor: *const AtomicUsize,
    n: usize,
    /// Participants ever admitted (the caller counts as the first).
    joined: AtomicUsize,
    max_participants: usize,
    /// Participants currently inside the sweep body.
    active: AtomicUsize,
    /// A helper panicked inside the body (on the submitter stack, like the
    /// cursor, so the body's claim loop can poll it and bail early instead
    /// of grinding through the remaining items; re-raised on the caller).
    panicked: *const AtomicBool,
    /// First helper panic's payload message (submitter stack), so the
    /// caller's re-raise names the real cause instead of a generic
    /// "a helper worker panicked".
    panic_note: *const Mutex<Option<String>>,
    /// Type- and lifetime-erased per-participant body (claims blocks until
    /// the cursor is exhausted). The `'static` bound here is a lie told to
    /// the type system — the join protocol guarantees no worker dereferences
    /// it after the submitting frame is gone.
    body: *const (dyn Fn() + Sync),
}

fn exec_worker_main(inner: Arc<ExecInner>, index: usize, pool_workers: usize) {
    // Affinity generation this worker last applied (0 = never).
    let mut applied_affinity = 0u64;
    loop {
        let entry: *const SweepEntry = {
            let mut reg = inner.reg.lock().expect("executor registry poisoned");
            loop {
                if reg.shutdown {
                    return;
                }
                let found = reg.entries.iter().map(|p| p.0).find(|&p| {
                    let e = unsafe { &*p };
                    e.joined.load(Ordering::Relaxed) < e.max_participants
                        && unsafe { &*e.cursor }.load(Ordering::Relaxed) < e.n
                });
                match found {
                    Some(p) => {
                        // Join under the lock: the entry is still registered,
                        // so the pointer is valid, and the submitter cannot
                        // deregister while `active` is being raised here.
                        let e = unsafe { &*p };
                        e.joined.fetch_add(1, Ordering::Relaxed);
                        e.active.fetch_add(1, Ordering::Relaxed);
                        break p;
                    }
                    None => reg = inner.work.wait(reg).expect("executor registry poisoned"),
                }
            }
        };
        inner.helper_joins.fetch_add(1, Ordering::Relaxed);
        // Re-pin lazily when the requested policy changed since the last
        // sweep this worker ran (policies can be set after spawn).
        let affinity = AFFINITY.load(Ordering::Acquire);
        if affinity != applied_affinity {
            applied_affinity = affinity;
            apply_affinity(index, pool_workers, executor_affinity());
        }
        let e = unsafe { &*entry };
        let body = unsafe { &*e.body };
        if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(body)) {
            let note = unsafe { &*e.panic_note };
            let mut note = note.lock().expect("executor panic note poisoned");
            if note.is_none() {
                *note = Some(panic_message(payload.as_ref()));
            }
            drop(note);
            unsafe { &*e.panicked }.store(true, Ordering::Release);
        }
        // Leaving: once `active` drops the submitter may free the sweep, so
        // the entry must not be touched after this decrement. Taking the
        // registry lock before notifying closes the missed-wakeup window
        // against a submitter that is between its condition check and its
        // `done.wait`.
        e.active.fetch_sub(1, Ordering::Release);
        let _guard = inner.reg.lock().expect("executor registry poisoned");
        inner.done.notify_all();
    }
}

impl PipelineExecutor {
    /// Spawn a dedicated executor with `workers` parked threads, each named
    /// `"{name}-{i}"`. Most code should use [`PipelineExecutor::global`].
    pub fn new(name: &str, workers: usize) -> PipelineExecutor {
        let workers = workers.max(1);
        let inner = Arc::new(ExecInner {
            reg: Mutex::new(Registry { shutdown: false, entries: Vec::new() }),
            work: Condvar::new(),
            done: Condvar::new(),
            sweeps: AtomicU64::new(0),
            helper_joins: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let inner = Arc::clone(&inner);
            handles.push(
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || exec_worker_main(inner, i, workers))
                    .expect("spawn executor worker"),
            );
        }
        PipelineExecutor { inner, handles }
    }

    /// The process-wide executor (lazily spawned, [`default_parallelism`]
    /// workers). Every parallel helper and the coordinator's pipelines run
    /// on it, so the whole process shares one set of parked threads.
    pub fn global() -> &'static PipelineExecutor {
        static GLOBAL: OnceLock<PipelineExecutor> = OnceLock::new();
        GLOBAL.get_or_init(|| PipelineExecutor::new("hegrid-exec", default_parallelism()))
    }

    /// Pool worker threads (excludes the participating caller).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Warm the pool for a run: apply the currently requested affinity to
    /// every parked worker **now** (instead of lazily on the next sweep each
    /// one happens to join) and first-touch a page of per-worker scratch, so
    /// each worker's thread-local allocator arena is resident on its own
    /// NUMA node before the first real sweep allocates `init()` scratch from
    /// it (see [`crate::util::numa`]).
    ///
    /// Best effort: a busy pool degrades to warming fewer workers (the
    /// caller soaks up unclaimed slots), and on single-node hosts the whole
    /// pass is an idempotent re-pin plus a few µs of page faults. Called by
    /// `HegridEngine::new` when an affinity policy is configured.
    pub fn init(&self) {
        let participants = self.handles.len() + 1;
        let joined = AtomicUsize::new(0);
        self.run(
            participants,
            participants,
            1,
            || {
                joined.fetch_add(1, Ordering::Relaxed);
                // One page of worker-local scratch: faulting it here — after
                // the lazy re-pin at sweep join — places it on the worker's
                // node under first-touch.
                (vec![0u8; 4096], false)
            },
            |state: &mut (Vec<u8>, bool), i| {
                let (page, waited) = state;
                page[i % page.len()] = 1;
                std::hint::black_box(&page[..]);
                if !*waited {
                    *waited = true;
                    // Give every parked worker a beat to join so the warm-up
                    // reaches the whole pool, not just the caller. Bounded:
                    // a busy pool simply gets warmed later, lazily.
                    let t0 = Instant::now();
                    while joined.load(Ordering::Relaxed) < participants
                        && t0.elapsed() < Duration::from_millis(2)
                    {
                        thread::yield_now();
                    }
                }
            },
        );
    }

    pub fn stats(&self) -> ExecutorStats {
        ExecutorStats {
            sweeps: self.inner.sweeps.load(Ordering::Relaxed),
            helper_joins: self.inner.helper_joins.load(Ordering::Relaxed),
        }
    }

    /// Execute one sweep: `n` items, at most `workers` participants
    /// (caller included), claimed `claim_block` at a time; each participant
    /// gets a fresh `init()` scratch for the duration of the sweep.
    ///
    /// Blocks until every item ran. With one effective participant the sweep
    /// runs inline, in order, entirely on the caller — `workers == 1` is the
    /// exact sequential semantics.
    pub fn run<S, I, F>(&self, n: usize, workers: usize, claim_block: usize, init: I, f: F)
    where
        I: Fn() -> S + Sync,
        F: Fn(&mut S, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let claim_block = claim_block.max(1);
        let max_participants = workers.clamp(1, n.div_ceil(claim_block));
        if max_participants == 1 {
            let mut state = init();
            for i in 0..n {
                f(&mut state, i);
            }
            return;
        }
        self.inner.sweeps.fetch_add(1, Ordering::Relaxed);
        let cursor = AtomicUsize::new(0);
        let panicked = AtomicBool::new(false);
        let panic_note: Mutex<Option<String>> = Mutex::new(None);
        let body = || {
            let mut state = init();
            loop {
                // A panic anywhere in the sweep dooms it (run re-raises), so
                // other participants stop claiming instead of grinding
                // through the remaining items.
                if panicked.load(Ordering::Acquire) {
                    break;
                }
                let start = cursor.fetch_add(claim_block, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + claim_block).min(n) {
                    f(&mut state, i);
                }
            }
        };
        // Erase the body's lifetime for the registry: helpers only
        // dereference it while `active`/registration keep this frame alive
        // (the Leave guard below blocks until both clear).
        let body_ptr: *const (dyn Fn() + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn() + Sync), &'static (dyn Fn() + Sync)>(&body)
        };
        let entry = SweepEntry {
            cursor: &cursor,
            n,
            joined: AtomicUsize::new(1),
            max_participants,
            active: AtomicUsize::new(1),
            panicked: &panicked,
            panic_note: &panic_note,
            body: body_ptr,
        };
        {
            let mut reg = self.inner.reg.lock().expect("executor registry poisoned");
            reg.entries.push(EntryPtr(&entry));
            self.inner.work.notify_all();
        }

        // The caller is participant 0. The guard leaves the sweep, waits out
        // every helper, and deregisters — running even if `f` panics on this
        // thread, so a helper can never observe a freed sweep.
        struct Leave<'a> {
            inner: &'a ExecInner,
            entry: &'a SweepEntry,
        }
        impl Drop for Leave<'_> {
            fn drop(&mut self) {
                self.entry.active.fetch_sub(1, Ordering::Release);
                let mut reg = self.inner.reg.lock().expect("executor registry poisoned");
                while self.entry.active.load(Ordering::Acquire) != 0 {
                    reg = self.inner.done.wait(reg).expect("executor registry poisoned");
                }
                let target = self.entry as *const SweepEntry;
                reg.entries.retain(|p| !std::ptr::eq(p.0, target));
            }
        }
        let leave = Leave { inner: &self.inner, entry: &entry };
        if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(&body)) {
            // Tell the helpers to stop claiming before waiting them out,
            // then continue unwinding on this thread.
            panicked.store(true, Ordering::Release);
            drop(leave);
            std::panic::resume_unwind(payload);
        }
        drop(leave);
        if panicked.load(Ordering::Acquire) {
            let note = panic_note
                .lock()
                .expect("executor panic note poisoned")
                .take()
                .unwrap_or_else(|| "no panic message captured".into());
            panic!("PipelineExecutor: a helper worker panicked during the sweep: {note}");
        }
    }
}

/// Best-effort text of a panic payload (`&str` and `String` payloads cover
/// every `panic!` in this crate; anything else gets a placeholder).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl Drop for PipelineExecutor {
    fn drop(&mut self) {
        {
            let mut reg = self.inner.reg.lock().expect("executor registry poisoned");
            reg.shutdown = true;
            self.inner.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw-pointer writer for parallel initialisation of disjoint slice indices.
///
/// Scoped worker closures only get `&self` through `Fn`, so filling a
/// pre-sized buffer from several threads needs a shared handle; this wraps
/// the base pointer and makes the disjointness contract explicit. Callers
/// guarantee every index is written by at most one thread, stays in bounds,
/// and is not read through another alias while writers are live.
pub struct DisjointWriter<T>(*mut T);

unsafe impl<T: Send> Sync for DisjointWriter<T> {}
unsafe impl<T: Send> Send for DisjointWriter<T> {}

impl<T> DisjointWriter<T> {
    pub fn new(slice: &mut [T]) -> Self {
        DisjointWriter(slice.as_mut_ptr())
    }

    /// Write `v` at index `i`.
    ///
    /// # Safety
    /// `i` must be in bounds of the source slice, and no other thread may
    /// access index `i` concurrently.
    pub unsafe fn write(&self, i: usize, v: T)
    where
        T: Copy,
    {
        unsafe { self.0.add(i).write(v) };
    }

    /// Mutable view of `[start, start + len)`.
    ///
    /// # Safety
    /// The range must be in bounds of the source slice and disjoint from
    /// every range/index other threads access concurrently.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        unsafe { std::slice::from_raw_parts_mut(self.0.add(start), len) }
    }
}

/// A persistent FIFO worker pool executing boxed jobs; the substrate under the
/// coordinator's pipeline workers ("CPU processes" in the paper's terms).
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl WorkerPool {
    /// Spawn a pool with `workers` threads, each named `"{name}-{i}"`.
    pub fn new(name: &str, workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let rx = Arc::clone(&rx);
            let queued = Arc::clone(&queued);
            let handle = thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    let job = {
                        let guard = rx.lock().expect("worker queue poisoned");
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            // AcqRel mirrors `submit`: the Release half
                            // publishes the job's effects to `pending`
                            // readers, the Acquire half keeps this RMW in the
                            // same release sequence as concurrent submits.
                            queued.fetch_sub(1, Ordering::AcqRel);
                        }
                        Err(_) => break, // all senders dropped
                    }
                })
                .expect("spawn worker");
            handles.push(handle);
        }
        Self { tx: Some(tx), handles, queued }
    }

    /// Enqueue a job (FIFO).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        // AcqRel: the Release half publishes the increment (and everything
        // before the submit) to the Acquire load in `pending`; the Acquire
        // half pairs with the workers' completion-side decrements, so a
        // submitter observing its own increment also observes the effects of
        // every job whose decrement precedes it in the counter's modification
        // order. A plain Release here let `pending` transiently under-report
        // mid-burst: the submitter's next read was not ordered after
        // completions it raced with.
        self.queued.fetch_add(1, Ordering::AcqRel);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker pool receiver dropped");
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_chunks_covers_everything_once() {
        let n = 10_001;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 7, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_items_covers_everything_once() {
        let n = 5000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_items(n, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_zero_items_is_noop() {
        parallel_chunks(0, 4, |_, _, _| panic!("must not run"));
        parallel_items(0, 4, |_| panic!("must not run"));
        parallel_items_scoped(0, 4, 8, || (), |_, _| panic!("must not run"));
    }

    #[test]
    fn parallel_items_scoped_covers_everything_once() {
        let n = 10_037;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let inits = AtomicUsize::new(0);
        parallel_items_scoped(
            n,
            8,
            64,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                0usize
            },
            |count, i| {
                *count += 1;
                hits[i].fetch_add(1, Ordering::Relaxed);
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        let inits = inits.load(Ordering::Relaxed);
        assert!((1..=8).contains(&inits), "one init per worker, got {inits}");
    }

    #[test]
    fn parallel_items_scoped_single_worker_runs_in_order() {
        let order = Mutex::new(Vec::new());
        parallel_items_scoped(9, 1, 4, || (), |_, i| order.lock().unwrap().push(i));
        assert_eq!(*order.lock().unwrap(), (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_items_scoped_few_items_shrink_worker_count() {
        // 5 items in blocks of 4 need at most 2 workers; must still cover all.
        let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        parallel_items_scoped(5, 16, 4, || (), |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn disjoint_writer_parallel_fill() {
        let n = 4097;
        let mut out = vec![0u64; n];
        {
            let w = DisjointWriter::new(&mut out);
            parallel_chunks(n, 5, |_, s, e| {
                for i in s..e {
                    unsafe { w.write(i, i as u64 * 3) };
                }
            });
        }
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64 * 3));
        // Slice view over a disjoint range.
        let w = DisjointWriter::new(&mut out);
        let s = unsafe { w.slice(10, 4) };
        s.fill(7);
        assert_eq!(out[9], 27);
        assert_eq!(&out[10..14], &[7, 7, 7, 7]);
    }

    #[test]
    fn adaptive_claim_block_scales_with_work() {
        // Small sweeps claim item-by-item so every worker stays engaged.
        assert_eq!(adaptive_claim_block(128, 8), 2);
        assert_eq!(adaptive_claim_block(5, 16), 1);
        assert_eq!(adaptive_claim_block(0, 4), 1);
        // Huge sweeps cap the cursor traffic at one fetch_add per 64 items.
        assert_eq!(adaptive_claim_block(1_000_000, 8), 64);
        // Mid-size: ~8 blocks per worker.
        assert_eq!(adaptive_claim_block(640, 8), 10);
        // Degenerate worker count.
        assert_eq!(adaptive_claim_block(100, 0), 12);
    }

    #[test]
    fn affinity_mode_round_trips_and_applies() {
        for mode in [AffinityMode::None, AffinityMode::Compact, AffinityMode::Spread] {
            assert_eq!(AffinityMode::from_name(mode.name()).unwrap(), mode);
        }
        assert_eq!(AffinityMode::from_name("").unwrap(), AffinityMode::None);
        assert!(AffinityMode::from_name("scatter").is_err());

        // Setting a policy is visible to the accessor; sweeps still complete
        // with pinning active (best-effort, never a correctness hazard).
        set_executor_affinity(AffinityMode::Compact);
        assert_eq!(executor_affinity(), AffinityMode::Compact);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_items_scoped(1000, 4, 8, || (), |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // Restore the default so other tests run unpinned.
        set_executor_affinity(AffinityMode::None);
        assert_eq!(executor_affinity(), AffinityMode::None);
    }

    #[test]
    fn default_parallelism_is_cached_and_sane() {
        let a = default_parallelism();
        let b = default_parallelism();
        assert_eq!(a, b);
        assert!((1..=32).contains(&a));
    }

    #[test]
    fn worker_pool_runs_all_jobs_fifo_per_worker() {
        let pool = WorkerPool::new("test", 4);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let sum = Arc::clone(&sum);
            pool.submit(move || {
                sum.fetch_add(i, Ordering::Relaxed);
            });
        }
        drop(pool); // joins
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn executor_sweep_scratch_is_per_sweep_and_dropped() {
        // Two sweeps on one dedicated executor: every participant gets a
        // fresh init() per sweep and its scratch is dropped at sweep exit —
        // nothing leaks into the next sweep.
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct Scratch(u64);
        impl Drop for Scratch {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        let ex = PipelineExecutor::new("test-exec", 3);
        let inits = AtomicUsize::new(0);
        let sum = AtomicU64::new(0);
        for sweep in 0..2u64 {
            let before = inits.load(Ordering::Relaxed);
            ex.run(
                1000,
                4,
                16,
                || {
                    inits.fetch_add(1, Ordering::Relaxed);
                    Scratch(0)
                },
                |s, i| {
                    s.0 += i as u64;
                    sum.fetch_add(i as u64, Ordering::Relaxed);
                },
            );
            let after = inits.load(Ordering::Relaxed);
            assert!((1..=4).contains(&(after - before)), "sweep {sweep}: {}", after - before);
        }
        assert_eq!(sum.load(Ordering::Relaxed), 2 * 999 * 1000 / 2);
        assert_eq!(DROPS.load(Ordering::Relaxed), inits.load(Ordering::Relaxed));
        let stats = ex.stats();
        assert_eq!(stats.sweeps, 2);
    }

    #[test]
    fn executor_nested_sweeps_complete() {
        // A sweep body that submits its own sweeps must make progress even
        // when the pool is saturated (the caller always participates).
        let total = AtomicUsize::new(0);
        parallel_items_scoped(8, 4, 1, || (), |_, _| {
            parallel_items(100, 4, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 800);
    }

    #[test]
    fn executor_init_warms_pool_and_stays_usable() {
        let ex = PipelineExecutor::new("warm-exec", 2);
        ex.init();
        // Normal sweeps still run after the warm-up pass.
        let sum = AtomicU64::new(0);
        ex.run(100, 3, 8, || (), |_, i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
        // init() is idempotent.
        ex.init();
    }

    #[test]
    #[should_panic]
    fn executor_propagates_sweep_panics() {
        let ex = PipelineExecutor::new("panic-exec", 2);
        ex.run(64, 4, 1, || (), |_, i| {
            if i == 0 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn worker_pool_pending_accounting_under_hammer() {
        const SUBMITTERS: usize = 8;
        const PER: usize = 200;
        let pool = WorkerPool::new("hammer", 4);
        let done = Arc::new(AtomicUsize::new(0));
        thread::scope(|s| {
            for _ in 0..SUBMITTERS {
                let pool = &pool;
                let done = &done;
                s.spawn(move || {
                    for _ in 0..PER {
                        let done = Arc::clone(done);
                        pool.submit(move || {
                            done.fetch_add(1, Ordering::Release);
                        });
                        let p = pool.pending();
                        // An underflowed counter shows up as a huge value.
                        assert!(p <= SUBMITTERS * PER, "pending wrapped: {p}");
                    }
                });
            }
        });
        // All jobs submitted; wait for completion, then the counter must
        // settle at exactly zero (each worker decrements after its job).
        while done.load(Ordering::Acquire) < SUBMITTERS * PER {
            thread::yield_now();
        }
        let mut spins = 0u64;
        while pool.pending() != 0 {
            spins += 1;
            assert!(spins < 100_000_000, "pending() stuck at {}", pool.pending());
            thread::yield_now();
        }
        drop(pool);
        assert_eq!(done.load(Ordering::Acquire), SUBMITTERS * PER);
    }

    #[test]
    fn worker_pool_single_thread_preserves_order() {
        let pool = WorkerPool::new("fifo", 1);
        let order = Arc::new(Mutex::new(Vec::new()));
        for i in 0..16 {
            let order = Arc::clone(&order);
            pool.submit(move || order.lock().unwrap().push(i));
        }
        drop(pool);
        let got = order.lock().unwrap().clone();
        assert_eq!(got, (0..16).collect::<Vec<_>>());
    }
}
