//! Thread-pool substrate: a small fixed-size worker pool with scoped parallel
//! iteration. Stands in for `rayon` (not vendored). Used by pre-processing
//! (parallel pixel_idx computation / radix sort) and the CPU baselines.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::thread;

/// Number of worker threads to use by default (logical cores, capped).
/// Queried from the OS once and cached — this sits on per-call paths
/// (`SharedComponent::for_kernel`, config accessors, gridder constructors).
pub fn default_parallelism() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED
        .get_or_init(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(32))
}

/// Run `f(chunk_index, start, end)` over `n` items split into ~`workers`
/// contiguous chunks, in parallel, on scoped threads. Blocks until done.
///
/// `f` must be `Sync` — chunks are disjoint so data races are the caller's
/// responsibility to avoid via disjoint output slices or atomics.
pub fn parallel_chunks<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    thread::scope(|s| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(w, start, end));
        }
    });
}

/// Dynamic work-stealing loop: workers repeatedly claim the next index until
/// `n` items are consumed. For irregular per-item cost (e.g. per-cell
/// neighbour search where sampling density varies across the map).
pub fn parallel_items<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..workers {
            let f = &f;
            let next = &next;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Work-stealing loop with **per-worker state** and **block claiming**: each
/// worker calls `init()` once, then repeatedly claims `claim_block` contiguous
/// indices from a shared cursor (one `fetch_add` per block instead of one per
/// item) and runs `f(&mut state, i)` for each.
///
/// This is the substrate for hot loops that need reusable scratch buffers
/// (ring ranges, contributor lists, channel-block accumulators): the former
/// per-item allocations become per-worker allocations made once. Block
/// claiming keeps the cursor off the coherence hot path when items are cheap;
/// irregular per-item cost still balances because blocks are claimed
/// dynamically.
pub fn parallel_items_scoped<S, I, F>(n: usize, workers: usize, claim_block: usize, init: I, f: F)
where
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let claim_block = claim_block.max(1);
    let workers = workers.clamp(1, n.div_ceil(claim_block));
    if workers == 1 {
        let mut state = init();
        for i in 0..n {
            f(&mut state, i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..workers {
            let (init, f, next) = (&init, &f, &next);
            s.spawn(move || {
                let mut state = init();
                loop {
                    let start = next.fetch_add(claim_block, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    for i in start..(start + claim_block).min(n) {
                        f(&mut state, i);
                    }
                }
            });
        }
    });
}

/// Raw-pointer writer for parallel initialisation of disjoint slice indices.
///
/// Scoped worker closures only get `&self` through `Fn`, so filling a
/// pre-sized buffer from several threads needs a shared handle; this wraps
/// the base pointer and makes the disjointness contract explicit. Callers
/// guarantee every index is written by at most one thread, stays in bounds,
/// and is not read through another alias while writers are live.
pub struct DisjointWriter<T>(*mut T);

unsafe impl<T: Send> Sync for DisjointWriter<T> {}
unsafe impl<T: Send> Send for DisjointWriter<T> {}

impl<T> DisjointWriter<T> {
    pub fn new(slice: &mut [T]) -> Self {
        DisjointWriter(slice.as_mut_ptr())
    }

    /// Write `v` at index `i`.
    ///
    /// # Safety
    /// `i` must be in bounds of the source slice, and no other thread may
    /// access index `i` concurrently.
    pub unsafe fn write(&self, i: usize, v: T)
    where
        T: Copy,
    {
        unsafe { self.0.add(i).write(v) };
    }

    /// Mutable view of `[start, start + len)`.
    ///
    /// # Safety
    /// The range must be in bounds of the source slice and disjoint from
    /// every range/index other threads access concurrently.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        unsafe { std::slice::from_raw_parts_mut(self.0.add(start), len) }
    }
}

/// A persistent FIFO worker pool executing boxed jobs; the substrate under the
/// coordinator's pipeline workers ("CPU processes" in the paper's terms).
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl WorkerPool {
    /// Spawn a pool with `workers` threads, each named `"{name}-{i}"`.
    pub fn new(name: &str, workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let rx = Arc::clone(&rx);
            let queued = Arc::clone(&queued);
            let handle = thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    let job = {
                        let guard = rx.lock().expect("worker queue poisoned");
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            queued.fetch_sub(1, Ordering::Release);
                        }
                        Err(_) => break, // all senders dropped
                    }
                })
                .expect("spawn worker");
            handles.push(handle);
        }
        Self { tx: Some(tx), handles, queued }
    }

    /// Enqueue a job (FIFO).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        // Release publishes the increment (and everything before the submit)
        // to the Acquire load in `pending`; the worker's post-job decrement
        // is the matching Release on the completion side. The previous
        // Acquire here ordered nothing — an increment is a store-side event.
        self.queued.fetch_add(1, Ordering::Release);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker pool receiver dropped");
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_chunks_covers_everything_once() {
        let n = 10_001;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 7, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_items_covers_everything_once() {
        let n = 5000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_items(n, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_zero_items_is_noop() {
        parallel_chunks(0, 4, |_, _, _| panic!("must not run"));
        parallel_items(0, 4, |_| panic!("must not run"));
        parallel_items_scoped(0, 4, 8, || (), |_, _| panic!("must not run"));
    }

    #[test]
    fn parallel_items_scoped_covers_everything_once() {
        let n = 10_037;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let inits = AtomicUsize::new(0);
        parallel_items_scoped(
            n,
            8,
            64,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                0usize
            },
            |count, i| {
                *count += 1;
                hits[i].fetch_add(1, Ordering::Relaxed);
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        let inits = inits.load(Ordering::Relaxed);
        assert!((1..=8).contains(&inits), "one init per worker, got {inits}");
    }

    #[test]
    fn parallel_items_scoped_single_worker_runs_in_order() {
        let order = Mutex::new(Vec::new());
        parallel_items_scoped(9, 1, 4, || (), |_, i| order.lock().unwrap().push(i));
        assert_eq!(*order.lock().unwrap(), (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_items_scoped_few_items_shrink_worker_count() {
        // 5 items in blocks of 4 need at most 2 workers; must still cover all.
        let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        parallel_items_scoped(5, 16, 4, || (), |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn disjoint_writer_parallel_fill() {
        let n = 4097;
        let mut out = vec![0u64; n];
        {
            let w = DisjointWriter::new(&mut out);
            parallel_chunks(n, 5, |_, s, e| {
                for i in s..e {
                    unsafe { w.write(i, i as u64 * 3) };
                }
            });
        }
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64 * 3));
        // Slice view over a disjoint range.
        let w = DisjointWriter::new(&mut out);
        let s = unsafe { w.slice(10, 4) };
        s.fill(7);
        assert_eq!(out[9], 27);
        assert_eq!(&out[10..14], &[7, 7, 7, 7]);
    }

    #[test]
    fn default_parallelism_is_cached_and_sane() {
        let a = default_parallelism();
        let b = default_parallelism();
        assert_eq!(a, b);
        assert!((1..=32).contains(&a));
    }

    #[test]
    fn worker_pool_runs_all_jobs_fifo_per_worker() {
        let pool = WorkerPool::new("test", 4);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let sum = Arc::clone(&sum);
            pool.submit(move || {
                sum.fetch_add(i, Ordering::Relaxed);
            });
        }
        drop(pool); // joins
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn worker_pool_single_thread_preserves_order() {
        let pool = WorkerPool::new("fifo", 1);
        let order = Arc::new(Mutex::new(Vec::new()));
        for i in 0..16 {
            let order = Arc::clone(&order);
            pool.submit(move || order.lock().unwrap().push(i));
        }
        drop(pool);
        let got = order.lock().unwrap().clone();
        assert_eq!(got, (0..16).collect::<Vec<_>>());
    }
}
