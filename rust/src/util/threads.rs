//! Thread-pool substrate: a small fixed-size worker pool with scoped parallel
//! iteration. Stands in for `rayon` (not vendored). Used by pre-processing
//! (parallel pixel_idx computation / radix sort) and the CPU baselines.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// Number of worker threads to use by default (logical cores, capped).
pub fn default_parallelism() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(32)
}

/// Run `f(chunk_index, start, end)` over `n` items split into ~`workers`
/// contiguous chunks, in parallel, on scoped threads. Blocks until done.
///
/// `f` must be `Sync` — chunks are disjoint so data races are the caller's
/// responsibility to avoid via disjoint output slices or atomics.
pub fn parallel_chunks<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    thread::scope(|s| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(w, start, end));
        }
    });
}

/// Dynamic work-stealing loop: workers repeatedly claim the next index until
/// `n` items are consumed. For irregular per-item cost (e.g. per-cell
/// neighbour search where sampling density varies across the map).
pub fn parallel_items<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..workers {
            let f = &f;
            let next = &next;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// A persistent FIFO worker pool executing boxed jobs; the substrate under the
/// coordinator's pipeline workers ("CPU processes" in the paper's terms).
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl WorkerPool {
    /// Spawn a pool with `workers` threads, each named `"{name}-{i}"`.
    pub fn new(name: &str, workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let rx = Arc::clone(&rx);
            let queued = Arc::clone(&queued);
            let handle = thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    let job = {
                        let guard = rx.lock().expect("worker queue poisoned");
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            queued.fetch_sub(1, Ordering::Release);
                        }
                        Err(_) => break, // all senders dropped
                    }
                })
                .expect("spawn worker");
            handles.push(handle);
        }
        Self { tx: Some(tx), handles, queued }
    }

    /// Enqueue a job (FIFO).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.queued.fetch_add(1, Ordering::Acquire);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker pool receiver dropped");
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_chunks_covers_everything_once() {
        let n = 10_001;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 7, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_items_covers_everything_once() {
        let n = 5000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_items(n, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_zero_items_is_noop() {
        parallel_chunks(0, 4, |_, _, _| panic!("must not run"));
        parallel_items(0, 4, |_| panic!("must not run"));
    }

    #[test]
    fn worker_pool_runs_all_jobs_fifo_per_worker() {
        let pool = WorkerPool::new("test", 4);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let sum = Arc::clone(&sum);
            pool.submit(move || {
                sum.fetch_add(i, Ordering::Relaxed);
            });
        }
        drop(pool); // joins
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn worker_pool_single_thread_preserves_order() {
        let pool = WorkerPool::new("fifo", 1);
        let order = Arc::new(Mutex::new(Vec::new()));
        for i in 0..16 {
            let order = Arc::clone(&order);
            pool.submit(move || order.lock().unwrap().push(i));
        }
        drop(pool);
        let got = order.lock().unwrap().clone();
        assert_eq!(got, (0..16).collect::<Vec<_>>());
    }
}
