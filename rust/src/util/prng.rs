//! Deterministic PRNGs: SplitMix64 (seeding, cheap draws) and Xoshiro256++
//! (bulk generation for the simulator). No external `rand` crate is vendored,
//! so these are first-class substrates with reference-vector tests.

/// SplitMix64 — tiny, fast, passes BigCrush when used as a stream.
/// Reference: Steele, Lea, Flood (2014); same constants as `java.util.SplittableRandom`.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (n > 0), bias-free via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Standard normal via Box–Muller (caches nothing; two draws per call).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Derive an independent child stream (for per-channel simulation).
    pub fn split(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }
}

/// Xoshiro256++ — bulk generator used by the drift-scan simulator.
/// Reference: Blackman & Vigna (2019), <https://prng.di.unimi.it/>.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    pub fn new(seed: u64) -> Self {
        // Seed the full state from SplitMix64, per the authors' recommendation.
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference vectors from the published SplitMix64 C code with seed 1234567.
    #[test]
    fn splitmix_reference_vectors() {
        let mut r = SplitMix64::new(1234567);
        let expect: [u64; 5] = [
            6457827717110365317,
            3203168211198807973,
            9817491932198370423,
            4593380528125082431,
            16408922859458223821,
        ];
        for e in expect {
            assert_eq!(r.next_u64(), e);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(42);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_range() {
        let mut r = SplitMix64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(99);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_from_splitmix() {
        let mut a = Xoshiro256pp::new(5);
        let mut b = Xoshiro256pp::new(5);
        let mut c = Xoshiro256pp::new(6);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = SplitMix64::new(11);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let v1: Vec<u64> = (0..4).map(|_| c1.next_u64()).collect();
        let v2: Vec<u64> = (0..4).map(|_| c2.next_u64()).collect();
        assert_ne!(v1, v2);
    }
}
