//! NUMA topology detection and best-effort first-touch memory placement —
//! the memory half of the executor's core pinning (`--affinity`).
//!
//! Thread pinning alone is not enough on multi-socket nodes: Linux places a
//! page on the NUMA node of the thread that **first writes** it
//! (first-touch), so a value matrix zeroed by the coordinating thread lands
//! entirely on that thread's node and every worker pinned to the other
//! socket pays remote-memory latency for the whole run. This module closes
//! that gap without new crates or `mbind`:
//!
//! * [`NumaTopology::detect`] reads `/sys/devices/system/node/node*/cpulist`
//!   (Linux; a single synthetic node everywhere else) once per process
//!   ([`topology`]).
//! * [`NumaTopology::cpu_for`] is the NUMA-aware worker→CPU map behind
//!   `--affinity compact|spread`: `compact` fills node 0's CPUs before
//!   spilling to node 1 (shared-cache locality), `spread` round-robins
//!   workers across nodes first and strides within a node second (memory
//!   bandwidth). [`NumaTopology::worker_nodes`] is the per-worker node map
//!   the reports print.
//! * [`first_touch_zeroed`] faults a freshly allocated buffer's pages from
//!   the executor's pinned workers (page-granular sweep, claim block 1), so
//!   pages interleave across the nodes the consumers run on instead of all
//!   landing on the allocating thread's node. Best effort by design: with
//!   dynamic block claiming the exact page→node assignment is not
//!   deterministic, but the *distribution* across nodes is what buys the
//!   bandwidth. A no-op on single-node hosts or with `--affinity none`
//!   ([`placement_active`]), so UMA laptops and CI pay nothing.
//!
//! Buffers that already receive a **parallel first write** on the executor
//! (the SoA unit columns in `SharedComponent::build`, the lane-padded value
//! matrix — whose fill claims ~page-sized row blocks when
//! [`placement_active`]) don't need the explicit sweep: the fill itself is
//! the first-touch pass. [`first_touch_zeroed`] is for buffers with a
//! *serial* fill but parallel consumers (e.g. the f32 staging planes of
//! `SharedComponent::staged_unit_f32`); `PipelineExecutor::init` warms the
//! per-worker scratch arenas. All placement writes are zeros over
//! logically-zero buffers, so placement can never change results.

use std::sync::OnceLock;

use crate::util::threads::{
    default_parallelism, parallel_items_scoped, AffinityMode, DisjointWriter,
};

/// CPU ids grouped by NUMA node. Always has at least one node; node 0 holds
/// every CPU when detection is unavailable (non-Linux, masked sysfs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NumaTopology {
    /// CPU ids per node, in sysfs node order.
    nodes: Vec<Vec<usize>>,
}

impl NumaTopology {
    /// Detect the host topology (sysfs on Linux, single node elsewhere).
    pub fn detect() -> NumaTopology {
        #[cfg(target_os = "linux")]
        if let Some(t) = Self::from_sysfs(std::path::Path::new("/sys/devices/system/node")) {
            return t;
        }
        Self::single_node()
    }

    /// Every CPU on one node — the UMA / detection-unavailable fallback.
    pub fn single_node() -> NumaTopology {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        NumaTopology { nodes: vec![(0..n).collect()] }
    }

    /// Build from explicit per-node CPU lists (tests, canned topologies).
    /// Empty nodes are dropped; an empty list degrades to
    /// [`NumaTopology::single_node`].
    pub fn from_nodes(nodes: Vec<Vec<usize>>) -> NumaTopology {
        let nodes: Vec<Vec<usize>> = nodes.into_iter().filter(|c| !c.is_empty()).collect();
        if nodes.is_empty() {
            return Self::single_node();
        }
        NumaTopology { nodes }
    }

    #[cfg(target_os = "linux")]
    fn from_sysfs(dir: &std::path::Path) -> Option<NumaTopology> {
        let mut found: Vec<(usize, Vec<usize>)> = Vec::new();
        for entry in std::fs::read_dir(dir).ok()? {
            let entry = entry.ok()?;
            let name = entry.file_name();
            let Some(idx) = name.to_str().and_then(|n| n.strip_prefix("node")) else {
                continue;
            };
            let Ok(idx) = idx.parse::<usize>() else { continue };
            let Ok(list) = std::fs::read_to_string(entry.path().join("cpulist")) else {
                continue;
            };
            let cpus = parse_cpulist(&list);
            if !cpus.is_empty() {
                found.push((idx, cpus));
            }
        }
        if found.is_empty() {
            return None;
        }
        found.sort_by_key(|(i, _)| *i);
        Some(NumaTopology { nodes: found.into_iter().map(|(_, c)| c).collect() })
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_multi_node(&self) -> bool {
        self.nodes.len() > 1
    }

    /// CPU ids of `node`.
    pub fn cpus(&self, node: usize) -> &[usize] {
        &self.nodes[node]
    }

    /// Total CPUs across all nodes.
    pub fn n_cpus(&self) -> usize {
        self.nodes.iter().map(|c| c.len()).sum()
    }

    /// Node owning `cpu` (0 when the CPU is not listed).
    pub fn node_of_cpu(&self, cpu: usize) -> usize {
        self.nodes.iter().position(|c| c.contains(&cpu)).unwrap_or(0)
    }

    /// The CPU pool worker `worker` (of `pool_workers`) pins to under
    /// `mode` — the NUMA-aware extension of the affinity policies:
    ///
    /// * `compact` — fill nodes in order: node 0's CPUs first, then node
    ///   1's, … (wraps past the last CPU). Maximises shared-cache locality;
    ///   on a single node this is the historical `worker % n_cpus`.
    /// * `spread` — round-robin workers across nodes first (worker *i* →
    ///   node *i* mod nodes), then stride within the node for cache
    ///   spacing. Maximises aggregate memory bandwidth; on a single node
    ///   this is the historical strided placement.
    ///
    /// `None` pins nothing.
    pub fn cpu_for(&self, worker: usize, pool_workers: usize, mode: AffinityMode) -> Option<usize> {
        match mode {
            AffinityMode::None => None,
            AffinityMode::Compact => {
                let total = self.n_cpus().max(1);
                let mut k = worker % total;
                for cpus in &self.nodes {
                    if k < cpus.len() {
                        return Some(cpus[k]);
                    }
                    k -= cpus.len();
                }
                None
            }
            AffinityMode::Spread => {
                let cpus = &self.nodes[worker % self.nodes.len()];
                let per_node = pool_workers.div_ceil(self.nodes.len()).max(1);
                let idx = worker / self.nodes.len();
                let stride = (cpus.len() / per_node).max(1);
                Some(cpus[(idx * stride) % cpus.len()])
            }
        }
    }

    /// Per-worker NUMA node map for a pool of `pool_workers` under `mode`
    /// (node 0 for unpinned workers) — what reports print next to the
    /// affinity policy.
    pub fn worker_nodes(&self, pool_workers: usize, mode: AffinityMode) -> Vec<usize> {
        (0..pool_workers)
            .map(|w| {
                self.cpu_for(w, pool_workers, mode)
                    .map(|c| self.node_of_cpu(c))
                    .unwrap_or(0)
            })
            .collect()
    }
}

/// The process-wide detected topology (detection runs once, then cached —
/// `sysfs` reads sit on the engine-construction path).
pub fn topology() -> &'static NumaTopology {
    static TOPO: OnceLock<NumaTopology> = OnceLock::new();
    TOPO.get_or_init(NumaTopology::detect)
}

/// Parse a sysfs cpulist (`"0-3,8,10-11"`) into CPU ids. Malformed parts
/// are skipped (sysfs is trusted but this also takes test input).
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                if a <= b && b - a < 4096 {
                    out.extend(a..=b);
                }
            }
        } else if let Ok(v) = part.parse::<usize>() {
            out.push(v);
        }
    }
    out
}

/// First-touch placement pays only when there is more than one node to
/// place on **and** the executor's workers are actually pinned somewhere
/// (`--affinity compact|spread`) — unpinned workers migrate, so the node a
/// page lands on is noise anyway.
pub fn placement_active() -> bool {
    crate::util::threads::executor_affinity() != AffinityMode::None && topology().is_multi_node()
}

/// Fault `buf`'s pages from the executor's (pinned) workers so they spread
/// across NUMA nodes, instead of all landing on the allocating thread's
/// node. Page-granular sweep with claim block 1: consecutive pages go to
/// whichever pinned worker claims them next, which interleaves pages across
/// the nodes the workers are pinned to (best-effort — the goal is the
/// cross-node *distribution*, not a deterministic page→node map).
///
/// Writes zeros, so callers must hand freshly allocated, still-logically-
/// zero buffers (`vec![0; n]`, [`crate::grid::simd::AlignedF32::zeroed`]);
/// both allocate lazily mapped zero pages, so this sweep really is the
/// first write. No-op unless [`placement_active`].
pub fn first_touch_zeroed<T: Copy + Default + Send>(buf: &mut [T]) {
    if !placement_active() || buf.is_empty() {
        return;
    }
    touch_pages(buf);
}

/// The touch sweep itself (separated so tests can exercise it on UMA CI
/// hosts where [`placement_active`] is false).
fn touch_pages<T: Copy + Default + Send>(buf: &mut [T]) {
    const PAGE_BYTES: usize = 4096;
    let per_page = (PAGE_BYTES / std::mem::size_of::<T>().max(1)).max(1);
    let n_pages = buf.len().div_ceil(per_page);
    let len = buf.len();
    let w = DisjointWriter::new(buf);
    parallel_items_scoped(n_pages, default_parallelism(), 1, || (), |_, p| {
        let start = p * per_page;
        let chunk = unsafe { w.slice(start, per_page.min(len - start)) };
        chunk.fill(T::default());
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_nodes() -> NumaTopology {
        NumaTopology::from_nodes(vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]])
    }

    #[test]
    fn parse_cpulist_formats() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0-1,8,10-11\n"), vec![0, 1, 8, 10, 11]);
        assert_eq!(parse_cpulist(" 5 "), vec![5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        // Malformed parts are skipped, huge ranges refused.
        assert_eq!(parse_cpulist("x,3-1,2"), vec![2]);
        assert_eq!(parse_cpulist("0-999999"), Vec::<usize>::new());
    }

    #[test]
    fn single_node_fallback_is_sane() {
        let t = NumaTopology::single_node();
        assert_eq!(t.n_nodes(), 1);
        assert!(!t.is_multi_node());
        assert!(t.n_cpus() >= 1);
        assert_eq!(t.node_of_cpu(0), 0);
        // from_nodes with nothing usable degrades to the same shape.
        let empty = NumaTopology::from_nodes(vec![vec![], vec![]]);
        assert_eq!(empty.n_nodes(), 1);
    }

    #[test]
    fn node_of_cpu_reverse_map() {
        let t = two_nodes();
        assert_eq!(t.n_nodes(), 2);
        assert!(t.is_multi_node());
        assert_eq!(t.n_cpus(), 8);
        assert_eq!(t.node_of_cpu(2), 0);
        assert_eq!(t.node_of_cpu(5), 1);
        assert_eq!(t.node_of_cpu(99), 0, "unknown CPUs fold to node 0");
        assert_eq!(t.cpus(1), &[4, 5, 6, 7]);
    }

    #[test]
    fn compact_fills_nodes_in_order() {
        let t = two_nodes();
        let cpus: Vec<usize> =
            (0..8).map(|w| t.cpu_for(w, 8, AffinityMode::Compact).unwrap()).collect();
        assert_eq!(cpus, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        // Wraps past the last CPU.
        assert_eq!(t.cpu_for(9, 8, AffinityMode::Compact), Some(1));
        // None mode pins nothing.
        assert_eq!(t.cpu_for(0, 8, AffinityMode::None), None);
    }

    #[test]
    fn spread_round_robins_nodes_then_strides() {
        let t = two_nodes();
        // 4 workers across 2×4 CPUs: alternate nodes, stride 2 within.
        let cpus: Vec<usize> =
            (0..4).map(|w| t.cpu_for(w, 4, AffinityMode::Spread).unwrap()).collect();
        assert_eq!(cpus, vec![0, 4, 2, 6]);
        assert_eq!(t.worker_nodes(4, AffinityMode::Spread), vec![0, 1, 0, 1]);
        // Compact on the same pool leans on node 0 first.
        assert_eq!(t.worker_nodes(4, AffinityMode::Compact), vec![0, 0, 0, 0]);
        // Single node: spread preserves the historical strided placement.
        let uma = NumaTopology::from_nodes(vec![(0..8).collect()]);
        let cpus: Vec<usize> =
            (0..4).map(|w| uma.cpu_for(w, 4, AffinityMode::Spread).unwrap()).collect();
        assert_eq!(cpus, vec![0, 2, 4, 6]);
    }

    #[test]
    fn touch_pages_covers_buffer_and_leaves_zeros() {
        // ~3.5 pages of f64 + a tail that is not page-aligned.
        let mut buf = vec![0.0f64; 4096 / 8 * 3 + 17];
        touch_pages(&mut buf);
        assert!(buf.iter().all(|&v| v == 0.0));
        // Degenerate sizes are fine.
        let mut tiny = vec![0u8; 3];
        touch_pages(&mut tiny);
        assert_eq!(tiny, vec![0, 0, 0]);
        let mut empty: Vec<f32> = Vec::new();
        first_touch_zeroed(&mut empty);
    }

    #[test]
    fn detected_topology_is_cached_and_nonempty() {
        let a = topology();
        let b = topology();
        assert!(std::ptr::eq(a, b));
        assert!(a.n_nodes() >= 1);
        assert!(a.n_cpus() >= 1);
    }
}
