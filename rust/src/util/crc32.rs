//! CRC-32 (IEEE 802.3, the zlib polynomial) — integrity checks for the HGD
//! dataset container. Table-driven, byte-at-a-time; plenty for header-sized
//! and chunk-sized checksums.

const POLY: u32 = 0xEDB8_8320;

/// The 256-entry lookup table, built at compile time.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Streaming CRC-32 hasher.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let mut c = self.state;
        for &b in data {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(data);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_equals_oneshot() {
        let data = b"hegrid dataset block 0123456789";
        let mut h = Crc32::new();
        h.update(&data[..7]);
        h.update(&data[7..]);
        assert_eq!(h.finalize(), crc32(data));
    }

    #[test]
    fn sensitive_to_single_bit() {
        let a = crc32(b"\x00\x00\x00\x00");
        let b = crc32(b"\x00\x00\x00\x01");
        assert_ne!(a, b);
    }
}
