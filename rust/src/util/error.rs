//! Crate-wide error type.

use std::fmt;

/// Unified error for every HEGrid subsystem.
#[derive(Debug)]
pub enum HegridError {
    /// I/O failure, with the path or operation that caused it.
    Io { context: String, source: std::io::Error },
    /// A malformed dataset / artifact / config file.
    Format(String),
    /// JSON parse error with byte offset.
    Json { offset: usize, message: String },
    /// Invalid user-supplied configuration or CLI arguments.
    Config(String),
    /// Stored data failed an integrity check (CRC mismatch, truncation):
    /// the file is structurally valid but its payload cannot be trusted.
    Corrupt(String),
    /// PJRT runtime failure (compile/execute/transfer).
    Runtime(String),
    /// Internal invariant violation — a bug in HEGrid.
    Internal(String),
    /// The run was cancelled cooperatively (service `DELETE /jobs/{id}`).
    /// Checked at channel-group boundaries, so partial work is discarded
    /// cleanly and the pipeline slots are released.
    Cancelled,
}

impl fmt::Display for HegridError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HegridError::Io { context, source } => write!(f, "I/O error ({context}): {source}"),
            HegridError::Format(m) => write!(f, "format error: {m}"),
            HegridError::Json { offset, message } => {
                write!(f, "JSON error at byte {offset}: {message}")
            }
            HegridError::Config(m) => write!(f, "config error: {m}"),
            HegridError::Corrupt(m) => write!(f, "data corruption: {m}"),
            HegridError::Runtime(m) => write!(f, "runtime error: {m}"),
            HegridError::Internal(m) => write!(f, "internal error: {m}"),
            HegridError::Cancelled => write!(f, "cancelled: job cancelled at a group boundary"),
        }
    }
}

impl std::error::Error for HegridError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            HegridError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl HegridError {
    /// Wrap an `io::Error` with context (usually a path).
    pub fn io(context: impl Into<String>) -> impl FnOnce(std::io::Error) -> HegridError {
        let context = context.into();
        move |source| HegridError::Io { context, source }
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for HegridError {
    fn from(e: xla::Error) -> Self {
        HegridError::Runtime(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, HegridError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = HegridError::Format("bad magic".into());
        assert_eq!(e.to_string(), "format error: bad magic");
        let e = HegridError::Json { offset: 12, message: "expected ':'".into() };
        assert!(e.to_string().contains("byte 12"));
        let e = HegridError::Corrupt("channel 3 CRC mismatch".into());
        assert!(e.to_string().contains("corruption"));
        assert!(HegridError::Cancelled.to_string().contains("cancelled"));
    }

    #[test]
    fn io_wrapper_keeps_context() {
        let err = std::fs::File::open("/definitely/not/here").unwrap_err();
        let e = HegridError::io("/definitely/not/here")(err);
        assert!(e.to_string().contains("/definitely/not/here"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
