//! Pre-processing: the paper's CPU stage and its **shared component**.
//!
//! Steps ①–④ of Fig 3: compute each sample's HEALPix `pixel_idx` (①), sort
//! samples by it (② — parallel radix sort), adjust the coordinate arrays to
//! the sorted order (③), and build the ring-indexed look-up table (④). The
//! result is channel-independent: data points in every frequency channel
//! share coordinates, so one [`SharedComponent`] serves all pipelines —
//! the component share-based redundancy elimination of §4.3.1. With sharing
//! disabled (Fig 11/12 baseline) the coordinator simply rebuilds this per
//! pipeline.
//!
//! Only the per-channel *values* are pipeline-local: [`SharedComponent::
//! permute_channel`] reorders a channel's value column into the sorted
//! layout (the per-pipeline half of step ③).

use std::time::Duration;

use crate::grid::kernels::ConvKernel;
use crate::grid::sort::{radix_sort_by_key, KeyIdx};
use crate::healpix::Healpix;
use crate::logging::timed;
use crate::util::error::{HegridError, Result};
use crate::util::threads::{
    adaptive_claim_block, default_parallelism, parallel_chunks, parallel_items_scoped,
    DisjointWriter,
};

/// Columns below this size are permuted serially — the gather is pure
/// memory traffic, so thread spawn overhead dominates on small inputs.
const PAR_PERMUTE_MIN: usize = 1 << 15;

/// Build-time metrics of a shared component (Fig 8's T-stage accounting).
#[derive(Clone, Debug, Default)]
pub struct PrepStats {
    pub n_samples: usize,
    pub nside: u64,
    pub t_pixel_idx: Duration,
    pub t_sort: Duration,
    pub t_adjust: Duration,
    pub t_lut: Duration,
}

impl PrepStats {
    pub fn total(&self) -> Duration {
        self.t_pixel_idx + self.t_sort + self.t_adjust + self.t_lut
    }
}

/// The shared pre-processing component: sorted samples + ring LUT.
#[derive(Clone, Debug)]
pub struct SharedComponent {
    pub healpix: Healpix,
    /// Sorted sample pixel ids (ascending).
    pub sorted_pix: Vec<u64>,
    /// `perm[j]` = original index of the sample at sorted position `j`.
    pub perm: Vec<u32>,
    /// Sorted coordinates in device precision (f32, radians).
    pub slon: Vec<f32>,
    pub slat: Vec<f32>,
    /// Sorted coordinates in full precision for the CPU gridder.
    pub slon64: Vec<f64>,
    pub slat64: Vec<f64>,
    /// Per-sample unit 3-vectors (bit-identical to `unit_vec(lon, lat)`),
    /// precomputed once from the sorted coordinates and stored as **SoA
    /// columns** so the SIMD backends ([`crate::grid::simd`]) can batch the
    /// squared-chord prefilter over 2/4 samples per vector — the operand of
    /// the trig-free chord distance in the gridder and neighbour-walk inner
    /// loops, and the source of the f32 staging planes T2 ships to the
    /// device ([`SharedComponent::staged_unit_f32`]). Redundancy
    /// elimination, §4.3.
    pub unit_x: Vec<f64>,
    pub unit_y: Vec<f64>,
    pub unit_z: Vec<f64>,
    /// Worker budget the component was built with; reused by the parallel
    /// [`SharedComponent::permute_channel`].
    pub workers: usize,
    pub stats: PrepStats,
}

impl SharedComponent {
    /// Build from raw sample coordinates (radians). `resolution` sets the
    /// HEALPix pixel spacing; use the kernel support radius so a contribution
    /// disc spans only a few rings ([`SharedComponent::for_kernel`] does
    /// this).
    pub fn build(lons: &[f64], lats: &[f64], resolution: f64, workers: usize) -> Result<Self> {
        if lons.len() != lats.len() {
            return Err(HegridError::Internal("lons/lats length mismatch".into()));
        }
        let n = lons.len();
        let healpix = Healpix::for_resolution(resolution);
        let workers = workers.max(1);
        let mut stats = PrepStats { n_samples: n, nside: healpix.nside(), ..Default::default() };

        // ① pixel_idx, in parallel.
        let mut items: Vec<KeyIdx> = vec![KeyIdx { key: 0, idx: 0 }; n];
        let (_, t) = timed(|| {
            let hp = &healpix;
            let items_w = DisjointWriter::new(&mut items);
            parallel_chunks(n, workers, |_, s, e| {
                for i in s..e {
                    let key = hp.ang2pix_radec(lons[i], lats[i]);
                    unsafe { items_w.write(i, KeyIdx { key, idx: i as u32 }) };
                }
            });
        });
        stats.t_pixel_idx = t;

        // ② sort by pixel_idx (stable ⇒ deterministic layout).
        let (_, t) = timed(|| radix_sort_by_key(&mut items, workers));
        stats.t_sort = t;

        // ③ adjust coordinate memory to the sorted order, in parallel, and
        // precompute the per-sample unit vectors so the gridding inner loops
        // (and the device staging planes) are trig-free.
        let mut sorted_pix = vec![0u64; n];
        let mut perm = vec![0u32; n];
        let mut slon = vec![0.0f32; n];
        let mut slat = vec![0.0f32; n];
        let mut slon64 = vec![0.0f64; n];
        let mut slat64 = vec![0.0f64; n];
        let mut unit_x = vec![0.0f64; n];
        let mut unit_y = vec![0.0f64; n];
        let mut unit_z = vec![0.0f64; n];
        // NUMA note: these columns get their first write from the parallel
        // fill below, which runs on the (optionally pinned) executor
        // workers — so under `--affinity` on a multi-node host the pages
        // already land distributed across the consumers' nodes (first-touch
        // via the fill itself; an extra pre-touch sweep would only re-write
        // the same pages). See util::numa for the placement machinery.
        let (_, t) = timed(|| {
            let w_pix = DisjointWriter::new(&mut sorted_pix);
            let w_perm = DisjointWriter::new(&mut perm);
            let w_slon = DisjointWriter::new(&mut slon);
            let w_slat = DisjointWriter::new(&mut slat);
            let w_slon64 = DisjointWriter::new(&mut slon64);
            let w_slat64 = DisjointWriter::new(&mut slat64);
            let w_ux = DisjointWriter::new(&mut unit_x);
            let w_uy = DisjointWriter::new(&mut unit_y);
            let w_uz = DisjointWriter::new(&mut unit_z);
            let items = &items;
            parallel_chunks(n, workers, |_, s, e| {
                for j in s..e {
                    let entry = &items[j];
                    let i = entry.idx as usize;
                    let (sin_lat, cos_lat) = lats[i].sin_cos();
                    let (sin_lon, cos_lon) = lons[i].sin_cos();
                    unsafe {
                        w_pix.write(j, entry.key);
                        w_perm.write(j, entry.idx);
                        w_slon.write(j, lons[i] as f32);
                        w_slat.write(j, lats[i] as f32);
                        w_slon64.write(j, lons[i]);
                        w_slat64.write(j, lats[i]);
                        // Same ops/order as `healpix::unit_vec` ⇒ bit-equal.
                        w_ux.write(j, cos_lat * cos_lon);
                        w_uy.write(j, cos_lat * sin_lon);
                        w_uz.write(j, sin_lat);
                    }
                }
            });
        });
        stats.t_adjust = t;

        // ④ the LUT itself is the sorted pixel array + HEALPix ring algebra;
        // nothing further to materialise (span lookups are binary searches).
        // Keep the stage for faithful Fig-8 accounting — it also validates
        // monotonicity in debug builds.
        let (_, t) = timed(|| {
            debug_assert!(sorted_pix.windows(2).all(|w| w[0] <= w[1]));
        });
        stats.t_lut = t;

        Ok(SharedComponent {
            healpix,
            sorted_pix,
            perm,
            slon,
            slat,
            slon64,
            slat64,
            unit_x,
            unit_y,
            unit_z,
            workers,
            stats,
        })
    }

    /// Unit 3-vector of sorted sample `j` (gathers the SoA columns).
    #[inline]
    pub fn unit3(&self, j: usize) -> [f64; 3] {
        [self.unit_x[j], self.unit_y[j], self.unit_z[j]]
    }

    /// Build with the HEALPix resolution matched to a kernel's support.
    pub fn for_kernel(lons: &[f64], lats: &[f64], kernel: &ConvKernel) -> Result<Self> {
        Self::build(lons, lats, kernel.support.max(1e-6), default_parallelism())
    }

    pub fn n_samples(&self) -> usize {
        self.sorted_pix.len()
    }

    /// Sample span `[lo, hi)` (sorted positions) whose pixel ids fall in the
    /// inclusive global-pixel range `[pix_lo, pix_hi]` — one LUT probe.
    pub fn samples_in_pix_range(&self, pix_lo: u64, pix_hi: u64) -> (usize, usize) {
        (
            self.sorted_pix.partition_point(|&p| p < pix_lo),
            self.sorted_pix.partition_point(|&p| p <= pix_hi),
        )
    }

    /// A contiguous sub-range `[lo, hi)` of the sorted samples as its own
    /// component (same HEALPix tessellation). Used for sample sharding when
    /// a dataset exceeds an artifact's shard capacity `n`: sorted order is
    /// pixel order, so a slice is a compact sky band and the LUT algebra
    /// keeps working. `perm` entries remain *original* dataset indices.
    pub fn slice(&self, lo: usize, hi: usize) -> SharedComponent {
        assert!(lo <= hi && hi <= self.n_samples());
        SharedComponent {
            healpix: self.healpix.clone(),
            sorted_pix: self.sorted_pix[lo..hi].to_vec(),
            perm: self.perm[lo..hi].to_vec(),
            slon: self.slon[lo..hi].to_vec(),
            slat: self.slat[lo..hi].to_vec(),
            slon64: self.slon64[lo..hi].to_vec(),
            slat64: self.slat64[lo..hi].to_vec(),
            unit_x: self.unit_x[lo..hi].to_vec(),
            unit_y: self.unit_y[lo..hi].to_vec(),
            unit_z: self.unit_z[lo..hi].to_vec(),
            workers: self.workers,
            stats: self.stats.clone(),
        }
    }

    /// Device-staging view of the precomputed unit-vector columns: `[3,
    /// pad_to]` f32 planes (x | y | z), zero-padded past the sample count.
    ///
    /// This is what T2 uploads alongside the raw coordinates, so the device
    /// kernel computes per-pair distances as a squared-chord test on staged
    /// columns instead of re-deriving trig from lon/lat for every
    /// sample-cell pair (the same redundancy elimination the CPU hot path
    /// got in `grid::cpu`). Pad entries are never gathered (`nbr` indices
    /// stay below the shard size) but must be finite for vectorised math.
    pub fn staged_unit_f32(&self, pad_to: usize) -> Vec<f32> {
        let n = self.n_samples();
        assert!(pad_to >= n, "pad_to {pad_to} < {n} samples");
        let mut out = vec![0.0f32; 3 * pad_to];
        // The fill below is serial (per-epoch, off the hot path), so on
        // multi-node hosts with pinned workers pre-fault the planes from the
        // executor instead: stream threads on every node read them for H2D
        // staging, and a serial first write would pile all pages onto the
        // building thread's node. No-op on UMA / `affinity none`.
        crate::util::numa::first_touch_zeroed(&mut out);
        for j in 0..n {
            out[j] = self.unit_x[j] as f32;
            out[pad_to + j] = self.unit_y[j] as f32;
            out[2 * pad_to + j] = self.unit_z[j] as f32;
        }
        out
    }

    /// Permute + transpose every channel into a **lane-padded, sample-major
    /// value matrix**: `row(j)[c] = channels[c][perm[j]]`, rows padded with
    /// zeros to a multiple of `lanes` and backed by a 64-byte-aligned
    /// allocation, so the SIMD accumulation loop needs no tail handling
    /// (pad lanes accumulate exact zeros that are never written out).
    pub fn value_matrix(&self, channels: &[Vec<f32>], lanes: usize, workers: usize) -> ValueMatrix {
        self.value_matrix_range(channels, lanes, workers, 0, self.n_samples())
    }

    /// Tile-local variant of [`SharedComponent::value_matrix`]: materialise
    /// only the sorted-sample sub-range `[lo, hi)` — row `j` of the result
    /// holds sorted sample `lo + j`. The row-band tiled gridder resolves a
    /// band's sample span once ([`SharedComponent::samples_in_pix_range`])
    /// and builds this span-sized matrix instead of the full `n_samples`
    /// one, which is what bounds its value-matrix footprint. Row contents
    /// are bit-identical to the same rows of the full matrix.
    pub fn value_matrix_range(
        &self,
        channels: &[Vec<f32>],
        lanes: usize,
        workers: usize,
        lo: usize,
        hi: usize,
    ) -> ValueMatrix {
        assert!(lo <= hi && hi <= self.n_samples(), "bad sample range [{lo}, {hi})");
        let n = hi - lo;
        let n_ch = channels.len();
        let lanes = lanes.max(1);
        let stride = if n_ch == 0 { 0 } else { n_ch.next_multiple_of(lanes) };
        let mut buf = crate::grid::simd::AlignedF32::zeroed(n * stride);
        if n_ch > 0 && n > 0 {
            let w = DisjointWriter::new(&mut buf[..]);
            let perm = &self.perm[lo..hi];
            let workers = workers.max(1);
            // This fill is the matrix's first write (`alloc_zeroed` maps
            // pages lazily), so the claim granularity doubles as the NUMA
            // placement granularity: with pinned workers on a multi-node
            // host, claim ~page-sized row blocks so pages interleave across
            // the nodes — the blocked accumulation later gathers rows at
            // random from every worker. Otherwise claim adaptively for
            // minimum cursor traffic. Output is identical either way.
            let claim = if crate::util::numa::placement_active() {
                (4096 / (stride * 4).max(1)).max(1)
            } else {
                adaptive_claim_block(n, workers)
            };
            parallel_items_scoped(n, workers, claim, || (), |_, j| {
                let orig = perm[j] as usize;
                let row = unsafe { w.slice(j * stride, n_ch) };
                for (dst, ch) in row.iter_mut().zip(channels) {
                    *dst = ch[orig];
                }
            });
        }
        ValueMatrix { buf, n_ch, stride }
    }

    /// Reorder one channel's value column into the sorted layout, replacing
    /// the contents of `out`. The per-pipeline half of step ③ — parallelised
    /// over sample chunks once the column is large enough to pay for it.
    pub fn permute_channel(&self, values: &[f32], out: &mut Vec<f32>) -> Result<()> {
        if values.len() != self.perm.len() {
            return Err(HegridError::Internal(format!(
                "permute_channel: {} values for {} samples",
                values.len(),
                self.perm.len()
            )));
        }
        let n = self.perm.len();
        out.clear();
        out.resize(n, 0.0);
        let workers = if n >= PAR_PERMUTE_MIN { self.workers } else { 1 };
        let w = DisjointWriter::new(&mut out[..]);
        let perm = &self.perm;
        parallel_chunks(n, workers, |_, s, e| {
            for j in s..e {
                unsafe { w.write(j, values[perm[j] as usize]) };
            }
        });
        Ok(())
    }
}

/// Sample-major channel-value matrix in the sorted layout, rows lane-padded
/// and 64-byte aligned — the operand of the SIMD channel-blocked
/// accumulation (built by [`SharedComponent::value_matrix`]).
#[derive(Debug)]
pub struct ValueMatrix {
    buf: crate::grid::simd::AlignedF32,
    /// Real channels per row (pad columns beyond this are zero).
    pub n_ch: usize,
    /// Row stride in f32s: `n_ch` rounded up to the lane multiple.
    pub stride: usize,
}

impl ValueMatrix {
    /// The full backing slice (`n_samples · stride` f32s).
    pub fn as_slice(&self) -> &[f32] {
        &self.buf
    }

    /// Row of sorted sample `j`, pad columns included.
    pub fn row(&self, j: usize) -> &[f32] {
        &self.buf[j * self.stride..(j + 1) * self.stride]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn random_coords(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = SplitMix64::new(seed);
        let lons: Vec<f64> = (0..n).map(|_| rng.uniform(0.4, 0.6)).collect();
        let lats: Vec<f64> = (0..n).map(|_| rng.uniform(0.6, 0.8)).collect();
        (lons, lats)
    }

    #[test]
    fn build_sorts_by_pixel_and_permutes_consistently() {
        let (lons, lats) = random_coords(5000, 1);
        let sc = SharedComponent::build(&lons, &lats, 0.01, 4).unwrap();
        assert_eq!(sc.n_samples(), 5000);
        assert!(sc.sorted_pix.windows(2).all(|w| w[0] <= w[1]));
        // Each sorted entry's pixel matches its permuted coordinates.
        for j in (0..5000).step_by(97) {
            let i = sc.perm[j] as usize;
            assert_eq!(sc.slon64[j], lons[i]);
            assert_eq!(sc.slat64[j], lats[i]);
            assert_eq!(sc.sorted_pix[j], sc.healpix.ang2pix_radec(lons[i], lats[i]));
        }
        // perm is a permutation.
        let mut seen = vec![false; 5000];
        for &i in &sc.perm {
            assert!(!seen[i as usize]);
            seen[i as usize] = true;
        }
    }

    #[test]
    fn unit_columns_match_recomputation() {
        let (lons, lats) = random_coords(3000, 11);
        let sc = SharedComponent::build(&lons, &lats, 0.02, 4).unwrap();
        for j in (0..3000).step_by(53) {
            let i = sc.perm[j] as usize;
            assert_eq!(sc.unit3(j), crate::healpix::unit_vec(lons[i], lats[i]));
        }
        // Parallel and serial builds agree bit-for-bit.
        let sc1 = SharedComponent::build(&lons, &lats, 0.02, 1).unwrap();
        assert_eq!(sc.perm, sc1.perm);
        assert_eq!(sc.unit_x, sc1.unit_x);
        assert_eq!(sc.unit_y, sc1.unit_y);
        assert_eq!(sc.unit_z, sc1.unit_z);
        assert_eq!(sc.slon64, sc1.slon64);
    }

    #[test]
    fn sample_span_lookup_matches_linear_scan() {
        let (lons, lats) = random_coords(3000, 2);
        let sc = SharedComponent::build(&lons, &lats, 0.02, 4).unwrap();
        let probes = [
            (0u64, 0u64),
            (sc.sorted_pix[0], sc.sorted_pix[0]),
            (sc.sorted_pix[100], sc.sorted_pix[2000]),
            (sc.sorted_pix[2999], u64::MAX),
        ];
        for (lo, hi) in probes {
            let (a, b) = sc.samples_in_pix_range(lo, hi);
            let expect_a = sc.sorted_pix.iter().filter(|&&p| p < lo).count();
            let expect_b = sc.sorted_pix.iter().filter(|&&p| p <= hi).count();
            assert_eq!((a, b), (expect_a, expect_b));
            assert!(a <= b);
        }
    }

    #[test]
    fn permute_channel_round_trips() {
        let (lons, lats) = random_coords(1000, 3);
        let sc = SharedComponent::build(&lons, &lats, 0.02, 2).unwrap();
        let values: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let mut sorted = Vec::new();
        sc.permute_channel(&values, &mut sorted).unwrap();
        for j in 0..1000 {
            assert_eq!(sorted[j], sc.perm[j] as f32);
        }
        assert!(sc.permute_channel(&values[..10], &mut sorted).is_err());
    }

    #[test]
    fn staged_unit_columns_match_precomputed_vectors() {
        let (lons, lats) = random_coords(500, 21);
        let sc = SharedComponent::build(&lons, &lats, 0.02, 2).unwrap();
        let pad = 640;
        let staged = sc.staged_unit_f32(pad);
        assert_eq!(staged.len(), 3 * pad);
        for j in (0..500).step_by(37) {
            assert_eq!(staged[j], sc.unit_x[j] as f32);
            assert_eq!(staged[pad + j], sc.unit_y[j] as f32);
            assert_eq!(staged[2 * pad + j], sc.unit_z[j] as f32);
        }
        // Padding is finite zeros.
        assert!(staged[500..pad].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn value_matrix_pads_rows_to_lane_multiples() {
        let (lons, lats) = random_coords(200, 31);
        let sc = SharedComponent::build(&lons, &lats, 0.02, 2).unwrap();
        let channels: Vec<Vec<f32>> =
            (0..5).map(|c| (0..200).map(|i| (c * 1000 + i) as f32).collect()).collect();
        for lanes in [1usize, 2, 4] {
            let vm = sc.value_matrix(&channels, lanes, 2);
            assert_eq!(vm.n_ch, 5);
            assert_eq!(vm.stride, 5usize.next_multiple_of(lanes));
            assert_eq!(vm.stride % lanes, 0);
            assert_eq!(vm.as_slice().len(), 200 * vm.stride);
            for j in (0..200).step_by(17) {
                let row = vm.row(j);
                let orig = sc.perm[j] as usize;
                for (c, ch) in channels.iter().enumerate() {
                    assert_eq!(row[c], ch[orig]);
                }
                assert!(row[5..].iter().all(|&v| v == 0.0), "pad lanes stay zero");
            }
        }
        // Degenerate shapes.
        let empty = sc.value_matrix(&[], 4, 2);
        assert_eq!((empty.n_ch, empty.stride, empty.as_slice().len()), (0, 0, 0));
    }

    #[test]
    fn value_matrix_range_matches_full_matrix_rows() {
        let (lons, lats) = random_coords(300, 41);
        let sc = SharedComponent::build(&lons, &lats, 0.02, 2).unwrap();
        let channels: Vec<Vec<f32>> =
            (0..3).map(|c| (0..300).map(|i| (c * 1000 + i) as f32).collect()).collect();
        let full = sc.value_matrix(&channels, 4, 2);
        for (lo, hi) in [(0usize, 300usize), (17, 203), (100, 100), (299, 300)] {
            let sub = sc.value_matrix_range(&channels, 4, 2, lo, hi);
            assert_eq!(sub.stride, full.stride);
            assert_eq!(sub.as_slice().len(), (hi - lo) * full.stride);
            for j in lo..hi {
                assert_eq!(sub.row(j - lo), full.row(j), "row {j} of [{lo}, {hi})");
            }
        }
    }

    #[test]
    fn resolution_controls_nside() {
        let (lons, lats) = random_coords(100, 4);
        let coarse = SharedComponent::build(&lons, &lats, 0.1, 2).unwrap();
        let fine = SharedComponent::build(&lons, &lats, 0.001, 2).unwrap();
        assert!(fine.healpix.nside() > coarse.healpix.nside());
    }

    #[test]
    fn empty_input_ok() {
        let sc = SharedComponent::build(&[], &[], 0.01, 4).unwrap();
        assert_eq!(sc.n_samples(), 0);
        assert_eq!(sc.samples_in_pix_range(0, u64::MAX), (0, 0));
    }

    #[test]
    fn slice_preserves_invariants() {
        let (lons, lats) = random_coords(2000, 9);
        let sc = SharedComponent::build(&lons, &lats, 0.02, 4).unwrap();
        let sub = sc.slice(500, 1500);
        assert_eq!(sub.n_samples(), 1000);
        assert!(sub.sorted_pix.windows(2).all(|w| w[0] <= w[1]));
        for j in (0..1000).step_by(73) {
            let i = sub.perm[j] as usize;
            assert_eq!(sub.slon64[j], lons[i]);
            assert_eq!(sub.sorted_pix[j], sc.sorted_pix[500 + j]);
            assert_eq!(sub.unit3(j), sc.unit3(500 + j));
        }
        // Span lookup agrees with the parent's, shifted.
        let (a, b) = sub.samples_in_pix_range(sub.sorted_pix[0], sub.sorted_pix[999]);
        assert_eq!((a, b), (0, 1000));
    }

    #[test]
    fn stats_are_populated() {
        let (lons, lats) = random_coords(10_000, 5);
        let sc = SharedComponent::build(&lons, &lats, 0.01, 4).unwrap();
        assert_eq!(sc.stats.n_samples, 10_000);
        assert_eq!(sc.stats.nside, sc.healpix.nside());
        assert!(sc.stats.total() > Duration::ZERO);
    }
}
