//! Interferometric uv-plane gridding: convolutional placement of
//! per-baseline, per-channel complex visibilities onto a regular uv grid.
//!
//! The sky-plane pipeline grids *real* single-dish samples by sky
//! coordinates; this module grids *complex* interferometric visibilities by
//! baseline coordinates, the accumulate core of imaging stacks from W-
//! stacking to IDG. Per channel, a baseline (u, v) in metres scales to
//! wavelengths by ν/c, lands on the grid in units of
//! [`UvGridSpec::cell_wavelengths`], and deposits its visibility through a
//! separable 1-D convolution kernel ([`UvKernel`]) evaluated from a
//! precomputed oversampled lookup table. With [`UvGridder::with_hermitian`]
//! (the default), every sample additionally deposits its complex conjugate
//! at (−u, −v) — V(−u,−v) = V*(u,v) for a real sky — so the grid is
//! hermitian by construction.
//!
//! ## Bit-identity contract
//!
//! The optimized path is a gather: per output cell, candidate placements
//! come from per-row lists built in ascending placement order, weights are
//! looked up from the shared kernel table, zero-weight candidates are
//! skipped, and the surviving `(weight, placement)` pairs feed one
//! [`crate::grid::simd::SimdBackend::accumulate_contribs`] call over the
//! lane-padded value rows `[re, im, 1.0, 0.0]`. The brute-force oracle
//! ([`UvGridder::grid_oracle`]) sweeps *every* placement per cell with
//! literally the same weight lookups, the same skip conditions, and the
//! scalar backend's serial `+= w * v as f64` arithmetic — so the two paths
//! see an identical contributor sequence per cell and agree **bit for
//! bit**, for every worker count, forced ISA, and tile height. The
//! equivalence suite (`rust/tests/uv_equivalence.rs`) and the seeded
//! property tests (`testkit::uv`) pin this.
//!
//! ## Memory
//!
//! [`UvGridder::with_tile_rows`] bounds the per-band working set (candidate
//! lists) by sweeping the grid in row bands of the given height, mirroring
//! the sky-plane tiled reduce; the output planes themselves are always
//! materialized in full. Banding never changes results — the per-cell
//! gather is independent of band boundaries.

use crate::grid::simd::{AlignedF32, SimdIsa};
use crate::util::error::{HegridError, Result};
use crate::util::threads::{
    adaptive_claim_block, default_parallelism, parallel_items_scoped, DisjointWriter,
};

/// Speed of light in m/s — converts baseline metres to wavelengths.
pub const SPEED_OF_LIGHT_M_S: f64 = 299_792_458.0;

/// Lane-padded planes per placement in the value matrix: re, im, unit
/// weight, pad. A multiple of every backend's lane width (1, 2, 4).
const LANES: usize = 4;

/// The separable kernel families of the uv gridder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UvKernelType {
    /// `exp(-x² / 2σ²)`, σ in cells, truncated at the support radius.
    Gaussian,
    /// Prolate spheroidal wave function (Schwab's m=6, α=1 rational
    /// approximation), the anti-aliasing kernel of classic imagers; zero at
    /// the support edge by construction.
    Spheroidal,
}

impl UvKernelType {
    pub fn from_name(s: &str) -> Result<UvKernelType> {
        match s {
            "gaussian" => Ok(UvKernelType::Gaussian),
            "spheroidal" => Ok(UvKernelType::Spheroidal),
            other => Err(HegridError::Config(format!(
                "unknown uv kernel type '{other}' (expected gaussian|spheroidal)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            UvKernelType::Gaussian => "gaussian",
            UvKernelType::Spheroidal => "spheroidal",
        }
    }
}

/// Schwab's rational approximation of the 0-order prolate spheroidal wave
/// function (support m=6, α=1), as used by classic gridders. `eta` is the
/// fractional distance |x|/support in [0, 1]; the returned value includes
/// the (1−η²) factor that makes the *gridding* function, and is exactly 0
/// at η ≥ 1.
fn spheroidal(eta: f64) -> f64 {
    const P0: [f64; 5] = [8.203343e-2, -3.644705e-1, 6.278660e-1, -5.335581e-1, 2.312756e-1];
    const P1: [f64; 5] = [4.028559e-3, -3.697768e-2, 1.021332e-1, -1.201436e-1, 6.412774e-2];
    const Q0: [f64; 3] = [1.0, 8.212018e-1, 2.078043e-1];
    const Q1: [f64; 3] = [1.0, 9.599102e-1, 2.918724e-1];
    if eta >= 1.0 {
        return 0.0;
    }
    let eta2 = eta * eta;
    let (p, q, x0) = if eta < 0.75 {
        (&P0, &Q0, 0.5625) // 0.75²
    } else {
        (&P1, &Q1, 1.0)
    };
    let d = eta2 - x0;
    let top = (((p[4] * d + p[3]) * d + p[2]) * d + p[1]) * d + p[0];
    let bot = (q[2] * d + q[1]) * d + q[0];
    (1.0 - eta2) * (top / bot)
}

/// A separable 1-D convolution kernel backed by a precomputed oversampled
/// lookup table: `table[i] = k(i / oversample)` for `i` in
/// `0..=support*oversample`.
///
/// [`UvKernel::weight_1d`] rounds the query distance to the nearest table
/// sample (half-up, exact in float for non-negative arguments) and returns
/// 0 past the table end — so the *table is the kernel*: the optimized path
/// and the oracle share it, which is what makes their weights identical to
/// the bit rather than merely close.
#[derive(Clone, Debug)]
pub struct UvKernel {
    kind: UvKernelType,
    support: usize,
    oversample: usize,
    table: Vec<f64>,
}

impl UvKernel {
    /// Build the lookup table. `sigma_cells` is only meaningful for
    /// [`UvKernelType::Gaussian`] (ignored by the spheroidal family).
    pub fn new(
        kind: UvKernelType,
        support: usize,
        oversample: usize,
        sigma_cells: f64,
    ) -> Result<UvKernel> {
        if support == 0 || support > 64 {
            return Err(HegridError::Config(format!(
                "uv kernel support must be in 1..=64, got {support}"
            )));
        }
        if oversample == 0 || oversample > 65_536 {
            return Err(HegridError::Config(format!(
                "uv kernel oversample must be in 1..=65536, got {oversample}"
            )));
        }
        if kind == UvKernelType::Gaussian && !(sigma_cells > 0.0 && sigma_cells.is_finite()) {
            return Err(HegridError::Config(format!(
                "uv gaussian kernel sigma must be finite and > 0, got {sigma_cells}"
            )));
        }
        let n = support * oversample + 1;
        let mut table = Vec::with_capacity(n);
        for i in 0..n {
            let x = i as f64 / oversample as f64;
            table.push(match kind {
                UvKernelType::Gaussian => (-(x * x) / (2.0 * sigma_cells * sigma_cells)).exp(),
                UvKernelType::Spheroidal => spheroidal(x / support as f64),
            });
        }
        Ok(UvKernel { kind, support, oversample, table })
    }

    pub fn kind(&self) -> UvKernelType {
        self.kind
    }

    pub fn support(&self) -> usize {
        self.support
    }

    pub fn oversample(&self) -> usize {
        self.oversample
    }

    pub fn table(&self) -> &[f64] {
        &self.table
    }

    /// Kernel weight at signed cell distance `d`: nearest table sample, 0
    /// past the table (|d| ≥ support + 0.5/oversample).
    #[inline]
    pub fn weight_1d(&self, d: f64) -> f64 {
        let x = d.abs() * self.oversample as f64;
        let i = (x + 0.5) as usize;
        if i >= self.table.len() {
            0.0
        } else {
            self.table[i]
        }
    }

    /// A footprint radius (in cells) guaranteed to contain every nonzero
    /// weight: the table ends at support + 0.5/oversample < support + 1.
    fn radius(&self) -> f64 {
        self.support as f64 + 1.0
    }
}

/// Geometry of the output uv grid. The grid origin (u = v = 0) sits at
/// pixel `(n_u/2, n_v/2)`; axis `u` is the fast (contiguous) axis.
#[derive(Clone, Debug, PartialEq)]
pub struct UvGridSpec {
    pub n_u: usize,
    pub n_v: usize,
    /// Cell size in wavelengths per pixel.
    pub cell_wavelengths: f64,
}

impl UvGridSpec {
    pub fn new(n_u: usize, n_v: usize, cell_wavelengths: f64) -> UvGridSpec {
        UvGridSpec { n_u, n_v, cell_wavelengths }
    }

    pub fn n_cells(&self) -> usize {
        self.n_u * self.n_v
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_u == 0 || self.n_v == 0 {
            return Err(HegridError::Config(format!(
                "uv grid must be non-empty, got {}x{}",
                self.n_u, self.n_v
            )));
        }
        if !(self.cell_wavelengths > 0.0 && self.cell_wavelengths.is_finite()) {
            return Err(HegridError::Config(format!(
                "uv cell size must be finite and > 0, got {}",
                self.cell_wavelengths
            )));
        }
        Ok(())
    }
}

/// An in-memory visibility set: per-sample baseline coordinates (metres)
/// and weights, shared across channels, plus per-channel complex
/// visibilities indexed `[channel][sample]`.
#[derive(Clone, Debug, Default)]
pub struct UvDataset {
    /// Baseline u coordinate per sample, metres.
    pub u_m: Vec<f64>,
    /// Baseline v coordinate per sample, metres.
    pub v_m: Vec<f64>,
    /// Statistical weight per sample (shared by all channels).
    pub weights: Vec<f32>,
    /// Channel centre frequencies, Hz.
    pub freqs_hz: Vec<f64>,
    /// Visibility real parts, `[n_channels][n_samples]`.
    pub re: Vec<Vec<f32>>,
    /// Visibility imaginary parts, `[n_channels][n_samples]`.
    pub im: Vec<Vec<f32>>,
}

impl UvDataset {
    pub fn n_samples(&self) -> usize {
        self.u_m.len()
    }

    pub fn n_channels(&self) -> usize {
        self.freqs_hz.len()
    }

    /// Shape and finiteness checks: consistent lengths, positive finite
    /// frequencies, NaN/inf-free coordinates, weights, and visibilities.
    pub fn validate(&self) -> Result<()> {
        let n = self.u_m.len();
        if self.v_m.len() != n || self.weights.len() != n {
            return Err(HegridError::Format(format!(
                "uv dataset sample arrays disagree: u={} v={} w={}",
                n,
                self.v_m.len(),
                self.weights.len()
            )));
        }
        let n_ch = self.freqs_hz.len();
        if self.re.len() != n_ch || self.im.len() != n_ch {
            return Err(HegridError::Format(format!(
                "uv dataset channel arrays disagree: freqs={} re={} im={}",
                n_ch,
                self.re.len(),
                self.im.len()
            )));
        }
        for c in 0..n_ch {
            if self.re[c].len() != n || self.im[c].len() != n {
                return Err(HegridError::Format(format!(
                    "uv dataset channel {c} visibility length mismatch: re={} im={} samples={n}",
                    self.re[c].len(),
                    self.im[c].len()
                )));
            }
            if !(self.freqs_hz[c] > 0.0 && self.freqs_hz[c].is_finite()) {
                return Err(HegridError::Format(format!(
                    "uv dataset channel {c} frequency must be finite and > 0, got {}",
                    self.freqs_hz[c]
                )));
            }
            if self.re[c].iter().chain(&self.im[c]).any(|v| !v.is_finite()) {
                return Err(HegridError::Format(format!(
                    "uv dataset channel {c} has non-finite visibilities"
                )));
            }
        }
        if self.u_m.iter().chain(&self.v_m).any(|v| !v.is_finite()) {
            return Err(HegridError::Format("uv dataset has non-finite baselines".into()));
        }
        if self.weights.iter().any(|w| !w.is_finite()) {
            return Err(HegridError::Format("uv dataset has non-finite weights".into()));
        }
        Ok(())
    }
}

/// One channel's gridded planes, each `n_v * n_u` row-major (`u` fast).
/// The planes are **unnormalized** kernel-weighted sums; divide `re`/`im`
/// by `wsum` (where nonzero) for weighted means.
#[derive(Clone, Debug, PartialEq)]
pub struct UvPlanes {
    pub re: Vec<f64>,
    pub im: Vec<f64>,
    /// Kernel-weighted sum of sample weights per cell.
    pub wsum: Vec<f64>,
}

/// Gridded planes per channel plus the exact deposit accounting the weight
/// conservation property pins.
#[derive(Clone, Debug, PartialEq)]
pub struct UvResult {
    pub planes: Vec<UvPlanes>,
    /// Per channel: the serial, placement-order sum of the weights of every
    /// non-clipped placement (each hermitian conjugate counts as its own
    /// placement). Exactly reproducible by folding the input weights in the
    /// same order — bit-equal, not approximately equal.
    pub deposited: Vec<f64>,
    /// Per channel: placements whose rounded centre cell fell outside the
    /// grid, dropped whole (no partial footprints are deposited for them).
    pub clipped: Vec<usize>,
}

/// One kernel placement: grid-frame centre, lane values, and the f64
/// contributor weight (sample weight; kernel weights multiply in later).
struct Placement {
    up: f64,
    vp: f64,
    re: f32,
    im: f32,
    w: f64,
}

/// The uv gridder. Construct with a grid and a kernel, adjust with the
/// builder methods, then call [`UvGridder::grid`] (optimized) or
/// [`UvGridder::grid_oracle`] (brute-force direct sum, for differential
/// testing — identical results, O(cells × placements) time).
#[derive(Clone)]
pub struct UvGridder {
    spec: UvGridSpec,
    kernel: UvKernel,
    workers: usize,
    simd: SimdIsa,
    tile_rows: usize,
    hermitian: bool,
}

impl UvGridder {
    pub fn new(spec: UvGridSpec, kernel: UvKernel) -> UvGridder {
        UvGridder { spec, kernel, workers: 0, simd: SimdIsa::Auto, tile_rows: 0, hermitian: true }
    }

    /// Worker threads for the per-band cell sweep; 0 = host parallelism.
    /// Results are bit-identical for every worker count.
    pub fn with_workers(mut self, workers: usize) -> UvGridder {
        self.workers = workers;
        self
    }

    /// Force a SIMD backend; unavailable ISAs degrade to scalar with a
    /// warning (same semantics as the sky-plane gridder).
    pub fn with_simd(mut self, isa: SimdIsa) -> UvGridder {
        self.simd = isa;
        self
    }

    /// Row-band height of the tiled sweep; 0 = whole grid in one band.
    /// Bounds the per-band candidate-list working set. Bit-identical to
    /// untiled for every value.
    pub fn with_tile_rows(mut self, rows: usize) -> UvGridder {
        self.tile_rows = rows;
        self
    }

    /// Also deposit each sample's complex conjugate at (−u, −v). On by
    /// default; disable to grid exactly the samples given.
    pub fn with_hermitian(mut self, hermitian: bool) -> UvGridder {
        self.hermitian = hermitian;
        self
    }

    pub fn spec(&self) -> &UvGridSpec {
        &self.spec
    }

    pub fn kernel(&self) -> &UvKernel {
        &self.kernel
    }

    /// Grid every channel with the optimized gather path.
    pub fn grid(&self, ds: &UvDataset) -> Result<UvResult> {
        self.run(ds, false)
    }

    /// Grid every channel with the brute-force direct-sum oracle: every
    /// placement is considered for every cell, serially, with the scalar
    /// accumulate arithmetic. Bit-identical to [`UvGridder::grid`].
    pub fn grid_oracle(&self, ds: &UvDataset) -> Result<UvResult> {
        self.run(ds, true)
    }

    /// Channel `c`'s placement stream, in the canonical order both paths
    /// share: samples ascending; per sample the direct placement, then
    /// (with hermitian on) the conjugate at the mirrored coordinates with
    /// negated imaginary part (f32 negation is exact). Placements whose
    /// rounded centre cell is off-grid are clipped — counted, not
    /// deposited. Returns (placements, deposited, clipped).
    fn placements(&self, ds: &UvDataset, c: usize) -> (Vec<Placement>, f64, usize) {
        // Pixel position: up = u[m]·(ν/c)/cell + n_u/2. The oracle shares
        // this code path, so the expression is definitionally correct —
        // the differential tests compare placements, not coordinates.
        let scale = ds.freqs_hz[c] / SPEED_OF_LIGHT_M_S / self.spec.cell_wavelengths;
        let cu = (self.spec.n_u / 2) as f64;
        let cv = (self.spec.n_v / 2) as f64;
        let per_sample = if self.hermitian { 2 } else { 1 };
        let mut out = Vec::with_capacity(ds.n_samples() * per_sample);
        let mut deposited = 0.0f64;
        let mut clipped = 0usize;
        for s in 0..ds.n_samples() {
            let du = ds.u_m[s] * scale;
            let dv = ds.v_m[s] * scale;
            let w = ds.weights[s] as f64;
            let re = ds.re[c][s];
            let im = ds.im[c][s];
            let cands = [(cu + du, cv + dv, im), (cu - du, cv - dv, -im)];
            for &(up, vp, pim) in &cands[..per_sample] {
                let iu0 = up.round();
                let iv0 = vp.round();
                let off_u = iu0 < 0.0 || iu0 >= self.spec.n_u as f64;
                let off_v = iv0 < 0.0 || iv0 >= self.spec.n_v as f64;
                if off_u || off_v {
                    clipped += 1;
                    continue;
                }
                deposited += w;
                out.push(Placement { up, vp, re, im: pim, w });
            }
        }
        (out, deposited, clipped)
    }

    fn run(&self, ds: &UvDataset, oracle: bool) -> Result<UvResult> {
        self.spec.validate()?;
        ds.validate()?;
        let backend = self.simd.resolve();
        let n_u = self.spec.n_u;
        let n_v = self.spec.n_v;
        let n_cells = n_u * n_v;
        let workers = if self.workers == 0 { default_parallelism() } else { self.workers };
        let rows_per_band = if self.tile_rows == 0 { n_v } else { self.tile_rows.min(n_v) };
        let rad = self.kernel.radius();
        let mut planes = Vec::with_capacity(ds.n_channels());
        let mut deposited = Vec::with_capacity(ds.n_channels());
        let mut clipped = Vec::with_capacity(ds.n_channels());
        for c in 0..ds.n_channels() {
            let (pls, dep, clip) = self.placements(ds, c);
            deposited.push(dep);
            clipped.push(clip);
            // Lane-padded value rows [re, im, 1.0, 0.0]. The sample weight
            // rides in the f64 contributor weight, not here — an f32
            // product would round before the accumulate.
            let mut vals = AlignedF32::zeroed(pls.len() * LANES);
            for (p, pl) in pls.iter().enumerate() {
                vals[p * LANES] = pl.re;
                vals[p * LANES + 1] = pl.im;
                vals[p * LANES + 2] = 1.0;
            }
            let mut pre = vec![0.0f64; n_cells];
            let mut pim = vec![0.0f64; n_cells];
            let mut pws = vec![0.0f64; n_cells];
            if oracle {
                for iv in 0..n_v {
                    for iu in 0..n_u {
                        let mut acc = [0.0f64; LANES];
                        for pl in &pls {
                            let wv = self.kernel.weight_1d(pl.vp - iv as f64);
                            if wv == 0.0 {
                                continue;
                            }
                            let wu = self.kernel.weight_1d(pl.up - iu as f64);
                            if wu == 0.0 {
                                continue;
                            }
                            let kw = (wu * wv) * pl.w;
                            // Lane-for-lane the scalar backend's
                            // `+= w * v as f64`, placements ascending.
                            acc[0] += kw * pl.re as f64;
                            acc[1] += kw * pl.im as f64;
                            acc[2] += kw * 1.0f32 as f64;
                        }
                        let g = iv * n_u + iu;
                        pre[g] = acc[0];
                        pim[g] = acc[1];
                        pws[g] = acc[2];
                    }
                }
            } else {
                let wre = DisjointWriter::new(&mut pre);
                let wim = DisjointWriter::new(&mut pim);
                let wws = DisjointWriter::new(&mut pws);
                let mut r0 = 0usize;
                while r0 < n_v {
                    let r1 = (r0 + rows_per_band).min(n_v);
                    let band_rows = r1 - r0;
                    // Per-row candidate lists (CSR), placement ids ascending
                    // within each row: iterate placements in order, append
                    // each to every band row its footprint can reach.
                    let ranges: Vec<(usize, usize)> = pls
                        .iter()
                        .map(|pl| {
                            let lo = (pl.vp - rad).ceil().max(r0 as f64);
                            let hi = (pl.vp + rad).floor().min(r1 as f64 - 1.0);
                            if lo > hi {
                                (1, 0)
                            } else {
                                (lo as usize, hi as usize)
                            }
                        })
                        .collect();
                    let mut offs = vec![0usize; band_rows + 1];
                    for &(lo, hi) in &ranges {
                        if lo > hi {
                            continue;
                        }
                        for r in lo..=hi {
                            offs[r - r0 + 1] += 1;
                        }
                    }
                    for i in 1..offs.len() {
                        offs[i] += offs[i - 1];
                    }
                    let mut csr = vec![0u32; offs[band_rows]];
                    let mut cursor: Vec<usize> = offs[..band_rows].to_vec();
                    for (p, &(lo, hi)) in ranges.iter().enumerate() {
                        if lo > hi {
                            continue;
                        }
                        for r in lo..=hi {
                            let slot = &mut cursor[r - r0];
                            csr[*slot] = p as u32;
                            *slot += 1;
                        }
                    }
                    let band_cells = band_rows * n_u;
                    let cb = adaptive_claim_block(band_cells, workers);
                    parallel_items_scoped(
                        band_cells,
                        workers,
                        cb,
                        Vec::<(f64, u32)>::new,
                        |scratch, cell| {
                            let lr = cell / n_u;
                            let iu = cell % n_u;
                            let iv = r0 + lr;
                            scratch.clear();
                            for &p in &csr[offs[lr]..offs[lr + 1]] {
                                let pl = &pls[p as usize];
                                let wv = self.kernel.weight_1d(pl.vp - iv as f64);
                                if wv == 0.0 {
                                    continue;
                                }
                                let wu = self.kernel.weight_1d(pl.up - iu as f64);
                                if wu == 0.0 {
                                    continue;
                                }
                                scratch.push(((wu * wv) * pl.w, p));
                            }
                            let mut acc = [0.0f64; LANES];
                            backend.accumulate_contribs(&mut acc, scratch, &vals, LANES, 0);
                            let g = iv * n_u + iu;
                            // SAFETY: cell indices of one sweep are unique
                            // and g is in bounds (iv < n_v, iu < n_u).
                            unsafe {
                                wre.write(g, acc[0]);
                                wim.write(g, acc[1]);
                                wws.write(g, acc[2]);
                            }
                        },
                    );
                    r0 = r1;
                }
            }
            planes.push(UvPlanes { re: pre, im: pim, wsum: pws });
        }
        Ok(UvResult { planes, deposited, clipped })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::SplitMix64;

    fn small_dataset(seed: u64, n_samples: usize, n_ch: usize) -> UvDataset {
        let mut rng = SplitMix64::new(seed);
        let mut ds = UvDataset::default();
        // ±150 m at ~1.4 GHz on 50-wavelength cells is ±~14 cells — every
        // placement (and its conjugate) stays on the 48x40 grid.
        for _ in 0..n_samples {
            ds.u_m.push(rng.uniform(-150.0, 150.0));
            ds.v_m.push(rng.uniform(-150.0, 150.0));
            ds.weights.push(rng.uniform(0.1, 2.0) as f32);
        }
        for c in 0..n_ch {
            ds.freqs_hz.push(1.4e9 + c as f64 * 1.0e7);
            let mut re = Vec::new();
            let mut im = Vec::new();
            for _ in 0..n_samples {
                re.push(rng.uniform(-1.0, 1.0) as f32);
                im.push(rng.uniform(-1.0, 1.0) as f32);
            }
            ds.re.push(re);
            ds.im.push(im);
        }
        ds
    }

    fn gridder() -> UvGridder {
        let spec = UvGridSpec::new(48, 40, 50.0);
        let kernel = UvKernel::new(UvKernelType::Gaussian, 3, 64, 1.0).unwrap();
        UvGridder::new(spec, kernel)
    }

    fn assert_planes_bits_eq(a: &UvResult, b: &UvResult) {
        assert_eq!(a.planes.len(), b.planes.len());
        for (pa, pb) in a.planes.iter().zip(&b.planes) {
            for (x, y) in pa.re.iter().zip(&pb.re) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in pa.im.iter().zip(&pb.im) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in pa.wsum.iter().zip(&pb.wsum) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(a.deposited.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
                   b.deposited.iter().map(|d| d.to_bits()).collect::<Vec<_>>());
        assert_eq!(a.clipped, b.clipped);
    }

    #[test]
    fn kernel_lookup_is_nearest_sample() {
        let k = UvKernel::new(UvKernelType::Gaussian, 3, 4, 1.0).unwrap();
        assert_eq!(k.table().len(), 13);
        assert_eq!(k.weight_1d(0.0), k.table()[0]);
        assert_eq!(k.table()[0], 1.0);
        // 0.3 cells * oversample 4 = 1.2 -> nearest index 1; negative
        // distances hit the same sample.
        assert_eq!(k.weight_1d(0.3), k.table()[1]);
        assert_eq!(k.weight_1d(-0.3), k.table()[1]);
        // Half-way rounds up: 0.375 * 4 = 1.5 -> index 2.
        assert_eq!(k.weight_1d(0.375), k.table()[2]);
        // Past the table end the weight is exactly zero.
        assert_eq!(k.weight_1d(3.2), 0.0);
        assert_eq!(k.weight_1d(1.0e9), 0.0);
    }

    #[test]
    fn spheroidal_vanishes_at_support_edge() {
        let k = UvKernel::new(UvKernelType::Spheroidal, 3, 8, 1.0).unwrap();
        assert_eq!(*k.table().last().unwrap(), 0.0);
        assert!(k.table()[0] > 0.0);
        // Strictly decreasing near the centre — a sanity check on the
        // rational approximation's region split.
        assert!(k.table()[1] < k.table()[0]);
        assert!(k.weight_1d(2.9) > 0.0);
    }

    #[test]
    fn optimized_matches_oracle_bitwise() {
        let ds = small_dataset(7, 60, 2);
        let g = gridder().with_workers(3);
        let fast = g.grid(&ds).unwrap();
        let oracle = g.grid_oracle(&ds).unwrap();
        assert_planes_bits_eq(&fast, &oracle);
        // The planes are non-trivial.
        assert!(fast.planes[0].wsum.iter().any(|&w| w > 0.0));
    }

    #[test]
    fn worker_count_and_tiling_are_bit_invariant() {
        let ds = small_dataset(11, 45, 2);
        let base = gridder().with_workers(1).grid(&ds).unwrap();
        for workers in [2, 5] {
            for tile in [0, 3, 7] {
                let r = gridder().with_workers(workers).with_tile_rows(tile).grid(&ds).unwrap();
                assert_planes_bits_eq(&base, &r);
            }
        }
    }

    #[test]
    fn hermitian_equals_explicit_conjugate_samples() {
        // hermitian=true on one sample must equal hermitian=false on the
        // sample plus its explicit conjugate (u,v -> -u,-v; im -> -im):
        // identical placement streams, therefore identical bits.
        let mut ds = small_dataset(13, 1, 1);
        let g = gridder();
        let her = g.grid(&ds).unwrap();
        ds.u_m.push(-ds.u_m[0]);
        ds.v_m.push(-ds.v_m[0]);
        ds.weights.push(ds.weights[0]);
        ds.re[0].push(ds.re[0][0]);
        ds.im[0].push(-ds.im[0][0]);
        let explicit = g.clone().with_hermitian(false).grid(&ds).unwrap();
        assert_planes_bits_eq(&her, &explicit);
    }

    #[test]
    fn off_grid_placements_are_clipped_whole() {
        let mut ds = small_dataset(17, 1, 1);
        // Push the sample far off the grid: both the direct and the
        // conjugate placement clip, nothing is deposited.
        ds.u_m[0] = 1.0e7;
        ds.v_m[0] = 1.0e7;
        let r = gridder().grid(&ds).unwrap();
        assert_eq!(r.clipped[0], 2);
        assert_eq!(r.deposited[0], 0.0);
        assert!(r.planes[0].wsum.iter().all(|&w| w == 0.0));
        let o = gridder().grid_oracle(&ds).unwrap();
        assert_planes_bits_eq(&r, &o);
    }

    #[test]
    fn deposited_is_the_serial_weight_fold() {
        let ds = small_dataset(19, 30, 2);
        let r = gridder().grid(&ds).unwrap();
        // All samples land on-grid for this seed; the exact deposit is the
        // placement-order fold: per sample, direct then conjugate.
        for c in 0..ds.n_channels() {
            assert_eq!(r.clipped[c], 0);
            let mut expect = 0.0f64;
            for s in 0..ds.n_samples() {
                expect += ds.weights[s] as f64;
                expect += ds.weights[s] as f64;
            }
            assert_eq!(expect.to_bits(), r.deposited[c].to_bits());
        }
    }

    #[test]
    fn dataset_validation_rejects_bad_shapes() {
        let mut ds = small_dataset(23, 4, 1);
        ds.v_m.pop();
        assert!(ds.validate().is_err());
        let mut ds = small_dataset(23, 4, 1);
        ds.re[0][1] = f32::NAN;
        assert!(ds.validate().is_err());
        let mut ds = small_dataset(23, 4, 1);
        ds.freqs_hz[0] = -1.0;
        assert!(ds.validate().is_err());
        assert!(small_dataset(23, 4, 1).validate().is_ok());
    }

    #[test]
    fn kernel_and_spec_validation() {
        assert!(UvKernel::new(UvKernelType::Gaussian, 0, 8, 1.0).is_err());
        assert!(UvKernel::new(UvKernelType::Gaussian, 3, 0, 1.0).is_err());
        assert!(UvKernel::new(UvKernelType::Gaussian, 3, 8, 0.0).is_err());
        assert!(UvKernel::new(UvKernelType::Spheroidal, 3, 8, 0.0).is_ok());
        assert!(UvGridSpec::new(0, 4, 1.0).validate().is_err());
        assert!(UvGridSpec::new(4, 4, 0.0).validate().is_err());
        assert!(UvKernelType::from_name("gaussian").is_ok());
        assert!(UvKernelType::from_name("boxcar").is_err());
    }
}
