//! Convolution (weighting) kernels — the `w(...)` of Eq. (1).
//!
//! Three families, matching `python/compile/kernels/gridding.py` bit-for-bit
//! in semantics (the Python oracle `ref.py` and this module are cross-checked
//! by integration tests): `gauss1d` (radially symmetric Gaussian — the
//! cygrid default), `gauss2d` (elliptical Gaussian), and `tapered_sinc`
//! (Gaussian-tapered sinc).

use crate::util::error::{HegridError, Result};

/// Kernel family. String names match the artifact variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvKernelType {
    Gauss1d,
    Gauss2d,
    TaperedSinc,
}

impl ConvKernelType {
    pub fn name(&self) -> &'static str {
        match self {
            ConvKernelType::Gauss1d => "gauss1d",
            ConvKernelType::Gauss2d => "gauss2d",
            ConvKernelType::TaperedSinc => "tapered_sinc",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "gauss1d" => Ok(ConvKernelType::Gauss1d),
            "gauss2d" => Ok(ConvKernelType::Gauss2d),
            "tapered_sinc" => Ok(ConvKernelType::TaperedSinc),
            _ => Err(HegridError::Config(format!("unknown kernel type '{s}'"))),
        }
    }
}

/// A fully-parameterised convolution kernel.
#[derive(Clone, Debug, PartialEq)]
pub struct ConvKernel {
    pub ktype: ConvKernelType,
    /// Primary width σ (rad). For `TaperedSinc` this is the sinc scale.
    pub sigma: f64,
    /// Secondary width (rad): σ_y for `Gauss2d`, taper scale for `TaperedSinc`.
    pub sigma2: f64,
    /// Support (cut-off) radius R (rad); weights are zero beyond it.
    pub support: f64,
}

impl ConvKernel {
    /// Radially symmetric Gaussian with σ = `kernel_sigma_beam`·σ_beam and
    /// support `support_sigma`·σ (cygrid's recommended σ_kernel = 0.5·σ_beam).
    pub fn gauss1d_for_beam_cfg(beam_fwhm_rad: f64, sigma_beam: f64, support_sigma: f64) -> Self {
        let sb = beam_fwhm_rad / (2.0 * (2.0f64.ln() * 2.0).sqrt());
        let sigma = sigma_beam * sb;
        ConvKernel {
            ktype: ConvKernelType::Gauss1d,
            sigma,
            sigma2: sigma,
            support: support_sigma * sigma,
        }
    }

    /// Radially symmetric Gaussian with the default σ = 0.5·σ_beam, R = 3σ.
    /// `beam_deg` is the beam FWHM in degrees.
    pub fn gauss1d_for_beam(beam_deg: f64) -> Self {
        Self::gauss1d_for_beam_cfg(crate::util::deg2rad(beam_deg), 0.5, 3.0)
    }

    /// Elliptical Gaussian.
    pub fn gauss2d(sigma_x: f64, sigma_y: f64, support: f64) -> Self {
        ConvKernel { ktype: ConvKernelType::Gauss2d, sigma: sigma_x, sigma2: sigma_y, support }
    }

    /// Gaussian-tapered sinc (cygrid's high-fidelity option).
    pub fn tapered_sinc(sigma: f64, taper: f64, support: f64) -> Self {
        ConvKernel { ktype: ConvKernelType::TaperedSinc, sigma, sigma2: taper, support }
    }

    /// Build from an engine config + dataset beam.
    pub fn from_config(beam_arcsec: f64, cfg: &crate::config::HegridConfig) -> Result<Self> {
        let ktype = ConvKernelType::from_name(&cfg.kernel_type)?;
        let beam = crate::util::arcsec2rad(beam_arcsec);
        let base = Self::gauss1d_for_beam_cfg(beam, cfg.kernel_sigma_beam, cfg.support_sigma);
        Ok(match ktype {
            ConvKernelType::Gauss1d => base,
            ConvKernelType::Gauss2d => Self::gauss2d(base.sigma, base.sigma, base.support),
            ConvKernelType::TaperedSinc => {
                // cygrid-like defaults: sinc scale ≈ σ/1.5, taper ≈ 2.52·σ.
                Self::tapered_sinc(base.sigma / 1.5, base.sigma * 2.52, base.support)
            }
        })
    }

    /// The `kparam` array shipped to the device kernel; layout documented in
    /// `python/compile/kernels/gridding.py::eval_weight`.
    pub fn kparam(&self) -> [f32; 4] {
        let r2 = (self.support * self.support) as f32;
        match self.ktype {
            ConvKernelType::Gauss1d => {
                [(1.0 / (2.0 * self.sigma * self.sigma)) as f32, r2, 0.0, 0.0]
            }
            ConvKernelType::Gauss2d => [
                (1.0 / (2.0 * self.sigma * self.sigma)) as f32,
                (1.0 / (2.0 * self.sigma2 * self.sigma2)) as f32,
                r2,
                0.0,
            ],
            ConvKernelType::TaperedSinc => {
                [(1.0 / self.sigma) as f32, (1.0 / self.sigma2) as f32, r2, 0.0]
            }
        }
    }

    /// CPU evaluation, identical semantics to the device kernel:
    /// `d2` is the squared angular separation, `dlon_cos` the cos(lat)-scaled
    /// longitude offset, `dlat` the latitude offset (all rad).
    #[inline]
    pub fn weight(&self, d2: f64, dlon_cos: f64, dlat: f64) -> f64 {
        if d2 > self.support * self.support {
            return 0.0;
        }
        match self.ktype {
            ConvKernelType::Gauss1d => (-d2 / (2.0 * self.sigma * self.sigma)).exp(),
            ConvKernelType::Gauss2d => (-(dlon_cos * dlon_cos) / (2.0 * self.sigma * self.sigma)
                - (dlat * dlat) / (2.0 * self.sigma2 * self.sigma2))
                .exp(),
            ConvKernelType::TaperedSinc => {
                let d = d2.sqrt();
                let x = d / self.sigma;
                let sinc = if x.abs() < 1e-12 { 1.0 } else { x.sin() / x };
                let t = d / self.sigma2;
                sinc * (-t * t).exp()
            }
        }
    }

    /// Variant-name fragment used to select artifacts (e.g. `gauss1d`).
    pub fn type_name(&self) -> &'static str {
        self.ktype.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn type_names_round_trip() {
        for t in [ConvKernelType::Gauss1d, ConvKernelType::Gauss2d, ConvKernelType::TaperedSinc] {
            assert_eq!(ConvKernelType::from_name(t.name()).unwrap(), t);
        }
        assert!(ConvKernelType::from_name("boxcar").is_err());
    }

    #[test]
    fn gauss1d_peak_and_halfwidth() {
        let k = ConvKernel::gauss1d_for_beam(0.05);
        assert!((k.weight(0.0, 0.0, 0.0) - 1.0).abs() < 1e-12);
        // w(σ) = exp(-1/2)
        let w = k.weight(k.sigma * k.sigma, k.sigma, 0.0);
        assert!((w - (-0.5f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn support_cutoff_exact() {
        let k = ConvKernel::gauss1d_for_beam(0.05);
        let r2 = k.support * k.support;
        assert!(k.weight(r2 * 1.0001, 0.0, 0.0) == 0.0);
        assert!(k.weight(r2 * 0.9999, 0.0, 0.0) > 0.0);
    }

    #[test]
    fn gauss2d_anisotropy() {
        let k = ConvKernel::gauss2d(0.01, 0.02, 0.1);
        let w_lon = k.weight(1e-4, 0.01, 0.0);
        let w_lat = k.weight(1e-4, 0.0, 0.01);
        assert!(w_lat > w_lon, "wider axis decays slower");
    }

    #[test]
    fn tapered_sinc_matches_numpy_sinc_convention() {
        // np.sinc(x/π) = sin(x)/x — the device kernel uses jnp.sinc(x/π).
        let k = ConvKernel::tapered_sinc(0.01, 0.025, 0.1);
        let d: f64 = 0.015;
        let x = d / 0.01;
        let expect = (x.sin() / x) * (-(d / 0.025) * (d / 0.025)).exp();
        assert!((k.weight(d * d, d, 0.0) - expect).abs() < 1e-12);
        assert!((k.weight(0.0, 0.0, 0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn kparam_layouts() {
        let g1 = ConvKernel::gauss1d_for_beam(0.05);
        let p = g1.kparam();
        assert!((p[0] as f64 - 1.0 / (2.0 * g1.sigma * g1.sigma)).abs() / (p[0] as f64) < 1e-6);
        assert!((p[1] as f64 - g1.support * g1.support).abs() / (p[1] as f64) < 1e-6);

        let g2 = ConvKernel::gauss2d(0.01, 0.02, 0.05);
        let p = g2.kparam();
        assert!(p[0] > p[1], "σx < σy ⇒ coefficient x > y");
        assert!((p[2] as f64 - 0.0025).abs() < 1e-9);

        let ts = ConvKernel::tapered_sinc(0.01, 0.02, 0.05);
        let p = ts.kparam();
        assert!((p[0] - 100.0).abs() < 1e-3);
        assert!((p[1] - 50.0).abs() < 1e-3);
    }

    #[test]
    fn from_config_respects_type() {
        let mut cfg = crate::config::HegridConfig::default();
        for t in ["gauss1d", "gauss2d", "tapered_sinc"] {
            cfg.kernel_type = t.into();
            let k = ConvKernel::from_config(180.0, &cfg).unwrap();
            assert_eq!(k.type_name(), t);
            assert!(k.support > 0.0);
        }
    }

    #[test]
    fn beam_scaling_linear() {
        let a = ConvKernel::gauss1d_for_beam(0.05);
        let b = ConvKernel::gauss1d_for_beam(0.10);
        assert!((b.sigma / a.sigma - 2.0).abs() < 1e-12);
        assert!((b.support / a.support - 2.0).abs() < 1e-12);
    }
}
