//! CPU reference gridder.
//!
//! Implements Eq. (1) directly over the shared LUT in f64 — the correctness
//! oracle for the device path (integration tests pin PJRT output against it)
//! and the computational core of the Cygrid baseline (`baselines::cygrid`).
//!
//! Hot-path design (README "Performance", `benches/cpu_throughput.rs`):
//!
//! * **Trig-free inner loop** — per-sample unit vectors are precomputed in
//!   [`SharedComponent`] (SoA columns) and per-cell trig comes from the
//!   separable row/column tables ([`CellTrig`]); the sample loop is a
//!   squared-chord distance test plus one `asin` for accepted pairs
//!   ([`crate::healpix::chord2_to_arc`]) instead of a four-trig haversine
//!   per pair.
//! * **SIMD lane-per-channel core** — both inner loops run on a
//!   [`SimdBackend`] ([`crate::grid::simd`]): the chord² prefilter is
//!   batched over 2/4 samples per vector with compare-mask compaction into
//!   the candidate list, and the blocked accumulation maps one *channel*
//!   per f64 lane. Because each lane owns its channel, per-channel
//!   accumulation order is exactly the scalar order and every backend is
//!   **bit-identical** to the scalar fallback (forced-ISA tests pin this).
//!   The backend dispatches once per process (AVX2+FMA / NEON / scalar),
//!   overridable via config `simd_isa` / `--simd` / `HEGRID_SIMD`.
//! * **Per-worker scratch** — ring ranges, candidate + contributor lists,
//!   and the channel-block accumulator live in worker-local state reused
//!   across cells ([`parallel_items_scoped`]), replacing the former
//!   per-cell heap allocations; cells are claimed in adaptively sized
//!   blocks ([`adaptive_claim_block`]), not one `fetch_add` each. The sweep
//!   runs on the persistent
//!   [`PipelineExecutor`](crate::util::threads::PipelineExecutor) (parked
//!   workers), so it no longer pays a scoped thread spawn per call.
//! * **Channel-blocked accumulation** — channel values are permuted once
//!   into a lane-padded sample-major [`ValueMatrix`]
//!   (`vals[j·stride + c]`, rows padded to the SIMD width, 64-byte-aligned
//!   allocation), and each cell's contributors are applied `channel_block`
//!   channels at a time: a unit-stride multiply-add loop with no tail
//!   handling whose accumulators stay resident in registers/L1 (the paper's
//!   thread-level data reuse, §4.3.3).
//!
//! Per-channel accumulation order depends only on the LUT walk, so results
//! are **bit-identical** across worker counts, claim blocks, ISAs, and
//! `channel_block` widths (`rust/tests/cpu_blocked_equivalence.rs`).

use std::f64::consts::FRAC_PI_2;

use crate::data::Dataset;
use crate::grid::kernels::ConvKernel;
use crate::grid::prep::{SharedComponent, ValueMatrix};
use crate::grid::simd::{SimdBackend, SimdIsa};
use crate::healpix::{chord2_prefilter_bound, chord2_to_arc, PixRange};
use crate::sky::{CellTrig, GridSpec, SkyMap};
use crate::util::threads::{adaptive_claim_block, parallel_items_scoped, DisjointWriter};

/// Default channel-block width: 8 f64 accumulators (one cache line) — wide
/// enough to amortise the weight evaluation over the FMAs, small enough to
/// stay register-resident.
pub const DEFAULT_CHANNEL_BLOCK: usize = 8;

/// Multi-channel CPU gridder (gather method, Fig 2 right).
#[derive(Clone, Debug)]
pub struct CpuGridder {
    pub spec: GridSpec,
    pub kernel: ConvKernel,
    pub workers: usize,
    /// Channel-block width B of the blocked accumulation
    /// (0 = [`DEFAULT_CHANNEL_BLOCK`]; rounded up to the SIMD lane width
    /// and clamped to the padded channel count).
    pub channel_block: usize,
    /// SIMD ISA request (default: the process-wide dispatched backend).
    pub simd: SimdIsa,
    /// Output-tile height in grid rows (0 = one full-map tile). With `R`
    /// rows per tile the sweep runs band by band: each band's sorted-sample
    /// span is resolved with one ring-band probe + binary search
    /// ([`crate::healpix::Healpix::ring_pix_span`]), only that span's value
    /// matrix is materialised, and the band accumulator is freed once the
    /// band is normalised — so peak working memory is
    /// `O(band span · channels)` instead of `O(n_samples · channels)`.
    /// Results are bit-identical for every tile height (the untiled path is
    /// literally the one-band case).
    pub tile_rows: usize,
}

/// Per-worker scratch reused across cells — the former per-cell heap
/// allocations of the hot loop.
struct CellScratch {
    ranges: Vec<PixRange>,
    /// `(chord², sorted sample index)` accepted by the SIMD prefilter.
    cand: Vec<(f64, u32)>,
    /// `(weight, sorted sample index)` of the current cell's contributors.
    contrib: Vec<(f64, u32)>,
    /// Channel-block accumulators (length = block width).
    local: Vec<f64>,
}

impl CpuGridder {
    pub fn new(spec: GridSpec, kernel: ConvKernel) -> Self {
        CpuGridder {
            spec,
            kernel,
            workers: crate::util::threads::default_parallelism(),
            channel_block: 0,
            simd: SimdIsa::Auto,
            tile_rows: 0,
        }
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn with_channel_block(mut self, block: usize) -> Self {
        self.channel_block = block;
        self
    }

    /// Force a SIMD backend (forced-ISA equivalence tests, `--simd`).
    pub fn with_simd(mut self, isa: SimdIsa) -> Self {
        self.simd = isa;
        self
    }

    /// Grid in row-band tiles of `rows` grid rows (0 = one full-map tile).
    pub fn with_tile_rows(mut self, rows: usize) -> Self {
        self.tile_rows = rows;
        self
    }

    /// Requested block width, rounded up to the lane width and clamped to
    /// the lane-padded channel count (`stride`), so the accumulation loop
    /// never needs a sub-lane tail.
    fn effective_channel_block(&self, stride: usize, lanes: usize) -> usize {
        let b = if self.channel_block == 0 { DEFAULT_CHANNEL_BLOCK } else { self.channel_block };
        b.next_multiple_of(lanes).clamp(lanes, stride.max(lanes))
    }

    /// Grid every channel of `dataset` (builds its own shared component).
    pub fn grid_dataset(&self, dataset: &Dataset) -> Vec<SkyMap> {
        let shared = SharedComponent::for_kernel(&dataset.lons, &dataset.lats, &self.kernel)
            .expect("consistent dataset");
        self.grid_with_shared(&shared, &dataset.channels)
    }

    /// Grid `channels` (original sample order) against a prebuilt component.
    /// All channels are accumulated in a single sweep over the cells, so the
    /// neighbour search cost is paid once — how Cygrid treats multi-channel
    /// data on the CPU.
    pub fn grid_with_shared(&self, shared: &SharedComponent, channels: &[Vec<f32>]) -> Vec<SkyMap> {
        let n_cells = self.spec.n_cells();
        let n_ch = channels.len();
        let backend: &'static dyn SimdBackend = self.simd.resolve();
        let lanes = backend.lanes();
        let rows_per_band = if self.tile_rows == 0 {
            self.spec.nlat
        } else {
            self.tile_rows.min(self.spec.nlat)
        };

        // Separable per-row/per-column cell trig (satellite of the SIMD
        // overhaul: nlat + nlon sin_cos calls instead of nlat·nlon).
        let trig: CellTrig = self.spec.trig();
        // Prefilter radius in squared-chord space, padded so rounding at
        // the boundary always defers to the exact d² cut inside
        // `ConvKernel::weight` (see `chord2_prefilter_bound`).
        let chord2_max = chord2_prefilter_bound(self.kernel.support);

        // Final normalised outputs, filled band by band; only the current
        // band's accumulator and sample-span value matrix are live at once.
        let mut values: Vec<Vec<f64>> = (0..n_ch).map(|_| vec![f64::NAN; n_cells]).collect();
        let mut weights = vec![0.0f64; n_cells];
        let mut band_acc: Vec<f64> = Vec::new();
        let mut band_wsum: Vec<f64> = Vec::new();

        let mut r0 = 0usize;
        while r0 < self.spec.nlat {
            let r1 = (r0 + rows_per_band).min(self.spec.nlat);
            let cell0 = r0 * self.spec.nlon;
            let band_cells = (r1 - r0) * self.spec.nlon;
            // Route the band to its sorted-sample slice: rows are
            // iso-latitude and pixel ids are ring-major in colatitude, so
            // one padded ring-band probe + one binary search bounds every
            // sample any cell of the band can touch (`ring_pix_span` is a
            // superset of the per-cell disc queries below by construction).
            let lat_s = self.spec.cell_center(r0, 0).1;
            let lat_n = self.spec.cell_center(r1 - 1, 0).1;
            let (pix_lo, pix_hi) = shared.healpix.ring_pix_span(
                FRAC_PI_2 - lat_n,
                FRAC_PI_2 - lat_s,
                self.kernel.support,
            );
            let (span_a, span_b) = shared.samples_in_pix_range(pix_lo, pix_hi);

            // Permute + transpose the span into the lane-padded sample-major
            // matrix (vals.row(j - span_a)[c] = channels[c][perm[j]]).
            let vals: ValueMatrix =
                shared.value_matrix_range(channels, lanes, self.workers, span_a, span_b);
            let stride = vals.stride;
            let block = self.effective_channel_block(stride, lanes);

            // acc[ch][band cell], wsum[band cell]; disjoint cells in parallel.
            band_acc.clear();
            band_acc.resize(n_ch * band_cells, 0.0);
            band_wsum.clear();
            band_wsum.resize(band_cells, 0.0);
            {
                let acc_w = DisjointWriter::new(&mut band_acc);
                let wsum_w = DisjointWriter::new(&mut band_wsum);
                let vals = &vals;
                let trig = &trig;
                parallel_items_scoped(
                    band_cells,
                    self.workers,
                    adaptive_claim_block(band_cells, self.workers),
                    || CellScratch {
                        ranges: Vec::new(),
                        cand: Vec::new(),
                        contrib: Vec::new(),
                        local: vec![0.0f64; block],
                    },
                    |scratch, bc| {
                        let cell = cell0 + bc;
                        crate::util::faults::sweep_panic_cell(cell);
                        let (clon, clat) = trig.lonlat(cell);
                        shared.healpix.query_disc_rings_into(
                            FRAC_PI_2 - clat,
                            clon,
                            self.kernel.support,
                            &mut scratch.ranges,
                        );
                        let cu = trig.unit(cell);
                        let clat_cos = trig.cos_lat(cell);
                        // ① batched chord² prefilter with compare-mask
                        // compaction into the candidate list.
                        scratch.cand.clear();
                        for r in &scratch.ranges {
                            let (a, b) = shared.samples_in_pix_range(r.lo, r.hi);
                            backend.chord2_filter(
                                &shared.unit_x[a..b],
                                &shared.unit_y[a..b],
                                &shared.unit_z[a..b],
                                &cu,
                                chord2_max,
                                a as u32,
                                &mut scratch.cand,
                            );
                        }
                        // ② exact weight per candidate (one `asin` per accept).
                        let mut w_tot = 0.0f64;
                        scratch.contrib.clear();
                        for &(c2, j) in &scratch.cand {
                            let d = chord2_to_arc(c2);
                            let j = j as usize;
                            let w = self.kernel.weight(
                                d * d,
                                (shared.slon64[j] - clon) * clat_cos,
                                shared.slat64[j] - clat,
                            );
                            if w != 0.0 {
                                w_tot += w;
                                debug_assert!(
                                    (span_a..span_b).contains(&j),
                                    "contributor {j} outside band span [{span_a}, {span_b})"
                                );
                                scratch.contrib.push((w, (j - span_a) as u32));
                            }
                        }
                        unsafe { wsum_w.write(bc, w_tot) };
                        // ③ blocked lane-per-channel accumulation: B
                        // accumulators swept over the contributor list,
                        // unit-stride in the lane-padded rows — no tail
                        // handling (pad lanes accumulate exact zeros that
                        // are never written out).
                        let mut c0 = 0;
                        while c0 < n_ch {
                            let wb = block.min(stride - c0);
                            let local = &mut scratch.local[..wb];
                            local.fill(0.0);
                            backend.accumulate_contribs(
                                local,
                                &scratch.contrib,
                                vals.as_slice(),
                                stride,
                                c0,
                            );
                            for (k, &sum) in local.iter().enumerate().take(n_ch - c0) {
                                unsafe { acc_w.write((c0 + k) * band_cells + bc, sum) };
                            }
                            c0 += wb;
                        }
                    },
                );
            }
            // Normalise the finished band straight into the output maps
            // (same `acc / wsum` arithmetic as `SkyMap::from_accumulators`).
            for (c, out_ch) in values.iter_mut().enumerate() {
                let row = &band_acc[c * band_cells..(c + 1) * band_cells];
                let out = &mut out_ch[cell0..cell0 + band_cells];
                for ((o, &a), &w) in out.iter_mut().zip(row).zip(&band_wsum) {
                    if w > 0.0 {
                        *o = a / w;
                    }
                }
            }
            weights[cell0..cell0 + band_cells].copy_from_slice(&band_wsum);
            r0 = r1;
        }
        values
            .into_iter()
            .map(|v| {
                SkyMap::from_parts(self.spec.clone(), v, weights.clone())
                    .expect("accumulator sizes consistent")
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::healpix::{ang_dist_vec, unit_vec};
    use crate::sim::SimConfig;
    use crate::util::SplitMix64;

    fn small_setup() -> (GridSpec, ConvKernel) {
        (GridSpec::centered(30.0, 41.0, 12, 6, 0.25), ConvKernel::gauss1d_for_beam(0.5))
    }

    /// Brute-force Eq. (1) without any LUT. Uses the same per-pair distance
    /// helper as the gridder — the oracle pins the LUT walk, the blocking,
    /// and the parallel machinery, while the metric itself is pinned against
    /// the haversine in `healpix::tests::chord_distance_matches_haversine`.
    fn brute_force(
        spec: &GridSpec,
        kernel: &ConvKernel,
        lons: &[f64],
        lats: &[f64],
        values: &[f32],
    ) -> Vec<f64> {
        let mut out = vec![f64::NAN; spec.n_cells()];
        for cell in 0..spec.n_cells() {
            let (clon, clat) = spec.cell_center_flat(cell);
            let cu = unit_vec(clon, clat);
            let mut acc = 0.0;
            let mut w_tot = 0.0;
            for j in 0..lons.len() {
                let d = ang_dist_vec(&unit_vec(lons[j], lats[j]), &cu);
                let w =
                    kernel.weight(d * d, (lons[j] - clon) * clat.cos(), lats[j] - clat);
                if w != 0.0 {
                    acc += w * values[j] as f64;
                    w_tot += w;
                }
            }
            if w_tot > 0.0 {
                out[cell] = acc / w_tot;
            }
        }
        out
    }

    #[test]
    fn matches_brute_force_exactly() {
        let (spec, kernel) = small_setup();
        let mut rng = SplitMix64::new(10);
        let (lon_lo, lon_hi, lat_lo, lat_hi) = spec.bounds();
        let n = 600;
        let lons: Vec<f64> = (0..n).map(|_| rng.uniform(lon_lo, lon_hi)).collect();
        let lats: Vec<f64> = (0..n).map(|_| rng.uniform(lat_lo, lat_hi)).collect();
        let values: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

        let gridder = CpuGridder::new(spec.clone(), kernel.clone());
        let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
        let maps = gridder.grid_with_shared(&shared, &[values.clone()]);
        let expect = brute_force(&spec, &kernel, &lons, &lats, &values);
        for cell in 0..spec.n_cells() {
            let got = maps[0].values()[cell];
            let want = expect[cell];
            if want.is_nan() {
                assert!(got.is_nan(), "cell {cell}");
            } else {
                assert!(
                    (got - want).abs() <= 1e-12 * want.abs().max(1.0),
                    "cell {cell}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let (spec, kernel) = small_setup();
        let d = SimConfig::quick_preset().generate();
        let shared = SharedComponent::for_kernel(&d.lons, &d.lats, &kernel).unwrap();
        let a = CpuGridder::new(spec.clone(), kernel.clone())
            .with_workers(1)
            .grid_with_shared(&shared, &d.channels);
        let b = CpuGridder::new(spec, kernel).with_workers(8).grid_with_shared(&shared, &d.channels);
        for (ma, mb) in a.iter().zip(&b) {
            for (va, vb) in ma.values().iter().zip(mb.values()) {
                assert!((va.is_nan() && vb.is_nan()) || va == vb);
            }
        }
    }

    #[test]
    fn channel_block_width_does_not_change_results() {
        let (spec, kernel) = small_setup();
        let d = SimConfig::quick_preset().generate();
        let shared = SharedComponent::for_kernel(&d.lons, &d.lats, &kernel).unwrap();
        let base = CpuGridder::new(spec.clone(), kernel.clone())
            .with_channel_block(1)
            .grid_with_shared(&shared, &d.channels);
        for block in [0usize, 3, d.n_channels(), 64] {
            let m = CpuGridder::new(spec.clone(), kernel.clone())
                .with_channel_block(block)
                .grid_with_shared(&shared, &d.channels);
            for (ma, mb) in base.iter().zip(&m) {
                for (va, vb) in ma.values().iter().zip(mb.values()) {
                    assert_eq!(va.to_bits(), vb.to_bits(), "block {block}");
                }
            }
        }
    }

    #[test]
    fn tile_rows_do_not_change_results() {
        let (spec, kernel) = small_setup();
        let d = SimConfig::quick_preset().generate();
        let shared = SharedComponent::for_kernel(&d.lons, &d.lats, &kernel).unwrap();
        let base =
            CpuGridder::new(spec.clone(), kernel.clone()).grid_with_shared(&shared, &d.channels);
        for rows in [1usize, 2, 5, spec.nlat, spec.nlat * 3] {
            let m = CpuGridder::new(spec.clone(), kernel.clone())
                .with_tile_rows(rows)
                .grid_with_shared(&shared, &d.channels);
            for (ma, mb) in base.iter().zip(&m) {
                for (va, vb) in ma.values().iter().zip(mb.values()) {
                    assert!(
                        (va.is_nan() && vb.is_nan()) || va.to_bits() == vb.to_bits(),
                        "tile_rows {rows}: {va} != {vb}"
                    );
                }
                for (wa, wb) in ma.weights().iter().zip(mb.weights()) {
                    assert_eq!(wa.to_bits(), wb.to_bits(), "tile_rows {rows}");
                }
            }
        }
    }

    #[test]
    fn grid_dataset_covers_field() {
        let d = SimConfig::quick_preset().generate();
        let spec = GridSpec::for_field(
            d.meta.center_deg.0,
            d.meta.center_deg.1,
            d.meta.extent_deg.0,
            d.meta.extent_deg.1,
            d.meta.beam_arcsec / 3600.0,
            1.0,
        );
        let kernel = ConvKernel::gauss1d_for_beam(d.meta.beam_arcsec / 3600.0);
        let maps = CpuGridder::new(spec, kernel).grid_dataset(&d);
        assert_eq!(maps.len(), d.n_channels());
        // The drift scan covers the field densely: most cells have data.
        assert!(maps[0].coverage() > 0.9, "coverage {}", maps[0].coverage());
        // Reconstructed values stay within the simulated brightness range.
        for m in &maps {
            for (&v, &w) in m.values().iter().zip(m.weights()) {
                if w > 0.0 {
                    assert!(v.is_finite() && v.abs() < 20.0);
                }
            }
        }
    }
}
