//! CPU reference gridder.
//!
//! Implements Eq. (1) directly over the shared LUT in f64 — the correctness
//! oracle for the device path (integration tests pin PJRT output against it)
//! and the computational core of the Cygrid baseline (`baselines::cygrid`).

use std::f64::consts::FRAC_PI_2;

use crate::data::Dataset;
use crate::grid::kernels::ConvKernel;
use crate::grid::prep::SharedComponent;
use crate::healpix::{ang_dist, PixRange};
use crate::sky::{GridSpec, SkyMap};
use crate::util::threads::parallel_items;

/// Multi-channel CPU gridder (gather method, Fig 2 right).
#[derive(Clone, Debug)]
pub struct CpuGridder {
    pub spec: GridSpec,
    pub kernel: ConvKernel,
    pub workers: usize,
}

impl CpuGridder {
    pub fn new(spec: GridSpec, kernel: ConvKernel) -> Self {
        CpuGridder { spec, kernel, workers: crate::util::threads::default_parallelism() }
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Grid every channel of `dataset` (builds its own shared component).
    pub fn grid_dataset(&self, dataset: &Dataset) -> Vec<SkyMap> {
        let shared = SharedComponent::for_kernel(&dataset.lons, &dataset.lats, &self.kernel)
            .expect("consistent dataset");
        self.grid_with_shared(&shared, &dataset.channels)
    }

    /// Grid `channels` (original sample order) against a prebuilt component.
    /// All channels are accumulated in a single sweep over the cells, so the
    /// neighbour search cost is paid once — how Cygrid treats multi-channel
    /// data on the CPU.
    pub fn grid_with_shared(&self, shared: &SharedComponent, channels: &[Vec<f32>]) -> Vec<SkyMap> {
        let n_cells = self.spec.n_cells();
        let n_ch = channels.len();
        // acc[ch][cell], wsum[cell]; written by disjoint cells in parallel.
        let mut acc = vec![0.0f64; n_ch * n_cells];
        let mut wsum = vec![0.0f64; n_cells];
        {
            let acc_ptr = CellPtr(acc.as_mut_ptr());
            let wsum_ptr = CellPtr(wsum.as_mut_ptr());
            parallel_items(n_cells, self.workers, |cell| {
                let (clon, clat) = self.spec.cell_center_flat(cell);
                let ctheta = FRAC_PI_2 - clat;
                let mut ranges: Vec<PixRange> = Vec::new();
                shared
                    .healpix
                    .query_disc_rings_into(ctheta, clon, self.kernel.support, &mut ranges);
                let clat_cos = clat.cos();
                let mut w_tot = 0.0f64;
                // Local per-channel accumulators to minimise shared writes.
                let mut local = vec![0.0f64; n_ch];
                for r in &ranges {
                    let (a, b) = shared.samples_in_pix_range(r.lo, r.hi);
                    for j in a..b {
                        let (slon, slat) = (shared.slon64[j], shared.slat64[j]);
                        let d = ang_dist(ctheta, clon, FRAC_PI_2 - slat, slon);
                        let d2 = d * d;
                        let w = self.kernel.weight(d2, (slon - clon) * clat_cos, slat - clat);
                        if w != 0.0 {
                            w_tot += w;
                            let orig = shared.perm[j] as usize;
                            for (c, ch) in channels.iter().enumerate() {
                                local[c] += w * ch[orig] as f64;
                            }
                        }
                    }
                }
                unsafe {
                    wsum_ptr.write(cell, w_tot);
                    for c in 0..n_ch {
                        acc_ptr.write(c * n_cells + cell, local[c]);
                    }
                }
            });
        }
        (0..n_ch)
            .map(|c| {
                SkyMap::from_accumulators(
                    self.spec.clone(),
                    &acc[c * n_cells..(c + 1) * n_cells],
                    &wsum,
                )
                .expect("accumulator sizes consistent")
            })
            .collect()
    }
}

/// Disjoint-cell writer handle.
struct CellPtr(*mut f64);
unsafe impl Sync for CellPtr {}
impl CellPtr {
    unsafe fn write(&self, i: usize, v: f64) {
        unsafe { self.0.add(i).write(v) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;
    use crate::util::SplitMix64;

    fn small_setup() -> (GridSpec, ConvKernel) {
        (GridSpec::centered(30.0, 41.0, 12, 6, 0.25), ConvKernel::gauss1d_for_beam(0.5))
    }

    /// Brute-force Eq. (1) without any LUT.
    fn brute_force(
        spec: &GridSpec,
        kernel: &ConvKernel,
        lons: &[f64],
        lats: &[f64],
        values: &[f32],
    ) -> Vec<f64> {
        let mut out = vec![f64::NAN; spec.n_cells()];
        for cell in 0..spec.n_cells() {
            let (clon, clat) = spec.cell_center_flat(cell);
            let mut acc = 0.0;
            let mut w_tot = 0.0;
            for j in 0..lons.len() {
                let d = ang_dist(
                    FRAC_PI_2 - clat,
                    clon,
                    FRAC_PI_2 - lats[j],
                    lons[j],
                );
                let w =
                    kernel.weight(d * d, (lons[j] - clon) * clat.cos(), lats[j] - clat);
                if w != 0.0 {
                    acc += w * values[j] as f64;
                    w_tot += w;
                }
            }
            if w_tot > 0.0 {
                out[cell] = acc / w_tot;
            }
        }
        out
    }

    #[test]
    fn matches_brute_force_exactly() {
        let (spec, kernel) = small_setup();
        let mut rng = SplitMix64::new(10);
        let (lon_lo, lon_hi, lat_lo, lat_hi) = spec.bounds();
        let n = 600;
        let lons: Vec<f64> = (0..n).map(|_| rng.uniform(lon_lo, lon_hi)).collect();
        let lats: Vec<f64> = (0..n).map(|_| rng.uniform(lat_lo, lat_hi)).collect();
        let values: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

        let gridder = CpuGridder::new(spec.clone(), kernel.clone());
        let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
        let maps = gridder.grid_with_shared(&shared, &[values.clone()]);
        let expect = brute_force(&spec, &kernel, &lons, &lats, &values);
        for cell in 0..spec.n_cells() {
            let got = maps[0].values()[cell];
            let want = expect[cell];
            if want.is_nan() {
                assert!(got.is_nan(), "cell {cell}");
            } else {
                assert!(
                    (got - want).abs() <= 1e-12 * want.abs().max(1.0),
                    "cell {cell}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let (spec, kernel) = small_setup();
        let d = SimConfig::quick_preset().generate();
        let shared = SharedComponent::for_kernel(&d.lons, &d.lats, &kernel).unwrap();
        let a = CpuGridder::new(spec.clone(), kernel.clone())
            .with_workers(1)
            .grid_with_shared(&shared, &d.channels);
        let b = CpuGridder::new(spec, kernel).with_workers(8).grid_with_shared(&shared, &d.channels);
        for (ma, mb) in a.iter().zip(&b) {
            for (va, vb) in ma.values().iter().zip(mb.values()) {
                assert!((va.is_nan() && vb.is_nan()) || va == vb);
            }
        }
    }

    #[test]
    fn grid_dataset_covers_field() {
        let d = SimConfig::quick_preset().generate();
        let spec = GridSpec::for_field(
            d.meta.center_deg.0,
            d.meta.center_deg.1,
            d.meta.extent_deg.0,
            d.meta.extent_deg.1,
            d.meta.beam_arcsec / 3600.0,
            1.0,
        );
        let kernel = ConvKernel::gauss1d_for_beam(d.meta.beam_arcsec / 3600.0);
        let maps = CpuGridder::new(spec, kernel).grid_dataset(&d);
        assert_eq!(maps.len(), d.n_channels());
        // The drift scan covers the field densely: most cells have data.
        assert!(maps[0].coverage() > 0.9, "coverage {}", maps[0].coverage());
        // Reconstructed values stay within the simulated brightness range.
        for m in &maps {
            for (&v, &w) in m.values().iter().zip(m.weights()) {
                if w > 0.0 {
                    assert!(v.is_finite() && v.abs() < 20.0);
                }
            }
        }
    }
}
