//! Neighbour materialisation: turning the LUT into the device kernel's
//! static-shape `nbr` index lists.
//!
//! The paper's GPU kernel walks LUT rings per cell at runtime (Algorithm 1).
//! An XLA AOT artifact needs static shapes, so L3 walks the rings here — once
//! per map geometry — and materialises, for every γ-cell group, up to `K`
//! candidate sample indices (−1 padded). The kernel then applies the exact
//! distance test and weights. γ > 1 is the paper's thread-level data reuse
//! (§4.3.3): one ring walk + one gather list serves γ adjacent cells, cutting
//! host-side search and H2D volume by ~γ×.

use crate::grid::kernels::ConvKernel;
use crate::grid::prep::SharedComponent;
use crate::grid::simd;
use crate::healpix::{ang_dist_vec, chord2_prefilter_bound, chord2_to_arc, unit_vec, PixRange};
use crate::sky::GridSpec;
use crate::util::threads::{adaptive_claim_block, parallel_items_scoped, DisjointWriter};
use std::f64::consts::FRAC_PI_2;

/// Per-worker scratch reused across groups (ring ranges + candidate lists) —
/// replaces the former per-group heap allocations. Lives for one executor
/// sweep: [`parallel_items_scoped`] runs the group walk on the persistent
/// [`PipelineExecutor`](crate::util::threads::PipelineExecutor).
struct GroupScratch {
    ranges: Vec<PixRange>,
    /// `(chord², sorted sample index)` accepted by the SIMD prefilter.
    cand: Vec<(f64, u32)>,
    found: Vec<(f64, i32)>,
}

/// Build statistics (Fig 13/14/16 instrumentation).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NbrStats {
    /// Groups whose candidate count exceeded K (truncated).
    pub overflow_groups: usize,
    /// Total candidates accepted across all groups.
    pub total_candidates: usize,
    /// Largest candidate count seen for a single group (pre-truncation).
    pub max_candidates: usize,
    /// Mean fraction of a group's candidates shared with the previous group
    /// on the same tile — the measured adjacent-cell data-reuse that backs
    /// the paper's L1-hit-rate argument (Fig 14).
    pub adjacent_reuse: f64,
}

/// Per-tile, device-shaped neighbour table.
#[derive(Clone, Debug)]
pub struct NeighborTable {
    /// Cells per dispatch tile (artifact `m`).
    pub m: usize,
    /// Max candidates per group (artifact `k`).
    pub k: usize,
    /// Reuse factor (artifact `gamma`).
    pub gamma: usize,
    pub n_tiles: usize,
    pub groups_per_tile: usize,
    /// Number of real (non-padding) cells = `spec.n_cells()`.
    pub valid_cells: usize,
    /// `n_tiles · m` cell longitudes (f32, padded with the map center).
    pub cell_lon: Vec<f32>,
    pub cell_lat: Vec<f32>,
    /// `n_tiles · groups_per_tile · k` candidate indices, −1 padded.
    pub nbr: Vec<i32>,
    pub stats: NbrStats,
}

impl NeighborTable {
    /// Materialise neighbour lists for every cell of `spec` against the
    /// sorted samples of `shared`, tiled for an `(m, k, gamma)` artifact,
    /// on the process-wide dispatched SIMD backend.
    pub fn build(
        shared: &SharedComponent,
        spec: &GridSpec,
        kernel: &ConvKernel,
        m: usize,
        k: usize,
        gamma: usize,
        workers: usize,
    ) -> NeighborTable {
        Self::build_with_simd(shared, spec, kernel, m, k, gamma, workers, simd::SimdIsa::Auto)
    }

    /// [`NeighborTable::build`] with an explicit SIMD ISA request (config
    /// `simd_isa` / CLI `--simd`, forwarded by the engine through
    /// [`crate::coordinator::GriddingJob`]). Every backend produces
    /// bit-identical candidate lists (pinned by the simd unit tests), so
    /// the resulting table is ISA-independent.
    #[allow(clippy::too_many_arguments)]
    pub fn build_with_simd(
        shared: &SharedComponent,
        spec: &GridSpec,
        kernel: &ConvKernel,
        m: usize,
        k: usize,
        gamma: usize,
        workers: usize,
        isa: simd::SimdIsa,
    ) -> NeighborTable {
        assert!(m > 0 && k > 0 && gamma > 0);
        assert!(m % gamma == 0, "gamma must divide the tile size");
        let n_cells = spec.n_cells();
        let n_tiles = n_cells.div_ceil(m).max(1);
        let groups_per_tile = m / gamma;
        let total_groups = n_tiles * groups_per_tile;

        // Padded cell coordinate arrays (f32 device layout).
        let mut cell_lon = vec![spec.lon_c as f32; n_tiles * m];
        let mut cell_lat = vec![spec.lat_c as f32; n_tiles * m];
        let (lons, lats) = spec.cell_centers();
        for i in 0..n_cells {
            cell_lon[i] = lons[i] as f32;
            cell_lat[i] = lats[i] as f32;
        }

        let mut nbr = vec![-1i32; total_groups * k];
        let overflow = std::sync::atomic::AtomicUsize::new(0);
        let total_cand = std::sync::atomic::AtomicUsize::new(0);
        let max_cand = std::sync::atomic::AtomicUsize::new(0);

        {
            let nbr_w = DisjointWriter::new(&mut nbr);
            let lons = &lons;
            let lats = &lats;
            // Per-row/per-column trig of the member cells (bit-identical to
            // per-cell `unit_vec`; see `sky::CellTrig`).
            let trig = spec.trig();
            let trig = &trig;
            let backend = isa.resolve();
            parallel_items_scoped(
                total_groups,
                workers.max(1),
                adaptive_claim_block(total_groups, workers.max(1)),
                || GroupScratch {
                    ranges: Vec::new(),
                    cand: Vec::new(),
                    found: Vec::with_capacity(k),
                },
                |scratch, g| {
                    // Member cells of this group: the contiguous flattened-id
                    // range [first_cell, end).
                    let first_cell = g * gamma;
                    if first_cell >= n_cells {
                        return; // pure padding group
                    }
                    let end = (first_cell + gamma).min(n_cells);
                    let count = (end - first_cell) as f64;
                    // Group center + search margin.
                    let clon = lons[first_cell..end].iter().sum::<f64>() / count;
                    let clat = lats[first_cell..end].iter().sum::<f64>() / count;
                    let cu = unit_vec(clon, clat);
                    let margin = (first_cell..end)
                        .map(|i| ang_dist_vec(&cu, &trig.unit(i)))
                        .fold(0.0f64, f64::max);
                    // Padded by 1e-12 rad (≪ any pixel) so ulp-level
                    // disagreement with other distance formulations at the
                    // exact support boundary can only *add* a zero-weight
                    // candidate, never drop a true neighbour.
                    let radius = kernel.support + margin + 1e-12;

                    // Ring walk (Algorithm 1's contribution region) →
                    // candidates.
                    shared.healpix.query_disc_rings_into(
                        FRAC_PI_2 - clat,
                        clon,
                        radius,
                        &mut scratch.ranges,
                    );
                    let out = unsafe { nbr_w.slice(g * k, k) };
                    // ① batched chord² prefilter (padded bound, see
                    // `chord2_prefilter_bound`): any sample within R of a
                    // member is within R + margin of the center, so this
                    // never drops a true neighbour.
                    let c2_pref = chord2_prefilter_bound(radius);
                    scratch.cand.clear();
                    for r in &scratch.ranges {
                        let (a, b) = shared.samples_in_pix_range(r.lo, r.hi);
                        backend.chord2_filter(
                            &shared.unit_x[a..b],
                            &shared.unit_y[a..b],
                            &shared.unit_z[a..b],
                            &cu,
                            c2_pref,
                            a as u32,
                            &mut scratch.cand,
                        );
                    }
                    // ② exact arc test on accepts only — one `asin` per
                    // prefiltered candidate instead of one per ring sample
                    // (the same shape as `CpuGridder`'s hot loop; the former
                    // per-candidate `ang_dist_vec` metric is gone).
                    let found = &mut scratch.found;
                    found.clear();
                    for &(c2, j) in &scratch.cand {
                        let d = chord2_to_arc(c2);
                        if d <= radius {
                            found.push((d, j as i32));
                        }
                    }
                    let candidates = found.len();
                    if candidates > k {
                        // Keep the K *nearest* candidates: far samples carry
                        // exponentially small weights, so this truncation is
                        // the graceful one (first-K-in-ring-order would drop
                        // whole rings and bias the result spatially).
                        overflow.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        found.select_nth_unstable_by(k - 1, |a, b| {
                            a.0.partial_cmp(&b.0).expect("distances are finite")
                        });
                        found.truncate(k);
                        // Restore ascending sample order (reuse measurement
                        // and gather locality both rely on it).
                        found.sort_unstable_by_key(|e| e.1);
                    }
                    for (slot, &(_, j)) in out.iter_mut().zip(found.iter()) {
                        *slot = j;
                    }
                    total_cand.fetch_add(found.len(), std::sync::atomic::Ordering::Relaxed);
                    max_cand.fetch_max(candidates, std::sync::atomic::Ordering::Relaxed);
                },
            );
        }

        let mut table = NeighborTable {
            m,
            k,
            gamma,
            n_tiles,
            groups_per_tile,
            valid_cells: n_cells,
            cell_lon,
            cell_lat,
            nbr,
            stats: NbrStats {
                overflow_groups: overflow.into_inner(),
                total_candidates: total_cand.into_inner(),
                max_candidates: max_cand.into_inner(),
                adjacent_reuse: 0.0,
            },
        };
        table.stats.adjacent_reuse = table.measure_adjacent_reuse();
        table
    }

    /// Cell-coordinate slice of tile `t` (length `m`).
    pub fn tile_cells(&self, t: usize) -> (&[f32], &[f32]) {
        let s = t * self.m;
        (&self.cell_lon[s..s + self.m], &self.cell_lat[s..s + self.m])
    }

    /// Neighbour-index slice of tile `t` (length `groups_per_tile · k`).
    pub fn tile_nbr(&self, t: usize) -> &[i32] {
        let s = t * self.groups_per_tile * self.k;
        &self.nbr[s..s + self.groups_per_tile * self.k]
    }

    /// Number of real cells in tile `t` (the rest is padding).
    pub fn tile_valid_cells(&self, t: usize) -> usize {
        self.valid_cells.saturating_sub(t * self.m).min(self.m)
    }

    /// Mean overlap fraction between consecutive groups' candidate lists —
    /// the measured analogue of adjacent-thread cache reuse (Fig 14).
    fn measure_adjacent_reuse(&self) -> f64 {
        let gk = self.k;
        let mut fractions = Vec::new();
        for t in 0..self.n_tiles {
            let tile = self.tile_nbr(t);
            for g in 1..self.groups_per_tile {
                let prev = &tile[(g - 1) * gk..g * gk];
                let cur = &tile[g * gk..(g + 1) * gk];
                let cur_len = cur.iter().filter(|&&x| x >= 0).count();
                if cur_len == 0 {
                    continue;
                }
                // Both lists are ascending (ring-walk order): two-pointer
                // intersection.
                let mut shared_count = 0usize;
                let (mut i, mut j) = (0usize, 0usize);
                while i < gk && j < gk && prev[i] >= 0 && cur[j] >= 0 {
                    match prev[i].cmp(&cur[j]) {
                        std::cmp::Ordering::Less => i += 1,
                        std::cmp::Ordering::Greater => j += 1,
                        std::cmp::Ordering::Equal => {
                            shared_count += 1;
                            i += 1;
                            j += 1;
                        }
                    }
                }
                fractions.push(shared_count as f64 / cur_len as f64);
            }
        }
        if fractions.is_empty() {
            0.0
        } else {
            fractions.iter().sum::<f64>() / fractions.len() as f64
        }
    }

    /// Measured within-block gather reuse for a hypothetical Pallas block of
    /// `bm` cells: 1 − unique/total candidate references inside the block.
    /// This is the L1-hit-rate proxy swept in Fig 14.
    pub fn block_reuse(&self, bm: usize) -> f64 {
        assert!(bm > 0 && bm % self.gamma == 0);
        let groups_per_block = bm / self.gamma;
        let mut total = 0usize;
        let mut unique = 0usize;
        let mut seen: std::collections::BTreeSet<i32> = std::collections::BTreeSet::new();
        for t in 0..self.n_tiles {
            let tile = self.tile_nbr(t);
            for block_start in (0..self.groups_per_tile).step_by(groups_per_block) {
                seen.clear();
                let block_end = (block_start + groups_per_block).min(self.groups_per_tile);
                for g in block_start..block_end {
                    // γ cells share one list: each list is referenced γ times.
                    for &idx in &tile[g * self.k..(g + 1) * self.k] {
                        if idx >= 0 {
                            total += self.gamma;
                            if seen.insert(idx) {
                                unique += 1;
                            }
                        }
                    }
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            1.0 - unique as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::healpix::ang_dist;
    use crate::util::SplitMix64;

    fn setup(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>, GridSpec, ConvKernel) {
        let mut rng = SplitMix64::new(seed);
        let spec = GridSpec::centered(30.0, 41.0, 16, 8, 0.2);
        let (lon_lo, lon_hi, lat_lo, lat_hi) = spec.bounds();
        let lons: Vec<f64> = (0..n).map(|_| rng.uniform(lon_lo, lon_hi)).collect();
        let lats: Vec<f64> = (0..n).map(|_| rng.uniform(lat_lo, lat_hi)).collect();
        let kernel = ConvKernel::gauss1d_for_beam(0.4);
        (lons, lats, spec, kernel)
    }

    /// Every sample within the kernel support of a cell must appear in that
    /// cell's group list (completeness — the invariant gridding accuracy
    /// rests on).
    #[test]
    fn neighbour_lists_complete_vs_brute_force() {
        let (lons, lats, spec, kernel) = setup(500, 1);
        let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
        for gamma in [1usize, 2, 4] {
            let t = NeighborTable::build(&shared, &spec, &kernel, 64, 320, gamma, 4);
            assert_eq!(t.stats.overflow_groups, 0, "K too small for test");
            for cell in 0..spec.n_cells() {
                let (clon, clat) = spec.cell_center_flat(cell);
                let tile = cell / t.m;
                let pos = cell % t.m;
                let g = pos / gamma;
                let list =
                    &t.tile_nbr(tile)[g * t.k..(g + 1) * t.k];
                for j in 0..shared.n_samples() {
                    let d = ang_dist(
                        FRAC_PI_2 - clat,
                        clon,
                        FRAC_PI_2 - shared.slat64[j],
                        shared.slon64[j],
                    );
                    if d <= kernel.support {
                        assert!(
                            list.contains(&(j as i32)),
                            "cell {cell} (γ={gamma}) missing sample {j} at d={d}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn tables_are_tiled_and_padded() {
        let (lons, lats, spec, kernel) = setup(300, 2);
        let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
        let m = 48; // 128 cells -> 3 tiles, last one padded
        let t = NeighborTable::build(&shared, &spec, &kernel, m, 32, 1, 4);
        assert_eq!(t.n_tiles, 3);
        assert_eq!(t.cell_lon.len(), 3 * m);
        assert_eq!(t.nbr.len(), 3 * m * 32);
        assert_eq!(t.tile_valid_cells(0), 48);
        assert_eq!(t.tile_valid_cells(2), 128 - 2 * 48);
        // Padding groups have no neighbours.
        let last = t.tile_nbr(2);
        for g in t.tile_valid_cells(2)..m {
            assert!(last[g * 32..(g + 1) * 32].iter().all(|&x| x == -1), "group {g}");
        }
    }

    #[test]
    fn overflow_detected_when_k_too_small() {
        let (lons, lats, spec, kernel) = setup(3000, 3);
        let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
        let t = NeighborTable::build(&shared, &spec, &kernel, 64, 2, 1, 4);
        assert!(t.stats.overflow_groups > 0);
        assert!(t.stats.max_candidates > 2);
    }

    #[test]
    fn gamma_shrinks_table_but_covers_same_cells() {
        let (lons, lats, spec, kernel) = setup(500, 4);
        let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
        let t1 = NeighborTable::build(&shared, &spec, &kernel, 64, 64, 1, 4);
        let t2 = NeighborTable::build(&shared, &spec, &kernel, 64, 64, 2, 4);
        assert_eq!(t2.nbr.len() * 2, t1.nbr.len());
        assert_eq!(t1.valid_cells, t2.valid_cells);
    }

    #[test]
    fn adjacent_reuse_increases_with_density() {
        // Dense sampling ⇒ adjacent cells share many contributors.
        let (lons, lats, spec, kernel) = setup(4000, 5);
        let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
        let dense = NeighborTable::build(&shared, &spec, &kernel, 64, 256, 1, 4);
        let (lons_s, lats_s, _, _) = setup(100, 6);
        let shared_s = SharedComponent::for_kernel(&lons_s, &lats_s, &kernel).unwrap();
        let sparse = NeighborTable::build(&shared_s, &spec, &kernel, 64, 256, 1, 4);
        assert!(dense.stats.adjacent_reuse > 0.3, "dense reuse {}", dense.stats.adjacent_reuse);
        assert!(
            dense.stats.adjacent_reuse >= sparse.stats.adjacent_reuse,
            "{} < {}",
            dense.stats.adjacent_reuse,
            sparse.stats.adjacent_reuse
        );
    }

    #[test]
    fn block_reuse_monotone_in_block_size() {
        let (lons, lats, spec, kernel) = setup(2000, 7);
        let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
        let t = NeighborTable::build(&shared, &spec, &kernel, 128, 128, 1, 4);
        let r8 = t.block_reuse(8);
        let r32 = t.block_reuse(32);
        let r128 = t.block_reuse(128);
        assert!(r8 <= r32 + 1e-9, "{r8} > {r32}");
        assert!(r32 <= r128 + 1e-9, "{r32} > {r128}");
        assert!(r128 > 0.0);
    }
}
