//! Occupancy models: the analytical device model behind Fig 13, and the
//! **rolling pipeline-stage occupancy** that drives the adaptive
//! `pipeline_width auto` controller.
//!
//! The device half ([`OccupancyModel`]) reproduces the paper's register-file
//! argument for the optimal thread-block size. The pipeline half
//! ([`StageOccupancy`] + [`decide_width`]) turns the coordinator's measured
//! [`StageSpan`]s into shrink/grow decisions: the fig8/table3 sweeps showed
//! the best `pipeline_width` is whatever keeps T3 streams saturated without
//! queueing and keeps pipelines out of ingest starvation — so instead of
//! hand-sweeping the knob, the coordinator feeds each finished group-batch's
//! spans into a rolling window and re-decides the width. The decision
//! function is pure (no clocks, no pipelines), so the canned-trace tests
//! below exercise exactly what the coordinator runs.
//!
//! The paper explains the optimal thread-block size on the V100 through the
//! register file: HEGrid's kernel uses 88 registers/thread, the SM has 65,536
//! registers, so at most ⌊65536 / (88·B)⌋ blocks of B threads co-reside; at
//! B = 352 two blocks fit (704 parallel threads) while one more warp (B = 384)
//! drops co-residency to a single block. nsight-compute is unavailable here,
//! so this model reproduces the *shape* of Fig 13 from the published
//! constants plus two standard effects:
//!
//! * a per-block static cost (launch/scheduling + cold cache), which is why
//!   the measured runtime keeps improving up to the register ceiling rather
//!   than being flat wherever occupancy is equal;
//! * a latency-hiding penalty when only one block is resident (a lone block
//!   cannot overlap its memory stalls with another block's compute).
//!
//! The measured counterpart (CPU-PJRT tile-size sweep) runs in
//! `benches/fig13_14_blocksize.rs`.

use std::collections::VecDeque;

use crate::coordinator::{PipeStage, StageSpan};

/// Rolling per-stage busy model over the most recent `window_s` seconds of
/// the run clock — the adaptive-width counterpart of
/// `PipelineReport::stage_occupancy`, which looks at the *whole* run after
/// the fact. The controller needs the recent past only: early-run behaviour
/// (cold caches, the first kernel compile) must age out of the decision.
#[derive(Clone, Debug)]
pub struct StageOccupancy {
    window_s: f64,
    spans: VecDeque<StageSpan>,
}

impl StageOccupancy {
    pub fn new(window_s: f64) -> StageOccupancy {
        StageOccupancy { window_s: window_s.max(1e-3), spans: VecDeque::new() }
    }

    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    /// Record a finished stage execution window (run-clock seconds).
    /// Degenerate (empty/inverted) spans are dropped.
    pub fn record(&mut self, span: StageSpan) {
        if span.end > span.start {
            self.spans.push_back(span);
        }
    }

    /// Record a raw `(start, end)` interval for `stage` (the T0 read
    /// intervals arrive from the prefetcher in this shape).
    pub fn record_interval(&mut self, stage: PipeStage, interval: (f64, f64)) {
        self.record(StageSpan { stage, start: interval.0, end: interval.1 });
    }

    /// Drop spans that ended before the rolling window `[now - window, now]`.
    pub fn prune(&mut self, now: f64) {
        let lo = now - self.window_s;
        self.spans.retain(|s| s.end >= lo);
    }

    /// Busy seconds of `stage` inside the window, summed across pipelines
    /// (concurrent windows count multiply); spans are clipped to the window.
    pub fn busy_s(&self, stage: PipeStage, now: f64) -> f64 {
        let lo = (now - self.window_s).max(0.0);
        self.spans
            .iter()
            .filter(|s| s.stage == stage)
            .map(|s| (s.end.min(now) - s.start.max(lo)).max(0.0))
            .sum()
    }

    /// Mean number of pipelines concurrently inside `stage` over the window
    /// (busy seconds / window span).
    pub fn occupancy(&self, stage: PipeStage, now: f64) -> f64 {
        let span = now.min(self.window_s);
        if span > 0.0 {
            self.busy_s(stage, now) / span
        } else {
            0.0
        }
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }
}

/// Tunables of the adaptive-width controller. Defaults are deliberately
/// conservative: a wrong Hold costs nothing (the width stays where a fixed
/// sweep would have put it), a wrong Grow/Shrink oscillation costs overlap.
#[derive(Clone, Copy, Debug)]
pub struct WidthPolicy {
    /// Stream slots T3 dispatches into (`HegridConfig::effective_streams`).
    pub n_streams: usize,
    /// T0 I/O workers feeding the prefetch ring.
    pub io_workers: usize,
    /// Fraction of a resource's capacity treated as saturated.
    pub saturation: f64,
    /// Mean per-pipeline busy fraction above which the run counts as
    /// width-limited (grow candidate).
    pub busy_grow: f64,
    /// Mean per-pipeline busy fraction below which pipelines count as
    /// starved (shrink candidate when ingest is the bottleneck).
    pub idle_shrink: f64,
}

impl WidthPolicy {
    /// Policy for a run with `n_streams` stream slots and `io_workers` T0
    /// threads (both clamped to ≥ 1), default thresholds.
    pub fn for_run(n_streams: usize, io_workers: usize) -> WidthPolicy {
        WidthPolicy {
            n_streams: n_streams.max(1),
            io_workers: io_workers.max(1),
            saturation: 0.85,
            busy_grow: 0.75,
            idle_shrink: 0.35,
        }
    }
}

/// One controller verdict; the coordinator applies it as ±1 within
/// `[1, pipeline_width_max]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WidthDecision {
    Shrink,
    Hold,
    Grow,
}

/// Shrink/grow decision from measured occupancy at width `width`:
///
/// * **Shrink** when T3 saturates the streams (mean concurrent kernels ≥
///   `n_streams · saturation`) — extra pipelines only queue on the slots
///   (HCGrid's collapse mode: one stage saturates, the pipeline stalls);
/// * **Shrink** when the run is ingest-bound: the I/O workers read flat out
///   while the pipelines' mean busy fraction collapses (they sit in
///   `Prefetcher::next`) — width does not create disk bandwidth;
/// * **Grow** when every pipeline is nearly always busy and the projected
///   T3 occupancy after adding one more (`t3 · (width+1)/width`) still fits
///   under the stream ceiling;
/// * **Hold** otherwise.
///
/// Pure: callers feed a [`StageOccupancy`] window and the run clock.
pub fn decide_width(
    occ: &StageOccupancy,
    now: f64,
    width: usize,
    policy: &WidthPolicy,
) -> WidthDecision {
    if occ.is_empty() || width == 0 {
        return WidthDecision::Hold;
    }
    let t3 = occ.occupancy(PipeStage::T3Kernel, now);
    let t0 = occ.occupancy(PipeStage::T0Ingest, now);
    let pipe_stages = [
        PipeStage::Prep,
        PipeStage::T1Permute,
        PipeStage::T2Submit,
        PipeStage::T3Kernel,
        PipeStage::T4Reduce,
    ];
    let busy: f64 = pipe_stages.iter().map(|&s| occ.occupancy(s, now)).sum();
    let per_pipe = busy / width as f64;
    let stream_cap = policy.n_streams as f64 * policy.saturation;
    if width > 1 && t3 >= stream_cap {
        return WidthDecision::Shrink;
    }
    if width > 1
        && t0 >= policy.io_workers as f64 * policy.saturation
        && per_pipe <= policy.idle_shrink
    {
        return WidthDecision::Shrink;
    }
    if per_pipe >= policy.busy_grow && t3 * (width as f64 + 1.0) / width as f64 <= stream_cap {
        return WidthDecision::Grow;
    }
    WidthDecision::Hold
}

/// Occupancy model constants (defaults = the paper's V100 numbers).
#[derive(Clone, Copy, Debug)]
pub struct OccupancyModel {
    /// Registers used per thread (paper: 88, via nsight-compute).
    pub regs_per_thread: usize,
    /// Register file size per SM (V100: 65,536).
    pub regs_per_sm: usize,
    /// Hardware ceiling on resident threads per SM.
    pub max_threads_per_sm: usize,
    /// Warp (wavefront) size: 32 NVIDIA / 64 AMD.
    pub warp: usize,
    /// Per-block static cost, in thread-equivalents: efficiency factor is
    /// `B / (B + block_overhead_threads)`.
    pub block_overhead_threads: f64,
    /// Throughput factor applied when a single block is resident.
    pub single_block_efficiency: f64,
}

impl OccupancyModel {
    /// The paper's Server_V (V100) configuration.
    pub fn v100() -> Self {
        OccupancyModel {
            regs_per_thread: 88,
            regs_per_sm: 65_536,
            max_threads_per_sm: 2_048,
            warp: 32,
            block_overhead_threads: 96.0,
            single_block_efficiency: 0.6,
        }
    }

    /// Server_M (MI50-class): wavefront 64, and the 128-parallel-thread cap
    /// the paper reports for HEGrid's kernel on the MI50 (§5.4).
    pub fn mi50() -> Self {
        OccupancyModel {
            regs_per_thread: 88,
            regs_per_sm: 65_536,
            max_threads_per_sm: 128,
            warp: 64,
            block_overhead_threads: 96.0,
            single_block_efficiency: 0.6,
        }
    }

    /// Blocks of `block` threads co-resident on one SM.
    pub fn blocks_per_sm(&self, block: usize) -> usize {
        assert!(block > 0);
        let by_regs = self.regs_per_sm / (self.regs_per_thread * block);
        let by_threads = self.max_threads_per_sm / block;
        by_regs.min(by_threads)
    }

    /// Parallel threads executing per SM for a given block size — the
    /// quantity the paper's Fig-13 argument revolves around.
    pub fn parallel_threads(&self, block: usize) -> usize {
        self.blocks_per_sm(block) * block
    }

    /// Effective cell-update throughput (cells per unit time, arbitrary
    /// units) for a given block size.
    pub fn throughput(&self, block: usize) -> f64 {
        let blocks = self.blocks_per_sm(block);
        if blocks == 0 {
            return 0.0;
        }
        let raw = (blocks * block) as f64;
        let eff = block as f64 / (block as f64 + self.block_overhead_threads);
        let hide = if blocks == 1 { self.single_block_efficiency } else { 1.0 };
        raw * eff * hide
    }

    /// Predicted relative runtime for gridding `total_cells` cells with
    /// blocks of `block` threads (one cell per thread). Arbitrary units —
    /// only the shape (minimum location, rise on both sides) is meaningful.
    pub fn predicted_time(&self, block: usize, total_cells: usize) -> f64 {
        let tp = self.throughput(block);
        if tp <= 0.0 {
            return f64::INFINITY;
        }
        total_cells as f64 / tp
    }

    /// Best block size (multiples of the warp, up to `max_block`).
    pub fn optimal_block(&self, max_block: usize, total_cells: usize) -> usize {
        let mut best = self.warp;
        let mut best_t = f64::INFINITY;
        let mut b = self.warp;
        while b <= max_block {
            let t = self.predicted_time(b, total_cells);
            if t < best_t {
                best_t = t;
                best = b;
            }
            b += self.warp;
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_reproduces_papers_352_argument() {
        let m = OccupancyModel::v100();
        // 2 × 352 × 88 = 61,952 ≤ 65,536 ⇒ two blocks resident.
        assert_eq!(m.blocks_per_sm(352), 2);
        assert_eq!(m.parallel_threads(352), 704);
        // One more warp (384): 2 × 384 × 88 > 65,536 ⇒ only one block.
        assert_eq!(m.blocks_per_sm(384), 1);
        assert_eq!(m.parallel_threads(384), 384);
        // The model's optimum lands at 352 for a large map.
        assert_eq!(m.optimal_block(1024, 1_000_000), 352);
    }

    #[test]
    fn time_curve_dips_then_rises() {
        let m = OccupancyModel::v100();
        let cells = 500_000;
        let t64 = m.predicted_time(64, cells);
        let t128 = m.predicted_time(128, cells);
        let t352 = m.predicted_time(352, cells);
        let t384 = m.predicted_time(384, cells);
        // Monotone improvement towards the optimum, collapse right after —
        // Fig 13's shape.
        assert!(t128 < t64, "{t128} !< {t64}");
        assert!(t352 < t128, "{t352} !< {t128}");
        assert!(t384 > t352, "{t384} !> {t352}");
    }

    #[test]
    fn mi50_caps_at_128_threads() {
        let m = OccupancyModel::mi50();
        for b in [64, 128] {
            assert!(m.parallel_threads(b) <= 128, "block {b}");
        }
        // Blocks larger than the thread cap cannot be scheduled at all.
        assert_eq!(m.blocks_per_sm(256), 0);
        assert!(m.predicted_time(256, 1000).is_infinite());
        let opt = m.optimal_block(512, 100_000);
        assert!(opt == 64 || opt == 128, "opt={opt}");
    }

    #[test]
    fn overhead_penalises_tiny_blocks() {
        let m = OccupancyModel::v100();
        let t32 = m.predicted_time(32, 10_000);
        let t256 = m.predicted_time(256, 10_000);
        assert!(t256 < t32, "{t256} !< {t32}");
    }

    #[test]
    fn throughput_zero_for_unschedulable() {
        let m = OccupancyModel::v100();
        // 1024 threads × 88 regs > 65,536 ⇒ no block fits.
        assert_eq!(m.blocks_per_sm(1024), 0);
        assert_eq!(m.throughput(1024), 0.0);
    }

    // ---- adaptive-width controller on canned StageSpan traces -------------

    fn span(stage: PipeStage, start: f64, end: f64) -> StageSpan {
        StageSpan { stage, start, end }
    }

    fn window(spans: &[StageSpan]) -> StageOccupancy {
        let mut occ = StageOccupancy::new(10.0);
        for &s in spans {
            occ.record(s);
        }
        occ
    }

    #[test]
    fn stage_occupancy_clips_and_prunes() {
        let mut occ = StageOccupancy::new(10.0);
        occ.record(span(PipeStage::T3Kernel, 0.0, 4.0));
        occ.record(span(PipeStage::T3Kernel, 2.0, 6.0));
        occ.record(span(PipeStage::T1Permute, 5.0, 5.0)); // degenerate: dropped
        assert_eq!(occ.len(), 2);
        // At now=6 the window is [0,6]: 4 + 4 busy seconds over span 6.
        assert!((occ.busy_s(PipeStage::T3Kernel, 6.0) - 8.0).abs() < 1e-12);
        assert!((occ.occupancy(PipeStage::T3Kernel, 6.0) - 8.0 / 6.0).abs() < 1e-12);
        // At now=13 the window is [3,13]: spans clip to 1 + 3 seconds.
        assert!((occ.busy_s(PipeStage::T3Kernel, 13.0) - 4.0).abs() < 1e-12);
        // Spans ending before the window fall out on prune.
        occ.prune(15.0); // window [5,15]: the [0,4) span goes
        assert_eq!(occ.len(), 1);
        occ.record_interval(PipeStage::T0Ingest, (14.0, 15.0));
        assert!((occ.busy_s(PipeStage::T0Ingest, 15.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn saturated_t3_shrinks() {
        // Two streams, width 4: two kernels run wall-to-wall for the whole
        // window ⇒ T3 occupancy 2.0 ≥ 2 × 0.85 — the streams are full and
        // the other two pipelines only queue.
        let occ = window(&[
            span(PipeStage::T3Kernel, 0.0, 10.0),
            span(PipeStage::T3Kernel, 0.0, 10.0),
        ]);
        let policy = WidthPolicy::for_run(2, 2);
        assert_eq!(decide_width(&occ, 10.0, 4, &policy), WidthDecision::Shrink);
        // Width 1 never shrinks below the floor.
        assert_eq!(decide_width(&occ, 10.0, 1, &policy), WidthDecision::Hold);
    }

    #[test]
    fn starved_t0_shrinks() {
        // One I/O worker reads flat out while the 4 pipelines barely touch
        // their stages: ingest-bound, width does not create bandwidth.
        let occ = window(&[
            span(PipeStage::T0Ingest, 0.0, 10.0),
            span(PipeStage::T1Permute, 0.0, 0.5),
            span(PipeStage::T3Kernel, 1.0, 1.5),
        ]);
        let policy = WidthPolicy::for_run(4, 1);
        assert_eq!(decide_width(&occ, 10.0, 4, &policy), WidthDecision::Shrink);
    }

    #[test]
    fn balanced_busy_grows_until_stream_ceiling() {
        // Two pipelines busy ~87% of the window, kernels at 0.8 of 4 slots:
        // projected T3 after one more pipeline (1.2) still fits ⇒ Grow.
        let spans = [
            span(PipeStage::T1Permute, 0.0, 4.0),
            span(PipeStage::T3Kernel, 4.0, 8.0),
            span(PipeStage::T4Reduce, 8.0, 9.0),
            span(PipeStage::T1Permute, 1.0, 5.0),
            span(PipeStage::T3Kernel, 5.0, 9.0),
            span(PipeStage::T4Reduce, 9.0, 9.5),
        ];
        let occ = window(&spans);
        assert_eq!(
            decide_width(&occ, 10.0, 2, &WidthPolicy::for_run(4, 2)),
            WidthDecision::Grow
        );
        // Same trace with a single stream slot: growing would push the
        // projected T3 (1.2) past the ceiling (0.85) ⇒ Hold.
        assert_eq!(
            decide_width(&occ, 10.0, 2, &WidthPolicy::for_run(1, 2)),
            WidthDecision::Hold
        );
    }

    #[test]
    fn idle_window_holds() {
        let occ = StageOccupancy::new(10.0);
        assert!(occ.is_empty());
        let policy = WidthPolicy::for_run(4, 2);
        assert_eq!(decide_width(&occ, 5.0, 3, &policy), WidthDecision::Hold);
        // Moderate load (neither starved nor width-limited) also holds.
        let occ = window(&[
            span(PipeStage::T1Permute, 0.0, 2.0),
            span(PipeStage::T3Kernel, 2.0, 5.0),
        ]);
        assert_eq!(decide_width(&occ, 10.0, 2, &policy), WidthDecision::Hold);
    }
}
