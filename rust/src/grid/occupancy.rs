//! Analytical occupancy model — the mechanism behind Fig 13.
//!
//! The paper explains the optimal thread-block size on the V100 through the
//! register file: HEGrid's kernel uses 88 registers/thread, the SM has 65,536
//! registers, so at most ⌊65536 / (88·B)⌋ blocks of B threads co-reside; at
//! B = 352 two blocks fit (704 parallel threads) while one more warp (B = 384)
//! drops co-residency to a single block. nsight-compute is unavailable here,
//! so this model reproduces the *shape* of Fig 13 from the published
//! constants plus two standard effects:
//!
//! * a per-block static cost (launch/scheduling + cold cache), which is why
//!   the measured runtime keeps improving up to the register ceiling rather
//!   than being flat wherever occupancy is equal;
//! * a latency-hiding penalty when only one block is resident (a lone block
//!   cannot overlap its memory stalls with another block's compute).
//!
//! The measured counterpart (CPU-PJRT tile-size sweep) runs in
//! `benches/fig13_14_blocksize.rs`.

/// Occupancy model constants (defaults = the paper's V100 numbers).
#[derive(Clone, Copy, Debug)]
pub struct OccupancyModel {
    /// Registers used per thread (paper: 88, via nsight-compute).
    pub regs_per_thread: usize,
    /// Register file size per SM (V100: 65,536).
    pub regs_per_sm: usize,
    /// Hardware ceiling on resident threads per SM.
    pub max_threads_per_sm: usize,
    /// Warp (wavefront) size: 32 NVIDIA / 64 AMD.
    pub warp: usize,
    /// Per-block static cost, in thread-equivalents: efficiency factor is
    /// `B / (B + block_overhead_threads)`.
    pub block_overhead_threads: f64,
    /// Throughput factor applied when a single block is resident.
    pub single_block_efficiency: f64,
}

impl OccupancyModel {
    /// The paper's Server_V (V100) configuration.
    pub fn v100() -> Self {
        OccupancyModel {
            regs_per_thread: 88,
            regs_per_sm: 65_536,
            max_threads_per_sm: 2_048,
            warp: 32,
            block_overhead_threads: 96.0,
            single_block_efficiency: 0.6,
        }
    }

    /// Server_M (MI50-class): wavefront 64, and the 128-parallel-thread cap
    /// the paper reports for HEGrid's kernel on the MI50 (§5.4).
    pub fn mi50() -> Self {
        OccupancyModel {
            regs_per_thread: 88,
            regs_per_sm: 65_536,
            max_threads_per_sm: 128,
            warp: 64,
            block_overhead_threads: 96.0,
            single_block_efficiency: 0.6,
        }
    }

    /// Blocks of `block` threads co-resident on one SM.
    pub fn blocks_per_sm(&self, block: usize) -> usize {
        assert!(block > 0);
        let by_regs = self.regs_per_sm / (self.regs_per_thread * block);
        let by_threads = self.max_threads_per_sm / block;
        by_regs.min(by_threads)
    }

    /// Parallel threads executing per SM for a given block size — the
    /// quantity the paper's Fig-13 argument revolves around.
    pub fn parallel_threads(&self, block: usize) -> usize {
        self.blocks_per_sm(block) * block
    }

    /// Effective cell-update throughput (cells per unit time, arbitrary
    /// units) for a given block size.
    pub fn throughput(&self, block: usize) -> f64 {
        let blocks = self.blocks_per_sm(block);
        if blocks == 0 {
            return 0.0;
        }
        let raw = (blocks * block) as f64;
        let eff = block as f64 / (block as f64 + self.block_overhead_threads);
        let hide = if blocks == 1 { self.single_block_efficiency } else { 1.0 };
        raw * eff * hide
    }

    /// Predicted relative runtime for gridding `total_cells` cells with
    /// blocks of `block` threads (one cell per thread). Arbitrary units —
    /// only the shape (minimum location, rise on both sides) is meaningful.
    pub fn predicted_time(&self, block: usize, total_cells: usize) -> f64 {
        let tp = self.throughput(block);
        if tp <= 0.0 {
            return f64::INFINITY;
        }
        total_cells as f64 / tp
    }

    /// Best block size (multiples of the warp, up to `max_block`).
    pub fn optimal_block(&self, max_block: usize, total_cells: usize) -> usize {
        let mut best = self.warp;
        let mut best_t = f64::INFINITY;
        let mut b = self.warp;
        while b <= max_block {
            let t = self.predicted_time(b, total_cells);
            if t < best_t {
                best_t = t;
                best = b;
            }
            b += self.warp;
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_reproduces_papers_352_argument() {
        let m = OccupancyModel::v100();
        // 2 × 352 × 88 = 61,952 ≤ 65,536 ⇒ two blocks resident.
        assert_eq!(m.blocks_per_sm(352), 2);
        assert_eq!(m.parallel_threads(352), 704);
        // One more warp (384): 2 × 384 × 88 > 65,536 ⇒ only one block.
        assert_eq!(m.blocks_per_sm(384), 1);
        assert_eq!(m.parallel_threads(384), 384);
        // The model's optimum lands at 352 for a large map.
        assert_eq!(m.optimal_block(1024, 1_000_000), 352);
    }

    #[test]
    fn time_curve_dips_then_rises() {
        let m = OccupancyModel::v100();
        let cells = 500_000;
        let t64 = m.predicted_time(64, cells);
        let t128 = m.predicted_time(128, cells);
        let t352 = m.predicted_time(352, cells);
        let t384 = m.predicted_time(384, cells);
        // Monotone improvement towards the optimum, collapse right after —
        // Fig 13's shape.
        assert!(t128 < t64, "{t128} !< {t64}");
        assert!(t352 < t128, "{t352} !< {t128}");
        assert!(t384 > t352, "{t384} !> {t352}");
    }

    #[test]
    fn mi50_caps_at_128_threads() {
        let m = OccupancyModel::mi50();
        for b in [64, 128] {
            assert!(m.parallel_threads(b) <= 128, "block {b}");
        }
        // Blocks larger than the thread cap cannot be scheduled at all.
        assert_eq!(m.blocks_per_sm(256), 0);
        assert!(m.predicted_time(256, 1000).is_infinite());
        let opt = m.optimal_block(512, 100_000);
        assert!(opt == 64 || opt == 128, "opt={opt}");
    }

    #[test]
    fn overhead_penalises_tiny_blocks() {
        let m = OccupancyModel::v100();
        let t32 = m.predicted_time(32, 10_000);
        let t256 = m.predicted_time(256, 10_000);
        assert!(t256 < t32, "{t256} !< {t32}");
    }

    #[test]
    fn throughput_zero_for_unschedulable() {
        let m = OccupancyModel::v100();
        // 1024 threads × 88 regs > 65,536 ⇒ no block fits.
        assert_eq!(m.blocks_per_sm(1024), 0);
        assert_eq!(m.throughput(1024), 0.0);
    }
}
