//! Parallel LSD radix sort on `(pixel_idx, sample_idx)` pairs.
//!
//! The paper uses Boost's Block Indirect sort (O(N log N) average) for the
//! `pixel_idx` ordering in pre-processing. We implement a parallel
//! least-significant-digit radix sort instead — O(N) with 8-bit digits — and
//! skip passes whose digit is constant across the whole key range (sample
//! pixel ids span only the map footprint, so high bytes are usually uniform).

use crate::util::threads::parallel_chunks;

/// A sortable (key, payload) pair: pixel id + original sample index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KeyIdx {
    pub key: u64,
    pub idx: u32,
}

const RADIX_BITS: usize = 8;
const BUCKETS: usize = 1 << RADIX_BITS;

/// Sort `items` ascending by `key` (stable), using up to `workers` threads.
pub fn radix_sort_by_key(items: &mut Vec<KeyIdx>, workers: usize) {
    let n = items.len();
    if n <= 1 {
        return;
    }
    if n < 4096 || workers <= 1 {
        items.sort_by_key(|e| e.key); // std sort is stable
        return;
    }

    // Determine which digit positions actually vary.
    let (mut min_key, mut max_key) = (u64::MAX, 0u64);
    for e in items.iter() {
        min_key = min_key.min(e.key);
        max_key = max_key.max(e.key);
    }
    let varying = min_key ^ max_key;
    let passes: Vec<usize> = (0..8).filter(|p| (varying >> (p * RADIX_BITS)) & 0xFF != 0).collect();
    if passes.is_empty() {
        return; // all keys equal
    }

    let workers = workers.min(n / 2048).max(1);
    let mut src: Vec<KeyIdx> = std::mem::take(items);
    let mut dst: Vec<KeyIdx> = vec![KeyIdx { key: 0, idx: 0 }; n];

    for &pass in &passes {
        let shift = pass * RADIX_BITS;
        // 1. Per-worker histograms.
        let mut hist = vec![0usize; workers * BUCKETS];
        {
            let hist_ptr = HistPtr(hist.as_mut_ptr());
            let src_ref = &src;
            parallel_chunks(n, workers, |w, s, e| {
                let h = unsafe { std::slice::from_raw_parts_mut(hist_ptr.at(w * BUCKETS), BUCKETS) };
                for item in &src_ref[s..e] {
                    h[((item.key >> shift) & 0xFF) as usize] += 1;
                }
            });
        }
        // 2. Exclusive prefix over (bucket-major, worker-minor) so the output
        //    of worker w for bucket b starts at offsets[w][b] — stability.
        let mut offsets = vec![0usize; workers * BUCKETS];
        let mut running = 0usize;
        for b in 0..BUCKETS {
            for w in 0..workers {
                offsets[w * BUCKETS + b] = running;
                running += hist[w * BUCKETS + b];
            }
        }
        debug_assert_eq!(running, n);
        // 3. Scatter.
        {
            let off_ptr = HistPtr(offsets.as_mut_ptr());
            let dst_ptr = ItemPtr(dst.as_mut_ptr());
            let src_ref = &src;
            parallel_chunks(n, workers, |w, s, e| {
                let my_off =
                    unsafe { std::slice::from_raw_parts_mut(off_ptr.at(w * BUCKETS), BUCKETS) };
                for item in &src_ref[s..e] {
                    let b = ((item.key >> shift) & 0xFF) as usize;
                    unsafe { dst_ptr.write(my_off[b], *item) };
                    my_off[b] += 1;
                }
            });
        }
        std::mem::swap(&mut src, &mut dst);
    }
    *items = src;
}

/// Shareable raw pointer into the histogram arena; each worker only touches
/// its own `BUCKETS`-sized window, so accesses are disjoint.
struct HistPtr(*mut usize);
unsafe impl Sync for HistPtr {}
impl HistPtr {
    fn at(&self, offset: usize) -> *mut usize {
        unsafe { self.0.add(offset) }
    }
}

/// Shareable raw pointer into the scatter destination; the offset tables give
/// every worker disjoint write positions.
struct ItemPtr(*mut KeyIdx);
unsafe impl Sync for ItemPtr {}
impl ItemPtr {
    unsafe fn write(&self, i: usize, v: KeyIdx) {
        unsafe { self.0.add(i).write(v) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::SplitMix64;

    fn is_sorted_stable(items: &[KeyIdx], original: &[KeyIdx]) -> bool {
        // ascending by key
        if !items.windows(2).all(|w| w[0].key <= w[1].key) {
            return false;
        }
        // same multiset
        let mut a: Vec<_> = items.iter().map(|e| (e.key, e.idx)).collect();
        let mut b: Vec<_> = original.iter().map(|e| (e.key, e.idx)).collect();
        a.sort_unstable();
        b.sort_unstable();
        if a != b {
            return false;
        }
        // stability: equal keys preserve original relative order of idx
        // (original was built with idx = position, so within equal keys the
        // idx sequence must be increasing).
        items
            .windows(2)
            .all(|w| w[0].key != w[1].key || w[0].idx < w[1].idx)
    }

    fn random_items(n: usize, key_range: u64, seed: u64) -> Vec<KeyIdx> {
        let mut rng = SplitMix64::new(seed);
        (0..n).map(|i| KeyIdx { key: rng.below(key_range.max(1)), idx: i as u32 }).collect()
    }

    #[test]
    fn sorts_small_and_large() {
        for (n, range) in [(0usize, 10u64), (1, 10), (100, 5), (5000, 1 << 20), (100_000, 1 << 40)]
        {
            let original = random_items(n, range, n as u64 + 1);
            let mut items = original.clone();
            radix_sort_by_key(&mut items, 8);
            assert!(is_sorted_stable(&items, &original), "n={n} range={range}");
        }
    }

    #[test]
    fn all_equal_keys_is_noop_order() {
        let original: Vec<KeyIdx> = (0..10_000).map(|i| KeyIdx { key: 42, idx: i }).collect();
        let mut items = original.clone();
        radix_sort_by_key(&mut items, 8);
        assert_eq!(items, original);
    }

    #[test]
    fn matches_std_sort_property() {
        testkit::check(
            0xBADC0DE,
            30,
            |g| {
                let n = g.usize(0, 20_000);
                let range = 1u64 << g.usize(1, 50);
                let seed = g.u64(0, u64::MAX - 1);
                random_items(n, range, seed)
                    .iter()
                    .map(|e| e.key)
                    .collect::<Vec<u64>>()
            },
            |keys| {
                let original: Vec<KeyIdx> = keys
                    .iter()
                    .enumerate()
                    .map(|(i, &k)| KeyIdx { key: k, idx: i as u32 })
                    .collect();
                let mut ours = original.clone();
                radix_sort_by_key(&mut ours, 6);
                let mut std_sorted = original.clone();
                std_sorted.sort_by_key(|e| e.key);
                if ours.iter().map(|e| e.key).eq(std_sorted.iter().map(|e| e.key)) {
                    Ok(())
                } else {
                    Err("key order differs from std sort".into())
                }
            },
        );
    }

    #[test]
    fn single_worker_falls_back() {
        let original = random_items(10_000, 1 << 30, 3);
        let mut items = original.clone();
        radix_sort_by_key(&mut items, 1);
        assert!(is_sorted_stable(&items, &original));
    }
}
