//! The gridding domain core: convolution kernels, pre-processing (LUT),
//! neighbour materialisation, the CPU reference gridder, and the occupancy
//! model. See each submodule's docs for the mapping to the paper's sections.

pub mod cpu;
pub mod kernels;
pub mod nbr;
pub mod occupancy;
pub mod prep;
pub mod simd;
pub mod sort;
pub mod uv;

pub use cpu::CpuGridder;
pub use kernels::{ConvKernel, ConvKernelType};
pub use nbr::{NbrStats, NeighborTable};
pub use prep::{PrepStats, SharedComponent, ValueMatrix};
pub use simd::{SimdBackend, SimdIsa};
pub use uv::{UvDataset, UvGridSpec, UvGridder, UvKernel, UvKernelType, UvPlanes, UvResult};
