//! Lane-per-channel SIMD backends for the CPU gridding hot path.
//!
//! The two inner loops that dominate `CpuGridder::grid_with_shared` (and the
//! neighbour walk in `NeighborTable::build`) are
//!
//! 1. the **squared-chord prefilter** — `chord²(sample, cell) ≤ r²` over the
//!    samples of each LUT ring range, and
//! 2. the **channel-blocked accumulation** — `acc[c] += w · vals[j][c]` over
//!    a cell's contributor list.
//!
//! Both vectorise with a **lane-per-channel / lane-per-sample mapping**: each
//! SIMD lane owns one channel (resp. one sample), so the per-channel
//! accumulation order — the invariant `rust/tests/cpu_blocked_equivalence.rs`
//! pins — is exactly the scalar order and results stay **bit-identical**
//! across backends. To keep that guarantee the vector code mirrors the
//! scalar operation sequence precisely:
//!
//! * accumulation is a widen (f32→f64, exact) + multiply + add — **not** a
//!   fused multiply-add, which rounds once instead of twice and would change
//!   low bits;
//! * `chord²` is `(dx·dx + dy·dy) + dz·dz` in the same association as the
//!   scalar [`crate::healpix::chord2`].
//!
//! Backends are selected once per process ([`dispatch`]): an explicit
//! `HEGRID_SIMD` env override (`scalar|avx2|neon`, how CI forces the
//! fallback path), else AVX2+FMA when the CPU reports it, else NEON on
//! aarch64, else scalar. Per-call-site overrides go through [`SimdIsa`]
//! (config `simd_isa` / CLI `--simd`).
//!
//! ```
//! use hegrid::grid::simd::{dispatch, Scalar, SimdBackend};
//!
//! // 4 samples × 1 channel, rows padded to the dispatched lane width.
//! let backend = dispatch();
//! let stride = backend.lanes();
//! let mut vals = vec![0.0f32; 4 * stride];
//! for j in 0..4 {
//!     vals[j * stride] = (j + 1) as f32;
//! }
//! let contrib = [(0.5f64, 0u32), (2.0, 3)]; // (weight, sample index)
//!
//! // Scalar reference…
//! let mut want = vec![0.0f64; stride];
//! Scalar.accumulate_contribs(&mut want, &contrib, &vals, stride, 0);
//! assert_eq!(want[0], 0.5 * 1.0 + 2.0 * 4.0);
//!
//! // …and the dispatched backend (AVX2/NEON/scalar) is bit-identical.
//! let mut got = vec![0.0f64; stride];
//! backend.accumulate_contribs(&mut got, &contrib, &vals, stride, 0);
//! assert_eq!(got[0].to_bits(), want[0].to_bits());
//! ```

use std::sync::OnceLock;

/// Requested instruction set (config `simd_isa` / CLI `--simd`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdIsa {
    /// Use the process-wide dispatched backend ([`dispatch`]).
    #[default]
    Auto,
    Scalar,
    Avx2,
    Neon,
}

impl SimdIsa {
    pub fn name(&self) -> &'static str {
        match self {
            SimdIsa::Auto => "auto",
            SimdIsa::Scalar => "scalar",
            SimdIsa::Avx2 => "avx2",
            SimdIsa::Neon => "neon",
        }
    }

    pub fn from_name(s: &str) -> crate::util::error::Result<Self> {
        match s {
            "auto" | "" => Ok(SimdIsa::Auto),
            "scalar" => Ok(SimdIsa::Scalar),
            "avx2" => Ok(SimdIsa::Avx2),
            "neon" => Ok(SimdIsa::Neon),
            _ => Err(crate::util::error::HegridError::Config(format!(
                "unknown SIMD ISA '{s}' (expected auto|scalar|avx2|neon)"
            ))),
        }
    }

    /// The backend this request resolves to on this host, falling back to
    /// scalar (with a warning) when the forced ISA is not available — a
    /// forced-but-unsupported ISA must degrade, not crash, because configs
    /// travel between machines.
    pub fn resolve(&self) -> &'static dyn SimdBackend {
        match backend_for(*self) {
            Ok(b) => b,
            Err(_) => {
                crate::log_warn!(
                    "simd: ISA '{}' unavailable on this host; falling back to scalar",
                    self.name()
                );
                &Scalar
            }
        }
    }
}

/// One vectorisation strategy for the gridding inner loops.
///
/// Implementations must be **bit-identical** to [`Scalar`] on every input:
/// the equivalence tests force each compiled-in backend against scalar and
/// compare output bits. The whole contributor/range loop lives inside the
/// backend so the per-item cost is not a virtual call.
pub trait SimdBackend: Sync {
    /// Backend name as recorded in bench payloads (`scalar`, `avx2`, `neon`).
    fn name(&self) -> &'static str;

    /// f64 lanes per vector (1 for scalar). Channel blocks and value-matrix
    /// row strides are padded to a multiple of this so the accumulation loop
    /// needs no tail handling.
    fn lanes(&self) -> usize;

    /// For every sample `i` in `0..ux.len()` with
    /// `chord²((ux[i],uy[i],uz[i]), cu) ≤ c2_max`, push
    /// `(chord², base + i)` onto `out`, in ascending `i` order.
    #[allow(clippy::too_many_arguments)]
    fn chord2_filter(
        &self,
        ux: &[f64],
        uy: &[f64],
        uz: &[f64],
        cu: &[f64; 3],
        c2_max: f64,
        base: u32,
        out: &mut Vec<(f64, u32)>,
    );

    /// Blocked accumulation over a contributor list:
    /// `acc[k] += w · vals[j·stride + c0 + k]` for every `(w, j)` of
    /// `contrib` in order, `k` in `0..acc.len()`.
    ///
    /// `acc.len()` must be a multiple of [`SimdBackend::lanes`] and
    /// `c0 + acc.len() ≤ stride` (rows are lane-padded by
    /// [`crate::grid::prep::SharedComponent::value_matrix`]).
    fn accumulate_contribs(
        &self,
        acc: &mut [f64],
        contrib: &[(f64, u32)],
        vals: &[f32],
        stride: usize,
        c0: usize,
    );
}

/// Portable scalar fallback — the reference semantics every other backend
/// must reproduce bit-for-bit.
pub struct Scalar;

impl SimdBackend for Scalar {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn lanes(&self) -> usize {
        1
    }

    #[allow(clippy::too_many_arguments)]
    fn chord2_filter(
        &self,
        ux: &[f64],
        uy: &[f64],
        uz: &[f64],
        cu: &[f64; 3],
        c2_max: f64,
        base: u32,
        out: &mut Vec<(f64, u32)>,
    ) {
        for i in 0..ux.len() {
            let dx = ux[i] - cu[0];
            let dy = uy[i] - cu[1];
            let dz = uz[i] - cu[2];
            let c2 = dx * dx + dy * dy + dz * dz;
            if c2 <= c2_max {
                out.push((c2, base + i as u32));
            }
        }
    }

    fn accumulate_contribs(
        &self,
        acc: &mut [f64],
        contrib: &[(f64, u32)],
        vals: &[f32],
        stride: usize,
        c0: usize,
    ) {
        let width = acc.len();
        for &(w, j) in contrib {
            let base = j as usize * stride + c0;
            let row = &vals[base..base + width];
            for (sum, &v) in acc.iter_mut().zip(row) {
                *sum += w * v as f64;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{Scalar, SimdBackend};
    use std::arch::x86_64::*;

    /// AVX2 backend: 4 f64 lanes. FMA is required for dispatch (every AVX2
    /// part since Haswell has it) but the accumulation deliberately issues
    /// separate multiply + add so results match the scalar two-rounding
    /// sequence bit-for-bit.
    pub struct Avx2;

    pub fn supported() -> bool {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn chord2_filter_impl(
        ux: &[f64],
        uy: &[f64],
        uz: &[f64],
        cu: &[f64; 3],
        c2_max: f64,
        base: u32,
        out: &mut Vec<(f64, u32)>,
    ) {
        let n = ux.len();
        let cx = _mm256_set1_pd(cu[0]);
        let cy = _mm256_set1_pd(cu[1]);
        let cz = _mm256_set1_pd(cu[2]);
        let cmax = _mm256_set1_pd(c2_max);
        let mut i = 0;
        while i + 4 <= n {
            let dx = _mm256_sub_pd(_mm256_loadu_pd(ux.as_ptr().add(i)), cx);
            let dy = _mm256_sub_pd(_mm256_loadu_pd(uy.as_ptr().add(i)), cy);
            let dz = _mm256_sub_pd(_mm256_loadu_pd(uz.as_ptr().add(i)), cz);
            // Same association as the scalar chord2: (dx² + dy²) + dz².
            let c2 = _mm256_add_pd(
                _mm256_add_pd(_mm256_mul_pd(dx, dx), _mm256_mul_pd(dy, dy)),
                _mm256_mul_pd(dz, dz),
            );
            let mask = _mm256_movemask_pd(_mm256_cmp_pd::<_CMP_LE_OQ>(c2, cmax));
            if mask != 0 {
                let mut c2s = [0.0f64; 4];
                _mm256_storeu_pd(c2s.as_mut_ptr(), c2);
                for (k, &c2k) in c2s.iter().enumerate() {
                    if mask & (1 << k) != 0 {
                        out.push((c2k, base + (i + k) as u32));
                    }
                }
            }
            i += 4;
        }
        // Tail: delegate to the scalar reference so the bit-identity
        // semantics live in exactly one place.
        Scalar.chord2_filter(&ux[i..], &uy[i..], &uz[i..], cu, c2_max, base + i as u32, out);
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn accumulate_impl(
        acc: &mut [f64],
        contrib: &[(f64, u32)],
        vals: &[f32],
        stride: usize,
        c0: usize,
    ) {
        let width = acc.len();
        debug_assert!(width % 4 == 0 && c0 + width <= stride);
        for &(w, j) in contrib {
            let wv = _mm256_set1_pd(w);
            let row = vals.as_ptr().add(j as usize * stride + c0);
            let mut k = 0;
            while k < width {
                // Widen 4 f32 → 4 f64 (exact), then mul + add — NOT fmadd,
                // to match the scalar rounding sequence.
                let v = _mm256_cvtps_pd(_mm_loadu_ps(row.add(k)));
                let a = _mm256_loadu_pd(acc.as_ptr().add(k));
                let r = _mm256_add_pd(a, _mm256_mul_pd(wv, v));
                _mm256_storeu_pd(acc.as_mut_ptr().add(k), r);
                k += 4;
            }
        }
    }

    impl super::SimdBackend for Avx2 {
        fn name(&self) -> &'static str {
            "avx2"
        }

        fn lanes(&self) -> usize {
            4
        }

        #[allow(clippy::too_many_arguments)]
        fn chord2_filter(
            &self,
            ux: &[f64],
            uy: &[f64],
            uz: &[f64],
            cu: &[f64; 3],
            c2_max: f64,
            base: u32,
            out: &mut Vec<(f64, u32)>,
        ) {
            debug_assert!(supported());
            unsafe { chord2_filter_impl(ux, uy, uz, cu, c2_max, base, out) }
        }

        fn accumulate_contribs(
            &self,
            acc: &mut [f64],
            contrib: &[(f64, u32)],
            vals: &[f32],
            stride: usize,
            c0: usize,
        ) {
            debug_assert!(supported());
            unsafe { accumulate_impl(acc, contrib, vals, stride, c0) }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{Scalar, SimdBackend};
    use std::arch::aarch64::*;

    /// NEON backend: 2 f64 lanes. Mandatory on aarch64, so "supported" is a
    /// formality kept for symmetry with the AVX2 guard.
    pub struct Neon;

    pub fn supported() -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    #[target_feature(enable = "neon")]
    unsafe fn chord2_filter_impl(
        ux: &[f64],
        uy: &[f64],
        uz: &[f64],
        cu: &[f64; 3],
        c2_max: f64,
        base: u32,
        out: &mut Vec<(f64, u32)>,
    ) {
        let n = ux.len();
        let cx = vdupq_n_f64(cu[0]);
        let cy = vdupq_n_f64(cu[1]);
        let cz = vdupq_n_f64(cu[2]);
        let cmax = vdupq_n_f64(c2_max);
        let mut i = 0;
        while i + 2 <= n {
            let dx = vsubq_f64(vld1q_f64(ux.as_ptr().add(i)), cx);
            let dy = vsubq_f64(vld1q_f64(uy.as_ptr().add(i)), cy);
            let dz = vsubq_f64(vld1q_f64(uz.as_ptr().add(i)), cz);
            // Same association as the scalar chord2: (dx² + dy²) + dz².
            let c2 = vaddq_f64(
                vaddq_f64(vmulq_f64(dx, dx), vmulq_f64(dy, dy)),
                vmulq_f64(dz, dz),
            );
            let le = vcleq_f64(c2, cmax);
            if vgetq_lane_u64::<0>(le) != 0 {
                out.push((vgetq_lane_f64::<0>(c2), base + i as u32));
            }
            if vgetq_lane_u64::<1>(le) != 0 {
                out.push((vgetq_lane_f64::<1>(c2), base + (i + 1) as u32));
            }
            i += 2;
        }
        // Tail: delegate to the scalar reference so the bit-identity
        // semantics live in exactly one place.
        Scalar.chord2_filter(&ux[i..], &uy[i..], &uz[i..], cu, c2_max, base + i as u32, out);
    }

    #[target_feature(enable = "neon")]
    unsafe fn accumulate_impl(
        acc: &mut [f64],
        contrib: &[(f64, u32)],
        vals: &[f32],
        stride: usize,
        c0: usize,
    ) {
        let width = acc.len();
        debug_assert!(width % 2 == 0 && c0 + width <= stride);
        for &(w, j) in contrib {
            let wv = vdupq_n_f64(w);
            let row = vals.as_ptr().add(j as usize * stride + c0);
            let mut k = 0;
            while k < width {
                // Widen 2 f32 → 2 f64 (exact), then mul + add — NOT vfma,
                // to match the scalar rounding sequence.
                let v = vcvt_f64_f32(vld1_f32(row.add(k)));
                let a = vld1q_f64(acc.as_ptr().add(k));
                let r = vaddq_f64(a, vmulq_f64(wv, v));
                vst1q_f64(acc.as_mut_ptr().add(k), r);
                k += 2;
            }
        }
    }

    impl super::SimdBackend for Neon {
        fn name(&self) -> &'static str {
            "neon"
        }

        fn lanes(&self) -> usize {
            2
        }

        #[allow(clippy::too_many_arguments)]
        fn chord2_filter(
            &self,
            ux: &[f64],
            uy: &[f64],
            uz: &[f64],
            cu: &[f64; 3],
            c2_max: f64,
            base: u32,
            out: &mut Vec<(f64, u32)>,
        ) {
            debug_assert!(supported());
            unsafe { chord2_filter_impl(ux, uy, uz, cu, c2_max, base, out) }
        }

        fn accumulate_contribs(
            &self,
            acc: &mut [f64],
            contrib: &[(f64, u32)],
            vals: &[f32],
            stride: usize,
            c0: usize,
        ) {
            debug_assert!(supported());
            unsafe { accumulate_impl(acc, contrib, vals, stride, c0) }
        }
    }
}

/// The backend for an explicit ISA request. `Err` when the ISA is not
/// compiled in or the CPU does not report the feature.
pub fn backend_for(isa: SimdIsa) -> crate::util::error::Result<&'static dyn SimdBackend> {
    use crate::util::error::HegridError;
    match isa {
        SimdIsa::Auto => Ok(dispatch()),
        SimdIsa::Scalar => Ok(&Scalar),
        SimdIsa::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if avx2::supported() {
                return Ok(&avx2::Avx2);
            }
            Err(HegridError::Config("avx2 not available on this host".into()))
        }
        SimdIsa::Neon => {
            #[cfg(target_arch = "aarch64")]
            if neon::supported() {
                return Ok(&neon::Neon);
            }
            Err(HegridError::Config("neon not available on this host".into()))
        }
    }
}

/// Every backend usable on this host, scalar first. The forced-ISA
/// equivalence tests sweep this list against scalar.
pub fn available_backends() -> Vec<&'static dyn SimdBackend> {
    let mut out: Vec<&'static dyn SimdBackend> = vec![&Scalar];
    #[cfg(target_arch = "x86_64")]
    if avx2::supported() {
        out.push(&avx2::Avx2);
    }
    #[cfg(target_arch = "aarch64")]
    if neon::supported() {
        out.push(&neon::Neon);
    }
    out
}

/// The process-wide backend, selected once: `HEGRID_SIMD` env override
/// (invalid or unsupported values warn and fall through), else the widest
/// ISA the CPU reports. Everything that does not carry an explicit
/// [`SimdIsa`] (neighbour builds, `SimdIsa::Auto` gridders) runs on this.
pub fn dispatch() -> &'static dyn SimdBackend {
    static DISPATCHED: OnceLock<&'static dyn SimdBackend> = OnceLock::new();
    *DISPATCHED.get_or_init(|| {
        if let Ok(name) = std::env::var("HEGRID_SIMD") {
            match SimdIsa::from_name(&name) {
                // "auto" (or empty) falls through to detection — calling
                // backend_for(Auto) here would re-enter this OnceLock
                // initialiser through dispatch() and deadlock.
                Ok(SimdIsa::Auto) => {}
                Ok(isa) => match backend_for(isa) {
                    Ok(b) => return b,
                    Err(_) => {
                        crate::log_warn!("simd: ignoring unusable HEGRID_SIMD='{name}'");
                    }
                },
                Err(_) => {
                    crate::log_warn!("simd: ignoring unusable HEGRID_SIMD='{name}'");
                }
            }
        }
        #[cfg(target_arch = "x86_64")]
        if avx2::supported() {
            return &avx2::Avx2;
        }
        #[cfg(target_arch = "aarch64")]
        if neon::supported() {
            return &neon::Neon;
        }
        &Scalar
    })
}

/// A 64-byte-aligned, zero-initialised `f32` buffer — the backing store of
/// the lane-padded value matrix. Alignment keeps vector rows from straddling
/// cache lines more than necessary; the SIMD loads themselves are unaligned
/// so alignment is a performance property, not a safety one.
pub struct AlignedF32 {
    ptr: std::ptr::NonNull<f32>,
    len: usize,
}

// The buffer is plain memory; sharing references across threads is safe.
unsafe impl Send for AlignedF32 {}
unsafe impl Sync for AlignedF32 {}

impl AlignedF32 {
    pub const ALIGN: usize = 64;

    pub fn zeroed(len: usize) -> AlignedF32 {
        if len == 0 {
            return AlignedF32 { ptr: std::ptr::NonNull::dangling(), len: 0 };
        }
        let layout = Self::layout(len);
        let raw = unsafe { std::alloc::alloc_zeroed(layout) };
        let Some(ptr) = std::ptr::NonNull::new(raw as *mut f32) else {
            std::alloc::handle_alloc_error(layout);
        };
        AlignedF32 { ptr, len }
    }

    fn layout(len: usize) -> std::alloc::Layout {
        std::alloc::Layout::from_size_align(len * std::mem::size_of::<f32>(), Self::ALIGN)
            .expect("aligned buffer layout")
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::ops::Deref for AlignedF32 {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl std::ops::DerefMut for AlignedF32 {
    fn deref_mut(&mut self) -> &mut [f32] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Drop for AlignedF32 {
    fn drop(&mut self) {
        if self.len > 0 {
            unsafe { std::alloc::dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.len)) };
        }
    }
}

impl std::fmt::Debug for AlignedF32 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedF32(len={})", self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::healpix::{chord2, unit_vec};
    use crate::util::SplitMix64;

    fn random_units(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = SplitMix64::new(seed);
        let mut ux = Vec::with_capacity(n);
        let mut uy = Vec::with_capacity(n);
        let mut uz = Vec::with_capacity(n);
        for _ in 0..n {
            let u = unit_vec(rng.uniform(0.0, 6.28), rng.uniform(-1.5, 1.5));
            ux.push(u[0]);
            uy.push(u[1]);
            uz.push(u[2]);
        }
        (ux, uy, uz)
    }

    #[test]
    fn isa_names_round_trip() {
        for isa in [SimdIsa::Auto, SimdIsa::Scalar, SimdIsa::Avx2, SimdIsa::Neon] {
            assert_eq!(SimdIsa::from_name(isa.name()).unwrap(), isa);
        }
        assert_eq!(SimdIsa::from_name("").unwrap(), SimdIsa::Auto);
        assert!(SimdIsa::from_name("sse9").is_err());
    }

    #[test]
    fn dispatch_and_fallback_are_sane() {
        let d = dispatch();
        assert!(d.lanes() >= 1);
        // Scalar is always available and always resolvable.
        assert_eq!(backend_for(SimdIsa::Scalar).unwrap().name(), "scalar");
        assert_eq!(SimdIsa::Scalar.resolve().lanes(), 1);
        // An unsupported forced ISA degrades to scalar via resolve().
        let missing = if cfg!(target_arch = "x86_64") { SimdIsa::Neon } else { SimdIsa::Avx2 };
        assert!(backend_for(missing).is_err());
        assert_eq!(missing.resolve().name(), "scalar");
        // available_backends starts with scalar and contains the dispatched
        // backend (unless dispatch was env-forced to something absent, which
        // backend_for would have rejected anyway).
        let avail = available_backends();
        assert_eq!(avail[0].name(), "scalar");
        assert!(avail.iter().any(|b| b.name() == d.name()));
    }

    #[test]
    fn chord2_filter_matches_scalar_bitwise_on_all_backends() {
        // 257 samples: not a multiple of any lane width, exercises tails.
        let (ux, uy, uz) = random_units(257, 7);
        let cu = unit_vec(1.0, 0.3);
        for &c2_max in &[0.05f64, 0.5, f64::INFINITY] {
            let mut want = Vec::new();
            Scalar.chord2_filter(&ux, &uy, &uz, &cu, c2_max, 10, &mut want);
            // Cross-check the scalar backend against the healpix helper.
            for &(c2, j) in &want {
                let a = [ux[(j - 10) as usize], uy[(j - 10) as usize], uz[(j - 10) as usize]];
                assert_eq!(c2.to_bits(), chord2(&a, &cu).to_bits());
            }
            for backend in available_backends() {
                let mut got = Vec::new();
                backend.chord2_filter(&ux, &uy, &uz, &cu, c2_max, 10, &mut got);
                assert_eq!(got.len(), want.len(), "{} c2_max={c2_max}", backend.name());
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.1, w.1, "{}", backend.name());
                    assert_eq!(g.0.to_bits(), w.0.to_bits(), "{}", backend.name());
                }
            }
        }
    }

    #[test]
    fn accumulate_matches_scalar_bitwise_on_all_backends() {
        let mut rng = SplitMix64::new(11);
        let n_samples = 300;
        let n_ch = 13;
        for backend in available_backends() {
            let lanes = backend.lanes();
            let stride = n_ch.next_multiple_of(lanes);
            let mut vals = AlignedF32::zeroed(n_samples * stride);
            for j in 0..n_samples {
                for c in 0..n_ch {
                    vals[j * stride + c] = rng.normal() as f32;
                }
            }
            let contrib: Vec<(f64, u32)> = (0..97)
                .map(|_| {
                    let j = (rng.uniform(0.0, n_samples as f64) as u32).min(n_samples as u32 - 1);
                    (rng.uniform(0.0, 1.0), j)
                })
                .collect();
            for c0 in (0..stride).step_by(lanes.max(4)) {
                let width = (stride - c0).min(lanes.max(4));
                let width = width.next_multiple_of(lanes).min(stride - c0);
                if width == 0 {
                    continue;
                }
                let mut want = vec![0.0f64; width];
                Scalar.accumulate_contribs(&mut want, &contrib, &vals, stride, c0);
                let mut got = vec![0.0f64; width];
                backend.accumulate_contribs(&mut got, &contrib, &vals, stride, c0);
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits(), "{} c0={c0}", backend.name());
                }
            }
        }
    }

    #[test]
    fn aligned_buffer_is_aligned_and_zeroed() {
        let buf = AlignedF32::zeroed(1000);
        assert_eq!(buf.len(), 1000);
        assert_eq!(buf.as_ptr() as usize % AlignedF32::ALIGN, 0);
        assert!(buf.iter().all(|&v| v == 0.0));
        let empty = AlignedF32::zeroed(0);
        assert!(empty.is_empty());
        assert_eq!(&empty[..], &[] as &[f32]);
    }
}
