//! HEALPix RING-scheme pixelation substrate.
//!
//! HEGrid's look-up table is built on HEALPix (Górski et al. 2005): raw
//! samples are binned by `pixel_idx`, sorted, and the contribution region of a
//! target cell is expressed as *per-ring pixel ranges* (Algorithm 1's
//! `ring_min..ring_max` × `pixel_min..pixel_max`). The reference C++/healpy
//! implementation is not available offline, so this module implements the
//! RING scheme from the published formulas, with exhaustive round-trip and
//! property tests (`ang2pix ∘ pix2ang = id` for every pixel at small nside,
//! ring geometry invariants, disc-query completeness against brute force).
//!
//! Conventions: colatitude `θ ∈ [0, π]` measured from the north pole,
//! longitude `φ ∈ [0, 2π)`. Astronomical (ra, dec) maps via `θ = π/2 − dec`.

use crate::util::wrap_2pi;
use std::f64::consts::{FRAC_PI_2, PI, TAU};

/// A HEALPix tessellation of the sphere at a fixed `nside` (RING scheme).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Healpix {
    nside: u64,
    npix: u64,
    ncap: u64,
}

/// Geometry of one iso-latitude ring.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RingInfo {
    /// 1-based ring index from the north pole, `1 ..= 4·nside − 1`.
    pub ring: u64,
    /// Global pixel id of the first pixel in the ring.
    pub start: u64,
    /// Number of pixels in the ring.
    pub count: u64,
    /// z = cos(θ) of the ring's pixel centers.
    pub z: f64,
    /// Longitude of pixel 0's center in the ring.
    pub phi0: f64,
}

/// A contiguous range of global pixel ids (half-open is avoided: inclusive
/// `lo..=hi` keeps the wrap logic simple).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PixRange {
    pub lo: u64,
    pub hi: u64,
}

impl Healpix {
    /// Create a tessellation. `nside` must be ≥ 1 (powers of two recommended;
    /// required by the standard for NESTED but RING works for any nside —
    /// we still enforce powers of two to match the ecosystem).
    pub fn new(nside: u64) -> Healpix {
        assert!(nside >= 1, "nside must be >= 1");
        assert!(nside.is_power_of_two(), "nside must be a power of two");
        Healpix { nside, npix: 12 * nside * nside, ncap: 2 * nside * (nside - 1) }
    }

    /// Choose the smallest power-of-two nside whose mean pixel spacing is at
    /// most `max_spacing_rad`. Used by pre-processing to size the LUT so that
    /// a kernel-support disc spans only a handful of pixels per ring.
    pub fn for_resolution(max_spacing_rad: f64) -> Healpix {
        assert!(max_spacing_rad > 0.0);
        // mean spacing ≈ sqrt(4π / npix) = sqrt(π/3) / nside
        let target = (PI / 3.0f64).sqrt() / max_spacing_rad;
        let nside = (target.ceil() as u64).next_power_of_two().clamp(1, 1 << 20);
        Healpix::new(nside)
    }

    pub fn nside(&self) -> u64 {
        self.nside
    }

    pub fn npix(&self) -> u64 {
        self.npix
    }

    /// Number of iso-latitude rings, `4·nside − 1`.
    pub fn n_rings(&self) -> u64 {
        4 * self.nside - 1
    }

    /// Mean pixel spacing in radians (`sqrt(4π/npix)`).
    pub fn mean_spacing(&self) -> f64 {
        (4.0 * PI / self.npix as f64).sqrt()
    }

    /// Conservative upper bound on the distance from any pixel center to any
    /// point inside that pixel. Empirically max_pixrad·nside ≲ 1.0 over all
    /// nside; we use 1.5/nside and validate by sampling in tests. Disc
    /// queries must be padded by this much to be complete.
    pub fn max_pixrad_bound(&self) -> f64 {
        (1.5 / self.nside as f64).min(PI)
    }

    // ------------------------------------------------------------------
    // ang2pix
    // ------------------------------------------------------------------

    /// Pixel containing the direction `(θ, φ)`.
    pub fn ang2pix(&self, theta: f64, phi: f64) -> u64 {
        assert!((0.0..=PI).contains(&theta), "theta out of range: {theta}");
        let nside = self.nside as i64;
        let z = theta.cos();
        let za = z.abs();
        let tt = wrap_2pi(phi) / FRAC_PI_2; // in [0, 4)

        if za <= 2.0 / 3.0 {
            // Equatorial region.
            let temp1 = nside as f64 * (0.5 + tt);
            let temp2 = nside as f64 * (z * 0.75);
            let jp = (temp1 - temp2) as i64; // ascending edge line
            let jm = (temp1 + temp2) as i64; // descending edge line
            let ir = nside + 1 + jp - jm; // ring counted from z = 2/3, in 1..=2n+1
            let kshift = 1 - (ir & 1);
            let nl4 = 4 * nside;
            let mut ip = (jp + jm - nside + kshift + 1) / 2;
            ip = ip.rem_euclid(nl4);
            (self.ncap as i64 + (ir - 1) * nl4 + ip) as u64
        } else {
            // Polar caps.
            let tp = tt - tt.floor();
            let tmp = nside as f64 * (3.0 * (1.0 - za)).sqrt();
            let jp = (tp * tmp) as i64;
            let jm = ((1.0 - tp) * tmp) as i64;
            let ir = jp + jm + 1; // ring counted from the closest pole
            let ip = ((tt * ir as f64) as i64).rem_euclid(4 * ir);
            if z > 0.0 {
                (2 * ir * (ir - 1) + ip) as u64
            } else {
                (self.npix as i64 - 2 * ir * (ir + 1) + ip) as u64
            }
        }
    }

    /// Pixel containing the sky position `(lon, lat)` in radians
    /// (lat ∈ [−π/2, π/2] — e.g. right ascension / declination).
    pub fn ang2pix_radec(&self, lon: f64, lat: f64) -> u64 {
        self.ang2pix(FRAC_PI_2 - lat, lon)
    }

    // ------------------------------------------------------------------
    // pix2ang
    // ------------------------------------------------------------------

    /// Center direction `(θ, φ)` of a pixel.
    pub fn pix2ang(&self, pix: u64) -> (f64, f64) {
        assert!(pix < self.npix, "pixel {pix} out of range (npix={})", self.npix);
        let nside = self.nside;
        if pix < self.ncap {
            // North polar cap: solve 2·i·(i−1) ≤ pix < 2·i·(i+1) for ring i.
            let iring = cap_ring_north(pix);
            let iphi = pix - 2 * iring * (iring - 1);
            let z = 1.0 - (iring * iring) as f64 / (3.0 * (nside * nside) as f64);
            let phi = (iphi as f64 + 0.5) * FRAC_PI_2 / iring as f64;
            (z.acos(), phi)
        } else if pix < self.npix - self.ncap {
            // Equatorial belt.
            let ip = pix - self.ncap;
            let nl4 = 4 * nside;
            let iring = ip / nl4 + nside; // 1-based ring from north pole
            let iphi = ip % nl4;
            // fodd = 0.5 when (ring+nside) even, 1.0 when odd — encodes the
            // half-pixel phase shift of alternating equatorial rings.
            let fodd = if (iring + nside) & 1 == 1 { 1.0 } else { 0.5 };
            let z = (2 * nside as i64 - iring as i64) as f64 * 2.0 / (3.0 * nside as f64);
            let phi = (iphi as f64 + 1.0 - fodd) * PI / (2.0 * nside as f64);
            (z.acos(), phi)
        } else {
            // South polar cap (mirror of the north).
            let ip = self.npix - pix;
            let iring = cap_ring_south(ip);
            let iphi = 4 * iring + 1 - (ip - 2 * iring * (iring - 1));
            let z = -1.0 + (iring * iring) as f64 / (3.0 * (nside * nside) as f64);
            let phi = (iphi as f64 - 0.5) * FRAC_PI_2 / iring as f64;
            (z.acos(), phi)
        }
    }

    /// Center of a pixel as `(lon, lat)`.
    pub fn pix2radec(&self, pix: u64) -> (f64, f64) {
        let (theta, phi) = self.pix2ang(pix);
        (phi, FRAC_PI_2 - theta)
    }

    // ------------------------------------------------------------------
    // Ring geometry
    // ------------------------------------------------------------------

    /// 1-based ring index of a pixel.
    pub fn ring_of(&self, pix: u64) -> u64 {
        assert!(pix < self.npix);
        if pix < self.ncap {
            cap_ring_north(pix)
        } else if pix < self.npix - self.ncap {
            (pix - self.ncap) / (4 * self.nside) + self.nside
        } else {
            4 * self.nside - cap_ring_south(self.npix - pix)
        }
    }

    /// Geometry of ring `ring` (1-based from the north pole).
    pub fn ring_info(&self, ring: u64) -> RingInfo {
        assert!((1..=self.n_rings()).contains(&ring), "ring {ring} out of range");
        let nside = self.nside;
        if ring < nside {
            // North cap.
            let count = 4 * ring;
            let start = 2 * ring * (ring - 1);
            let z = 1.0 - (ring * ring) as f64 / (3.0 * (nside * nside) as f64);
            RingInfo { ring, start, count, z, phi0: 0.5 * FRAC_PI_2 / ring as f64 }
        } else if ring <= 3 * nside {
            // Equatorial belt.
            let count = 4 * nside;
            let start = self.ncap + (ring - nside) * count;
            let z = (2 * nside as i64 - ring as i64) as f64 * 2.0 / (3.0 * nside as f64);
            let fodd = if (ring + nside) & 1 == 1 { 1.0 } else { 0.5 };
            let phi0 = (1.0 - fodd) * PI / (2.0 * nside as f64);
            RingInfo { ring, start, count, z, phi0 }
        } else {
            // South cap.
            let sring = 4 * nside - ring; // mirrored cap index
            let count = 4 * sring;
            let start = self.npix - 2 * sring * (sring + 1);
            let z = -1.0 + (sring * sring) as f64 / (3.0 * (nside * nside) as f64);
            RingInfo { ring, start, count, z, phi0: 0.5 * FRAC_PI_2 / sring as f64 }
        }
    }

    /// φ step between adjacent pixel centers in a ring.
    pub fn ring_phi_step(&self, info: &RingInfo) -> f64 {
        TAU / info.count as f64
    }

    // ------------------------------------------------------------------
    // Disc queries
    // ------------------------------------------------------------------

    /// All pixels whose *pixels* (not just centers) may intersect the disc of
    /// `radius` around `(θ0, φ0)`, as per-ring inclusive global-id ranges.
    /// Conservative: pads by [`Self::max_pixrad_bound`], so every sample lying
    /// within `radius` of the center is inside the returned ranges (samples
    /// live inside pixels; their pixel's center is at most the bound away).
    /// Ranges are emitted in ascending ring order; a range wrapping φ=0
    /// splits in two. This is Algorithm 1's contribution-region computation.
    pub fn query_disc_rings(&self, theta0: f64, phi0: f64, radius: f64) -> Vec<PixRange> {
        let mut out = Vec::new();
        self.query_disc_rings_into(theta0, phi0, radius, &mut out);
        out
    }

    /// Allocation-free variant of [`Self::query_disc_rings`] for hot loops.
    pub fn query_disc_rings_into(
        &self,
        theta0: f64,
        phi0: f64,
        radius: f64,
        out: &mut Vec<PixRange>,
    ) {
        out.clear();
        let r = radius + self.max_pixrad_bound();
        if r >= PI {
            out.push(PixRange { lo: 0, hi: self.npix - 1 });
            return;
        }
        let phi0 = wrap_2pi(phi0);
        let (ct0, st0) = (theta0.cos(), theta0.sin());
        let cosr = r.cos();

        // Candidate ring band from the z extent of the padded disc:
        // z decreases with ring index, so the disc top (θ_lo, largest z)
        // bounds the first ring and the disc bottom bounds the last.
        let theta_lo = (theta0 - r).max(0.0);
        let theta_hi = (theta0 + r).min(PI);
        let ring_lo = self.ring_above(theta_lo.cos()).max(1);
        let ring_hi = self.ring_below(theta_hi.cos()).min(self.n_rings());

        for ring in ring_lo..=ring_hi {
            let info = self.ring_info(ring);
            let z = info.z;
            let st = (1.0 - z * z).max(0.0).sqrt();
            // cos Δφ_max on this ring.
            let denom = st0 * st;
            let dphi = if denom.abs() < 1e-12 {
                // Ring at a pole or disc centered at a pole: include the
                // whole ring iff the colatitude band overlaps.
                if (theta0 - z.acos()).abs() <= r {
                    PI
                } else {
                    continue;
                }
            } else {
                let x = (cosr - ct0 * z) / denom;
                if x > 1.0 {
                    continue; // ring entirely outside
                } else if x < -1.0 {
                    PI // ring entirely inside
                } else {
                    x.acos()
                }
            };

            self.push_ring_phi_range(&info, phi0, dphi, out);
        }
    }

    /// Inclusive global-pixel span `[lo, hi]` that contains every range
    /// [`Self::query_disc_rings`] can emit for a disc of `radius` centred at
    /// any colatitude in `[theta_lo, theta_hi]` (any φ). Rings are emitted in
    /// ascending pixel-id order and a ring's pixels are contiguous, so the
    /// span is the first pixel of the highest candidate ring through the
    /// last pixel of the lowest — computed with the same padded ring-band
    /// algebra as the disc query itself. One such probe routes a whole
    /// row-band tile of output cells to its sorted-sample slice (the tiled
    /// gridder's per-band binary search).
    pub fn ring_pix_span(&self, theta_lo: f64, theta_hi: f64, radius: f64) -> (u64, u64) {
        debug_assert!(theta_lo <= theta_hi);
        let r = radius + self.max_pixrad_bound();
        if r >= PI {
            return (0, self.npix - 1);
        }
        let t_lo = (theta_lo - r).max(0.0);
        let t_hi = (theta_hi + r).min(PI);
        let ring_lo = self.ring_above(t_lo.cos()).max(1);
        let ring_hi = self.ring_below(t_hi.cos()).min(self.n_rings());
        if ring_lo > ring_hi {
            // Degenerate padded band; stay conservative.
            return (0, self.npix - 1);
        }
        let lo = self.ring_info(ring_lo).start;
        let hi_info = self.ring_info(ring_hi);
        (lo, hi_info.start + hi_info.count - 1)
    }

    /// Append the global-id range(s) of pixels on `ring` whose centers lie in
    /// `φ0 ± Δφ` (padded by one pixel on each side).
    fn push_ring_phi_range(&self, info: &RingInfo, phi0: f64, dphi: f64, out: &mut Vec<PixRange>) {
        let n = info.count as i64;
        if dphi >= PI {
            out.push(PixRange { lo: info.start, hi: info.start + info.count - 1 });
            return;
        }
        let step = TAU / info.count as f64;
        // Pixel j center at φ = phi0_ring + j·step. Solve for j range, pad ±1.
        let j_lo = (((phi0 - dphi) - info.phi0) / step).floor() as i64 - 1;
        let j_hi = (((phi0 + dphi) - info.phi0) / step).ceil() as i64 + 1;
        if j_hi - j_lo + 1 >= n {
            out.push(PixRange { lo: info.start, hi: info.start + info.count - 1 });
            return;
        }
        let a = j_lo.rem_euclid(n) as u64;
        let b = j_hi.rem_euclid(n) as u64;
        if a <= b {
            out.push(PixRange { lo: info.start + a, hi: info.start + b });
        } else {
            // Wraps φ = 0: split into two ranges.
            out.push(PixRange { lo: info.start, hi: info.start + b });
            out.push(PixRange { lo: info.start + a, hi: info.start + info.count - 1 });
        }
    }

    /// Highest ring (smallest index) whose z is ≤ `z` (i.e. first ring at or
    /// below latitude z). Returns 1 if z is above every ring.
    fn ring_above(&self, z: f64) -> u64 {
        // Binary search over rings; z decreases monotonically with ring index.
        let (mut lo, mut hi) = (1u64, self.n_rings());
        if self.ring_info(1).z <= z {
            return 1;
        }
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            if self.ring_info(mid).z <= z {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        hi
    }

    /// Lowest ring (largest index) whose z is ≥ `z`.
    fn ring_below(&self, z: f64) -> u64 {
        let n = self.n_rings();
        if self.ring_info(n).z >= z {
            return n;
        }
        let (mut lo, mut hi) = (1u64, n);
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            if self.ring_info(mid).z >= z {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

/// Integer square root (floor). Uses u128 internally so `u64::MAX` is safe.
fn isqrt(v: u64) -> u64 {
    if v == 0 {
        return 0;
    }
    let v128 = v as u128;
    let mut x = (v as f64).sqrt() as u128;
    // Correct potential off-by-one from float rounding.
    while x * x > v128 {
        x -= 1;
    }
    while (x + 1) * (x + 1) <= v128 {
        x += 1;
    }
    x as u64
}

/// North-cap ring of a cap pixel: smallest i ≥ 1 with pix < 2·i·(i+1).
fn cap_ring_north(pix: u64) -> u64 {
    // pix ∈ [2i(i−1), 2i(i+1)) for ring i ⇒ i = floor((1+sqrt(1+2·pix))/2)
    let i = (1 + isqrt(1 + 2 * pix)) / 2;
    // Guard float/integer edge cases exactly.
    let i = i.max(1);
    if pix < 2 * i * (i - 1) {
        i - 1
    } else if pix >= 2 * i * (i + 1) {
        i + 1
    } else {
        i
    }
}

/// South-cap ring index (counted from the south pole) for `ip = npix − pix`,
/// `ip ∈ [2i(i−1)+1, 2i(i+1)]`.
fn cap_ring_south(ip: u64) -> u64 {
    let i = (1 + isqrt(2 * ip - 1)) / 2;
    let i = i.max(1);
    if ip <= 2 * i * (i - 1) {
        i - 1
    } else if ip > 2 * i * (i + 1) {
        i + 1
    } else {
        i
    }
}

/// Great-circle distance between two directions given as (θ, φ), radians.
pub fn ang_dist(theta1: f64, phi1: f64, theta2: f64, phi2: f64) -> f64 {
    // Haversine on colatitudes.
    let sdt = ((theta2 - theta1) * 0.5).sin();
    let sdp = ((phi2 - phi1) * 0.5).sin();
    let h = sdt * sdt + theta1.sin() * theta2.sin() * sdp * sdp;
    2.0 * h.sqrt().clamp(0.0, 1.0).asin()
}

/// Unit 3-vector of a direction given as (lon, lat), radians.
///
/// The trig half of the chord distance: precompute this per sample
/// ([`crate::grid::prep::SharedComponent`]) and per cell, and the hot-loop
/// distance [`ang_dist_vec`] needs no trig beyond one `asin` per pair.
#[inline]
pub fn unit_vec(lon: f64, lat: f64) -> [f64; 3] {
    let (sin_lat, cos_lat) = lat.sin_cos();
    let (sin_lon, cos_lon) = lon.sin_cos();
    [cos_lat * cos_lon, cos_lat * sin_lon, sin_lat]
}

/// Squared chord length between two unit vectors — a trig-free, monotone
/// proxy for angular distance (`chord = 2·sin(d/2)`), usable directly as a
/// cut-off prefilter.
#[inline]
pub fn chord2(a: &[f64; 3], b: &[f64; 3]) -> f64 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    let dz = a[2] - b[2];
    dx * dx + dy * dy + dz * dz
}

/// Arc length from a squared chord: `d = 2·asin(√c²/2)`.
///
/// Numerically stable at small separations — the chord is formed from
/// coordinate *differences*, so there is no `acos(≈1)` cancellation; agrees
/// with the haversine [`ang_dist`] to ~1 ulp (pinned by tests).
#[inline]
pub fn chord2_to_arc(c2: f64) -> f64 {
    2.0 * (0.5 * c2.sqrt()).min(1.0).asin()
}

/// Angular distance between two precomputed unit vectors (see [`unit_vec`]).
#[inline]
pub fn ang_dist_vec(a: &[f64; 3], b: &[f64; 3]) -> f64 {
    chord2_to_arc(chord2(a, b))
}

/// Squared-chord prefilter bound for an arc-distance cut at `radius`:
/// `(2·sin(radius/2))²`, padded by 1e-9 **relative** so rounding differences
/// between the chord and arc formulations at the exact boundary can only
/// *add* a candidate for the exact downstream test, never drop a true one.
/// A radius ≥ π covers the whole sphere (sin is no longer monotone there),
/// so the prefilter is disabled (`+∞`). Shared by the gridding and
/// neighbour-walk hot loops (`grid::cpu`, `grid::nbr`).
#[inline]
pub fn chord2_prefilter_bound(radius: f64) -> f64 {
    if radius >= PI {
        f64::INFINITY
    } else {
        let half = (0.5 * radius).sin();
        4.0 * half * half * (1.0 + 1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn npix_and_rings() {
        for nside in [1u64, 2, 4, 8, 16] {
            let hp = Healpix::new(nside);
            assert_eq!(hp.npix(), 12 * nside * nside);
            assert_eq!(hp.n_rings(), 4 * nside - 1);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        Healpix::new(3);
    }

    #[test]
    fn ring_pixel_counts_partition_sphere() {
        for nside in [1u64, 2, 4, 8, 32] {
            let hp = Healpix::new(nside);
            let mut total = 0;
            let mut expected_start = 0;
            for ring in 1..=hp.n_rings() {
                let info = hp.ring_info(ring);
                assert_eq!(info.start, expected_start, "ring {ring} nside {nside}");
                expected_start += info.count;
                total += info.count;
            }
            assert_eq!(total, hp.npix());
        }
    }

    #[test]
    fn ring_z_strictly_decreasing() {
        let hp = Healpix::new(16);
        let mut prev = f64::INFINITY;
        for ring in 1..=hp.n_rings() {
            let z = hp.ring_info(ring).z;
            assert!(z < prev, "ring {ring}: z {z} !< {prev}");
            prev = z;
        }
    }

    #[test]
    fn pix2ang_round_trips_every_pixel_small_nside() {
        for nside in [1u64, 2, 4, 8, 16] {
            let hp = Healpix::new(nside);
            for pix in 0..hp.npix() {
                let (theta, phi) = hp.pix2ang(pix);
                assert!((0.0..=PI).contains(&theta));
                assert!((0.0..TAU).contains(&phi), "pix {pix} phi {phi}");
                let back = hp.ang2pix(theta, phi);
                assert_eq!(back, pix, "nside={nside} pix={pix} θ={theta} φ={phi}");
            }
        }
    }

    #[test]
    fn ring_of_matches_pix2ang_z() {
        for nside in [1u64, 4, 16] {
            let hp = Healpix::new(nside);
            for pix in 0..hp.npix() {
                let ring = hp.ring_of(pix);
                let info = hp.ring_info(ring);
                assert!(pix >= info.start && pix < info.start + info.count);
                let (theta, _) = hp.pix2ang(pix);
                assert!((theta.cos() - info.z).abs() < 1e-12, "pix {pix}");
            }
        }
    }

    #[test]
    fn ang2pix_random_directions_in_range() {
        let hp = Healpix::new(64);
        let mut rng = SplitMix64::new(2024);
        for _ in 0..20_000 {
            let z = rng.uniform(-1.0, 1.0);
            let phi = rng.uniform(0.0, TAU);
            let pix = hp.ang2pix(z.acos(), phi);
            assert!(pix < hp.npix());
        }
    }

    #[test]
    fn center_distance_within_pixrad_bound() {
        for nside in [1u64, 4, 64, 1024] {
            let hp = Healpix::new(nside);
            let bound = hp.max_pixrad_bound();
            let mut rng = SplitMix64::new(7 + nside);
            for _ in 0..5000 {
                let z: f64 = rng.uniform(-1.0, 1.0);
                let phi = rng.uniform(0.0, TAU);
                let theta = z.acos();
                let pix = hp.ang2pix(theta, phi);
                let (tc, pc) = hp.pix2ang(pix);
                let d = ang_dist(theta, phi, tc, pc);
                assert!(d <= bound, "nside={nside} d={d} bound={bound}");
            }
        }
    }

    #[test]
    fn poles_map_to_cap_rings() {
        let hp = Healpix::new(8);
        let north = hp.ang2pix(0.0, 0.3);
        let south = hp.ang2pix(PI, 0.3);
        assert!(north < 4, "north pole pixel {north}");
        assert!(south >= hp.npix() - 4, "south pole pixel {south}");
    }

    #[test]
    fn radec_helpers_consistent() {
        let hp = Healpix::new(32);
        let (lon, lat) = (1.234, 0.345);
        let pix = hp.ang2pix_radec(lon, lat);
        assert_eq!(pix, hp.ang2pix(FRAC_PI_2 - lat, lon));
        let (plon, plat) = hp.pix2radec(pix);
        assert!(ang_dist(FRAC_PI_2 - lat, lon, FRAC_PI_2 - plat, plon) < hp.max_pixrad_bound());
    }

    #[test]
    fn chord_distance_matches_haversine() {
        let mut rng = SplitMix64::new(99);
        for _ in 0..5000 {
            let (lon1, lat1) = (rng.uniform(0.0, TAU), rng.uniform(-1.5, 1.5));
            let (lon2, lat2) = (rng.uniform(0.0, TAU), rng.uniform(-1.5, 1.5));
            let d_h = ang_dist(FRAC_PI_2 - lat1, lon1, FRAC_PI_2 - lat2, lon2);
            let d_c = ang_dist_vec(&unit_vec(lon1, lat1), &unit_vec(lon2, lat2));
            // Both are stable formulations; near-antipodal pairs amplify the
            // asin, hence the |π − d| guard on the tight bound.
            let tol = if (PI - d_h).abs() > 1e-3 { 1e-12 * (1.0 + d_h) } else { 1e-9 };
            assert!((d_c - d_h).abs() <= tol, "({lon1},{lat1})-({lon2},{lat2}): {d_c} vs {d_h}");
        }
    }

    #[test]
    fn chord_distance_small_separations_exact_scale() {
        // The chord's error is *absolute* (~ulps of the O(1) vector
        // components), so the bound is abs + rel, not purely relative.
        let mut rng = SplitMix64::new(100);
        for _ in 0..2000 {
            let (lon, lat) = (rng.uniform(0.0, TAU), rng.uniform(-1.4, 1.4));
            let eps = rng.uniform(1e-9, 1e-3);
            let d_h = ang_dist(FRAC_PI_2 - lat, lon, FRAC_PI_2 - (lat + eps), lon);
            let d_c = ang_dist_vec(&unit_vec(lon, lat), &unit_vec(lon, lat + eps));
            assert!((d_c - d_h).abs() <= 1e-14 + 1e-12 * d_h, "{d_c} vs {d_h} at eps={eps}");
        }
    }

    #[test]
    fn chord_helpers_edge_values() {
        let a = unit_vec(0.3, 0.7);
        assert_eq!(chord2(&a, &a), 0.0);
        assert_eq!(ang_dist_vec(&a, &a), 0.0);
        // Unit norm.
        let n2 = a[0] * a[0] + a[1] * a[1] + a[2] * a[2];
        assert!((n2 - 1.0).abs() < 1e-15);
        // Antipodal: chord² = 4 ⇒ arc = π (min-clamp guards rounding above 1).
        assert!((chord2_to_arc(4.0) - PI).abs() < 1e-12);
        assert!((chord2_to_arc(4.0 + 1e-9) - PI).abs() < 1e-12);
        let b = unit_vec(0.3 + PI, -0.7);
        assert!((ang_dist_vec(&a, &b) - PI).abs() < 1e-7);
    }

    /// Brute-force completeness: every pixel whose center is within `r` of the
    /// disc center must be covered by the returned ranges.
    #[test]
    fn query_disc_complete_vs_brute_force() {
        for nside in [4u64, 16, 64] {
            let hp = Healpix::new(nside);
            let mut rng = SplitMix64::new(nside * 31 + 1);
            for _ in 0..40 {
                let z = rng.uniform(-0.999, 0.999);
                let theta0 = z.acos();
                let phi0 = rng.uniform(0.0, TAU);
                let radius = rng.uniform(0.01, 0.8);
                let ranges = hp.query_disc_rings(theta0, phi0, radius);
                // ranges sane
                for r in &ranges {
                    assert!(r.lo <= r.hi && r.hi < hp.npix());
                }
                let inside = |pix: u64| {
                    ranges.iter().any(|r| (r.lo..=r.hi).contains(&pix))
                };
                for pix in 0..hp.npix() {
                    let (t, p) = hp.pix2ang(pix);
                    if ang_dist(theta0, phi0, t, p) <= radius {
                        assert!(
                            inside(pix),
                            "nside={nside} missing pix {pix} at d={} r={radius}",
                            ang_dist(theta0, phi0, t, p)
                        );
                    }
                }
            }
        }
    }

    /// Conservativeness sanity: the query should not return the whole sphere
    /// for a small disc at a moderate nside.
    #[test]
    fn query_disc_not_absurdly_loose() {
        let hp = Healpix::new(256);
        let ranges = hp.query_disc_rings(1.0, 1.0, 0.01);
        let total: u64 = ranges.iter().map(|r| r.hi - r.lo + 1).sum();
        // disc area fraction ≈ (r+pad)²/4 ⇒ a few hundred pixels at nside 256
        assert!(total > 0);
        assert!(total < hp.npix() / 100, "query too loose: {total} pixels");
    }

    #[test]
    fn query_disc_wraps_phi_zero() {
        let hp = Healpix::new(32);
        // Disc straddling φ=0 on the equator.
        let ranges = hp.query_disc_rings(FRAC_PI_2, 0.02, 0.05);
        assert!(!ranges.is_empty());
        // Every equatorial ring covered must include pixel ranges on both
        // sides of φ=0 (i.e. at least one ring contributes two ranges).
        let mut per_ring = std::collections::BTreeMap::new();
        for r in &ranges {
            *per_ring.entry(hp.ring_of(r.lo)).or_insert(0) += 1;
        }
        assert!(per_ring.values().any(|&c| c == 2), "expected a wrapped ring: {per_ring:?}");
    }

    #[test]
    fn whole_sphere_query() {
        let hp = Healpix::new(8);
        let ranges = hp.query_disc_rings(1.0, 2.0, PI);
        assert_eq!(ranges, vec![PixRange { lo: 0, hi: hp.npix() - 1 }]);
    }

    #[test]
    fn isqrt_exact() {
        for v in 0..5000u64 {
            let r = isqrt(v);
            assert!(r * r <= v && (r + 1) * (r + 1) > v, "isqrt({v}) = {r}");
        }
        assert_eq!(isqrt(u64::MAX), u32::MAX as u64);
    }

    #[test]
    fn for_resolution_scales() {
        let coarse = Healpix::for_resolution(0.1);
        let fine = Healpix::for_resolution(0.001);
        assert!(fine.nside() > coarse.nside());
        assert!(coarse.mean_spacing() <= 0.1 + 1e-9);
        assert!(fine.mean_spacing() <= 0.001 + 1e-9);
    }

    #[test]
    fn ang_dist_basics() {
        assert!(ang_dist(1.0, 2.0, 1.0, 2.0) < 1e-12);
        let d = ang_dist(FRAC_PI_2, 0.0, FRAC_PI_2, PI);
        assert!((d - PI).abs() < 1e-9);
    }
}
