//! Run configuration for the HEGrid engine, with JSON (de)serialisation.
//!
//! Every knob the paper sweeps is a field here: stream count (Fig 15), the
//! shared pre-processing component (Fig 11/12), the Pallas block size
//! (Fig 13/14), the thread-level reuse factor γ (Fig 16), channels per
//! dispatch, and the device profile (Table 4 portability).

use crate::json::Json;
use crate::util::error::{HegridError, Result};

/// Hardware profile — the Table-4 portability axis. Profiles cap the
/// concurrency resources the engine may use, modelling the V100-class
/// (Server_V) vs MI50-class (Server_M) gap the paper measures: the MI50
/// schedules at most 128 parallel threads per CU for HEGrid's kernel, so
/// Server_M runs with fewer stream slots and smaller dispatch tiles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceProfile {
    /// Xeon Gold 6151 + V100-class budget.
    ServerV,
    /// Xeon E5-2620 + MI50-class budget (reduced concurrency).
    ServerM,
}

impl DeviceProfile {
    pub fn name(&self) -> &'static str {
        match self {
            DeviceProfile::ServerV => "server_v",
            DeviceProfile::ServerM => "server_m",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "server_v" | "v" | "V" => Ok(DeviceProfile::ServerV),
            "server_m" | "m" | "M" => Ok(DeviceProfile::ServerM),
            _ => Err(HegridError::Config(format!("unknown device profile '{s}'"))),
        }
    }

    /// Maximum concurrent PJRT stream slots.
    pub fn max_streams(&self) -> usize {
        match self {
            DeviceProfile::ServerV => 8,
            DeviceProfile::ServerM => 2,
        }
    }

    /// Preferred Pallas block size (the Fig-13 optimum for the profile).
    pub fn preferred_block(&self) -> usize {
        match self {
            DeviceProfile::ServerV => 256,
            DeviceProfile::ServerM => 128,
        }
    }

    /// Register budget per SM/CU used by the occupancy model (Fig 13).
    pub fn registers_per_sm(&self) -> usize {
        match self {
            DeviceProfile::ServerV => 65_536,
            DeviceProfile::ServerM => 65_536,
        }
    }

    /// Max parallel threads the profile can co-schedule per SM/CU
    /// ("thread blocks can only schedule up to 128 parallel threads ... on
    /// the MI50" — §5.4).
    pub fn max_parallel_threads(&self) -> usize {
        match self {
            DeviceProfile::ServerV => 2 * 352,
            DeviceProfile::ServerM => 128,
        }
    }
}

/// Interferometric uv-plane gridding block (`hegrid uv-grid`; the
/// `uv_grid` object in config JSON). Geometry and kernel of the
/// [`crate::grid::uv::UvGridder`] — see docs/uv-gridding.md.
#[derive(Clone, Debug, PartialEq)]
pub struct UvConfig {
    /// Grid width in cells (u axis, the fast axis).
    pub n_u: usize,
    /// Grid height in cells (v axis).
    pub n_v: usize,
    /// Cell size in wavelengths per pixel.
    pub cell_wavelengths: f64,
    /// Separable kernel family: gaussian | spheroidal.
    pub kernel_type: String,
    /// Kernel support radius in cells (table ends there).
    pub kernel_support: usize,
    /// Lookup-table samples per cell distance.
    pub kernel_oversample: usize,
    /// Gaussian σ in cells (ignored by the spheroidal family).
    pub kernel_sigma_cells: f64,
    /// Row-band height of the tiled uv sweep; 0 = whole grid in one band.
    /// Bit-identical for every value.
    pub tile_rows: usize,
    /// Also deposit each sample's complex conjugate at (−u, −v).
    pub hermitian: bool,
}

impl Default for UvConfig {
    fn default() -> Self {
        UvConfig {
            n_u: 256,
            n_v: 256,
            cell_wavelengths: 50.0,
            kernel_type: "spheroidal".into(),
            kernel_support: 3,
            kernel_oversample: 128,
            kernel_sigma_cells: 1.0,
            tile_rows: 0,
            hermitian: true,
        }
    }
}

impl UvConfig {
    pub fn validate(&self) -> Result<()> {
        // Kernel-family, support, oversample, and σ ranges are enforced by
        // the kernel constructor; grid shape by the spec. Building both
        // here keeps one source of truth for the bounds.
        crate::grid::uv::UvGridSpec::new(self.n_u, self.n_v, self.cell_wavelengths).validate()?;
        let kind = crate::grid::uv::UvKernelType::from_name(&self.kernel_type)?;
        crate::grid::uv::UvKernel::new(
            kind,
            self.kernel_support,
            self.kernel_oversample,
            self.kernel_sigma_cells,
        )?;
        Ok(())
    }

    /// Build the configured gridder (kernel table included). `validate()`
    /// in constructor form.
    pub fn build_gridder(&self) -> Result<crate::grid::uv::UvGridder> {
        let spec = crate::grid::uv::UvGridSpec::new(self.n_u, self.n_v, self.cell_wavelengths);
        spec.validate()?;
        let kind = crate::grid::uv::UvKernelType::from_name(&self.kernel_type)?;
        let kernel = crate::grid::uv::UvKernel::new(
            kind,
            self.kernel_support,
            self.kernel_oversample,
            self.kernel_sigma_cells,
        )?;
        Ok(crate::grid::uv::UvGridder::new(spec, kernel)
            .with_tile_rows(self.tile_rows)
            .with_hermitian(self.hermitian))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_u", Json::num(self.n_u as f64)),
            ("n_v", Json::num(self.n_v as f64)),
            ("cell_wavelengths", Json::num(self.cell_wavelengths)),
            ("kernel_type", Json::str(self.kernel_type.clone())),
            ("kernel_support", Json::num(self.kernel_support as f64)),
            ("kernel_oversample", Json::num(self.kernel_oversample as f64)),
            ("kernel_sigma_cells", Json::num(self.kernel_sigma_cells)),
            ("tile_rows", Json::num(self.tile_rows as f64)),
            ("hermitian", Json::Bool(self.hermitian)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let d = UvConfig::default();
        let get_usize = |k: &str, dv: usize| -> Result<usize> {
            match v.get(k) {
                Some(x) => x.as_usize().ok_or_else(|| {
                    HegridError::Config(format!(
                        "uv_grid field '{k}' must be a non-negative integer"
                    ))
                }),
                None => Ok(dv),
            }
        };
        let get_f64 = |k: &str, dv: f64| -> Result<f64> {
            match v.get(k) {
                Some(x) => x.as_f64().ok_or_else(|| {
                    HegridError::Config(format!("uv_grid field '{k}' must be a number"))
                }),
                None => Ok(dv),
            }
        };
        Ok(UvConfig {
            n_u: get_usize("n_u", d.n_u)?,
            n_v: get_usize("n_v", d.n_v)?,
            cell_wavelengths: get_f64("cell_wavelengths", d.cell_wavelengths)?,
            kernel_type: v
                .get("kernel_type")
                .and_then(|x| x.as_str())
                .unwrap_or(&d.kernel_type)
                .to_string(),
            kernel_support: get_usize("kernel_support", d.kernel_support)?,
            kernel_oversample: get_usize("kernel_oversample", d.kernel_oversample)?,
            kernel_sigma_cells: get_f64("kernel_sigma_cells", d.kernel_sigma_cells)?,
            tile_rows: get_usize("tile_rows", d.tile_rows)?,
            hermitian: v.get("hermitian").and_then(|x| x.as_bool()).unwrap_or(d.hermitian),
        })
    }
}

/// Engine configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct HegridConfig {
    /// Directory holding `manifest.json` + `*.hlo.txt`.
    pub artifacts_dir: String,
    /// Concurrent PJRT stream slots (paper: GPU streams). 0 = profile default.
    pub streams: usize,
    /// CPU pipeline worker threads (paper: CPU processes). 0 = auto.
    pub pipelines: usize,
    /// Channel-group pipelines in flight at once on the persistent executor:
    /// while group *k* grids (T3), group *k+1* permutes (T1–T2) and group
    /// *k+2* prefetches (T0). Takes precedence over `pipelines` when set;
    /// 0 = fall back to `pipelines`/auto. 1 = the sequential coordinator.
    pub pipeline_width: usize,
    /// Adaptive pipeline width (CLI `--pipeline-width auto`): start narrow
    /// and let the coordinator's occupancy controller shrink/grow the
    /// concurrent pipeline count from measured stage occupancy (shrink when
    /// T3 saturates the streams or T0 starves the pipelines, grow while
    /// pipelines are busy and streams have headroom). Takes precedence over
    /// `pipeline_width`/`pipelines`; bounded by `pipeline_width_max`.
    /// Results stay bit-identical to every fixed width.
    pub pipeline_width_auto: bool,
    /// Upper bound of the adaptive width controller (CLI
    /// `--pipeline-width-max`). 0 = auto (min(host parallelism, 8)).
    pub pipeline_width_max: usize,
    /// Channels per device dispatch (C of the artifact variant).
    pub channels_per_dispatch: usize,
    /// Share the pre-processing component across pipelines (Fig 11/12 knob).
    pub share_preprocessing: bool,
    /// Thread-level reuse factor γ (Fig 16). 1 = off.
    pub gamma: usize,
    /// Pallas block size bm (Fig 13). 0 = profile default.
    pub block_size: usize,
    /// Channel-block width B of the CPU gridder's blocked accumulation
    /// (Cygrid baseline / accuracy oracle hot path). 0 = built-in default;
    /// rounded up to the SIMD lane width at run time.
    pub cpu_channel_block: usize,
    /// SIMD ISA of the CPU gridding hot path: auto | scalar | avx2 | neon
    /// (CLI `--simd`). `auto` uses the process-wide dispatched backend; a
    /// forced ISA unavailable on the host degrades to scalar with a warning.
    pub simd_isa: String,
    /// Core-affinity policy for the executor's pool workers:
    /// none | compact | spread (CLI `--affinity`; Linux only, best effort,
    /// behind the default-on `affinity` feature).
    pub executor_affinity: String,
    /// Streaming ingest (T0): channel groups the I/O workers read ahead of
    /// the pipelines. Also bounds how many groups are ever resident, so it
    /// is the memory/overlap trade-off knob. 1 = no read-ahead.
    pub prefetch_depth: usize,
    /// I/O worker threads feeding the prefetcher. 0 = auto
    /// (min(2, prefetch_depth)).
    pub io_workers: usize,
    /// Output-tile height in grid rows (CLI `--tile-rows`). 0 = untiled
    /// legacy path (the whole map is one accumulator). With `R > 0` the
    /// engine reduces each channel group band by band into tile-sized
    /// accumulators and streams finished bands into an on-disk output cube,
    /// bounding peak memory by `O(tile × pipeline_width)` instead of
    /// `O(map × channels)`. Results are bit-identical for every value.
    pub output_tile_rows: usize,
    /// Checkpoint directory for tiled runs (CLI `--checkpoint`). Empty =
    /// spill to an anonymous temp cube that is deleted on completion. When
    /// set, the tiled reducer writes the output cube plus a CRC'd manifest
    /// there after every finished channel group, which `resume` picks up.
    pub checkpoint_dir: String,
    /// Resume a tiled run from `checkpoint_dir` (CLI `--resume`): verify the
    /// manifest, skip channel groups it records as finished, and grid only
    /// the rest — producing a cube bit-identical to an uninterrupted run.
    /// Requires a non-empty `checkpoint_dir`.
    pub resume: bool,
    /// Abort the run on the first unrecoverable per-group failure (today's
    /// semantics; the default). `false` (CLI `--degrade`) quarantines the
    /// failing channel group instead: its output planes are zeroed, it is
    /// recorded in `DegradationReport` (and as `failed` in the checkpoint
    /// manifest, so `--resume` retries exactly the quarantined groups), and
    /// the run completes with every surviving group bit-identical.
    pub fail_fast: bool,
    /// Retries after a failed channel read before the error is terminal
    /// (transient I/O and CRC errors only; format errors never retry).
    /// 0 = no retry. Applies in both fail-fast and degrade modes.
    pub retry_io: usize,
    /// Base backoff in milliseconds between channel-read retries, doubled
    /// on each attempt (10 → 10 ms, 20 ms, 40 ms, ...). 0 = retry
    /// immediately.
    pub retry_io_backoff_ms: usize,
    /// Supervised multi-process sharding (CLI `--shard-procs`): partition
    /// the output map into this many contiguous row ranges and grid each in
    /// a child worker process (`hegrid shard-worker`, a re-exec of this
    /// binary) under the parent's supervisor loop — heartbeats, liveness
    /// timeout, bounded restart, deterministic shard-ascending merge.
    /// 0 = off (single-process, today's semantics). Requires a non-empty
    /// `checkpoint_dir` (shard checkpoints + the merged cube live there).
    pub shard_procs: usize,
    /// Restarts granted to each shard worker before the shard is given up
    /// on: quarantined like a degraded channel group (planes zeroed, cause
    /// recorded in `DegradationReport`) under `--degrade`, a fatal error
    /// under `--fail-fast`.
    pub shard_max_restarts: usize,
    /// Liveness timeout in seconds: a worker that emits no heartbeat frame
    /// for this long is declared hung, SIGKILLed, and restarted (counting
    /// against `shard_max_restarts`). 0 = no liveness timeout (exit-status
    /// supervision only).
    pub shard_heartbeat_timeout_s: usize,
    /// Base backoff in milliseconds before restarting a dead shard worker,
    /// doubled on each successive restart of the same shard (exponential,
    /// capped at 30 s). 0 = restart immediately.
    pub shard_restart_backoff_ms: usize,
    /// Fault-injection spec (`<seed>:<site>@<target>[x<count>][%<prob>]`,
    /// comma-separated; see `util::faults`). Empty = no injection (the
    /// `HEGRID_FAULTS` env var is consulted instead). Non-empty specs are
    /// rejected unless the crate was built with `--features fault-injection`.
    pub faults: String,
    /// Width governor: a stage counts as saturating its backing resource
    /// when its occupancy reaches `resource_count × width_saturation`
    /// (shrink trigger for both stream-bound T3 and starved-T0 detection).
    pub width_saturation: f64,
    /// Width governor: grow only while the mean per-pipeline busy fraction
    /// is at least this (pipelines are actually loaded, not idling).
    pub width_busy_grow: f64,
    /// Width governor: a starved-T0 shrink additionally requires the mean
    /// per-pipeline busy fraction at or below this bound.
    pub width_idle_shrink: f64,
    /// Convolution kernel type: gauss1d | gauss2d | tapered_sinc.
    pub kernel_type: String,
    /// Exact artifact variant name to use, bypassing selection (benches,
    /// debugging). Empty = automatic selection.
    pub variant_override: String,
    /// Kernel σ as a multiple of the beam σ (cygrid convention: 0.5–1).
    pub kernel_sigma_beam: f64,
    /// Kernel support radius as a multiple of kernel σ.
    pub support_sigma: f64,
    /// Target map oversampling (cells per beam FWHM).
    pub oversample: f64,
    /// Interferometric uv-plane gridding block (`hegrid uv-grid`).
    pub uv_grid: UvConfig,
    /// Device profile (Table 4).
    pub profile: DeviceProfile,
}

impl Default for HegridConfig {
    fn default() -> Self {
        HegridConfig {
            artifacts_dir: "artifacts".into(),
            streams: 0,
            pipelines: 0,
            pipeline_width: 0,
            pipeline_width_auto: false,
            pipeline_width_max: 0,
            channels_per_dispatch: 10,
            share_preprocessing: true,
            gamma: 1,
            block_size: 0,
            cpu_channel_block: 0,
            simd_isa: "auto".into(),
            executor_affinity: "none".into(),
            prefetch_depth: 2,
            io_workers: 0,
            output_tile_rows: 0,
            checkpoint_dir: String::new(),
            resume: false,
            fail_fast: true,
            retry_io: 2,
            retry_io_backoff_ms: 10,
            shard_procs: 0,
            shard_max_restarts: 2,
            shard_heartbeat_timeout_s: 30,
            shard_restart_backoff_ms: 200,
            faults: String::new(),
            width_saturation: 0.85,
            width_busy_grow: 0.75,
            width_idle_shrink: 0.35,
            kernel_type: "gauss1d".into(),
            variant_override: String::new(),
            kernel_sigma_beam: 0.5,
            support_sigma: 3.0,
            oversample: 2.0,
            uv_grid: UvConfig::default(),
            profile: DeviceProfile::ServerV,
        }
    }
}

impl HegridConfig {
    /// Effective stream count after applying the profile cap. When unset,
    /// defaults to min(profile budget, host parallelism): each stream slot
    /// owns a PJRT client + compiled executables, so slots beyond the
    /// physical parallelism only add compile time and contention (§Perf).
    pub fn effective_streams(&self) -> usize {
        let want = if self.streams == 0 {
            self.profile.max_streams().min(crate::util::threads::default_parallelism())
        } else {
            self.streams
        };
        want.clamp(1, self.profile.max_streams().max(1))
    }

    /// Effective pipeline worker count (the run's pipeline width):
    /// `pipeline_width` when set, else `pipelines`, else auto. With
    /// `pipeline_width_auto` this is only the *fixed-width fallback*; the
    /// coordinator starts from [`HegridConfig::effective_width_max`] slots
    /// and lets the controller pick the live width.
    pub fn effective_pipelines(&self) -> usize {
        if self.pipeline_width > 0 {
            self.pipeline_width
        } else if self.pipelines == 0 {
            crate::util::threads::default_parallelism().min(8)
        } else {
            self.pipelines
        }
    }

    /// Upper bound of the adaptive width controller:
    /// `pipeline_width_max` when set, else min(host parallelism, 8).
    pub fn effective_width_max(&self) -> usize {
        if self.pipeline_width_max > 0 {
            self.pipeline_width_max
        } else {
            crate::util::threads::default_parallelism().min(8).max(1)
        }
    }

    /// Effective I/O worker count: capped by the prefetch window (a worker
    /// beyond the window can never claim a slot, it would only block).
    pub fn effective_io_workers(&self) -> usize {
        let want = if self.io_workers == 0 { 2 } else { self.io_workers };
        want.clamp(1, self.prefetch_depth.max(1))
    }

    /// Parsed SIMD ISA request (validated names only; `auto` after a
    /// `validate()`-passing construction can never hit the fallback).
    pub fn simd(&self) -> crate::grid::simd::SimdIsa {
        crate::grid::simd::SimdIsa::from_name(&self.simd_isa).unwrap_or_default()
    }

    /// Parsed executor-affinity policy (same validation contract as
    /// [`HegridConfig::simd`]).
    pub fn affinity(&self) -> crate::util::threads::AffinityMode {
        crate::util::threads::AffinityMode::from_name(&self.executor_affinity).unwrap_or_default()
    }

    /// Effective Pallas block size.
    pub fn effective_block(&self) -> usize {
        if self.block_size == 0 {
            self.profile.preferred_block()
        } else {
            self.block_size
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !["gauss1d", "gauss2d", "tapered_sinc"].contains(&self.kernel_type.as_str()) {
            return Err(HegridError::Config(format!(
                "unknown kernel type '{}'",
                self.kernel_type
            )));
        }
        if self.gamma == 0 || self.gamma > 8 {
            return Err(HegridError::Config(format!("gamma {} out of range 1..=8", self.gamma)));
        }
        if self.channels_per_dispatch == 0 {
            return Err(HegridError::Config("channels_per_dispatch must be >= 1".into()));
        }
        if self.pipeline_width > 64 {
            return Err(HegridError::Config(format!(
                "pipeline_width {} out of range 0..=64",
                self.pipeline_width
            )));
        }
        if self.pipeline_width_max > 64 {
            return Err(HegridError::Config(format!(
                "pipeline_width_max {} out of range 0..=64",
                self.pipeline_width_max
            )));
        }
        if self.prefetch_depth == 0 || self.prefetch_depth > 1024 {
            return Err(HegridError::Config(format!(
                "prefetch_depth {} out of range 1..=1024",
                self.prefetch_depth
            )));
        }
        if self.cpu_channel_block > 4096 {
            return Err(HegridError::Config(format!(
                "cpu_channel_block {} out of range 0..=4096",
                self.cpu_channel_block
            )));
        }
        if self.resume && self.checkpoint_dir.is_empty() {
            return Err(HegridError::Config(
                "resume requires a checkpoint_dir (--checkpoint <dir> --resume)".into(),
            ));
        }
        if self.retry_io > 16 {
            return Err(HegridError::Config(format!(
                "retry_io {} out of range 0..=16",
                self.retry_io
            )));
        }
        if self.retry_io_backoff_ms > 60_000 {
            return Err(HegridError::Config(format!(
                "retry_io_backoff_ms {} out of range 0..=60000",
                self.retry_io_backoff_ms
            )));
        }
        if self.shard_procs > 64 {
            return Err(HegridError::Config(format!(
                "shard_procs {} out of range 0..=64",
                self.shard_procs
            )));
        }
        if self.shard_procs > 0 && self.checkpoint_dir.is_empty() {
            return Err(HegridError::Config(
                "shard_procs requires a checkpoint_dir (--shard-procs N --checkpoint <dir>)"
                    .into(),
            ));
        }
        if self.shard_max_restarts > 16 {
            return Err(HegridError::Config(format!(
                "shard_max_restarts {} out of range 0..=16",
                self.shard_max_restarts
            )));
        }
        if self.shard_heartbeat_timeout_s > 3600 {
            return Err(HegridError::Config(format!(
                "shard_heartbeat_timeout_s {} out of range 0..=3600",
                self.shard_heartbeat_timeout_s
            )));
        }
        if self.shard_restart_backoff_ms > 60_000 {
            return Err(HegridError::Config(format!(
                "shard_restart_backoff_ms {} out of range 0..=60000",
                self.shard_restart_backoff_ms
            )));
        }
        #[cfg(feature = "fault-injection")]
        if !self.faults.is_empty() {
            crate::util::faults::FaultPlan::parse(&self.faults)?;
        }
        #[cfg(not(feature = "fault-injection"))]
        if !self.faults.is_empty() {
            return Err(HegridError::Config(
                "a fault spec is set but this build has no fault injection \
                 (rebuild with --features fault-injection)"
                    .into(),
            ));
        }
        for (name, v) in [
            ("width_saturation", self.width_saturation),
            ("width_busy_grow", self.width_busy_grow),
            ("width_idle_shrink", self.width_idle_shrink),
        ] {
            if !(v > 0.0 && v <= 1.0) {
                return Err(HegridError::Config(format!("{name} {v} out of range (0, 1]")));
            }
        }
        crate::grid::simd::SimdIsa::from_name(&self.simd_isa)?;
        crate::util::threads::AffinityMode::from_name(&self.executor_affinity)?;
        if !(self.kernel_sigma_beam > 0.0) || !(self.support_sigma > 0.0) || !(self.oversample > 0.0)
        {
            return Err(HegridError::Config("kernel/oversample parameters must be positive".into()));
        }
        self.uv_grid.validate()?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("artifacts_dir", Json::str(self.artifacts_dir.clone())),
            ("streams", Json::num(self.streams as f64)),
            ("pipelines", Json::num(self.pipelines as f64)),
            ("pipeline_width", Json::num(self.pipeline_width as f64)),
            ("pipeline_width_auto", Json::Bool(self.pipeline_width_auto)),
            ("pipeline_width_max", Json::num(self.pipeline_width_max as f64)),
            ("channels_per_dispatch", Json::num(self.channels_per_dispatch as f64)),
            ("share_preprocessing", Json::Bool(self.share_preprocessing)),
            ("gamma", Json::num(self.gamma as f64)),
            ("block_size", Json::num(self.block_size as f64)),
            ("cpu_channel_block", Json::num(self.cpu_channel_block as f64)),
            ("simd_isa", Json::str(self.simd_isa.clone())),
            ("executor_affinity", Json::str(self.executor_affinity.clone())),
            ("prefetch_depth", Json::num(self.prefetch_depth as f64)),
            ("io_workers", Json::num(self.io_workers as f64)),
            ("output_tile_rows", Json::num(self.output_tile_rows as f64)),
            ("checkpoint_dir", Json::str(self.checkpoint_dir.clone())),
            ("resume", Json::Bool(self.resume)),
            ("fail_fast", Json::Bool(self.fail_fast)),
            ("retry_io", Json::num(self.retry_io as f64)),
            ("retry_io_backoff_ms", Json::num(self.retry_io_backoff_ms as f64)),
            ("shard_procs", Json::num(self.shard_procs as f64)),
            ("shard_max_restarts", Json::num(self.shard_max_restarts as f64)),
            ("shard_heartbeat_timeout_s", Json::num(self.shard_heartbeat_timeout_s as f64)),
            ("shard_restart_backoff_ms", Json::num(self.shard_restart_backoff_ms as f64)),
            ("faults", Json::str(self.faults.clone())),
            ("width_saturation", Json::num(self.width_saturation)),
            ("width_busy_grow", Json::num(self.width_busy_grow)),
            ("width_idle_shrink", Json::num(self.width_idle_shrink)),
            ("kernel_type", Json::str(self.kernel_type.clone())),
            ("variant_override", Json::str(self.variant_override.clone())),
            ("kernel_sigma_beam", Json::num(self.kernel_sigma_beam)),
            ("support_sigma", Json::num(self.support_sigma)),
            ("oversample", Json::num(self.oversample)),
            ("uv_grid", self.uv_grid.to_json()),
            ("profile", Json::str(self.profile.name())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let d = HegridConfig::default();
        let get_usize = |k: &str, dv: usize| -> Result<usize> {
            match v.get(k) {
                Some(x) => x.as_usize().ok_or_else(|| {
                    HegridError::Config(format!("config field '{k}' must be a non-negative integer"))
                }),
                None => Ok(dv),
            }
        };
        let get_f64 = |k: &str, dv: f64| -> Result<f64> {
            match v.get(k) {
                Some(x) => x
                    .as_f64()
                    .ok_or_else(|| HegridError::Config(format!("config field '{k}' must be a number"))),
                None => Ok(dv),
            }
        };
        let cfg = HegridConfig {
            artifacts_dir: v
                .get("artifacts_dir")
                .and_then(|x| x.as_str())
                .unwrap_or(&d.artifacts_dir)
                .to_string(),
            streams: get_usize("streams", d.streams)?,
            pipelines: get_usize("pipelines", d.pipelines)?,
            pipeline_width: get_usize("pipeline_width", d.pipeline_width)?,
            pipeline_width_auto: v
                .get("pipeline_width_auto")
                .and_then(|x| x.as_bool())
                .unwrap_or(d.pipeline_width_auto),
            pipeline_width_max: get_usize("pipeline_width_max", d.pipeline_width_max)?,
            channels_per_dispatch: get_usize("channels_per_dispatch", d.channels_per_dispatch)?,
            share_preprocessing: v
                .get("share_preprocessing")
                .and_then(|x| x.as_bool())
                .unwrap_or(d.share_preprocessing),
            gamma: get_usize("gamma", d.gamma)?,
            block_size: get_usize("block_size", d.block_size)?,
            cpu_channel_block: get_usize("cpu_channel_block", d.cpu_channel_block)?,
            simd_isa: v
                .get("simd_isa")
                .and_then(|x| x.as_str())
                .unwrap_or(&d.simd_isa)
                .to_string(),
            executor_affinity: v
                .get("executor_affinity")
                .and_then(|x| x.as_str())
                .unwrap_or(&d.executor_affinity)
                .to_string(),
            prefetch_depth: get_usize("prefetch_depth", d.prefetch_depth)?,
            io_workers: get_usize("io_workers", d.io_workers)?,
            output_tile_rows: get_usize("output_tile_rows", d.output_tile_rows)?,
            checkpoint_dir: v
                .get("checkpoint_dir")
                .and_then(|x| x.as_str())
                .unwrap_or(&d.checkpoint_dir)
                .to_string(),
            resume: v.get("resume").and_then(|x| x.as_bool()).unwrap_or(d.resume),
            fail_fast: v.get("fail_fast").and_then(|x| x.as_bool()).unwrap_or(d.fail_fast),
            retry_io: get_usize("retry_io", d.retry_io)?,
            retry_io_backoff_ms: get_usize("retry_io_backoff_ms", d.retry_io_backoff_ms)?,
            shard_procs: get_usize("shard_procs", d.shard_procs)?,
            shard_max_restarts: get_usize("shard_max_restarts", d.shard_max_restarts)?,
            shard_heartbeat_timeout_s: get_usize(
                "shard_heartbeat_timeout_s",
                d.shard_heartbeat_timeout_s,
            )?,
            shard_restart_backoff_ms: get_usize(
                "shard_restart_backoff_ms",
                d.shard_restart_backoff_ms,
            )?,
            faults: v.get("faults").and_then(|x| x.as_str()).unwrap_or(&d.faults).to_string(),
            width_saturation: get_f64("width_saturation", d.width_saturation)?,
            width_busy_grow: get_f64("width_busy_grow", d.width_busy_grow)?,
            width_idle_shrink: get_f64("width_idle_shrink", d.width_idle_shrink)?,
            kernel_type: v
                .get("kernel_type")
                .and_then(|x| x.as_str())
                .unwrap_or(&d.kernel_type)
                .to_string(),
            variant_override: v
                .get("variant_override")
                .and_then(|x| x.as_str())
                .unwrap_or("")
                .to_string(),
            kernel_sigma_beam: get_f64("kernel_sigma_beam", d.kernel_sigma_beam)?,
            support_sigma: get_f64("support_sigma", d.support_sigma)?,
            oversample: get_f64("oversample", d.oversample)?,
            uv_grid: match v.get("uv_grid") {
                Some(x) => UvConfig::from_json(x)?,
                None => d.uv_grid,
            },
            profile: match v.get("profile").and_then(|x| x.as_str()) {
                Some(s) => DeviceProfile::from_name(s)?,
                None => d.profile,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(HegridError::io(path.display().to_string()))?;
        Self::from_json(&crate::json::parse(&text)?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
            .map_err(HegridError::io(path.display().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        HegridConfig::default().validate().unwrap();
    }

    #[test]
    fn pipeline_width_takes_precedence() {
        let mut c = HegridConfig::default();
        c.pipelines = 3;
        assert_eq!(c.effective_pipelines(), 3);
        c.pipeline_width = 2;
        assert_eq!(c.effective_pipelines(), 2);
        c.pipeline_width = 1;
        assert_eq!(c.effective_pipelines(), 1, "width 1 = sequential coordinator");
        c.pipeline_width = 0;
        c.pipelines = 0;
        assert!(c.effective_pipelines() >= 1);
        c.pipeline_width = 65;
        assert!(c.validate().is_err());
    }

    #[test]
    fn adaptive_width_bounds() {
        let mut c = HegridConfig::default();
        assert!(!c.pipeline_width_auto);
        // Auto default bound: min(host parallelism, 8), never 0.
        let auto_max = c.effective_width_max();
        assert!((1..=8).contains(&auto_max), "{auto_max}");
        c.pipeline_width_max = 5;
        assert_eq!(c.effective_width_max(), 5);
        c.pipeline_width_max = 65;
        assert!(c.validate().is_err());
        c.pipeline_width_max = 0;
        c.pipeline_width_auto = true;
        c.validate().unwrap();
    }

    #[test]
    fn uv_grid_defaults_and_validation() {
        let c = UvConfig::default();
        c.validate().unwrap();
        assert_eq!((c.n_u, c.n_v), (256, 256));
        assert_eq!(c.kernel_type, "spheroidal");
        assert_eq!((c.kernel_support, c.kernel_oversample), (3, 128));
        assert_eq!(c.tile_rows, 0, "untiled uv sweep by default");
        assert!(c.hermitian);
        let g = c.build_gridder().unwrap();
        assert_eq!(g.spec().n_u, 256);
        assert_eq!(g.kernel().support(), 3);
        let mut c = UvConfig::default();
        c.kernel_type = "boxcar".into();
        assert!(c.validate().is_err());
        let mut c = UvConfig::default();
        c.n_u = 0;
        assert!(c.validate().is_err());
        let mut c = UvConfig::default();
        c.kernel_type = "gaussian".into();
        c.kernel_sigma_cells = 0.0;
        assert!(c.validate().is_err());
        // σ only matters for the gaussian family.
        let mut c = UvConfig::default();
        c.kernel_sigma_cells = 0.0;
        c.validate().unwrap();
    }

    #[test]
    fn uv_grid_json_nests_and_rejects() {
        // The uv_grid block round-trips nested, partial blocks take the
        // block defaults, and bad nested values fail the whole config.
        let v = crate::json::parse(r#"{"uv_grid": {"n_u": 64, "kernel_type": "gaussian"}}"#)
            .unwrap();
        let c = HegridConfig::from_json(&v).unwrap();
        assert_eq!(c.uv_grid.n_u, 64);
        assert_eq!(c.uv_grid.n_v, 256, "unset nested fields keep defaults");
        assert_eq!(c.uv_grid.kernel_type, "gaussian");
        let v = crate::json::parse(r#"{"uv_grid": {"kernel_type": "boxcar"}}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"uv_grid": {"cell_wavelengths": 0}}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"uv_grid": {"n_u": -3}}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
    }

    #[test]
    fn json_round_trip() {
        let mut c = HegridConfig::default();
        c.streams = 4;
        c.pipeline_width = 4;
        c.pipeline_width_auto = true;
        c.pipeline_width_max = 6;
        c.gamma = 2;
        c.prefetch_depth = 5;
        c.io_workers = 3;
        c.cpu_channel_block = 16;
        c.simd_isa = "scalar".into();
        c.executor_affinity = "compact".into();
        c.profile = DeviceProfile::ServerM;
        c.kernel_type = "gauss2d".into();
        c.output_tile_rows = 48;
        c.checkpoint_dir = "/tmp/hegrid_ckpt".into();
        c.resume = true;
        c.width_saturation = 0.9;
        c.width_busy_grow = 0.6;
        c.width_idle_shrink = 0.25;
        c.fail_fast = false;
        c.retry_io = 5;
        c.retry_io_backoff_ms = 3;
        c.shard_procs = 3;
        c.shard_max_restarts = 4;
        c.shard_heartbeat_timeout_s = 12;
        c.shard_restart_backoff_ms = 50;
        c.uv_grid.n_u = 128;
        c.uv_grid.kernel_type = "gaussian".into();
        c.uv_grid.kernel_sigma_cells = 0.8;
        c.uv_grid.tile_rows = 16;
        c.uv_grid.hermitian = false;
        // A non-empty fault spec only validates on instrumented builds.
        #[cfg(feature = "fault-injection")]
        {
            c.faults = "7:read-err@3x2,panic@1".into();
        }
        let j = c.to_json().to_pretty();
        let back = HegridConfig::from_json(&crate::json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let v = crate::json::parse(r#"{"streams": 3}"#).unwrap();
        let c = HegridConfig::from_json(&v).unwrap();
        assert_eq!(c.streams, 3);
        assert_eq!(c.channels_per_dispatch, 10);
    }

    #[test]
    fn invalid_rejected() {
        let v = crate::json::parse(r#"{"kernel_type": "boxcar"}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"gamma": 0}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"profile": "tpu"}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"prefetch_depth": 0}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"cpu_channel_block": 100000}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"simd_isa": "sse9"}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"executor_affinity": "scatter"}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"width_saturation": 0.0}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"width_busy_grow": 1.5}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"resume": true}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err(), "resume without checkpoint_dir");
        let v = crate::json::parse(r#"{"retry_io": 17}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"retry_io_backoff_ms": 60001}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"shard_procs": 65, "checkpoint_dir": "c"}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"shard_procs": 2}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err(), "shard_procs without checkpoint_dir");
        let v = crate::json::parse(r#"{"shard_max_restarts": 17}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"shard_heartbeat_timeout_s": 3601}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        let v = crate::json::parse(r#"{"shard_restart_backoff_ms": 60001}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
        // Malformed fault spec rejected on every build; on builds without
        // the feature any non-empty spec is rejected.
        let v = crate::json::parse(r#"{"faults": "no-seed"}"#).unwrap();
        assert!(HegridConfig::from_json(&v).is_err());
    }

    #[test]
    fn robustness_fields_default_to_strict_mode() {
        let c = HegridConfig::default();
        assert!(c.fail_fast, "fail-fast is the default: semantics unchanged");
        assert_eq!((c.retry_io, c.retry_io_backoff_ms), (2, 10));
        assert!(c.faults.is_empty());
        let mut c = HegridConfig::default();
        c.fail_fast = false;
        c.retry_io = 0;
        c.validate().unwrap();
    }

    #[test]
    fn tiled_and_governor_fields_default_sanely() {
        let c = HegridConfig::default();
        assert_eq!(c.output_tile_rows, 0, "untiled by default");
        assert!(c.checkpoint_dir.is_empty() && !c.resume);
        assert_eq!(
            (c.width_saturation, c.width_busy_grow, c.width_idle_shrink),
            (0.85, 0.75, 0.35)
        );
        let mut c = HegridConfig::default();
        c.resume = true;
        c.checkpoint_dir = "ckpt".into();
        c.validate().unwrap();
    }

    #[test]
    fn shard_fields_default_off_and_validate() {
        let c = HegridConfig::default();
        assert_eq!(c.shard_procs, 0, "single-process by default");
        assert_eq!(c.shard_max_restarts, 2);
        assert_eq!(c.shard_heartbeat_timeout_s, 30);
        assert_eq!(c.shard_restart_backoff_ms, 200);
        let mut c = HegridConfig::default();
        c.shard_procs = 4;
        assert!(c.validate().is_err(), "sharding needs a checkpoint_dir");
        c.checkpoint_dir = "/tmp/ckpt".into();
        c.validate().unwrap();
    }

    #[test]
    fn simd_and_affinity_accessors_parse() {
        use crate::grid::simd::SimdIsa;
        use crate::util::threads::AffinityMode;
        let mut c = HegridConfig::default();
        assert_eq!(c.simd(), SimdIsa::Auto);
        assert_eq!(c.affinity(), AffinityMode::None);
        c.simd_isa = "scalar".into();
        c.executor_affinity = "spread".into();
        c.validate().unwrap();
        assert_eq!(c.simd(), SimdIsa::Scalar);
        assert_eq!(c.affinity(), AffinityMode::Spread);
    }

    #[test]
    fn io_workers_follow_prefetch_window() {
        let mut c = HegridConfig::default();
        assert_eq!(c.effective_io_workers(), 2); // auto = min(2, depth=2)
        c.prefetch_depth = 1;
        assert_eq!(c.effective_io_workers(), 1);
        c.prefetch_depth = 8;
        c.io_workers = 4;
        assert_eq!(c.effective_io_workers(), 4);
        c.io_workers = 32;
        assert_eq!(c.effective_io_workers(), 8, "capped by the window");
    }

    #[test]
    fn profile_caps_streams() {
        let mut c = HegridConfig::default();
        c.profile = DeviceProfile::ServerM;
        c.streams = 16;
        assert_eq!(c.effective_streams(), 2);
        c.streams = 0;
        // Unset: host-parallelism-aware default, still within the cap.
        let auto = c.effective_streams();
        assert!(auto >= 1 && auto <= 2, "{auto}");
        c.profile = DeviceProfile::ServerV;
        c.streams = 16;
        assert_eq!(c.effective_streams(), 8);
        c.streams = 0;
        assert!(c.effective_streams() <= 8);
    }

    #[test]
    fn effective_block_follows_profile() {
        let mut c = HegridConfig::default();
        assert_eq!(c.effective_block(), 256);
        c.profile = DeviceProfile::ServerM;
        assert_eq!(c.effective_block(), 128);
        c.block_size = 64;
        assert_eq!(c.effective_block(), 64);
    }
}
