//! Dispatch planning: channel grouping, sample sharding, and device-shaped
//! tile data.
//!
//! A [`DispatchPlan`] is the channel-independent half of a gridding run —
//! exactly what the shared component covers: sorted/padded sample
//! coordinates, per-shard neighbour tables, and per-tile cell arrays, all
//! `Arc`-wrapped so every pipeline dispatches from the same memory and the
//! stream threads can keep the uploads device-resident.

use std::sync::Arc;

use crate::grid::nbr::NeighborTable;
use crate::grid::prep::SharedComponent;
use crate::runtime::VariantInfo;
use crate::util::error::{HegridError, Result};

use super::GriddingJob;

/// Epoch-id stride reserved per plan (shards consume consecutive epochs).
pub const EPOCHS_PER_PLAN: u64 = 1 << 20;

/// Channels grouped into dispatch batches of the variant's `c`.
#[derive(Clone, Debug)]
pub struct ChannelGroups {
    groups: Vec<Vec<usize>>,
}

impl ChannelGroups {
    pub fn new(n_channels: usize, per_group: usize) -> ChannelGroups {
        assert!(per_group > 0);
        let groups = (0..n_channels)
            .collect::<Vec<_>>()
            .chunks(per_group)
            .map(|c| c.to_vec())
            .collect();
        ChannelGroups { groups }
    }

    /// Build groups from explicit member lists — the resume path's pending
    /// subset of a full partition, remapped to dense indices `0..len` (the
    /// prefetcher and pipelines address groups densely; callers keep their
    /// own dense→original map for checkpoint records and `wsum` ownership).
    pub fn from_members(groups: Vec<Vec<usize>>) -> ChannelGroups {
        assert!(groups.iter().all(|g| !g.is_empty()), "empty channel group");
        ChannelGroups { groups }
    }

    pub fn len(&self) -> usize {
        self.groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    pub fn members(&self, g: usize) -> &[usize] {
        &self.groups[g]
    }
}

/// Contiguous output-map partition for supervised multi-process runs: the
/// sky split into `n_parts` balanced, adjacent row ranges (HEALPix-style
/// iso-latitude rings in this repo's CAR map layout — each grid row is one
/// ring, so a row range is a contiguous ring range). Extends the
/// sample-axis [`ShardPlan`] with an *output*-axis partition: every worker
/// process grids **all** samples and channels but only accumulates the
/// cells of its row range, so per-cell contribution order inside a range is
/// identical to a single-process run and a shard-ascending concatenation of
/// the ranges reproduces the full cube byte for byte.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SkyPartition {
    /// `(row_lo, row_hi)` half-open row ranges, ascending and adjacent:
    /// `parts[i].1 == parts[i+1].0`, covering `0..nlat` exactly.
    parts: Vec<(usize, usize)>,
}

impl SkyPartition {
    /// Split `nlat` grid rows into at most `n_parts` contiguous ranges.
    /// Balanced to within one row (the first `nlat % n` ranges get the
    /// extra row); `n_parts` is clamped to `nlat` so every range is
    /// non-empty.
    pub fn split(nlat: usize, n_parts: usize) -> SkyPartition {
        assert!(nlat > 0 && n_parts > 0, "empty map or zero shards");
        let n = n_parts.min(nlat);
        let base = nlat / n;
        let extra = nlat % n;
        let mut parts = Vec::with_capacity(n);
        let mut lo = 0;
        for i in 0..n {
            let hi = lo + base + usize::from(i < extra);
            parts.push((lo, hi));
            lo = hi;
        }
        debug_assert_eq!(lo, nlat);
        SkyPartition { parts }
    }

    pub fn len(&self) -> usize {
        self.parts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parts.is_empty()
    }

    /// Half-open row range `[lo, hi)` of shard `s`.
    pub fn rows(&self, s: usize) -> (usize, usize) {
        self.parts[s]
    }
}

/// Device-shaped inputs for one tile (shared across channel groups).
#[derive(Clone, Debug)]
pub struct TileData {
    pub cell_lon: Arc<Vec<f32>>,
    pub cell_lat: Arc<Vec<f32>>,
    /// `[groups_per_tile, k]` flattened, shard-local indices.
    pub nbr: Arc<Vec<i32>>,
}

/// One sample shard: padded coordinates + per-tile neighbour tables.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Sorted, padded sample coordinates (length = variant `n`).
    pub slon: Arc<Vec<f32>>,
    pub slat: Arc<Vec<f32>>,
    /// Staged unit-vector columns `[3, n]` (x | y | z planes), f32-cast from
    /// the shared component's precomputed f64 trig — T2 ships these so the
    /// device kernel's per-pair distance is a chord test on staged columns
    /// rather than per-pair haversine trig from raw lon/lat.
    pub sunit: Arc<Vec<f32>>,
    /// Original-sample index of each shard-local sorted sample.
    perm: Vec<u32>,
    /// Minimum channel length the permute accepts (max original index + 1),
    /// precomputed so T1 validation is O(1) instead of a scan per channel.
    required_len: usize,
    tiles: Vec<TileData>,
    pub overflow_groups: usize,
    pub adjacent_reuse: f64,
}

impl ShardPlan {
    pub fn tile(&self, t: usize) -> &TileData {
        &self.tiles[t]
    }

    fn check_channel_len(&self, values: &[f32]) -> Result<()> {
        if values.len() < self.required_len {
            return Err(HegridError::Internal(
                "permute_into: channel shorter than dataset".into(),
            ));
        }
        Ok(())
    }

    /// Append one channel's shard values in sorted order, zero-padded to
    /// `n`, onto `out` (building the `[c, n]` staging buffer).
    pub fn permute_into(&self, values: &[f32], n: usize, out: &mut Vec<f32>) -> Result<()> {
        self.check_channel_len(values)?;
        out.reserve(n);
        for &i in &self.perm {
            out.push(values[i as usize]);
        }
        for _ in self.perm.len()..n {
            out.push(0.0);
        }
        Ok(())
    }

    /// Permute every channel of a group in one pass over `perm` (the gather
    /// index and its cache misses are paid once per group instead of once
    /// per channel), appending each channel's sorted values zero-padded to
    /// `n` — the `[c, n]` staging layout T1 feeds the device.
    pub fn permute_group_into(
        &self,
        channels: &[&[f32]],
        n: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        for values in channels {
            self.check_channel_len(values)?;
        }
        if self.perm.len() > n {
            return Err(HegridError::Internal(format!(
                "permute_group_into: shard of {} samples exceeds padded width {n}",
                self.perm.len()
            )));
        }
        let base = out.len();
        out.resize(base + channels.len() * n, 0.0);
        let dst = &mut out[base..];
        for (j, &i) in self.perm.iter().enumerate() {
            let i = i as usize;
            for (c, values) in channels.iter().enumerate() {
                dst[c * n + j] = values[i];
            }
        }
        Ok(())
    }
}

/// The full channel-independent dispatch plan.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    pub shards: Vec<ShardPlan>,
    base_epoch: u64,
    tiles_per_shard: usize,
}

impl DispatchPlan {
    /// Build the plan: shared pre-processing, sharding, neighbour tables,
    /// tile arrays. Takes the shared coordinate table directly — the plan is
    /// channel-independent, so streaming sources can build it before (or
    /// while) any channel values exist in memory.
    pub fn build(
        lons: &[f64],
        lats: &[f64],
        job: &GriddingJob,
        variant: &VariantInfo,
        base_epoch: u64,
        workers: usize,
    ) -> Result<DispatchPlan> {
        let shared =
            SharedComponent::build(lons, lats, job.kernel.support.max(1e-9), workers.max(1))?;
        let n = shared.n_samples();
        let n_shards = n.div_ceil(variant.n).max(1);
        let n_tiles = job.spec.n_cells().div_ceil(variant.m).max(1);

        let mut shards = Vec::with_capacity(n_shards);
        // Cell coordinate tiles depend only on the map — compute once and
        // share the Arcs across shards (only `nbr` differs).
        let mut cell_tiles: Option<Vec<(Arc<Vec<f32>>, Arc<Vec<f32>>)>> = None;

        for s in 0..n_shards {
            let lo = s * variant.n;
            let hi = ((s + 1) * variant.n).min(n);
            let view = shared.slice(lo, hi);
            let table = NeighborTable::build_with_simd(
                &view,
                &job.spec,
                &job.kernel,
                variant.m,
                variant.k,
                variant.gamma,
                workers.max(1),
                job.simd,
            );
            debug_assert_eq!(table.n_tiles, n_tiles);

            let cells = cell_tiles.get_or_insert_with(|| {
                (0..n_tiles)
                    .map(|t| {
                        let (lon, lat) = table.tile_cells(t);
                        (Arc::new(lon.to_vec()), Arc::new(lat.to_vec()))
                    })
                    .collect()
            });

            let tiles: Vec<TileData> = (0..n_tiles)
                .map(|t| TileData {
                    cell_lon: Arc::clone(&cells[t].0),
                    cell_lat: Arc::clone(&cells[t].1),
                    nbr: Arc::new(table.tile_nbr(t).to_vec()),
                })
                .collect();

            // Pad shard coordinates to the variant's n. Pad values are never
            // referenced (nbr only holds indices < shard size) but must be
            // finite for the kernel's vectorised math.
            let mut slon = view.slon.clone();
            let mut slat = view.slat.clone();
            slon.resize(variant.n, 0.0);
            slat.resize(variant.n, 0.0);

            let required_len =
                view.perm.iter().map(|&i| i as usize + 1).max().unwrap_or(0);
            shards.push(ShardPlan {
                slon: Arc::new(slon),
                slat: Arc::new(slat),
                sunit: Arc::new(view.staged_unit_f32(variant.n)),
                perm: view.perm.clone(),
                required_len,
                tiles,
                overflow_groups: table.stats.overflow_groups,
                adjacent_reuse: table.stats.adjacent_reuse,
            });
        }

        Ok(DispatchPlan { shards, base_epoch, tiles_per_shard: n_tiles })
    }

    pub fn n_tiles(&self) -> usize {
        self.tiles_per_shard * self.shards.len()
    }

    pub fn tiles_per_shard(&self) -> usize {
        self.tiles_per_shard
    }

    /// Device-cache epoch for shard `s` (distinct per shard so coordinate
    /// buffers never alias).
    pub fn epoch_for_shard(&self, s: usize) -> u64 {
        self.base_epoch + s as u64
    }

    pub fn overflow_groups(&self) -> usize {
        self.shards.iter().map(|s| s.overflow_groups).sum()
    }

    pub fn adjacent_reuse(&self) -> f64 {
        if self.shards.is_empty() {
            return 0.0;
        }
        self.shards.iter().map(|s| s.adjacent_reuse).sum::<f64>() / self.shards.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HegridConfig;
    use crate::runtime::VariantInfo;

    fn fake_variant(m: usize, k: usize, c: usize, n: usize, gamma: usize) -> VariantInfo {
        VariantInfo {
            name: format!("fake_m{m}_k{k}_c{c}_n{n}_g{gamma}"),
            path: std::path::PathBuf::from("/dev/null"),
            kernel_type: "gauss1d".into(),
            m,
            bm: m.min(64),
            k,
            c,
            n,
            gamma,
            groups: m / gamma,
            tags: vec![],
        }
    }

    #[test]
    fn channel_groups_cover_all_channels_once() {
        let g = ChannelGroups::new(23, 10);
        assert_eq!(g.len(), 3);
        let all: Vec<usize> = (0..g.len()).flat_map(|i| g.members(i).to_vec()).collect();
        assert_eq!(all, (0..23).collect::<Vec<_>>());
        assert_eq!(g.members(2).len(), 3);
        assert!(ChannelGroups::new(0, 4).is_empty());
        // Resume subset: dense indices over an explicit member list.
        let sub = ChannelGroups::from_members(vec![g.members(2).to_vec(), g.members(0).to_vec()]);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.members(0), g.members(2));
        assert_eq!(sub.members(1), g.members(0));
    }

    #[test]
    fn sky_partition_is_contiguous_balanced_and_total() {
        for (nlat, n_parts) in [(10, 1), (10, 3), (10, 10), (7, 4), (100, 8), (3, 16)] {
            let p = SkyPartition::split(nlat, n_parts);
            assert_eq!(p.len(), n_parts.min(nlat), "clamped to the row count");
            let (mut lo_prev, mut covered) = (0, 0);
            let mut sizes = Vec::new();
            for s in 0..p.len() {
                let (lo, hi) = p.rows(s);
                assert_eq!(lo, lo_prev, "ranges adjacent, ascending");
                assert!(hi > lo, "every range non-empty");
                sizes.push(hi - lo);
                covered += hi - lo;
                lo_prev = hi;
            }
            assert_eq!(lo_prev, nlat, "ranges end at the map");
            assert_eq!(covered, nlat, "rows covered exactly once");
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "balanced to within one row: {sizes:?}");
        }
    }

    #[test]
    fn plan_shards_and_tiles() {
        let d = crate::sim::SimConfig::quick_preset().generate();
        let cfg = HegridConfig::default();
        let job = super::super::GriddingJob::for_dataset(&d, &cfg).unwrap();
        // Force sharding: n smaller than the sample count (4000).
        let v = fake_variant(256, 32, 4, 1536, 1);
        let plan = DispatchPlan::build(&d.lons, &d.lats, &job, &v, 100, 4).unwrap();
        assert_eq!(plan.shards.len(), 3); // ceil(4000 / 1536)
        assert_eq!(plan.tiles_per_shard(), job.spec.n_cells().div_ceil(256));
        assert_eq!(plan.epoch_for_shard(2), 102);
        for shard in &plan.shards {
            assert_eq!(shard.slon.len(), 1536);
            // Staged unit columns: [3, n] planes, consistent with slon/slat.
            assert_eq!(shard.sunit.len(), 3 * 1536);
            for j in (0..shard.perm.len()).step_by(211) {
                let u = crate::healpix::unit_vec(shard.slon[j] as f64, shard.slat[j] as f64);
                // f32-cast of f64 unit vectors built from f64 coords vs unit
                // vectors of f32-rounded coords: equal to f32 precision.
                assert!((shard.sunit[j] as f64 - u[0]).abs() < 1e-6);
                assert!((shard.sunit[1536 + j] as f64 - u[1]).abs() < 1e-6);
                assert!((shard.sunit[2 * 1536 + j] as f64 - u[2]).abs() < 1e-6);
            }
            for t in 0..plan.tiles_per_shard() {
                let tile = shard.tile(t);
                assert_eq!(tile.cell_lon.len(), 256);
                assert_eq!(tile.nbr.len(), 256 * 32);
                // Shard-local indices stay within the shard.
                assert!(tile.nbr.iter().all(|&i| i < shard.perm.len() as i32));
            }
        }
        // Cell arrays are shared across shards (same Arc).
        if plan.shards.len() > 1 {
            assert!(Arc::ptr_eq(
                &plan.shards[0].tile(0).cell_lon,
                &plan.shards[1].tile(0).cell_lon
            ));
        }
    }

    #[test]
    fn group_permute_matches_per_channel_permute() {
        let d = crate::sim::SimConfig::quick_preset().generate();
        let cfg = HegridConfig::default();
        let job = super::super::GriddingJob::for_dataset(&d, &cfg).unwrap();
        let v = fake_variant(256, 32, 4, 1536, 1);
        let plan = DispatchPlan::build(&d.lons, &d.lats, &job, &v, 0, 4).unwrap();
        let chans: Vec<Vec<f32>> = (0..3)
            .map(|c| (0..d.n_samples()).map(|i| (c * 100_000 + i) as f32).collect())
            .collect();
        for shard in &plan.shards {
            let mut per_channel = Vec::new();
            for ch in &chans {
                shard.permute_into(ch, v.n, &mut per_channel).unwrap();
            }
            let mut grouped = Vec::new();
            let refs: Vec<&[f32]> = chans.iter().map(|c| c.as_slice()).collect();
            shard.permute_group_into(&refs, v.n, &mut grouped).unwrap();
            assert_eq!(per_channel, grouped);
            // Short channels are rejected (O(1) check).
            let short = vec![0.0f32; 1];
            assert!(shard.permute_group_into(&[short.as_slice()], v.n, &mut grouped).is_err());
        }
    }

    #[test]
    fn sharded_permute_covers_every_sample_once() {
        let d = crate::sim::SimConfig::quick_preset().generate();
        let cfg = HegridConfig::default();
        let job = super::super::GriddingJob::for_dataset(&d, &cfg).unwrap();
        let v = fake_variant(256, 32, 4, 1536, 1);
        let plan = DispatchPlan::build(&d.lons, &d.lats, &job, &v, 0, 4).unwrap();
        let values: Vec<f32> = (0..d.n_samples()).map(|i| i as f32).collect();
        let mut seen = vec![false; d.n_samples()];
        for shard in &plan.shards {
            let mut out = Vec::new();
            shard.permute_into(&values, v.n, &mut out).unwrap();
            assert_eq!(out.len(), v.n);
            for &x in &out[..shard.perm.len()] {
                let i = x as usize;
                assert!(!seen[i], "sample {i} in two shards");
                seen[i] = true;
            }
            assert!(out[shard.perm.len()..].iter().all(|&x| x == 0.0));
        }
        assert!(seen.iter().all(|&s| s));
    }
}
