//! Discrete-event simulator of the multi-pipeline timeline (Fig 8/9).
//!
//! The paper's concurrency results (multi-stream overlap, Fig 15; the
//! T1+T2 vs T3 prerequisite of §4.2.1) are properties of how pipeline stages
//! contend for four resources:
//!
//! * **CPU** — `pipelines` parallel workers run T1 (pre-processing/permute);
//! * **H2D** — one copy engine; same-direction transfers serialize (the
//!   "wait" annotation of Fig 9);
//! * **DEV** — the compute device executes one kernel at a time (stream
//!   concurrency buys *overlap* with transfers, not intra-kernel overlap);
//! * **D2H** — the second copy engine.
//!
//! A channel group flows T1 → T2 → T3 → T4, holding one resource at a time;
//! at most `streams` groups may occupy the device section (T2..T4)
//! concurrently. This reproduces the paper's observed shapes: speedup from
//! streams saturates at `(T2+T3+T4)/max(T2,T3,T4)`, gains are larger when
//! transfer and compute times are balanced, and serial execution re-emerges
//! when `T1+T2 > T3` with too few pipelines.
//!
//! The host running this reproduction has a single CPU core, so wall-clock
//! cannot exhibit real thread concurrency; the benches therefore calibrate
//! this simulator with *measured* per-stage costs from real runs and report
//! both (see DESIGN.md "Substituted substrates").

/// Per-channel-group stage durations, seconds (calibrate from
/// `PipelineReport::stages / n_groups`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StageCost {
    /// T1: CPU permute/pre-processing per group.
    pub t1_cpu: f64,
    /// T2: host→device transfer per group.
    pub t2_h2d: f64,
    /// T3: kernel execution per group.
    pub t3_kernel: f64,
    /// T4: device→host + reduce per group.
    pub t4_d2h: f64,
}

impl StageCost {
    /// Fig-8 shape check: the paper measures T1 > T3 > T2 > T4.
    pub fn matches_paper_ordering(&self) -> bool {
        self.t1_cpu > self.t3_kernel && self.t3_kernel > self.t2_h2d && self.t2_h2d > self.t4_d2h
    }
}

/// Simulation input.
#[derive(Clone, Copy, Debug)]
pub struct SimParams {
    pub n_groups: usize,
    /// Concurrent CPU workers (the paper's processes).
    pub pipelines: usize,
    /// Concurrent device streams.
    pub streams: usize,
    pub cost: StageCost,
    /// One-off shared pre-processing cost (T0); paid once when `share`,
    /// once per group otherwise (added to that group's T1).
    pub prep: f64,
    pub share: bool,
    /// Kernels that can co-execute on the device. >1 when one dispatch does
    /// not fill the machine (small maps / low output resolution — the
    /// paper's §5.3.3 explanation of why stream gains are largest there).
    /// Compute it as ⌈device parallel threads / cells per dispatch⌉, e.g.
    /// from [`crate::grid::occupancy::OccupancyModel`]. Clamped to ≥ 1.
    pub kernel_slots: usize,
}

impl SimParams {
    /// Kernel concurrency for a map of `n_cells` on a device able to run
    /// `device_threads` cell-updates in parallel (one thread per cell).
    pub fn kernel_slots_for(device_threads: usize, n_cells: usize) -> usize {
        (device_threads / n_cells.max(1)).max(1)
    }
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// End-to-end makespan, seconds.
    pub makespan: f64,
    /// Busy time of each resource [CPU, H2D, DEV, D2H].
    pub busy: [f64; 4],
    /// Per-group (start, finish) times.
    pub spans: Vec<(f64, f64)>,
}

impl SimResult {
    /// Utilisation of the device compute resource.
    pub fn device_utilisation(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.busy[2] / self.makespan
        }
    }
}

/// Run the event simulation.
pub fn simulate(p: &SimParams) -> SimResult {
    assert!(p.pipelines >= 1 && p.streams >= 1);
    let n = p.n_groups;
    let mut spans = vec![(0.0f64, 0.0f64); n];
    let mut busy = [0.0f64; 4];
    if n == 0 {
        return SimResult { makespan: if p.share { p.prep } else { 0.0 }, busy, spans };
    }

    // Resource free-times. CPU is a set of `pipelines` workers; H2D/DEV/D2H
    // are single units. Streams bound the number of groups inside the device
    // section: model as a vector of stream free-times (a group claims the
    // earliest-free stream for its whole T2..T4 span).
    let mut cpu_free = vec![0.0f64; p.pipelines];
    let mut h2d_free = 0.0f64;
    let mut dev_free = vec![0.0f64; p.kernel_slots.max(1)];
    let mut d2h_free = 0.0f64;
    let mut stream_free = vec![0.0f64; p.streams];

    let shared_prep_done = if p.share { p.prep } else { 0.0 };

    // FIFO: group g is picked up by the earliest-free CPU worker.
    for (g, span) in spans.iter_mut().enumerate() {
        // T1 on a CPU worker (plus per-group prep when not shared).
        let w = earliest(&cpu_free);
        let t1_cost = p.cost.t1_cpu + if p.share { 0.0 } else { p.prep };
        let t1_start = cpu_free[w].max(shared_prep_done);
        let t1_end = t1_start + t1_cost;
        cpu_free[w] = t1_end;
        busy[0] += t1_cost;

        // Claim a stream for the device section.
        let s = earliest(&stream_free);
        let section_start = t1_end.max(stream_free[s]);

        // T2 on the H2D engine.
        let t2_start = section_start.max(h2d_free);
        let t2_end = t2_start + p.cost.t2_h2d;
        h2d_free = t2_end;
        busy[1] += p.cost.t2_h2d;

        // T3 on a device kernel slot. A stream can only occupy one slot, so
        // effective kernel concurrency is min(kernel_slots, streams).
        let k = earliest(&dev_free[..p.kernel_slots.min(p.streams).max(1)]);
        let t3_start = t2_end.max(dev_free[k]);
        let t3_end = t3_start + p.cost.t3_kernel;
        dev_free[k] = t3_end;
        busy[2] += p.cost.t3_kernel;

        // T4 on the D2H engine.
        let t4_start = t3_end.max(d2h_free);
        let t4_end = t4_start + p.cost.t4_d2h;
        d2h_free = t4_end;
        busy[3] += p.cost.t4_d2h;

        stream_free[s] = t4_end;
        *span = (t1_start, t4_end);
        let _ = g;
    }

    let makespan = spans.iter().map(|s| s.1).fold(0.0, f64::max);
    SimResult { makespan, busy, spans }
}

/// Speedup of `streams` concurrent streams over a single stream, all else
/// equal (the Fig-15 quantity).
pub fn stream_speedup(base: &SimParams, streams: usize) -> f64 {
    let mut one = *base;
    one.streams = 1;
    let mut many = *base;
    many.streams = streams;
    simulate(&one).makespan / simulate(&many).makespan
}

fn earliest(free: &[f64]) -> usize {
    let mut best = 0;
    for (i, &t) in free.iter().enumerate() {
        if t < free[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> StageCost {
        // Paper Fig-8 ordering: T1 > T3 > T2 > T4.
        StageCost { t1_cpu: 4.0, t2_h2d: 2.0, t3_kernel: 3.0, t4_d2h: 1.0 }
    }

    fn params(groups: usize, pipelines: usize, streams: usize) -> SimParams {
        SimParams { n_groups: groups, pipelines, streams, cost: cost(), prep: 5.0, share: true, kernel_slots: 1 }
    }

    #[test]
    fn single_stream_single_pipeline_is_serial() {
        let p = params(4, 1, 1);
        let r = simulate(&p);
        // prep + n·(t1+t2+t3+t4): with one stream the device section cannot
        // overlap the next group's T1? It can: CPU is free while the device
        // works. Serial lower bound per group on the stream: t2+t3+t4 = 6,
        // T1 overlaps. makespan = prep + t1 + n·(t2+t3+t4) … minus pipelined
        // t1 overlap: the first T1 then each stream section of 6.
        let expect = 5.0 + 4.0 + 4.0 * 6.0;
        assert!((r.makespan - expect).abs() < 1e-9, "{} vs {expect}", r.makespan);
    }

    #[test]
    fn stream_overlap_bounded_by_bottleneck() {
        // Many streams: makespan → prep + t1 + n·max(t2,t3,t4) + tail.
        let p = params(32, 8, 8);
        let r = simulate(&p);
        let bottleneck = 3.0; // t3
        let lower = 5.0 + 32.0 * bottleneck;
        assert!(r.makespan >= lower, "{} < {lower}", r.makespan);
        assert!(r.makespan <= lower + 20.0, "{} too slow", r.makespan);
        // Device utilisation approaches 1.
        assert!(r.device_utilisation() > 0.8, "{}", r.device_utilisation());
    }

    #[test]
    fn speedup_saturates_with_streams() {
        let p = params(32, 8, 1);
        let s2 = stream_speedup(&p, 2);
        let s4 = stream_speedup(&p, 4);
        let s16 = stream_speedup(&p, 16);
        assert!(s2 > 1.05, "{s2}");
        assert!(s4 >= s2 - 1e-9);
        // Saturation: the analytic ceiling is (t2+t3+t4)/max = 6/3 = 2.
        assert!(s16 <= 2.0 + 1e-9, "{s16}");
        assert!((s16 - s4).abs() < 0.3, "should flatten: {s4} → {s16}");
    }

    #[test]
    fn serial_degeneration_when_cpu_starves_device() {
        // T1 + T2 > T3 with a single pipeline: streams cannot help (the
        // §4.2.1 prerequisite). CPU feeds a group every t1 = 4s, the device
        // section takes 6 ≤ ... with t1=4 > 0 the device idles between
        // groups when t1 > t2+t3+t4? Here t1=4 < 6 so partial overlap.
        let mut one_pipe = params(16, 1, 8);
        one_pipe.cost = StageCost { t1_cpu: 10.0, t2_h2d: 2.0, t3_kernel: 3.0, t4_d2h: 1.0 };
        let r8 = simulate(&one_pipe);
        let mut serial = one_pipe;
        serial.streams = 1;
        let r1 = simulate(&serial);
        // CPU-bound: streams give (almost) nothing.
        assert!(r8.makespan > 0.95 * r1.makespan, "{} vs {}", r8.makespan, r1.makespan);
    }

    #[test]
    fn pipelines_relieve_cpu_bottleneck() {
        let mut p = params(16, 1, 8);
        p.cost = StageCost { t1_cpu: 10.0, t2_h2d: 2.0, t3_kernel: 3.0, t4_d2h: 1.0 };
        let one = simulate(&p).makespan;
        p.pipelines = 4;
        let four = simulate(&p).makespan;
        assert!(four < one * 0.45, "{four} vs {one}");
    }

    #[test]
    fn sharing_eliminates_per_group_prep() {
        // One pipeline: per-group prep lands squarely on the critical path.
        let mut p = params(16, 1, 4);
        p.prep = 8.0;
        let shared = simulate(&p).makespan;
        p.share = false;
        let unshared = simulate(&p).makespan;
        assert!(unshared > shared + 8.0, "{unshared} vs {shared}");
        // The redundancy-elimination speedup grows with prep cost (Fig 11's
        // "more obvious for large datasets").
        let mut p_big = p;
        p_big.prep = 32.0;
        p_big.share = false;
        let unshared_big = simulate(&p_big).makespan;
        p_big.share = true;
        let shared_big = simulate(&p_big).makespan;
        assert!(unshared_big / shared_big > unshared / shared);
    }

    #[test]
    fn spare_cpu_capacity_hides_unshared_prep() {
        // With plenty of pipelines and a device bottleneck, rebuilding the
        // LUT per group hides in CPU slack — matching the paper's
        // observation that redundancy elimination matters most when
        // pre-processing is expensive relative to the device stages.
        let mut p = params(16, 8, 4);
        p.prep = 2.0;
        let shared = simulate(&p).makespan;
        p.share = false;
        let unshared = simulate(&p).makespan;
        assert!(unshared < shared * 1.3, "{unshared} vs {shared}");
    }

    #[test]
    fn fifo_spans_are_ordered_and_busy_consistent() {
        let p = params(8, 2, 2);
        let r = simulate(&p);
        assert_eq!(r.spans.len(), 8);
        for w in r.spans.windows(2) {
            assert!(w[0].0 <= w[1].0 + 1e-12, "FIFO start order");
        }
        for (i, &b) in r.busy.iter().enumerate() {
            assert!(b <= r.makespan * 4.0 + 1e-9, "resource {i}");
        }
        // Device busy equals n·t3 exactly.
        assert!((r.busy[2] - 8.0 * 3.0).abs() < 1e-9);
    }

    #[test]
    fn kernel_slots_lift_the_stream_ceiling() {
        // With one kernel slot the stream speedup is capped by t3; with many
        // slots (small maps) kernels co-run and streams buy much more — the
        // paper's low-resolution Fig-15 regime.
        let mut p = params(32, 8, 1);
        p.kernel_slots = 1;
        let s_one_slot = stream_speedup(&p, 8);
        p.kernel_slots = 8;
        let s_many_slots = stream_speedup(&p, 8);
        assert!(s_many_slots > s_one_slot * 1.2, "{s_many_slots} vs {s_one_slot}");
        // Slots beyond the stream count change nothing.
        p.kernel_slots = 64;
        let s_caps = stream_speedup(&p, 8);
        assert!((s_caps - s_many_slots).abs() < 1e-9);
    }

    #[test]
    fn kernel_slots_for_scales_with_map() {
        assert_eq!(SimParams::kernel_slots_for(56_320, 3_600), 15);
        assert_eq!(SimParams::kernel_slots_for(56_320, 40_000), 1);
        assert_eq!(SimParams::kernel_slots_for(0, 100), 1);
    }

    #[test]
    fn zero_groups() {
        let r = simulate(&params(0, 2, 2));
        assert_eq!(r.spans.len(), 0);
        assert!(r.makespan >= 0.0);
    }

    #[test]
    fn paper_ordering_helper() {
        assert!(cost().matches_paper_ordering());
        let bad = StageCost { t1_cpu: 1.0, t2_h2d: 2.0, t3_kernel: 3.0, t4_d2h: 4.0 };
        assert!(!bad.matches_paper_ordering());
    }
}
