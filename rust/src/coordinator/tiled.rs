//! Tiled output gridding: bounded-memory row-band tiles, spill-to-disk
//! reduce, and resumable channel-group checkpoints.
//!
//! The untiled coordinator holds the whole `[n_channels][n_cells]` f64
//! accumulator cube in memory — the output side dominates peak RSS once
//! maps are large (the input side is already streaming-bounded by the T0
//! prefetch ring). The tiled path replaces it with a **band-major** reduce:
//! the target map is split into contiguous row bands of
//! `output_tile_rows` rows, and each pipeline processes its channel group
//! band by band, reducing kernel responses into a band-local accumulator
//! and streaming every finished band into an on-disk
//! [`CubeFile`] — peak accumulator memory becomes
//! `O(band_cells × channels_per_group × pipeline_width)` instead of
//! `O(n_cells × n_channels)`.
//!
//! **Bit-identity** with the untiled path is structural, not approximate:
//! every output cell receives its contributions in the same order (shards
//! ascending; exactly one dispatch tile covers a given cell per shard),
//! kernel execution is deterministic per `(shard, tile)` — re-dispatching
//! a tile that straddles a band boundary reproduces identical f32
//! responses — and only the band-overlapping cell range of each response
//! is reduced, so the per-cell f64 sums are bitwise the untiled ones.
//! `rust/tests/tiled_equivalence.rs` pins this across band heights,
//! pipeline widths, and forced SIMD ISAs.
//!
//! With a `checkpoint_dir` configured the cube lives there alongside a
//! CRC'd [`CheckpointManifest`]; after every finished channel group the
//! manifest is atomically rewritten, so `--resume` restarts a crashed run
//! by verifying the finished groups' cube bytes and re-gridding only the
//! pending ones — the final cube is bit-identical to an uninterrupted run.

use std::path::{Path, PathBuf};

use super::*;
use crate::data::checkpoint::{
    anonymous_cube_path, CheckpointManifest, CubeFile, CubeHandle, CUBE_FILE,
};
use crate::util::crc32::Crc32;

/// Immutable per-run context shared by every tiled pipeline.
struct TiledCtx<'a> {
    job: &'a GriddingJob,
    variant: &'a VariantInfo,
    lons: &'a [f64],
    lats: &'a [f64],
    shared_plan: Option<&'a DispatchPlan>,
    /// Dense (resume-remapped) group index → original group index.
    dense_to_orig: &'a [usize],
    n_cells: usize,
    nlon: usize,
    /// Output row range this run accumulates, `[row_lo, row_hi)` — the full
    /// map for ordinary runs, one [`SkyPartition`] range for a shard-worker
    /// process. Tiles are dispatched globally either way; only the clip +
    /// reduce window narrows.
    row_lo: usize,
    row_hi: usize,
    /// First cube cell of the row range (`row_lo * nlon`): global cell
    /// indices minus this are local cube offsets.
    cell_base: usize,
    rows_per_band: usize,
    cube: &'a CubeFile,
    /// Checkpoint directory + manifest; `None` for anonymous spill runs.
    ckpt: Option<(&'a Path, &'a Mutex<CheckpointManifest>)>,
    shared_builds: &'a AtomicU64,
    overflow: &'a AtomicU64,
    dispatches: &'a AtomicU64,
}

impl HegridEngine {
    /// Grid every channel of `source` through the tiled output path and
    /// leave the result as an on-disk accumulator cube, returned as a
    /// [`CubeHandle`] for per-channel (bounded-memory) map reads.
    ///
    /// `output_tile_rows = 0` still runs this path with one full-map band —
    /// useful for checkpointed runs that only want group-level resume. With
    /// an empty `checkpoint_dir` the cube is an anonymous temp file,
    /// deleted when the handle drops.
    pub fn grid_source_to_cube(
        &self,
        source: &dyn ChannelSource,
        job: &GriddingJob,
    ) -> Result<(CubeHandle, PipelineReport)> {
        let (cube, report, cleanup) = self.grid_source_to_cube_rows(source, job, None)?;
        Ok((CubeHandle::new(cube, job.spec.clone(), cleanup), report))
    }

    /// The row-restricted core of [`HegridEngine::grid_source_to_cube`]:
    /// grid every channel, accumulating only the output rows `[lo, hi)` of
    /// `rows` (the whole map when `None`) into a cube of exactly those rows.
    /// This is what a `hegrid shard-worker` process runs for its
    /// [`SkyPartition`] range — all samples, all channels, one row slice —
    /// so per-cell contribution order matches a single-process run and the
    /// supervisor's shard-ascending concatenation reproduces the full cube
    /// byte for byte. Returns `(cube, report, cleanup)` rather than a
    /// [`CubeHandle`]: a partial cube has fewer cells than the job's
    /// `GridSpec` and must not be read as one.
    pub(crate) fn grid_source_to_cube_rows(
        &self,
        source: &dyn ChannelSource,
        job: &GriddingJob,
        rows: Option<(usize, usize)>,
    ) -> Result<(CubeFile, PipelineReport, bool)> {
        let wall0 = Instant::now();
        let RunSetup { variant, mut report, stages, shared_plan } = self.prepare_run(source, job)?;
        let n_ch = source.n_channels();
        let (lons, lats) = source.coords()?;
        let n_cells = job.spec.n_cells();
        let (nlon, nlat) = (job.spec.nlon, job.spec.nlat);
        let (row_lo, row_hi) = rows.unwrap_or((0, nlat));
        assert!(row_lo < row_hi && row_hi <= nlat, "bad output row range");
        let n_rows = row_hi - row_lo;
        let local_cells = n_rows * nlon;
        let cell_base = row_lo * nlon;
        let rows_per_band = if self.config.output_tile_rows == 0 {
            n_rows
        } else {
            self.config.output_tile_rows.min(n_rows)
        };
        report.tile_rows = rows_per_band;
        report.tile_bands = n_rows.div_ceil(rows_per_band);

        let full_groups = ChannelGroups::new(n_ch, variant.c);
        let identity =
            job_identity(job, &variant, n_ch, source.n_samples(), rows_per_band, rows);

        // ---- cube + manifest ------------------------------------------------
        let (cube, manifest, cleanup) = if self.config.checkpoint_dir.is_empty() {
            (CubeFile::create(&anonymous_cube_path(), n_ch, local_cells)?, None, true)
        } else {
            let dir = PathBuf::from(&self.config.checkpoint_dir);
            std::fs::create_dir_all(&dir).map_err(HegridError::io(dir.display().to_string()))?;
            let cube_path = dir.join(CUBE_FILE);
            if self.config.resume {
                let m = CheckpointManifest::load(&dir)?;
                if m.job != identity {
                    return Err(HegridError::Config(format!(
                        "--resume checkpoint at {} was written by a different job\n  \
                         checkpoint: {}\n  this run:   {identity}",
                        dir.display(),
                        m.job
                    )));
                }
                let cube = CubeFile::open(&cube_path, n_ch, local_cells)?;
                // Re-verify every finished group's cube bytes against its
                // recorded CRC before trusting them (band by band, so even
                // verification stays memory-bounded).
                for &(g, crc) in &m.groups_done {
                    if g >= full_groups.len() {
                        return Err(HegridError::Corrupt(format!(
                            "checkpoint records group {g} but the job has only {} groups",
                            full_groups.len()
                        )));
                    }
                    let members = full_groups.members(g);
                    verify_group(&cube, g, members, nlon, n_rows, rows_per_band, crc)?;
                }
                (cube, Some(m), false)
            } else {
                let cube = CubeFile::create(&cube_path, n_ch, local_cells)?;
                let m = CheckpointManifest::new(identity.clone());
                m.save(&dir)?;
                (cube, Some(m), false)
            }
        };

        // ---- resume filtering: dense groups = the pending subset ------------
        let pending: Vec<usize> = match &manifest {
            Some(m) => (0..full_groups.len()).filter(|&g| !m.is_done(g)).collect(),
            None => (0..full_groups.len()).collect(),
        };
        report.groups_skipped = full_groups.len() - pending.len();
        report.n_groups = pending.len();
        let dense_groups = ChannelGroups::from_members(
            pending.iter().map(|&g| full_groups.members(g).to_vec()).collect(),
        );

        let shared_builds = AtomicU64::new(report.shared_builds as u64);
        let overflow = AtomicU64::new(0);
        let dispatches = AtomicU64::new(0);
        let ckpt_dir = PathBuf::from(&self.config.checkpoint_dir);
        let manifest = manifest.map(Mutex::new);
        let ctx = TiledCtx {
            job,
            variant: &variant,
            lons,
            lats,
            shared_plan: shared_plan.as_deref(),
            dense_to_orig: &pending,
            n_cells,
            nlon,
            row_lo,
            row_hi,
            cell_base,
            rows_per_band,
            cube: &cube,
            ckpt: manifest.as_ref().map(|m| (ckpt_dir.as_path(), m)),
            shared_builds: &shared_builds,
            overflow: &overflow,
            dispatches: &dispatches,
        };

        self.drive_pipelines(
            source,
            &dense_groups,
            variant.c,
            &mut report,
            stages,
            &job.cancel,
            |batch, local_stages, local_spans, pf| {
                self.run_pipeline_tiled(&ctx, batch, local_stages, local_spans, pf)
            },
        )?;

        // ---- isolate quarantined groups -------------------------------------
        // Degrade mode only (empty otherwise). The driver reports batch
        // (dense) group indices; remap them to original job groups first —
        // they differ on a resume.
        for g in report.degradation.quarantined_groups.iter_mut() {
            *g = pending[*g];
        }
        if report.degradation.is_degraded() {
            // A quarantined sweep may have torn mid-write: zero the group's
            // cube planes band by band (and wsum, owned by group 0) so the
            // cube holds blanks, not poison, and record the group `failed`
            // in the manifest so `--resume` retries exactly these groups.
            let zeros = vec![0.0f64; (rows_per_band * nlon).min(local_cells).max(1)];
            let mut zero_band = |write: &mut dyn FnMut(usize, &[f64]) -> Result<()>| -> Result<()> {
                let mut c0 = 0usize;
                while c0 < local_cells {
                    let len = zeros.len().min(local_cells - c0);
                    write(c0, &zeros[..len])?;
                    c0 += len;
                }
                Ok(())
            };
            for (i, &g) in report.degradation.quarantined_groups.iter().enumerate() {
                for &ch in full_groups.members(g) {
                    zero_band(&mut |c0, z| cube.write_channel_band(ch, c0, z, None))?;
                }
                if g == 0 {
                    zero_band(&mut |c0, z| cube.write_wsum_band(c0, z, None))?;
                }
                if let Some(m) = &manifest {
                    m.lock().unwrap().record_failed(g, &report.degradation.causes[i]);
                }
            }
            if let Some(m) = &manifest {
                m.lock().unwrap().save(&ckpt_dir)?;
            }
        }

        report.shared_builds = shared_builds.into_inner() as usize;
        report.dispatches = dispatches.into_inner() as usize;
        if let Some(plan) = &shared_plan {
            report.n_tiles = plan.n_tiles();
            report.n_shards = plan.shards.len();
            report.overflow_groups = plan.overflow_groups();
            report.adjacent_reuse = plan.adjacent_reuse();
        } else {
            report.overflow_groups = overflow.into_inner() as usize;
        }
        report.tile_spill_bytes = cube.spill_bytes();
        report.tile_merge_s = report.stage_s("T4 merge(cube)");
        report.wall = wall0.elapsed();
        Ok((cube, report, cleanup))
    }

    /// One tiled pipeline: process one channel group end to end, band-major.
    /// T1 permutes every shard once up front (the staged Arcs are held for
    /// the whole group — `O(samples × c)`, the same order as the batch's
    /// input values — so straddle re-dispatches never re-permute); then for
    /// each row band every shard's overlapping dispatch tiles are submitted
    /// (T2), drained (T3), and clip-reduced into a band-local accumulator
    /// (T4), whose finished bands stream into the cube.
    fn run_pipeline_tiled(
        &self,
        ctx: &TiledCtx<'_>,
        batch: &GroupBatch,
        stages: &mut StageTimes,
        spans: &mut Vec<StageSpan>,
        pf: &Prefetcher,
    ) -> Result<()> {
        let variant = ctx.variant;
        // Without sharing, every pipeline rebuilds the whole pre-processing
        // stack (the redundancy the paper eliminates) — same as untiled.
        let local_plan;
        let plan: &DispatchPlan = match ctx.shared_plan {
            Some(p) => p,
            None => {
                let t0 = Instant::now();
                let s0 = pf.now_s();
                local_plan = DispatchPlan::build(
                    ctx.lons,
                    ctx.lats,
                    ctx.job,
                    variant,
                    super::next_epoch_base(),
                    1, // a lone pipeline gets no extra build parallelism
                )?;
                stages.add("prep+nbr", t0.elapsed());
                spans.push(StageSpan { stage: PipeStage::Prep, start: s0, end: pf.now_s() });
                ctx.shared_builds.fetch_add(1, Ordering::Relaxed);
                ctx.overflow.store(local_plan.overflow_groups() as u64, Ordering::Relaxed);
                &local_plan
            }
        };

        let g_orig = ctx.dense_to_orig[batch.group];
        // Fault-injection `panic@<group>` site (no-op without the feature),
        // keyed by the original job group so specs survive a resume remap.
        crate::util::faults::sweep_panic_point(g_orig);
        // `wsum` is identical across groups, so only the group that was
        // *originally* group 0 writes it; if that group is already complete
        // in a resumed checkpoint, its wsum bytes are already in the cube.
        let owns_wsum = g_orig == 0;
        let members = &batch.channels;
        let stream = batch.group % self.streams.n_streams();
        let kparam = ctx.job.kernel.kparam();
        let group_values: Vec<&[f32]> = batch.values.iter().map(|v| v.as_slice()).collect();

        // T1: permute + pad this group's channel values into [c, n], once
        // per shard, up front.
        let t1 = Instant::now();
        let s1 = pf.now_s();
        let mut svals = Vec::with_capacity(plan.shards.len());
        for shard in &plan.shards {
            let mut staged = self.mem.take(variant.c * variant.n);
            shard.permute_group_into(&group_values, variant.n, &mut staged)?;
            // Pad missing channels (last group) with zeros.
            staged.resize(variant.c * variant.n, 0.0);
            svals.push(Arc::new(staged.into_inner()));
        }
        stages.add("T1 permute", t1.elapsed());
        spans.push(StageSpan { stage: PipeStage::T1Permute, start: s1, end: pf.now_s() });

        // Streaming digest over exactly the bytes this group writes, in
        // write order (bands ascending; per band the member channels in
        // order, then wsum if owned) — the manifest's per-group CRC.
        let mut digest = Crc32::new();
        let mut band_acc: Vec<f64> = Vec::new();
        let mut band_wsum: Vec<f64> = Vec::new();

        let mut r0 = ctx.row_lo;
        while r0 < ctx.row_hi {
            let r1 = (r0 + ctx.rows_per_band).min(ctx.row_hi);
            let cell0 = r0 * ctx.nlon;
            let cell1 = r1 * ctx.nlon;
            let band_cells = cell1 - cell0;
            // Dispatch tiles overlapping this band — tiles partition the
            // cell range, so one division per band edge routes the claim
            // block (no per-cell or per-sample search).
            let t_lo = cell0 / variant.m;
            let t_hi = (cell1 - 1) / variant.m;

            band_acc.clear();
            band_acc.resize(members.len() * band_cells, 0.0);
            if owns_wsum {
                band_wsum.clear();
                band_wsum.resize(band_cells, 0.0);
            }

            for (shard_idx, shard) in plan.shards.iter().enumerate() {
                // T2: submit this shard's overlapping tiles to our stream.
                let t2 = Instant::now();
                let s2 = pf.now_s();
                let mut pending: Vec<(usize, Receiver<Result<ExecuteResponse>>)> = Vec::new();
                for t in t_lo..=t_hi {
                    let tile = shard.tile(t);
                    let req = ExecuteRequest {
                        variant: variant.name.clone(),
                        epoch: plan.epoch_for_shard(shard_idx),
                        group: batch.group as u64,
                        cell_lon: Arc::clone(&tile.cell_lon),
                        cell_lat: Arc::clone(&tile.cell_lat),
                        nbr: Arc::clone(&tile.nbr),
                        slon: Arc::clone(&shard.slon),
                        slat: Arc::clone(&shard.slat),
                        sunit: Arc::clone(&shard.sunit),
                        sval: Arc::clone(&svals[shard_idx]),
                        kparam,
                    };
                    pending.push((t, self.streams.submit(stream, req)));
                    ctx.dispatches.fetch_add(1, Ordering::Relaxed);
                }
                stages.add("T2 submit", t2.elapsed());
                spans.push(StageSpan { stage: PipeStage::T2Submit, start: s2, end: pf.now_s() });

                // T3: drain.
                let t_drain = Instant::now();
                let s3 = pf.now_s();
                let mut t3_total = Duration::ZERO;
                let mut h2d_total = Duration::ZERO;
                let mut d2h_total = Duration::ZERO;
                let mut responses: Vec<(usize, ExecuteResponse)> = Vec::new();
                for (t, rx) in pending {
                    let resp = self.streams.wait(rx)?;
                    t3_total += resp.t_exec;
                    h2d_total += resp.t_h2d;
                    d2h_total += resp.t_d2h;
                    responses.push((t, resp));
                }
                stages.add("T3 kernel(+wait)", t_drain.elapsed());
                spans.push(StageSpan { stage: PipeStage::T3Kernel, start: s3, end: pf.now_s() });
                stages.add("T2 H2D(device)", h2d_total);
                stages.add("T3 kernel(device)", t3_total);
                stages.add("T4 D2H(device)", d2h_total);

                // T4: reduce the band-overlapping cell range of every
                // response into the band accumulator — the same per-cell
                // addition order as the untiled path (shards ascending, one
                // covering tile per cell per shard).
                let t4 = Instant::now();
                let s4 = pf.now_s();
                for (t, resp) in responses {
                    let tc0 = t * variant.m;
                    let valid = ctx.n_cells.saturating_sub(tc0).min(variant.m);
                    let lo = cell0.max(tc0);
                    let hi = cell1.min(tc0 + valid);
                    if lo >= hi {
                        continue;
                    }
                    for ci in 0..members.len() {
                        let sa = ci * variant.m;
                        let src = &resp.acc[sa + (lo - tc0)..sa + (hi - tc0)];
                        let da = ci * band_cells;
                        let dst = &mut band_acc[da + (lo - cell0)..da + (hi - cell0)];
                        for (d, &v) in dst.iter_mut().zip(src) {
                            *d += v as f64;
                        }
                    }
                    if owns_wsum {
                        let src = &resp.wsum[lo - tc0..hi - tc0];
                        let dst = &mut band_wsum[lo - cell0..hi - cell0];
                        for (d, &v) in dst.iter_mut().zip(src) {
                            *d += v as f64;
                        }
                    }
                }
                stages.add("T4 reduce", t4.elapsed());
                spans.push(StageSpan { stage: PipeStage::T4Reduce, start: s4, end: pf.now_s() });
            }

            // Merge: stream the finished band into the cube (+ digest).
            let tm = Instant::now();
            let sm = pf.now_s();
            for (ci, &ch) in members.iter().enumerate() {
                ctx.cube.write_channel_band(
                    ch,
                    cell0 - ctx.cell_base,
                    &band_acc[ci * band_cells..(ci + 1) * band_cells],
                    Some(&mut digest),
                )?;
            }
            if owns_wsum {
                ctx.cube.write_wsum_band(cell0 - ctx.cell_base, &band_wsum, Some(&mut digest))?;
            }
            stages.add("T4 merge(cube)", tm.elapsed());
            spans.push(StageSpan { stage: PipeStage::T4Reduce, start: sm, end: pf.now_s() });

            r0 = r1;
        }

        // Group complete: record it in the manifest (atomic tmp + rename),
        // so a crash after this point resumes past this group.
        if let Some((dir, manifest)) = ctx.ckpt {
            let mut m = manifest.lock().unwrap();
            m.record(g_orig, digest.finalize());
            m.save(dir)?;
        }
        Ok(())
    }
}

/// Canonical job-identity string for checkpoint manifests: everything that
/// must match for finished groups to be reusable — grid geometry, kernel
/// parameters (bit-exact), sample/channel counts, the dispatch variant
/// (its `m`/`k`/`c` shape the numerics), the band height (it fixes the
/// per-group digest's write order), and — for shard-worker row slices —
/// the output row range (a shard checkpoint is only resumable by the same
/// shard). Full-map runs carry no row suffix, so pre-sharding checkpoints
/// stay loadable.
fn job_identity(
    job: &GriddingJob,
    variant: &VariantInfo,
    n_channels: usize,
    n_samples: usize,
    rows_per_band: usize,
    rows: Option<(usize, usize)>,
) -> String {
    let spec = &job.spec;
    let k = &job.kernel;
    let kp = k.kparam();
    let mut id = format!(
        "grid:{}x{} step:{:016x} center:{:016x},{:016x} kernel:{} \
         kparam:{:08x},{:08x},{:08x},{:08x} support:{:016x} samples:{n_samples} \
         channels:{n_channels} variant:{} tile_rows:{rows_per_band}",
        spec.nlon,
        spec.nlat,
        spec.step.to_bits(),
        spec.lon_c.to_bits(),
        spec.lat_c.to_bits(),
        k.type_name(),
        kp[0].to_bits(),
        kp[1].to_bits(),
        kp[2].to_bits(),
        kp[3].to_bits(),
        k.support.to_bits(),
        variant.name,
    );
    if let Some((lo, hi)) = rows {
        id.push_str(&format!(" rows:{lo}:{hi}"));
    }
    id
}

/// Re-verify one finished group against the cube: recompute the streaming
/// CRC over its bytes in write order (band by band — bounded memory) and
/// compare with the manifest's record.
fn verify_group(
    cube: &CubeFile,
    group: usize,
    members: &[usize],
    nlon: usize,
    nlat: usize,
    rows_per_band: usize,
    expect: u32,
) -> Result<()> {
    let mut crc = Crc32::new();
    let mut buf = Vec::new();
    let mut r0 = 0usize;
    while r0 < nlat {
        let r1 = (r0 + rows_per_band).min(nlat);
        let cell0 = r0 * nlon;
        let band_cells = (r1 - r0) * nlon;
        for &ch in members {
            cube.read_channel_band(ch, cell0, band_cells, &mut buf)?;
            for v in &buf {
                crc.update(&v.to_le_bytes());
            }
        }
        if group == 0 {
            cube.read_wsum_band(cell0, band_cells, &mut buf)?;
            for v in &buf {
                crc.update(&v.to_le_bytes());
            }
        }
        r0 = r1;
    }
    let got = crc.finalize();
    if got != expect {
        return Err(HegridError::Corrupt(format!(
            "checkpoint cube bytes for finished group {group} fail their CRC \
             (computed {got:#010x}, manifest {expect:#010x}); the spill was modified or torn — \
             delete the checkpoint directory to re-grid from scratch"
        )));
    }
    Ok(())
}
