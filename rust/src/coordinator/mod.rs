//! The HEGrid coordinator: multi-pipeline concurrency over frequency
//! channels (§4.2) with pipeline-based co-optimization (§4.3).
//!
//! One **pipeline** processes one channel group end to end; a **T0 ingest**
//! stage feeds it:
//!
//! ```text
//! T0  read the group's channels from the source  (I/O workers, read-ahead)
//! T1  permute channel values into LUT order      (CPU, pipeline worker)
//! T2  stage + upload to the device               (H2D, stream thread)
//! T3  cell-update kernel                         (PJRT execution)
//! T4  read back + accumulate into the maps       (D2H + CPU reduce)
//! ```
//!
//! Channels come from a [`ChannelSource`] (in-memory, HGD streaming, or
//! simulated), pulled through a bounded [`Prefetcher`] ring: `prefetch_depth`
//! groups are read ahead by `io_workers` threads, so group `g+1`'s disk read
//! (T0) overlaps group `g`'s T1–T4 — the paper's third co-optimization
//! (Fig 8's I/O/compute overlap). Backpressure caps the ring at
//! `prefetch_depth` groups; with the one batch each pipeline holds while
//! staging, peak resident channel data is `prefetch_depth + n_pipelines`
//! groups — bounded independently of channel count, which is what makes
//! larger-than-RAM datasets streamable.
//!
//! Multiple pipelines run concurrently: `pipeline_width` of them execute as
//! one sweep on the persistent [`PipelineExecutor`] (parked workers — no
//! per-run thread spawns), pulling channel groups from the prefetcher's
//! FIFO. Each pipeline pins its dispatches to a PJRT stream slot (the
//! paper's GPU streams) so its group-value buffers stay device-resident
//! across tile dispatches; while group *k* drains its kernel (T3), group
//! *k+1* permutes and submits (T1–T2) and group *k+2* is read ahead (T0).
//! Every stage records its execution window ([`StageSpan`]), so a run
//! reports per-stage occupancy and the measured inter-pipeline overlap
//! ([`PipelineReport::stage_overlap_s`]). With `pipeline_width auto` the
//! same spans feed a width governor: a rolling occupancy window decides
//! after every group-batch whether to shrink the width (T3 saturating the
//! streams, T0 starving the pipelines) or grow it (busy pipelines with
//! stream headroom), bounded by `pipeline_width_max` — the fig8/table3
//! sweeps become self-tuning, and the chosen schedule is reported as
//! [`PipelineReport::width_trace`]. Adaptive runs put each slot on a
//! dedicated scoped thread so a shed (parked) slot never occupies one of
//! the executor's pool workers, which the active pipelines' nested
//! fine-grained sweeps still need. The **shared component** (sorted
//! samples + LUT + neighbour tables + device-resident coordinates + staged
//! unit-vector columns) is built once and reused by every pipeline;
//! disabling it (Fig 11/12) rebuilds all of it per group, reproducing the
//! redundant compute + transfer the paper eliminates.

pub mod plan;
pub mod simulator;
mod tiled;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::HegridConfig;
use crate::data::{ChannelSource, Dataset, DatasetMeta, InMemorySource};
use crate::grid::kernels::ConvKernel;
use crate::grid::occupancy::{decide_width, StageOccupancy, WidthDecision, WidthPolicy};
use crate::logging::StageTimes;
use crate::runtime::prefetch::{overlap_seconds, GroupBatch, Prefetcher, ReadPolicy};
use crate::runtime::{
    ExecuteRequest, ExecuteResponse, Manifest, MemoryPool, StreamPool, VariantInfo, VariantQuery,
};
use crate::sky::{GridSpec, SkyMap};
use crate::util::error::{HegridError, Result};
use crate::util::threads::PipelineExecutor;

pub use plan::{ChannelGroups, DispatchPlan, SkyPartition};
pub use simulator::{simulate, SimParams, SimResult, StageCost};

/// Process-global epoch allocator for [`DispatchPlan`] builds. Epoch IDs
/// key per-plan device-buffer caches in the stream pools, so they must be
/// unique across *every* engine in the process — the service runs one
/// engine per job but shares plans through a [`crate::service::cache::PlanCache`],
/// and a per-engine counter would let two engines mint colliding IDs for
/// different plans.
static EPOCH_COUNTER: AtomicU64 = AtomicU64::new(1);

/// Reserve a fresh block of [`plan::EPOCHS_PER_PLAN`] epoch IDs.
pub(crate) fn next_epoch_base() -> u64 {
    EPOCH_COUNTER.fetch_add(plan::EPOCHS_PER_PLAN, Ordering::Relaxed)
}

/// Pipeline stages for span-level accounting (occupancy + inter-pipeline
/// overlap — the Fig-8/9 instrumentation of the multi-pipeline design).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipeStage {
    /// T0: channel-group reads by the I/O workers.
    T0Ingest,
    /// Per-group shared-component rebuild (only with sharing disabled).
    Prep,
    /// T1: permute + pad group values into the staging layout.
    T1Permute,
    /// T2: tile submission to the pinned stream.
    T2Submit,
    /// T3: kernel execution + drain wait.
    T3Kernel,
    /// T4: accumulation of tile outputs into the global maps.
    T4Reduce,
}

impl PipeStage {
    pub const ALL: [PipeStage; 6] = [
        PipeStage::T0Ingest,
        PipeStage::Prep,
        PipeStage::T1Permute,
        PipeStage::T2Submit,
        PipeStage::T3Kernel,
        PipeStage::T4Reduce,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            PipeStage::T0Ingest => "T0",
            PipeStage::Prep => "prep",
            PipeStage::T1Permute => "T1",
            PipeStage::T2Submit => "T2",
            PipeStage::T3Kernel => "T3",
            PipeStage::T4Reduce => "T4",
        }
    }
}

/// One stage execution window, in seconds on the run clock (the prefetcher
/// clock that also timestamps the T0 read intervals).
#[derive(Clone, Copy, Debug)]
pub struct StageSpan {
    pub stage: PipeStage,
    pub start: f64,
    pub end: f64,
}

/// Cooperative cancellation token for a gridding run, checked by every
/// pipeline slot at channel-group boundaries (between groups, never inside
/// a sweep). The default token is inert — `is_cancelled()` is always false
/// and costs one branch per group — so one-shot CLI runs pay nothing. The
/// service arms one per job and trips it on `DELETE /jobs/{id}`; the run
/// then drains cleanly and returns [`HegridError::Cancelled`].
#[derive(Clone, Debug, Default)]
pub struct CancelFlag(Option<Arc<std::sync::atomic::AtomicBool>>);

impl CancelFlag {
    /// An armed token (cancellable). Clones share the flag.
    pub fn armed() -> CancelFlag {
        CancelFlag(Some(Arc::new(std::sync::atomic::AtomicBool::new(false))))
    }

    /// Request cancellation. No-op on an inert (default) token.
    pub fn cancel(&self) {
        if let Some(f) = &self.0 {
            f.store(true, Ordering::SeqCst);
        }
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.as_ref().is_some_and(|f| f.load(Ordering::SeqCst))
    }
}

/// What to grid: a dataset onto a map with a kernel.
#[derive(Clone, Debug)]
pub struct GriddingJob {
    pub spec: GridSpec,
    pub kernel: ConvKernel,
    /// SIMD ISA request forwarded to the neighbour-table build (config
    /// `simd_isa` / CLI `--simd`).
    pub simd: crate::grid::simd::SimdIsa,
    /// Cooperative cancellation token, checked at group boundaries by
    /// [`HegridEngine::grid_source`]'s pipeline loop. Inert by default.
    pub cancel: CancelFlag,
}

impl GriddingJob {
    /// Derive map + kernel from dataset metadata and the engine config.
    pub fn for_meta(meta: &DatasetMeta, cfg: &HegridConfig) -> Result<GriddingJob> {
        let beam_deg = meta.beam_arcsec / 3600.0;
        let spec = GridSpec::for_field(
            meta.center_deg.0,
            meta.center_deg.1,
            meta.extent_deg.0,
            meta.extent_deg.1,
            beam_deg,
            cfg.oversample,
        );
        let kernel = ConvKernel::from_config(meta.beam_arcsec, cfg)?;
        Ok(GriddingJob { spec, kernel, simd: cfg.simd(), cancel: CancelFlag::default() })
    }

    /// Attach a cancellation token (service jobs).
    pub fn with_cancel(mut self, cancel: CancelFlag) -> GriddingJob {
        self.cancel = cancel;
        self
    }

    /// Derive map + kernel from dataset metadata and the engine config.
    pub fn for_dataset(dataset: &Dataset, cfg: &HegridConfig) -> Result<GriddingJob> {
        Self::for_meta(&dataset.meta, cfg)
    }

    /// Derive map + kernel from a channel source's metadata.
    pub fn for_source(source: &dyn ChannelSource, cfg: &HegridConfig) -> Result<GriddingJob> {
        Self::for_meta(source.meta(), cfg)
    }
}

/// Everything the run reports back (Fig-8 timeline, reuse stats, …).
#[derive(Clone, Debug, Default)]
pub struct PipelineReport {
    /// Merged per-stage wall time across pipelines (T1..T4 + prep/nbr).
    pub stages: StageTimes,
    /// End-to-end wall time of `grid_dataset`.
    pub wall: Duration,
    pub variant: String,
    pub n_streams: usize,
    pub n_pipelines: usize,
    pub n_groups: usize,
    pub n_tiles: usize,
    pub n_shards: usize,
    pub dispatches: usize,
    /// Times the shared component was built (1 with sharing, ≥ groups
    /// without, 0 when a service [`crate::service::cache::PlanCache`] hit
    /// supplied the plan).
    pub shared_builds: usize,
    /// The shared component came out of a service plan cache instead of
    /// being built by this run (always `false` outside `hegrid serve`).
    pub plan_cache_hit: bool,
    /// Neighbour-table stats of the last build.
    pub overflow_groups: usize,
    pub adjacent_reuse: f64,
    /// Host staging pool counters (allocations, reuses).
    pub pool_alloc: usize,
    pub pool_reused: usize,
    /// Streaming ingest (T0): configured read-ahead window and workers.
    pub prefetch_depth: usize,
    pub io_workers: usize,
    /// Total time the I/O workers spent reading channel groups.
    pub io_busy_s: f64,
    /// Measured wall-clock window during which T0 reads overlapped T1–T4
    /// compute — the paper's Fig-8 I/O/compute overlap. ~0 for in-memory
    /// sources (reads are memcpys) and for `prefetch_depth = 1`.
    pub io_overlap_s: f64,
    /// Per-stage execution windows across every pipeline (plus the T0 read
    /// intervals), all on one clock — the raw material for
    /// [`PipelineReport::stage_occupancy`] and
    /// [`PipelineReport::stage_overlap_s`].
    pub spans: Vec<StageSpan>,
    /// The run used the adaptive width controller (`pipeline_width auto`).
    pub width_auto: bool,
    /// `(run-clock seconds, width)` at every controller change, starting
    /// with the initial width at t = 0. Fixed-width runs get the single
    /// entry `(0, width)`. Benches record this as an additive JSON field.
    pub width_trace: Vec<(f64, usize)>,
    /// NUMA nodes detected on the host (1 = UMA or detection unavailable);
    /// see [`crate::util::numa`].
    pub numa_nodes: usize,
    /// Rows per output band on the tiled path (`0` = untiled run).
    pub tile_rows: usize,
    /// Row bands the output map was split into (`0` = untiled run).
    pub tile_bands: usize,
    /// Bytes streamed into the on-disk output cube (tiled path).
    pub tile_spill_bytes: u64,
    /// Wall seconds pipelines spent merging finished bands into the cube.
    pub tile_merge_s: f64,
    /// Channel groups skipped on `--resume` (already whole in the
    /// checkpoint and CRC-verified against the cube).
    pub groups_skipped: usize,
    /// Degraded-run accounting: quarantined groups, retried reads, causes.
    /// Empty (`!is_degraded()`) on every fault-free or fail-fast run.
    pub degradation: DegradationReport,
}

/// What a degrade-mode run (`fail_fast = false`) survived: which channel
/// groups were quarantined (their output planes zeroed, recorded `failed`
/// in the checkpoint manifest so `--resume` retries exactly them), why, and
/// how many channel-read retries the ingest performed. Carried on
/// [`PipelineReport`]; all-zero on fault-free runs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DegradationReport {
    /// Original (job-order) indices of quarantined channel groups, sorted.
    pub quarantined_groups: Vec<usize>,
    /// Channel-read retries performed by the T0 workers (successful
    /// recoveries included — nonzero retries with no quarantined groups
    /// means transient faults were fully absorbed).
    pub retries: usize,
    /// Terminal cause of each quarantined group, parallel to
    /// `quarantined_groups`.
    pub causes: Vec<String>,
    /// Supervised runs: shard indices whose worker process exceeded
    /// `shard_max_restarts` and was given up on (their output rows are
    /// zeroed in the merged cube, mirroring group quarantine). Causes are
    /// appended to `causes`, prefixed `shard N:`. Empty on single-process
    /// runs.
    pub quarantined_shards: Vec<usize>,
    /// Supervised runs: total worker-process restarts the supervisor
    /// performed (successful recoveries included).
    pub worker_restarts: usize,
}

impl DegradationReport {
    /// Did any group or shard fail to grid?
    pub fn is_degraded(&self) -> bool {
        !self.quarantined_groups.is_empty() || !self.quarantined_shards.is_empty()
    }
}

impl PipelineReport {
    /// Seconds spent in a stage (0 if absent).
    pub fn stage_s(&self, stage: &str) -> f64 {
        self.stages.total(stage).as_secs_f64()
    }

    /// Calibrated per-channel-group stage costs for the timeline simulator
    /// (see [`simulator`]): measured totals divided by the group count.
    pub fn stage_cost_per_group(&self) -> StageCost {
        let n = self.n_groups.max(1) as f64;
        StageCost {
            t1_cpu: self.stage_s("T1 permute") / n,
            t2_h2d: self.stage_s("T2 H2D(device)") / n,
            t3_kernel: self.stage_s("T3 kernel(device)") / n,
            t4_d2h: (self.stage_s("T4 D2H(device)") + self.stage_s("T4 reduce")) / n,
        }
    }

    /// Measured one-off pre-processing cost (per build).
    pub fn prep_cost(&self) -> f64 {
        self.stage_s("prep+nbr") / self.shared_builds.max(1) as f64
    }

    /// Execution windows of `stage` across all pipelines (run clock).
    pub fn stage_windows(&self, stage: PipeStage) -> Vec<(f64, f64)> {
        self.spans
            .iter()
            .filter(|s| s.stage == stage)
            .map(|s| (s.start, s.end))
            .collect()
    }

    /// Total pipeline-seconds spent in `stage` (raw sum across pipelines;
    /// concurrent windows count multiply).
    pub fn stage_busy_s(&self, stage: PipeStage) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.stage == stage)
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Mean number of pipelines concurrently inside `stage`
    /// (`stage_busy_s / wall`) — the per-stage occupancy the fig8/table3
    /// benches report. > 1 means the stage itself ran multi-pipeline.
    pub fn stage_occupancy(&self, stage: PipeStage) -> f64 {
        let w = self.wall.as_secs_f64();
        if w > 0.0 {
            self.stage_busy_s(stage) / w
        } else {
            0.0
        }
    }

    /// Measured wall-clock window during which stages `a` and `b` were both
    /// active in *some* pipeline. Within one pipeline the stages serialise,
    /// so e.g. `stage_overlap_s(T1Permute, T3Kernel) > 0` demonstrates
    /// inter-pipeline overlap: a group's permute hid under another group's
    /// kernel (zero by construction at `pipeline_width = 1`).
    pub fn stage_overlap_s(&self, a: PipeStage, b: PipeStage) -> f64 {
        overlap_seconds(&self.stage_windows(a), &self.stage_windows(b))
    }

    /// Overlap of the **union** of several stages' windows with `b`'s
    /// windows — e.g. "T0+T1 hidden under T3". Summing two
    /// [`PipelineReport::stage_overlap_s`] values would double-count wall
    /// seconds where both hidden stages run at once; the union counts each
    /// hidden second exactly once.
    pub fn stages_overlap_s(&self, a: &[PipeStage], b: PipeStage) -> f64 {
        let mut windows = Vec::new();
        for &stage in a {
            windows.extend(self.stage_windows(stage));
        }
        overlap_seconds(&windows, &self.stage_windows(b))
    }
}

/// Run-time governor of the pipeline width: every pipeline slot asks to be
/// admitted before pulling another group, and each finished batch feeds the
/// rolling [`StageOccupancy`] window that decides shrink/grow
/// ([`decide_width`]). In fixed-width runs the governor is inert (every
/// slot admitted, no decisions), so the knob's semantics are unchanged.
///
/// Width changes only gate *which slots may pull the next group* — a
/// group's channels are still owned by exactly one pipeline and processed
/// in a fixed order, so any width schedule produces bit-identical maps
/// (pinned by `rust/tests/pipeline_overlap.rs`, auto included).
struct WidthGovernor {
    auto: bool,
    max: usize,
    policy: WidthPolicy,
    state: Mutex<GovernorState>,
    cond: Condvar,
}

struct GovernorState {
    /// Slots `0..allowed` may pull; the rest park until a grow or the end
    /// of the run. Never below 1, so slot 0 (always run by the sweep's
    /// caller) keeps draining and the run cannot stall.
    allowed: usize,
    done: bool,
    occ: StageOccupancy,
    /// T0 read intervals already folded into `occ` (prefix length of the
    /// prefetcher's interval list).
    io_seen: usize,
    /// Batches observed since the last width change (decision cooldown).
    since_change: usize,
    trace: Vec<(f64, usize)>,
}

impl WidthGovernor {
    /// Rolling occupancy window: long enough to smooth one slow group,
    /// short enough that cold-start behaviour ages out.
    const WINDOW_S: f64 = 2.0;
    /// Batches a fresh width must observe before the next decision.
    const COOLDOWN: usize = 2;

    fn new(initial: usize, max: usize, auto: bool, policy: WidthPolicy) -> WidthGovernor {
        let initial = initial.clamp(1, max.max(1));
        WidthGovernor {
            auto,
            max: max.max(1),
            policy,
            state: Mutex::new(GovernorState {
                allowed: initial,
                done: false,
                occ: StageOccupancy::new(Self::WINDOW_S),
                io_seen: 0,
                since_change: 0,
                trace: vec![(0.0, initial)],
            }),
            cond: Condvar::new(),
        }
    }

    /// Block until pipeline slot `slot` may pull another group; `false`
    /// once the run is over (shed slots exit their loop through this).
    fn admit(&self, slot: usize) -> bool {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.done {
                return false;
            }
            if slot < st.allowed {
                return true;
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    /// Feed one finished batch's stage spans (plus the prefetcher's T0 read
    /// intervals, of which `io_intervals` is the full list so far) and, in
    /// auto mode, re-evaluate the width.
    fn observe(&self, batch_spans: &[StageSpan], io_intervals: &[(f64, f64)], now: f64) {
        if !self.auto {
            return;
        }
        let mut st = self.state.lock().unwrap();
        for &s in batch_spans {
            st.occ.record(s);
        }
        while st.io_seen < io_intervals.len() {
            let iv = io_intervals[st.io_seen];
            st.occ.record_interval(PipeStage::T0Ingest, iv);
            st.io_seen += 1;
        }
        st.occ.prune(now);
        st.since_change += 1;
        if st.since_change < Self::COOLDOWN {
            return;
        }
        let w = st.allowed;
        let next = match decide_width(&st.occ, now, w, &self.policy) {
            WidthDecision::Grow => (w + 1).min(self.max),
            WidthDecision::Shrink => (w - 1).max(1),
            WidthDecision::Hold => w,
        };
        if next != w {
            st.allowed = next;
            st.since_change = 0;
            // Callers read the run clock before taking this lock, so a
            // stalled observer can arrive with an older `now` than the last
            // recorded change; clamp to keep the trace monotone.
            let t = st.trace.last().map_or(now, |&(prev, _)| now.max(prev));
            st.trace.push((t, next));
            if next > w {
                // A parked slot may resume pulling.
                self.cond.notify_all();
            }
        }
    }

    /// The run is over (prefetcher drained or failed): release every parked
    /// slot so the executor sweep can join. Idempotent — every pipeline
    /// calls it on exit.
    fn finish(&self) {
        let mut st = self.state.lock().unwrap();
        st.done = true;
        self.cond.notify_all();
    }

    fn trace(&self) -> Vec<(f64, usize)> {
        self.state.lock().unwrap().trace.clone()
    }
}

/// Output of [`HegridEngine::prepare_run`]: the state both output paths
/// share before any pipeline spins up.
struct RunSetup {
    variant: VariantInfo,
    report: PipelineReport,
    /// Pre-seeded with the shared build's `prep+nbr` time.
    stages: StageTimes,
    shared_plan: Option<Arc<DispatchPlan>>,
}

/// The engine: config + manifest + stream pool. Reusable across jobs.
pub struct HegridEngine {
    pub config: HegridConfig,
    manifest: Arc<Manifest>,
    streams: StreamPool,
    mem: MemoryPool,
    /// Service-attached shared plan cache ([`HegridEngine::with_plan_cache`]);
    /// `None` (no cache, always build) for one-shot CLI engines.
    plan_cache: Option<Arc<crate::service::cache::PlanCache>>,
}

impl HegridEngine {
    pub fn new(config: HegridConfig) -> Result<HegridEngine> {
        config.validate()?;
        // Install (or clear) the process-wide fault plan from `config.faults`
        // / HEGRID_FAULTS. A no-op returning Ok(()) unless the crate is built
        // with `--features fault-injection`.
        crate::util::faults::install_from_spec(&config.faults)?;
        // Executor-worker core pinning (config `executor_affinity`): applied
        // lazily by each pool worker on its next sweep, so it also covers
        // the case where the global executor spawned before the engine.
        crate::util::threads::set_executor_affinity(config.affinity());
        if config.affinity() != crate::util::threads::AffinityMode::None {
            // NUMA warm-up: pin the pool now and first-touch per-worker
            // scratch on each worker's node before the first sweep (no-op
            // effectwise on single-node hosts; see util::numa).
            PipelineExecutor::global().init();
        }
        let dir = std::path::Path::new(&config.artifacts_dir);
        // The native executor interprets dispatches from variant shapes
        // alone, so a *missing* artifacts directory falls back to the
        // built-in set. A manifest that exists but fails to load is a real
        // error on every backend — masking it would silently substitute
        // different variants than the user configured.
        let manifest = if !dir.join("manifest.json").exists()
            && crate::runtime::backend_name() == "native"
        {
            crate::log_info!(
                "no manifest at {}; using the built-in native variant set",
                dir.display()
            );
            Manifest::native_default(dir)
        } else {
            Manifest::load(dir)?
        };
        let manifest = Arc::new(manifest);
        let streams = StreamPool::new(Arc::clone(&manifest), config.effective_streams())?;
        Ok(HegridEngine { config, manifest, streams, mem: MemoryPool::new(), plan_cache: None })
    }

    /// Attach a shared [`crate::service::cache::PlanCache`]: `prepare_run`
    /// will consult it (when `share_preprocessing` is on) before building
    /// the shared component, so concurrent service jobs with the same sky
    /// setup reuse one `DispatchPlan` (NeighborTable, CellTrig, staged unit
    /// vectors, permutation) instead of building it per job. Safe across
    /// engines because epoch IDs are allocated process-globally
    /// ([`next_epoch_base`]).
    pub fn with_plan_cache(mut self, cache: Arc<crate::service::cache::PlanCache>) -> HegridEngine {
        self.plan_cache = Some(cache);
        self
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Grid every channel of `dataset` with job geometry derived from its
    /// metadata.
    pub fn grid_dataset(&self, dataset: &Dataset) -> Result<(Vec<SkyMap>, PipelineReport)> {
        let job = GriddingJob::for_dataset(dataset, &self.config)?;
        self.grid(dataset, &job)
    }

    /// Grid an interferometric visibility set onto the configured uv grid
    /// (the `uv_grid` config block), inheriting the engine's SIMD request.
    /// The sweep runs on the same process-global executor as the sky-plane
    /// pipelines; results are bit-identical across worker counts, forced
    /// ISAs, and tile heights (see docs/uv-gridding.md).
    pub fn grid_uv(
        &self,
        dataset: &crate::grid::uv::UvDataset,
    ) -> Result<crate::grid::uv::UvResult> {
        let gridder = self.config.uv_grid.build_gridder()?.with_simd(self.config.simd());
        gridder.grid(dataset)
    }

    /// Grid an in-memory `dataset` onto an explicit map/kernel.
    ///
    /// Goes through the same T0 ingest ring as streaming sources: each
    /// group's values are copied once into pooled staging buffers by the
    /// I/O workers. The copy overlaps pipeline compute and is linear in the
    /// dataset (~1% of a gridding run at bench scales) — the price of one
    /// unified ingest path instead of two.
    pub fn grid(
        &self,
        dataset: &Dataset,
        job: &GriddingJob,
    ) -> Result<(Vec<SkyMap>, PipelineReport)> {
        self.grid_source(&InMemorySource::new(dataset), job)
    }

    /// Grid every channel of `source` — the streaming-capable core path.
    /// `config.io_workers` T0 threads read `config.prefetch_depth` channel
    /// groups ahead of the pipelines through a bounded ring, so only the
    /// in-flight window is ever resident and disk reads overlap compute.
    ///
    /// With `output_tile_rows > 0` or a `checkpoint_dir` configured the run
    /// takes the tiled output path (bounded accumulator memory,
    /// spill-to-disk reduce, resumable checkpoints — see
    /// [`HegridEngine::grid_source_to_cube`]) and reads the maps back from
    /// the spilled cube; the result is bit-identical to the untiled path.
    pub fn grid_source(
        &self,
        source: &dyn ChannelSource,
        job: &GriddingJob,
    ) -> Result<(Vec<SkyMap>, PipelineReport)> {
        if self.config.output_tile_rows == 0 && self.config.checkpoint_dir.is_empty() {
            return self.grid_source_full(source, job);
        }
        let (cube, mut report) = self.grid_source_to_cube(source, job)?;
        let t4 = Instant::now();
        let maps = cube.read_all_maps()?;
        report.stages.add("normalize", t4.elapsed());
        report.wall += t4.elapsed();
        Ok((maps, report))
    }

    /// Shared run setup for both output paths: validation, variant
    /// selection (+ stream warm-up), the report skeleton, and the one-off
    /// shared-component build — extracted so the untiled and tiled paths
    /// cannot drift apart.
    fn prepare_run(&self, source: &dyn ChannelSource, job: &GriddingJob) -> Result<RunSetup> {
        let n_ch = source.n_channels();
        let n_samples = source.n_samples();
        if n_ch == 0 {
            return Err(HegridError::Config("dataset has no channels".into()));
        }
        let mut report = PipelineReport {
            n_streams: self.streams.n_streams(),
            n_pipelines: self.config.effective_pipelines(),
            prefetch_depth: self.config.prefetch_depth,
            io_workers: self.config.effective_io_workers(),
            ..Default::default()
        };

        // ---- variant selection --------------------------------------------
        // K hint from sampling density: the kernel pays for K gathered
        // candidates per cell group whether or not they exist, so pick the
        // smallest artifact K that (with 3× margin over the expected count)
        // still avoids truncation. §Perf: ~2x kernel time on sparse data.
        let k_hint = {
            let (w, h) = (
                job.spec.nlon as f64 * job.spec.step,
                job.spec.nlat as f64 * job.spec.step,
            );
            let density = n_samples as f64 / (w * h).max(1e-12);
            // Accepted candidates are within support + the γ-group span
            // (the exact-distance prefilter strips the HEALPix pad).
            let r = job.kernel.support
                + self.config.gamma.saturating_sub(1) as f64 * job.spec.step;
            let expected = density * std::f64::consts::PI * r * r;
            // 3× peak-to-mean margin over the drift-scan's row clustering.
            (expected * 3.0).ceil() as usize
        };
        let variant = if !self.config.variant_override.is_empty() {
            self.manifest.get(&self.config.variant_override)?.clone()
        } else {
            self
            .manifest
            .select(&VariantQuery {
                kernel_type: job.kernel.type_name().to_string(),
                gamma: self.config.gamma,
                channels: self.config.channels_per_dispatch.min(n_ch),
                n_samples,
                block: self.config.effective_block(),
                k_hint,
            })?
            .clone()
        };
        report.variant = variant.name.clone();
        self.streams.warm(&variant.name)?;

        // The shared coordinate table is the only payload a streaming run
        // keeps resident for its whole duration (borrowed — no copy).
        let (lons, lats) = source.coords()?;

        // ---- shared component (built once here; per group below if sharing
        // is disabled) --------------------------------------------------------
        let mut stages = StageTimes::default();
        let shared_plan: Option<Arc<DispatchPlan>> = if self.config.share_preprocessing {
            let t0 = Instant::now();
            // Full host parallelism for the one-off build: it runs before
            // any pipeline exists, so the pipeline-width knob must not
            // throttle it (that would contaminate width sweeps with prep
            // speed differences).
            let build = || {
                DispatchPlan::build(
                    lons,
                    lats,
                    job,
                    &variant,
                    next_epoch_base(),
                    crate::util::threads::default_parallelism(),
                )
                .map(Arc::new)
            };
            // With a service plan cache attached, same-sky-setup jobs reuse
            // one plan; a concurrent same-key miss waits for the in-flight
            // build instead of duplicating it.
            let (plan, cache_hit) = match &self.plan_cache {
                Some(cache) => {
                    let key = crate::service::cache::plan_key(lons, lats, job, &variant);
                    cache.get_or_build(&key, build)?
                }
                None => (build()?, false),
            };
            stages.add("prep+nbr", t0.elapsed());
            report.shared_builds = usize::from(!cache_hit);
            report.plan_cache_hit = cache_hit;
            Some(plan)
        } else {
            None
        };
        Ok(RunSetup { variant, report, stages, shared_plan })
    }

    /// The untiled output path: full in-memory `[n_channels][n_cells]`
    /// accumulators, every pipeline reducing straight into them.
    fn grid_source_full(
        &self,
        source: &dyn ChannelSource,
        job: &GriddingJob,
    ) -> Result<(Vec<SkyMap>, PipelineReport)> {
        let wall0 = Instant::now();
        let RunSetup { variant, mut report, stages, shared_plan } = self.prepare_run(source, job)?;
        let n_ch = source.n_channels();
        let groups = ChannelGroups::new(n_ch, variant.c);
        report.n_groups = groups.len();
        let (lons, lats) = source.coords()?;

        // ---- global accumulators -------------------------------------------
        let n_cells = job.spec.n_cells();
        let mut acc = vec![0.0f64; n_ch * n_cells];
        let mut wsum = vec![0.0f64; n_cells];
        let acc_ptr = SyncPtr(acc.as_mut_ptr());
        let wsum_ptr = SyncPtr(wsum.as_mut_ptr());
        let shared_builds = AtomicU64::new(report.shared_builds as u64);
        let overflow = AtomicU64::new(0);
        let dispatches = AtomicU64::new(0);
        let plan_ref = shared_plan.as_deref();

        self.drive_pipelines(
            source,
            &groups,
            variant.c,
            &mut report,
            stages,
            &job.cancel,
            |batch, local_stages, local_spans, pf| {
                self.run_pipeline(
                    lons,
                    lats,
                    job,
                    &variant,
                    batch,
                    plan_ref,
                    local_stages,
                    local_spans,
                    pf,
                    &shared_builds,
                    &overflow,
                    &dispatches,
                    n_cells,
                    &acc_ptr,
                    &wsum_ptr,
                )
            },
        )?;

        report.shared_builds = shared_builds.into_inner() as usize;
        report.dispatches = dispatches.into_inner() as usize;
        if let Some(plan) = &shared_plan {
            report.n_tiles = plan.n_tiles();
            report.n_shards = plan.shards.len();
            report.overflow_groups = plan.overflow_groups();
            report.adjacent_reuse = plan.adjacent_reuse();
        } else {
            report.overflow_groups = overflow.into_inner() as usize;
        }

        // ---- isolate quarantined groups -------------------------------------
        // Degrade mode: a quarantined group's sweep may have torn mid-
        // accumulation, so its channel planes are zeroed rather than left
        // poisoned. Group 0 owns the weight-sum plane; losing it zeroes
        // wsum too (every map of this run normalises to blanks) — honest
        // rather than silently wrong. Untiled batch groups are already in
        // job order, so no index remap is needed here.
        for &g in &report.degradation.quarantined_groups {
            for &ch in groups.members(g) {
                acc[ch * n_cells..(ch + 1) * n_cells].fill(0.0);
            }
            if g == 0 {
                wsum.fill(0.0);
            }
        }

        // ---- normalise ------------------------------------------------------
        let t4 = Instant::now();
        let maps = (0..n_ch)
            .map(|c| {
                SkyMap::from_accumulators(
                    job.spec.clone(),
                    &acc[c * n_cells..(c + 1) * n_cells],
                    &wsum,
                )
            })
            .collect::<Result<Vec<_>>>()?;
        report.stages.add("normalize", t4.elapsed());
        report.wall = wall0.elapsed();
        Ok((maps, report))
    }

    /// The multi-pipeline driver shared by both output paths: spawn the T0
    /// ingest workers, run `process` (a pipeline's per-group T1–T4 body) on
    /// one prefetched batch per admitted slot until the run drains — width
    /// governed — then fold the I/O, occupancy, width-trace, and pool
    /// accounting into `report`.
    fn drive_pipelines<F>(
        &self,
        source: &dyn ChannelSource,
        groups: &ChannelGroups,
        channels_per_group: usize,
        report: &mut PipelineReport,
        stages: StageTimes,
        cancel: &CancelFlag,
        process: F,
    ) -> Result<()>
    where
        F: Fn(&GroupBatch, &mut StageTimes, &mut Vec<StageSpan>, &Prefetcher) -> Result<()> + Sync,
    {
        // ---- T0 ingest ring + pipelines --------------------------------------
        // The prefetcher replaces the old eager FIFO of group indices: I/O
        // workers read channel groups ahead of the pipelines into pooled
        // buffers, bounded at `prefetch_depth` groups (backpressure).
        // Transient read errors retry with exponential backoff; in degrade
        // mode (`fail_fast = false`) a group whose read stays broken is
        // quarantined instead of failing the stream.
        let degrade = !self.config.fail_fast;
        let prefetcher = Prefetcher::new(groups.len(), self.config.prefetch_depth)
            .with_read_policy(ReadPolicy {
                retries: self.config.retry_io,
                backoff_ms: self.config.retry_io_backoff_ms as u64,
                degrade,
            });
        // Pipeline slots: capped at what can actually run — the group count
        // (extra pipelines would find the prefetcher already drained) and
        // the host's thread budget (the executor's pool workers + the
        // participating caller, which fixed-width sweeps are bound by and
        // which doubles as a core-count proxy for auto's scoped threads).
        // In auto mode the cap is `pipeline_width_max` and the governor
        // starts narrow (2) and adapts; fixed-width runs admit every slot
        // for the whole run.
        let auto = self.config.pipeline_width_auto;
        let width_cap = groups.len().max(1).min(PipelineExecutor::global().workers() + 1);
        let n_pipe = if auto {
            self.config.effective_width_max().min(width_cap)
        } else {
            self.config.effective_pipelines().min(width_cap)
        };
        let initial_width = if auto { n_pipe.min(2) } else { n_pipe };
        report.n_pipelines = n_pipe;
        report.width_auto = auto;
        report.numa_nodes = crate::util::numa::topology().n_nodes();
        // T0 workers actually spawned (a worker per group at most). The
        // governor's starved-T0 rule scales with this, not the configured
        // count — with fewer spawned workers the saturation bar must drop.
        let n_io = report.io_workers.min(groups.len().max(1));
        // Governor thresholds come from the config (`width_saturation`,
        // `width_busy_grow`, `width_idle_shrink`; defaults match the old
        // hardcoded policy) — `for_run` contributes the stream/io scaling.
        let mut policy = WidthPolicy::for_run(self.streams.n_streams(), n_io);
        policy.saturation = self.config.width_saturation;
        policy.busy_grow = self.config.width_busy_grow;
        policy.idle_shrink = self.config.width_idle_shrink;
        let governor = WidthGovernor::new(initial_width, n_pipe, auto, policy);
        // Buffers in circulation: the ring window plus one batch held by each
        // pipeline while it stages — size the free list for all of them so a
        // full steady state recycles instead of reallocating.
        let io_pool =
            MemoryPool::with_limit((self.config.prefetch_depth + n_pipe) * channels_per_group + 4);

        let stage_sink: Mutex<StageTimes> = Mutex::new(stages);
        let compute_spans: Mutex<Vec<(f64, f64)>> = Mutex::new(Vec::new());
        let span_sink: Mutex<Vec<StageSpan>> = Mutex::new(Vec::new());
        let first_error: Mutex<Option<HegridError>> = Mutex::new(None);
        // Degrade mode: per-group failures (errors *and* caught sweep
        // panics) land here instead of killing the run. Indices are the
        // run's batch-group indices; callers remap to original job groups
        // (they differ on a resume) and isolate the groups' output planes.
        let quarantined: Mutex<Vec<(usize, String)>> = Mutex::new(Vec::new());

        // One pipeline slot: pull admitted batches until the run drains.
        // Shared by both execution paths below.
        let pipeline_loop = |pipe: usize| {
            // Unwind safety: if this pipeline panics mid-batch, abort the
            // ingest (io workers drain and exit) and release every parked
            // slot — otherwise a shed slot waiting on the governor would
            // hang the join while the panic propagates. Disarmed on the
            // normal exit path, where the loop's own finish() calls handle
            // shutdown.
            let mut guard =
                AbortOnUnwind { prefetcher: &prefetcher, governor: &governor, armed: true };
            let mut local_stages = StageTimes::default();
            let mut local_spans: Vec<StageSpan> = Vec::new();
            let mut batch_spans: Vec<(f64, f64)> = Vec::new();
            loop {
                if !governor.admit(pipe) {
                    break;
                }
                // Cooperative cancellation (service `DELETE /jobs/{id}`):
                // checked at the group boundary, before pulling another
                // batch, so an in-flight group finishes or quarantines
                // normally and no partial sweep is ever observed. Wins over
                // degrade mode — a cancelled run stops even if every
                // remaining group would have been quarantinable.
                if cancel.is_cancelled() {
                    let mut slot = first_error.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(HegridError::Cancelled);
                    }
                    prefetcher.abort();
                    governor.finish();
                    break;
                }
                let batch = match prefetcher.next() {
                    None => {
                        // Drained: release every parked slot.
                        governor.finish();
                        break;
                    }
                    Some(Err(e)) => {
                        let mut slot = first_error.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(e);
                        }
                        governor.finish();
                        break;
                    }
                    Some(Ok(b)) => b,
                };
                let t_start = prefetcher.now_s();
                let span_base = local_spans.len();
                // The group sweep runs under catch_unwind so a panicking
                // worker (the executor re-raises helper panics on the
                // sweep's caller — this slot) is a per-group failure, not a
                // process abort. Unwind safety: on a caught panic the
                // batch's partial output is discarded (degrade zeroes the
                // group's planes; fail-fast aborts the run), and the
                // slot-local accounting (`local_stages`/`local_spans`) is
                // at worst missing the torn batch's spans.
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    process(&batch, &mut local_stages, &mut local_spans, &prefetcher)
                }));
                batch_spans.push((t_start, prefetcher.now_s()));
                let failure = match out {
                    Ok(Ok(())) => None,
                    Ok(Err(e)) => Some(e),
                    Err(payload) => Some(HegridError::Runtime(format!(
                        "worker panicked while gridding channel group {}: {}",
                        batch.group,
                        crate::util::threads::panic_message(payload.as_ref())
                    ))),
                };
                if let Some(e) = failure {
                    if degrade {
                        // Quarantine the group and keep pulling: the caller
                        // zeroes its output planes and records it failed.
                        quarantined.lock().unwrap().push((batch.group, format!("{e}")));
                        continue;
                    }
                    let mut slot = first_error.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(e);
                    }
                    // Unblock the I/O workers and the parked slots, or the
                    // scope never joins.
                    prefetcher.abort();
                    governor.finish();
                    break;
                }
                // Feed this batch's spans (and any new T0 read intervals)
                // into the rolling occupancy window — this is where the
                // width shrinks or grows. Gated here, not just inside
                // observe(): the prefetcher stats snapshot (a clone of the
                // interval list, behind the shared prefetcher lock) must
                // not be paid on fixed-width runs.
                if auto {
                    governor.observe(
                        &local_spans[span_base..],
                        &prefetcher.stats().read_intervals,
                        prefetcher.now_s(),
                    );
                }
            }
            stage_sink.lock().unwrap().merge(&local_stages);
            compute_spans.lock().unwrap().extend(batch_spans);
            span_sink.lock().unwrap().extend(local_spans);
            guard.armed = false;
        };

        std::thread::scope(|scope| {
            for _ in 0..n_io {
                let prefetcher = &prefetcher;
                let io_pool = &io_pool;
                scope.spawn(move || prefetcher.run_worker(source, groups, io_pool));
            }
            if auto {
                // Adaptive mode runs each slot on a dedicated scoped thread
                // (one coarse spawn per slot per run): a shed slot parks on
                // the governor's condvar holding only its own OS thread, so
                // the persistent executor's pool workers stay free for the
                // nested fine-grained sweeps the *active* pipelines issue
                // (permute, value-matrix fills, CPU gridding). Running the
                // slots as executor sweep items here would park pool
                // workers for the whole run whenever width < slots.
                for pipe in 0..n_pipe {
                    let pipeline_loop = &pipeline_loop;
                    scope.spawn(move || pipeline_loop(pipe));
                }
            } else {
                // Fixed width: one sweep on the persistent executor (item =
                // pipeline slot, every slot admitted for the whole run): the
                // calling thread runs one pipeline itself and parked
                // executor workers pick up the rest, so no run pays a
                // pipeline-thread spawn. With `pipeline_width` ≥ 2, group
                // k's T3 drain overlaps group k+1's T1–T2 staging while
                // group k+2 prefetches underneath (T0). Every pipeline is a
                // pull-until-drained loop, so a busy pool only narrows the
                // effective width — never stalls the run.
                PipelineExecutor::global().run(n_pipe, n_pipe, 1, || (), |_, pipe| {
                    pipeline_loop(pipe)
                });
            }
        });
        if let Some(e) = first_error.into_inner().unwrap() {
            return Err(e);
        }

        let io = prefetcher.stats();
        // Fold both quarantine sources — sweeps that failed or panicked,
        // and groups the ingest skipped after post-retry read failures —
        // into one sorted DegradationReport (batch-group indices; callers
        // remap to original job groups and isolate the output planes).
        report.degradation.retries = io.retries;
        let mut entries = quarantined.into_inner().unwrap();
        entries.extend(io.failed_groups.iter().cloned());
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        report.degradation.quarantined_groups = entries.iter().map(|e| e.0).collect();
        report.degradation.causes = entries.into_iter().map(|e| e.1).collect();
        let spans = compute_spans.into_inner().unwrap();
        report.io_busy_s = io.io_busy_s;
        report.io_overlap_s = overlap_seconds(&io.read_intervals, &spans);
        report.width_trace = governor.trace();
        if auto {
            // `n_pipelines` keeps its "what actually ran" semantics: the
            // peak width the governor admitted — slots above it only ever
            // parked (the pre-run value was the slot cap).
            report.n_pipelines = report.width_trace.iter().map(|&(_, w)| w).max().unwrap_or(n_pipe);
        }
        report.spans = span_sink.into_inner().unwrap();
        for &(a, b) in &io.read_intervals {
            report.spans.push(StageSpan { stage: PipeStage::T0Ingest, start: a, end: b });
        }
        report.stages = stage_sink.into_inner().unwrap();
        report.stages.add("T0 ingest(io)", Duration::from_secs_f64(io.io_busy_s));
        let (pa, pr) = self.mem.stats();
        report.pool_alloc = pa;
        report.pool_reused = pr;
        Ok(())
    }

    /// One pipeline: process one prefetched channel group end to end.
    #[allow(clippy::too_many_arguments)]
    fn run_pipeline(
        &self,
        lons: &[f64],
        lats: &[f64],
        job: &GriddingJob,
        variant: &crate::runtime::VariantInfo,
        batch: &GroupBatch,
        shared_plan: Option<&DispatchPlan>,
        stages: &mut StageTimes,
        spans: &mut Vec<StageSpan>,
        pf: &Prefetcher,
        shared_builds: &AtomicU64,
        overflow: &AtomicU64,
        dispatches: &AtomicU64,
        n_cells: usize,
        acc_ptr: &SyncPtr,
        wsum_ptr: &SyncPtr,
    ) -> Result<()> {
        // Fault-injection `panic@<group>` site (no-op without the feature):
        // exercises the pipeline-slot catch_unwind boundary.
        crate::util::faults::sweep_panic_point(batch.group);
        // Without sharing, every pipeline rebuilds the whole pre-processing
        // stack (the redundancy the paper eliminates).
        let local_plan;
        let plan: &DispatchPlan = match shared_plan {
            Some(p) => p,
            None => {
                let t0 = Instant::now();
                let s0 = pf.now_s();
                local_plan = DispatchPlan::build(
                    lons,
                    lats,
                    job,
                    variant,
                    next_epoch_base(),
                    1, // a lone pipeline gets no extra build parallelism
                )?;
                stages.add("prep+nbr", t0.elapsed());
                spans.push(StageSpan { stage: PipeStage::Prep, start: s0, end: pf.now_s() });
                shared_builds.fetch_add(1, Ordering::Relaxed);
                overflow.store(local_plan.overflow_groups() as u64, Ordering::Relaxed);
                &local_plan
            }
        };

        let g = batch.group;
        let channels = &batch.channels;
        let stream = g % self.streams.n_streams();
        let kparam = job.kernel.kparam();

        // The group's channel values, borrowed once for all shards.
        let group_values: Vec<&[f32]> = batch.values.iter().map(|v| v.as_slice()).collect();

        for (shard_idx, shard) in plan.shards.iter().enumerate() {
            // T1: permute + pad this group's channel values into [c, n] —
            // one pass over the shard's gather index for the whole group
            // (O(1) validation per channel; see `ShardPlan::permute_group_into`).
            let t1 = Instant::now();
            let s1 = pf.now_s();
            let mut staged = self.mem.take(variant.c * variant.n);
            shard.permute_group_into(&group_values, variant.n, &mut staged)?;
            // Pad missing channels (last group) with zeros.
            staged.resize(variant.c * variant.n, 0.0);
            let sval = Arc::new(staged.into_inner());
            stages.add("T1 permute", t1.elapsed());
            spans.push(StageSpan { stage: PipeStage::T1Permute, start: s1, end: pf.now_s() });

            // T2+T3: submit every tile of this shard to our pinned stream,
            // then drain — submission overlaps with execution.
            let t2 = Instant::now();
            let s2 = pf.now_s();
            let mut pending: Vec<(usize, Receiver<Result<ExecuteResponse>>)> = Vec::new();
            for t in 0..plan.tiles_per_shard() {
                let tile = shard.tile(t);
                let req = ExecuteRequest {
                    variant: variant.name.clone(),
                    epoch: plan.epoch_for_shard(shard_idx),
                    group: g as u64,
                    cell_lon: Arc::clone(&tile.cell_lon),
                    cell_lat: Arc::clone(&tile.cell_lat),
                    nbr: Arc::clone(&tile.nbr),
                    slon: Arc::clone(&shard.slon),
                    slat: Arc::clone(&shard.slat),
                    sunit: Arc::clone(&shard.sunit),
                    sval: Arc::clone(&sval),
                    kparam,
                };
                pending.push((t, self.streams.submit(stream, req)));
                dispatches.fetch_add(1, Ordering::Relaxed);
            }
            stages.add("T2 submit", t2.elapsed());
            spans.push(StageSpan { stage: PipeStage::T2Submit, start: s2, end: pf.now_s() });

            let mut t3_total = Duration::ZERO;
            let mut h2d_total = Duration::ZERO;
            let mut d2h_total = Duration::ZERO;
            let t_drain = Instant::now();
            let s3 = pf.now_s();
            let mut responses: Vec<(usize, ExecuteResponse)> = Vec::new();
            for (t, rx) in pending {
                let resp = self.streams.wait(rx)?;
                t3_total += resp.t_exec;
                h2d_total += resp.t_h2d;
                d2h_total += resp.t_d2h;
                responses.push((t, resp));
            }
            stages.add("T3 kernel(+wait)", t_drain.elapsed());
            spans.push(StageSpan { stage: PipeStage::T3Kernel, start: s3, end: pf.now_s() });
            stages.add("T2 H2D(device)", h2d_total);
            stages.add("T3 kernel(device)", t3_total);
            stages.add("T4 D2H(device)", d2h_total);

            // T4: accumulate tile outputs into the global maps. Channels of
            // distinct groups are disjoint; wsum is identical across groups,
            // so only group 0 accumulates it (per shard).
            let t4 = Instant::now();
            let s4 = pf.now_s();
            for (t, resp) in responses {
                let cell0 = t * variant.m;
                let valid = n_cells.saturating_sub(cell0).min(variant.m);
                for (ci, &ch) in channels.iter().enumerate() {
                    let src = &resp.acc[ci * variant.m..ci * variant.m + valid];
                    unsafe { acc_ptr.add_slice(ch * n_cells + cell0, src) };
                }
                if g == 0 {
                    unsafe { wsum_ptr.add_slice(cell0, &resp.wsum[..valid]) };
                }
            }
            stages.add("T4 reduce", t4.elapsed());
            spans.push(StageSpan { stage: PipeStage::T4Reduce, start: s4, end: pf.now_s() });
        }
        Ok(())
    }
}

/// Drop guard for a pipeline slot's pull loop: while `armed`, an unwind
/// aborts the prefetcher (io workers drain and exit) and finishes the width
/// governor (parked slots wake and exit), so a panicking pipeline cannot
/// strand the run. Disarmed on the normal exit path.
struct AbortOnUnwind<'a> {
    prefetcher: &'a Prefetcher,
    governor: &'a WidthGovernor,
    armed: bool,
}

impl Drop for AbortOnUnwind<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.prefetcher.abort();
            self.governor.finish();
        }
    }
}

/// Raw-pointer accumulator handle. Safety: channel ranges are disjoint across
/// groups (each group owns its channels); `wsum` is written only by group 0;
/// tiles within a group are processed by a single pipeline thread.
struct SyncPtr(*mut f64);
unsafe impl Sync for SyncPtr {}
unsafe impl Send for SyncPtr {}
impl SyncPtr {
    unsafe fn add_slice(&self, offset: usize, src: &[f32]) {
        unsafe {
            let dst = self.0.add(offset);
            for (i, &v) in src.iter().enumerate() {
                *dst.add(i) += v as f64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_for_dataset_uses_meta() {
        let d = crate::sim::SimConfig::quick_preset().generate();
        let cfg = HegridConfig::default();
        let job = GriddingJob::for_dataset(&d, &cfg).unwrap();
        let (w, h) = job.spec.extent_deg();
        assert!(w >= d.meta.extent_deg.0);
        assert!(h >= d.meta.extent_deg.1);
        assert_eq!(job.kernel.type_name(), "gauss1d");
    }

    #[test]
    fn report_stage_accessor() {
        let mut r = PipelineReport::default();
        r.stages.add("T1 permute", Duration::from_millis(250));
        assert!((r.stage_s("T1 permute") - 0.25).abs() < 1e-9);
        assert_eq!(r.stage_s("absent"), 0.0);
    }

    #[test]
    fn width_governor_shrinks_on_saturated_t3_and_releases_parked_slots() {
        let g = WidthGovernor::new(2, 4, true, WidthPolicy::for_run(2, 2));
        assert!(g.admit(0) && g.admit(1));
        // Two kernels wall-to-wall across the whole window: T3 occupancy 2.0
        // ≥ 2 streams × 0.85 ⇒ shrink (after the 2-batch cooldown).
        let sat = [
            StageSpan { stage: PipeStage::T3Kernel, start: 0.0, end: 2.0 },
            StageSpan { stage: PipeStage::T3Kernel, start: 0.0, end: 2.0 },
        ];
        g.observe(&sat, &[], 2.0); // first batch: cooldown, record only
        g.observe(&sat, &[], 2.0);
        let trace = g.trace();
        assert_eq!(trace.first(), Some(&(0.0, 2)));
        assert_eq!(trace.last(), Some(&(2.0, 1)));
        // Slot 1 is shed now; a parked slot wakes on finish and exits.
        std::thread::scope(|s| {
            let h = s.spawn(|| g.admit(1));
            std::thread::sleep(Duration::from_millis(20));
            g.finish();
            assert!(!h.join().unwrap());
        });
        assert!(!g.admit(0), "after finish no slot pulls again");
    }

    #[test]
    fn width_governor_grows_when_busy_with_stream_headroom() {
        let g = WidthGovernor::new(2, 4, true, WidthPolicy::for_run(4, 2));
        // Both pipelines ~always busy, kernels far under 4 stream slots.
        let busy = [
            StageSpan { stage: PipeStage::T1Permute, start: 0.0, end: 1.0 },
            StageSpan { stage: PipeStage::T3Kernel, start: 1.0, end: 2.0 },
            StageSpan { stage: PipeStage::T1Permute, start: 0.1, end: 1.1 },
            StageSpan { stage: PipeStage::T3Kernel, start: 1.1, end: 2.0 },
        ];
        g.observe(&busy, &[], 2.0);
        g.observe(&busy, &[], 2.0);
        assert_eq!(g.trace().last(), Some(&(2.0, 3)));
        assert!(g.admit(2), "grown width admits the third slot");
        g.finish();
    }

    #[test]
    fn width_governor_is_inert_for_fixed_widths() {
        let g = WidthGovernor::new(3, 3, false, WidthPolicy::for_run(1, 1));
        let sat = [StageSpan { stage: PipeStage::T3Kernel, start: 0.0, end: 2.0 }];
        for _ in 0..5 {
            g.observe(&sat, &[], 2.0);
        }
        assert_eq!(g.trace(), vec![(0.0, 3)]);
        assert!(g.admit(2));
        g.finish();
    }

    #[test]
    fn span_accounting_occupancy_and_overlap() {
        let mut r = PipelineReport { wall: Duration::from_secs(2), ..Default::default() };
        // Pipeline A: T1 [0,1), T3 [1,2). Pipeline B: T1 [0.5,1.5).
        r.spans.push(StageSpan { stage: PipeStage::T1Permute, start: 0.0, end: 1.0 });
        r.spans.push(StageSpan { stage: PipeStage::T3Kernel, start: 1.0, end: 2.0 });
        r.spans.push(StageSpan { stage: PipeStage::T1Permute, start: 0.5, end: 1.5 });
        assert!((r.stage_busy_s(PipeStage::T1Permute) - 2.0).abs() < 1e-12);
        assert!((r.stage_occupancy(PipeStage::T1Permute) - 1.0).abs() < 1e-12);
        // B's permute [0.5,1.5) overlaps A's kernel [1,2) for 0.5s.
        assert!((r.stage_overlap_s(PipeStage::T1Permute, PipeStage::T3Kernel) - 0.5).abs() < 1e-12);
        // A T0 read [1.0,1.5) also hides under the kernel; the union overlap
        // counts the shared [1.0,1.5) window once, not per hidden stage.
        r.spans.push(StageSpan { stage: PipeStage::T0Ingest, start: 1.0, end: 1.5 });
        assert!((r.stage_overlap_s(PipeStage::T0Ingest, PipeStage::T3Kernel) - 0.5).abs() < 1e-12);
        let union =
            r.stages_overlap_s(&[PipeStage::T0Ingest, PipeStage::T1Permute], PipeStage::T3Kernel);
        assert!((union - 0.5).abs() < 1e-12, "union overlap double-counted: {union}");
        assert_eq!(PipeStage::ALL.len(), 6);
        assert_eq!(PipeStage::T3Kernel.name(), "T3");
    }
}
