//! Integration: sample sharding through the real device path, and
//! consistency between the measured engine and the timeline simulator.

use hegrid::config::HegridConfig;
use hegrid::coordinator::{simulate, GriddingJob, HegridEngine, SimParams};
use hegrid::grid::cpu::CpuGridder;
use hegrid::sim::SimConfig;

fn base_config() -> Option<HegridConfig> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() && hegrid::runtime::backend_name() == "pjrt" {
        // Only the PJRT backend needs the AOT HLO files; the native executor
        // runs on the built-in variant set.
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    let mut cfg = HegridConfig::default();
    cfg.artifacts_dir = dir.display().to_string();
    cfg.streams = 2;
    cfg.pipelines = 2;
    Some(cfg)
}

/// Force multi-shard dispatch by shrinking channels-per-dispatch to the
/// tiny c=4/n=4096 artifact while the dataset holds ~12k samples, and check
/// the sharded result against the CPU oracle.
#[test]
fn multi_shard_engine_matches_cpu_oracle() {
    let Some(mut cfg) = base_config() else { return };
    cfg.channels_per_dispatch = 4;
    let mut sim = SimConfig::quick_preset();
    sim.points = 12_000; // > 4096 ⇒ 3 shards on the tiny variant
    let dataset = sim.generate();
    let job = GriddingJob::for_dataset(&dataset, &cfg).unwrap();

    let engine = HegridEngine::new(cfg).unwrap();
    let (maps, report) = engine.grid(&dataset, &job).unwrap();
    if !report.variant.contains("n4096") {
        // Variant selection may legitimately prefer an unsharded fit; only
        // the sharded path is under test here.
        eprintln!("SKIP: selected {} (not the tiny shard variant)", report.variant);
        return;
    }
    assert!(report.n_shards >= 3, "expected sharding, got {}", report.n_shards);

    let cpu = CpuGridder::new(job.spec.clone(), job.kernel.clone()).grid_dataset(&dataset);
    // With the k=128 shard variant there is no truncation and the sharded
    // device path must match the oracle tightly; if variant selection ever
    // falls back to a K that overflows, nearest-K truncation bounds the
    // error but cannot make it exact.
    let tol = if report.overflow_groups == 0 { 5e-4 } else { 5e-3 };
    for (c, (a, b)) in maps.iter().zip(&cpu).enumerate() {
        let d = a.diff_stats(b).unwrap();
        assert!(d.compared > 0);
        let scale = a.mean().abs().max(0.1);
        assert!(d.rms <= tol * scale, "channel {c}: rms {} scale {scale}", d.rms);
    }
}

/// The calibrated simulator's single-stream/single-pipeline makespan must
/// land in the right ballpark of the measured serial run (same stage costs,
/// so the only differences are scheduling slack and measurement noise).
#[test]
fn simulator_consistent_with_measured_serial_run() {
    let Some(mut cfg) = base_config() else { return };
    cfg.streams = 1;
    cfg.pipelines = 1;
    let dataset = SimConfig::observed(30).generate();
    let job = GriddingJob::for_dataset(&dataset, &cfg).unwrap();
    let engine = HegridEngine::new(cfg).unwrap();
    let _ = engine.grid(&dataset, &job).unwrap(); // warm
    let t0 = std::time::Instant::now();
    let (_, report) = engine.grid(&dataset, &job).unwrap();
    let measured = t0.elapsed().as_secs_f64();

    let params = SimParams {
        n_groups: report.n_groups,
        pipelines: 1,
        streams: 1,
        cost: report.stage_cost_per_group(),
        prep: report.prep_cost(),
        share: true,
        kernel_slots: 1,
    };
    let sim = simulate(&params);
    // The simulated makespan is built from the measured stage totals, so it
    // can only undershoot by scheduling slack / overshoot by noise: 2× band.
    assert!(
        sim.makespan > measured * 0.4 && sim.makespan < measured * 2.0,
        "simulated {:.3}s vs measured {measured:.3}s",
        sim.makespan
    );
}

/// FITS output round-trips through the real pipeline output.
#[test]
fn engine_output_writes_valid_fits() {
    let Some(cfg) = base_config() else { return };
    let dataset = SimConfig::quick_preset().generate().take_channels(1);
    let engine = HegridEngine::new(cfg).unwrap();
    let (maps, _) = engine.grid_dataset(&dataset).unwrap();
    let dir = std::env::temp_dir().join("hegrid_fits_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("engine.fits");
    maps[0].write_fits(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert!(bytes.starts_with(b"SIMPLE  ="));
    assert_eq!(bytes.len() % 2880, 0);
}
