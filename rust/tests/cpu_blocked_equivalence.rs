//! Equivalence suite for the blocked/trig-free/SIMD CPU hot path.
//!
//! Pins `CpuGridder::grid_with_shared` against a no-LUT brute-force oracle
//! (tight tolerance — only accumulation order differs), and requires
//! **bit-identical** output across worker counts, channel-block widths
//! {1, 4, odd n_ch, auto, oversized}, and every compiled-in SIMD backend
//! forced against scalar (lane-per-channel mapping: each lane owns one
//! channel, so per-channel accumulation order — and therefore every output
//! bit — is ISA-independent), for every kernel family, including
//! non-multiple-of-lane channel counts down to 1, plus the empty-channel /
//! empty-dataset edge cases.

use hegrid::grid::cpu::CpuGridder;
use hegrid::grid::kernels::ConvKernel;
use hegrid::grid::prep::SharedComponent;
use hegrid::grid::simd::{available_backends, SimdIsa};
use hegrid::healpix::{ang_dist_vec, unit_vec};
use hegrid::sky::{GridSpec, SkyMap};
use hegrid::util::SplitMix64;

fn setup(n: usize, n_ch: usize, seed: u64) -> (GridSpec, Vec<f64>, Vec<f64>, Vec<Vec<f32>>) {
    let spec = GridSpec::centered(30.0, 41.0, 14, 8, 0.22);
    let (lon_lo, lon_hi, lat_lo, lat_hi) = spec.bounds();
    let mut rng = SplitMix64::new(seed);
    let lons: Vec<f64> = (0..n).map(|_| rng.uniform(lon_lo, lon_hi)).collect();
    let lats: Vec<f64> = (0..n).map(|_| rng.uniform(lat_lo, lat_hi)).collect();
    let channels: Vec<Vec<f32>> =
        (0..n_ch).map(|_| (0..n).map(|_| rng.normal() as f32).collect()).collect();
    (spec, lons, lats, channels)
}

/// Brute-force Eq. (1): exhaustive, no LUT, same per-pair distance helper as
/// the gridder (the metric itself is pinned against the haversine in the
/// healpix unit tests). Returns per-channel cell values (NaN = no coverage).
fn brute_force(
    spec: &GridSpec,
    kernel: &ConvKernel,
    lons: &[f64],
    lats: &[f64],
    channels: &[Vec<f32>],
) -> Vec<Vec<f64>> {
    let mut out = vec![vec![f64::NAN; spec.n_cells()]; channels.len()];
    for cell in 0..spec.n_cells() {
        let (clon, clat) = spec.cell_center_flat(cell);
        let cu = unit_vec(clon, clat);
        let mut acc = vec![0.0f64; channels.len()];
        let mut w_tot = 0.0f64;
        for j in 0..lons.len() {
            let d = ang_dist_vec(&unit_vec(lons[j], lats[j]), &cu);
            let w = kernel.weight(d * d, (lons[j] - clon) * clat.cos(), lats[j] - clat);
            if w != 0.0 {
                w_tot += w;
                for (c, ch) in channels.iter().enumerate() {
                    acc[c] += w * ch[j] as f64;
                }
            }
        }
        if w_tot > 0.0 {
            for (c, a) in acc.iter().enumerate() {
                out[c][cell] = a / w_tot;
            }
        }
    }
    out
}

fn assert_close_to_oracle(maps: &[SkyMap], oracle: &[Vec<f64>]) {
    assert_eq!(maps.len(), oracle.len());
    for (c, (m, want_col)) in maps.iter().zip(oracle).enumerate() {
        for (cell, (&got, &want)) in m.values().iter().zip(want_col).enumerate() {
            if want.is_nan() {
                assert!(got.is_nan(), "ch {c} cell {cell}: {got} vs NaN");
            } else {
                assert!(
                    (got - want).abs() <= 1e-12 * want.abs().max(1.0),
                    "ch {c} cell {cell}: {got} vs {want}"
                );
            }
        }
    }
}

fn assert_maps_bit_identical(a: &[SkyMap], b: &[SkyMap], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}");
    for (c, (ma, mb)) in a.iter().zip(b).enumerate() {
        for (cell, (va, vb)) in ma.values().iter().zip(mb.values()).enumerate() {
            assert_eq!(va.to_bits(), vb.to_bits(), "{what}: ch {c} cell {cell} values");
        }
        for (cell, (wa, wb)) in ma.weights().iter().zip(mb.weights()).enumerate() {
            assert_eq!(wa.to_bits(), wb.to_bits(), "{what}: ch {c} cell {cell} weights");
        }
    }
}

fn kernels_under_test() -> Vec<ConvKernel> {
    let base = ConvKernel::gauss1d_for_beam(0.5);
    vec![
        base.clone(),
        ConvKernel::gauss2d(base.sigma, base.sigma * 1.5, base.support),
        ConvKernel::tapered_sinc(base.sigma / 1.5, base.sigma * 2.52, base.support),
    ]
}

#[test]
fn blocked_gridder_matches_brute_force() {
    // Gaussian kernels only: their weights are strictly positive inside the
    // support, so `w_tot` has no cancellation and the 1e-12 accumulation-
    // order tolerance is sound. `tapered_sinc` (signed side lobes) is
    // covered by the bit-identity tests below and the kernel unit tests.
    let (spec, lons, lats, channels) = setup(700, 5, 42);
    let base = ConvKernel::gauss1d_for_beam(0.5);
    let gauss2d = ConvKernel::gauss2d(base.sigma, base.sigma * 1.5, base.support);
    for kernel in [base, gauss2d] {
        let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
        let maps = CpuGridder::new(spec.clone(), kernel.clone())
            .grid_with_shared(&shared, &channels);
        let oracle = brute_force(&spec, &kernel, &lons, &lats, &channels);
        assert_close_to_oracle(&maps, &oracle);
    }
}

#[test]
fn block_widths_are_bit_identical() {
    // 7 channels: widths 1, 4 (uneven split), odd 5, odd n_ch itself,
    // auto (0), and oversized all agree bit-for-bit.
    let (spec, lons, lats, channels) = setup(900, 7, 7);
    let kernel = ConvKernel::gauss1d_for_beam(0.5);
    let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
    let base = CpuGridder::new(spec.clone(), kernel.clone())
        .with_channel_block(1)
        .grid_with_shared(&shared, &channels);
    for block in [4usize, 5, 7, 0, 1024] {
        let maps = CpuGridder::new(spec.clone(), kernel.clone())
            .with_channel_block(block)
            .grid_with_shared(&shared, &channels);
        assert_maps_bit_identical(&base, &maps, &format!("block {block}"));
    }
}

#[test]
fn worker_counts_are_bit_identical_across_blocks() {
    let (spec, lons, lats, channels) = setup(800, 5, 13);
    for kernel in kernels_under_test() {
        let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
        for block in [1usize, 4] {
            let serial = CpuGridder::new(spec.clone(), kernel.clone())
                .with_workers(1)
                .with_channel_block(block)
                .grid_with_shared(&shared, &channels);
            let parallel = CpuGridder::new(spec.clone(), kernel.clone())
                .with_workers(7)
                .with_channel_block(block)
                .grid_with_shared(&shared, &channels);
            assert_maps_bit_identical(&serial, &parallel, &format!("workers, block {block}"));
        }
    }
}

#[test]
fn forced_isa_backends_are_bit_identical_to_scalar() {
    // Every compiled-in backend, every kernel family, channel counts that
    // are not lane multiples (incl. 1) — all must reproduce the forced-
    // scalar output bit-for-bit. 500 samples is enough to exercise the
    // vector bodies and the non-multiple-of-lane range tails of the chord²
    // prefilter.
    let backends = available_backends();
    assert_eq!(backends[0].name(), "scalar");
    for n_ch in [1usize, 3, 5, 8] {
        let (spec, lons, lats, channels) = setup(500, n_ch, 100 + n_ch as u64);
        for kernel in kernels_under_test() {
            let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
            let scalar = CpuGridder::new(spec.clone(), kernel.clone())
                .with_simd(SimdIsa::Scalar)
                .grid_with_shared(&shared, &channels);
            for backend in &backends {
                let isa = SimdIsa::from_name(backend.name()).unwrap();
                let maps = CpuGridder::new(spec.clone(), kernel.clone())
                    .with_simd(isa)
                    .grid_with_shared(&shared, &channels);
                assert_maps_bit_identical(
                    &scalar,
                    &maps,
                    &format!("isa {} n_ch {n_ch} kernel {}", backend.name(), kernel.type_name()),
                );
            }
        }
    }
}

#[test]
fn forced_isa_identity_holds_across_blocks_and_workers() {
    // ISA × block × worker interactions: an uneven block split over a
    // non-multiple-of-lane channel count, serial and parallel.
    let (spec, lons, lats, channels) = setup(700, 7, 77);
    let kernel = ConvKernel::gauss1d_for_beam(0.5);
    let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
    let base = CpuGridder::new(spec.clone(), kernel.clone())
        .with_simd(SimdIsa::Scalar)
        .with_workers(1)
        .with_channel_block(1)
        .grid_with_shared(&shared, &channels);
    for backend in available_backends() {
        let isa = SimdIsa::from_name(backend.name()).unwrap();
        for block in [1usize, 3, 0] {
            for workers in [1usize, 6] {
                let maps = CpuGridder::new(spec.clone(), kernel.clone())
                    .with_simd(isa)
                    .with_workers(workers)
                    .with_channel_block(block)
                    .grid_with_shared(&shared, &channels);
                assert_maps_bit_identical(
                    &base,
                    &maps,
                    &format!("isa {} block {block} workers {workers}", backend.name()),
                );
            }
        }
    }
}

#[test]
fn empty_channels_yield_empty_output() {
    let (spec, lons, lats, _) = setup(300, 0, 3);
    let kernel = ConvKernel::gauss1d_for_beam(0.5);
    let shared = SharedComponent::for_kernel(&lons, &lats, &kernel).unwrap();
    let maps = CpuGridder::new(spec, kernel).grid_with_shared(&shared, &[]);
    assert!(maps.is_empty());
}

#[test]
fn empty_dataset_yields_nan_maps() {
    let spec = GridSpec::centered(30.0, 41.0, 14, 8, 0.22);
    let kernel = ConvKernel::gauss1d_for_beam(0.5);
    let shared = SharedComponent::for_kernel(&[], &[], &kernel).unwrap();
    let empty_channels: Vec<Vec<f32>> = vec![Vec::new(); 3];
    for block in [0usize, 1, 2] {
        let maps = CpuGridder::new(spec.clone(), kernel.clone())
            .with_channel_block(block)
            .grid_with_shared(&shared, &empty_channels);
        assert_eq!(maps.len(), 3);
        for m in &maps {
            assert_eq!(m.coverage(), 0.0);
            assert!(m.values().iter().all(|v| v.is_nan()));
        }
    }
}
